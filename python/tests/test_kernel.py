"""Pallas FA2 forward kernel vs the pure-jnp oracle.

This is the CORE correctness signal for Layer 1: every mapping policy must
be numerically identical (swizzling only reorders WHERE work runs, never
WHAT it computes), across causal/non-causal, MHA/GQA, dtypes and shapes
(hypothesis sweep).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fa2, ref, swizzle


def make_qkv(z, h_q, h_k, n, d, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (z, h_q, n, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (z, h_k, n, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (z, h_k, n, d), jnp.float32).astype(dtype)
    return q, k, v


def assert_matches_ref(q, k, v, causal=False, atol=2e-5, **kw):
    o, lse = fa2.fa2_forward(q, k, v, causal=causal, **kw)
    o_ref = ref.attention_ref(q, k, v, causal=causal)
    lse_ref = ref.attention_lse_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref), atol=atol, rtol=1e-3)
    np.testing.assert_allclose(
        np.asarray(lse), np.asarray(lse_ref), atol=atol, rtol=1e-3)


@pytest.mark.parametrize("policy", swizzle.POLICIES)
def test_policies_match_ref(policy):
    """All four mapping policies compute identical attention."""
    q, k, v = make_qkv(1, 8, 8, 128, 32)
    assert_matches_ref(q, k, v, block_m=32, block_n=32,
                       policy=policy, num_xcd=4)


@pytest.mark.parametrize("policy", swizzle.POLICIES)
def test_policies_bitwise_identical(policy):
    """Swizzling must not change the numerics AT ALL vs naive head-first."""
    q, k, v = make_qkv(1, 8, 8, 128, 32, seed=7)
    o_base, lse_base = fa2.fa2_forward(
        q, k, v, block_m=32, block_n=32,
        policy="naive_head_first", num_xcd=4)
    o, lse = fa2.fa2_forward(
        q, k, v, block_m=32, block_n=32, policy=policy, num_xcd=4)
    assert np.array_equal(np.asarray(o), np.asarray(o_base))
    assert np.array_equal(np.asarray(lse), np.asarray(lse_base))


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("h_k", [8, 4, 2, 1])
def test_gqa_group_sizes(causal, h_k):
    """GQA with group sizes 1, 2, 4, 8 (MQA)."""
    q, k, v = make_qkv(1, 8, h_k, 128, 32, seed=h_k)
    assert_matches_ref(q, k, v, causal=causal,
                       block_m=32, block_n=32, num_xcd=4)


def test_causal_first_row_block():
    """Causal masking of the very first row block (row 0 sees only col 0)."""
    q, k, v = make_qkv(1, 4, 4, 64, 16, seed=3)
    o, _ = fa2.fa2_forward(q, k, v, causal=True,
                           block_m=16, block_n=16, num_xcd=4)
    # Row 0 attends only to position 0 => output row 0 == v[..., 0, :]
    np.testing.assert_allclose(
        np.asarray(o)[:, :, 0, :], np.asarray(v)[:, :, 0, :],
        atol=1e-5, rtol=1e-5)


def test_batch_gt_one():
    q, k, v = make_qkv(4, 8, 8, 64, 32, seed=11)
    assert_matches_ref(q, k, v, block_m=32, block_n=32, num_xcd=8)


def test_block_m_ne_block_n():
    """Paper's config uses BLOCK_M=128, BLOCK_N=64 (rectangular tiles)."""
    q, k, v = make_qkv(1, 8, 8, 256, 32, seed=5)
    assert_matches_ref(q, k, v, block_m=64, block_n=32, num_xcd=4)
    assert_matches_ref(q, k, v, causal=True,
                       block_m=64, block_n=32, num_xcd=4)


def test_bf16_inputs():
    q, k, v = make_qkv(1, 8, 8, 128, 32, dtype=jnp.bfloat16, seed=9)
    o, _ = fa2.fa2_forward(q, k, v, block_m=32, block_n=32, num_xcd=4)
    assert o.dtype == jnp.bfloat16
    o_ref = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref), atol=2e-2, rtol=2e-2)


def test_sm_scale_override():
    q, k, v = make_qkv(1, 4, 4, 64, 16, seed=13)
    o, _ = fa2.fa2_forward(q, k, v, sm_scale=0.5,
                           block_m=16, block_n=16, num_xcd=4)
    o_ref = ref.attention_ref(q, k, v, sm_scale=0.5)
    np.testing.assert_allclose(
        np.asarray(o), np.asarray(o_ref), atol=2e-5, rtol=1e-3)


def test_single_head_single_block():
    """Degenerate grid: 1 workgroup total."""
    q, k, v = make_qkv(1, 1, 1, 32, 16, seed=17)
    assert_matches_ref(q, k, v, block_m=32, block_n=32,
                       policy="naive_head_first", num_xcd=1)


def test_shape_validation():
    q, k, v = make_qkv(1, 8, 8, 100, 32)  # 100 not divisible by 32
    with pytest.raises(AssertionError):
        fa2.fa2_forward(q, k, v, block_m=32, block_n=32)
    q, k, v = make_qkv(1, 6, 4, 64, 32)  # 4 does not divide 6
    with pytest.raises(AssertionError):
        fa2.fa2_forward(q, k, v, block_m=32, block_n=32)


@settings(max_examples=12, deadline=None)
@given(
    z=st.integers(1, 2),
    h_exp=st.integers(0, 2),          # h_q in {4, 8, 16}
    group_exp=st.integers(0, 2),      # GQA group in {1, 2, 4}
    n_blocks=st.integers(1, 4),       # n in {32..128}
    d=st.sampled_from([16, 32, 64]),
    causal=st.booleans(),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
    seed=st.integers(0, 2 ** 16),
)
def test_kernel_property_sweep(z, h_exp, group_exp, n_blocks, d, causal,
                               dtype, seed):
    """Hypothesis sweep of shapes/dtypes/causal/GQA against the oracle."""
    h_q = 4 * 2 ** h_exp
    group = 2 ** group_exp
    h_k = h_q // group
    n = 32 * n_blocks
    q, k, v = make_qkv(z, h_q, h_k, n, d, dtype=dtype, seed=seed)
    o, _ = fa2.fa2_forward(q, k, v, causal=causal,
                           block_m=32, block_n=32, num_xcd=4)
    o_ref = ref.attention_ref(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(o_ref), atol=tol, rtol=tol)
