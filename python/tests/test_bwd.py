"""Pallas FA2 backward kernels vs jax.vjp of the naive reference.

Covers the paper's Sec. 4.6 configuration space: all mapping policies,
causal/non-causal, GQA group sizes, rectangular blocks, and the
custom_vjp wiring used by the L2 model layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import fa2, fa2_bwd, ref, swizzle


def make_tensors(z, h_q, h_k, n, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (z, h_q, n, d), jnp.float32)
    k = jax.random.normal(ks[1], (z, h_k, n, d), jnp.float32)
    v = jax.random.normal(ks[2], (z, h_k, n, d), jnp.float32)
    do = jax.random.normal(ks[3], (z, h_q, n, d), jnp.float32)
    return q, k, v, do


def run_and_compare(q, k, v, do, causal=False, atol=2e-4, **kw):
    o, lse = fa2.fa2_forward(q, k, v, causal=causal, **kw)
    dq, dk, dv = fa2_bwd.fa2_backward(q, k, v, o, lse, do,
                                      causal=causal, **kw)
    rq, rk, rv = ref.attention_bwd_ref(q, k, v, do, causal=causal)
    for got, want, name in ((dq, rq, "dq"), (dk, rk, "dk"), (dv, rv, "dv")):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), atol=atol, rtol=1e-3,
            err_msg=name)


@pytest.mark.parametrize("policy", swizzle.POLICIES)
def test_bwd_policies_match_ref(policy):
    q, k, v, do = make_tensors(1, 8, 8, 64, 32)
    run_and_compare(q, k, v, do, block_m=32, block_n=32,
                    policy=policy, num_xcd=4)


@pytest.mark.parametrize("causal", [False, True])
def test_bwd_causal(causal):
    q, k, v, do = make_tensors(1, 4, 4, 128, 16, seed=2)
    run_and_compare(q, k, v, do, causal=causal,
                    block_m=32, block_n=32, num_xcd=4)


@pytest.mark.parametrize("h_k", [4, 2, 1])
def test_bwd_gqa(h_k):
    q, k, v, do = make_tensors(1, 8, h_k, 64, 16, seed=h_k)
    run_and_compare(q, k, v, do, block_m=32, block_n=32, num_xcd=4)


def test_bwd_rectangular_blocks():
    q, k, v, do = make_tensors(1, 4, 4, 128, 32, seed=5)
    run_and_compare(q, k, v, do, block_m=64, block_n=32, num_xcd=4)
    run_and_compare(q, k, v, do, causal=True,
                    block_m=64, block_n=32, num_xcd=4)


def test_bwd_batch():
    q, k, v, do = make_tensors(2, 8, 8, 64, 16, seed=7)
    run_and_compare(q, k, v, do, block_m=32, block_n=32, num_xcd=8)


def test_custom_vjp_grad_matches_ref():
    """jax.grad through model.flash_attention == grad through the oracle."""
    q, k, v, _ = make_tensors(1, 4, 4, 64, 16, seed=9)
    params = model.DEFAULT_PARAMS._replace(
        block_m=32, block_n=32, num_xcd=4)

    def loss_kernel(q_, k_, v_):
        return jnp.sum(model.flash_attention(q_, k_, v_, params) ** 2)

    def loss_ref(q_, k_, v_):
        return jnp.sum(ref.attention_ref(q_, k_, v_) ** 2)

    g_kernel = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for got, want in zip(g_kernel, g_ref):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), atol=5e-4, rtol=1e-3)


def test_custom_vjp_causal_grad():
    q, k, v, _ = make_tensors(1, 4, 2, 64, 16, seed=10)
    params = model.DEFAULT_PARAMS._replace(
        causal=True, block_m=32, block_n=32, num_xcd=4)

    def loss_kernel(q_, k_, v_):
        return jnp.mean(model.flash_attention(q_, k_, v_, params) ** 2)

    def loss_ref(q_, k_, v_):
        return jnp.mean(ref.attention_ref(q_, k_, v_, causal=True) ** 2)

    g_kernel = jax.grad(loss_kernel, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for got, want in zip(g_kernel, g_ref):
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), atol=5e-4, rtol=1e-3)


@settings(max_examples=8, deadline=None)
@given(
    h_q=st.sampled_from([4, 8]),
    group=st.sampled_from([1, 2, 4]),
    n_blocks=st.integers(1, 3),
    causal=st.booleans(),
    seed=st.integers(0, 2 ** 16),
)
def test_bwd_property_sweep(h_q, group, n_blocks, causal, seed):
    h_k = h_q // group
    n = 32 * n_blocks
    q, k, v, do = make_tensors(1, h_q, h_k, n, 16, seed=seed)
    run_and_compare(q, k, v, do, causal=causal,
                    block_m=32, block_n=32, num_xcd=4, atol=5e-4)
