"""AOT path tests: HLO text emission, manifest schema, golden checksums,
and a python-side round-trip (compile the emitted HLO text back with the
local XLA client and check numerics) — the same load path the Rust
runtime uses.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_det_input_deterministic():
    a = aot.det_input(1, (4, 8))
    b = aot.det_input(1, (4, 8))
    assert np.array_equal(a, b)
    c = aot.det_input(2, (4, 8))
    assert not np.array_equal(a, c)
    assert a.min() >= -0.5 and a.max() < 0.5


def test_det_input_golden():
    """Golden values the Rust input generator must reproduce exactly
    (rust/src/runtime/inputs.rs mirrors this hash)."""
    v = aot.det_input(1, (4,))
    # (seed + i) * 2654435761 mod 2^32 / 2^32 - 0.5
    expected = [
        ((1 + 0) * 2654435761 % 2 ** 32) / 2 ** 32 - 0.5,
        ((1 + 1) * 2654435761 % 2 ** 32) / 2 ** 32 - 0.5,
        ((1 + 2) * 2654435761 % 2 ** 32) / 2 ** 32 - 0.5,
        ((1 + 3) * 2654435761 % 2 ** 32) / 2 ** 32 - 0.5,
    ]
    np.testing.assert_allclose(v, np.array(expected, np.float32), rtol=1e-7)


def test_hlo_text_emission():
    entry = aot.attn_fwd_entry(False, "swizzled_head_first", 4, 32, 32)
    spec = jax.ShapeDtypeStruct((1, 4, 64, 16), jnp.float32)
    lowered = jax.jit(entry).lower(spec, spec, spec)
    text = aot._hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[1,4,64,16]" in text


def test_quick_catalogue_schema():
    arts = aot.build_catalogue(quick=True)
    assert len(arts) >= 2
    for art in arts:
        assert {"name", "kind", "text", "inputs", "outputs",
                "input_seeds"} <= set(art)
        assert len(art["input_seeds"]) == len(art["inputs"])
        assert "HloModule" in art["text"]


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACT_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)")
def test_manifest_matches_files():
    with open(os.path.join(ARTIFACT_DIR, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["format"] == "hlo-text-v1"
    for art in manifest["artifacts"]:
        path = os.path.join(ARTIFACT_DIR, art["file"])
        assert os.path.exists(path), art["file"]
        with open(path) as fh:
            assert fh.read(200).lstrip().startswith("HloModule")


def test_attn_artifact_text_roundtrip_structure():
    """The emitted HLO text must re-parse with XLA's HLO parser (the same
    parser the Rust runtime's HloModuleProto::from_text_file uses) and
    survive a print->parse->print round trip structurally.

    (Numeric execution of the parsed text is covered on the Rust side by
    rust/tests/runtime_serving.rs, which executes every artifact on the
    PJRT CPU client and checks golden checksums; jaxlib's Python client
    no longer accepts raw HLO protos for execution.)"""
    art = aot._attn_variant("rt", 1, 4, 4, 64, 16,
                            block_m=32, block_n=32, num_xcd=4)
    try:
        mod = xc._xla.hlo_module_from_text(art["text"])
    except AttributeError:
        pytest.skip("local xla_client lacks hlo_module_from_text")
    reprinted = mod.to_string()
    assert "ENTRY" in reprinted
    mod2 = xc._xla.hlo_module_from_text(reprinted)
    # Parameter/result shapes preserved through the round trip.
    assert "f32[1,4,64,16]" in reprinted
    assert mod2.to_string().count("parameter") == reprinted.count("parameter")


def test_attn_entry_numerics_match_golden():
    """Execute the exact AOT entry function (what the HLO text encodes)
    on the deterministic manifest inputs and check the golden stats the
    Rust runtime verifies against."""
    art = aot._attn_variant("rt", 1, 4, 4, 64, 16,
                            block_m=32, block_n=32, num_xcd=4)
    q = aot.det_input(1, (1, 4, 64, 16))
    k = aot.det_input(2, (1, 4, 64, 16))
    v = aot.det_input(3, (1, 4, 64, 16))
    entry = aot.attn_fwd_entry(False, "swizzled_head_first", 4, 32, 32)
    (o,) = jax.jit(entry)(q, k, v)
    o = np.asarray(o)
    o_ref = np.asarray(ref.attention_ref(q, k, v))
    np.testing.assert_allclose(o, o_ref, atol=2e-5, rtol=1e-3)
    assert abs(np.abs(o).sum() - art["golden"]["abs_sum"]) < 1e-2


def test_golden_checksum_consistency():
    """Golden stats recomputed from deterministic inputs must match."""
    art = aot._attn_variant("g", 1, 4, 2, 64, 16,
                            block_m=32, block_n=32, num_xcd=4)
    q = aot.det_input(1, (1, 4, 64, 16))
    k = aot.det_input(2, (1, 2, 64, 16))
    v = aot.det_input(3, (1, 2, 64, 16))
    o = np.asarray(ref.attention_ref(q, k, v))
    assert abs(float(np.abs(o).sum()) - art["golden"]["abs_sum"]) < 1e-3
    assert abs(float(o.mean()) - art["golden"]["mean"]) < 1e-6
