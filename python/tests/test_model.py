"""L2 model-layer tests: shapes, numerics vs oracle-based model, training.

``transformer_block`` with the Pallas flash_attention must agree with the
same block computed with the naive oracle attention, and one SGD step must
reduce the loss (proving the custom_vjp backward is wired correctly).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

CFG = dict(d_model=64, num_q_heads=4, num_kv_heads=2, head_dim=16)
PARAMS = model.DEFAULT_PARAMS._replace(block_m=32, block_n=32, num_xcd=4)


def setup(z=1, n=64, seed=0):
    w = model.init_layer(
        jax.random.PRNGKey(seed), CFG["d_model"], CFG["num_q_heads"],
        CFG["num_kv_heads"], CFG["head_dim"])
    x = jax.random.normal(jax.random.PRNGKey(seed + 1),
                          (z, n, CFG["d_model"]), jnp.float32)
    return w, x


def block_with_oracle(x, w):
    """transformer_block but with naive reference attention."""

    def attn(x_):
        q = model._split_heads(x_ @ w.wq, CFG["num_q_heads"], CFG["head_dim"])
        k = model._split_heads(x_ @ w.wk, CFG["num_kv_heads"], CFG["head_dim"])
        v = model._split_heads(x_ @ w.wv, CFG["num_kv_heads"], CFG["head_dim"])
        o = ref.attention_ref(q, k, v, causal=PARAMS.causal)
        return model._merge_heads(o.astype(x_.dtype)) @ w.wo

    x = x + attn(model._rms_norm(x))
    h = model._rms_norm(x) @ w.w1
    return x + (jax.nn.gelu(h) @ w.w2)


def test_attention_layer_shape():
    w, x = setup()
    y = model.attention_layer(x, w, CFG["num_q_heads"], CFG["num_kv_heads"],
                              CFG["head_dim"], PARAMS)
    assert y.shape == x.shape


def test_block_matches_oracle():
    w, x = setup()
    y_kernel = model.transformer_block(
        x, w, CFG["num_q_heads"], CFG["num_kv_heads"], CFG["head_dim"],
        PARAMS)
    y_oracle = block_with_oracle(x, w)
    np.testing.assert_allclose(
        np.asarray(y_kernel), np.asarray(y_oracle), atol=2e-5, rtol=1e-4)


def test_block_batch():
    w, x = setup(z=3)
    y = model.transformer_block(
        x, w, CFG["num_q_heads"], CFG["num_kv_heads"], CFG["head_dim"],
        PARAMS)
    assert y.shape == x.shape


def test_grad_matches_oracle_grad():
    w, x = setup(seed=3)
    y = jax.random.normal(jax.random.PRNGKey(99), x.shape)

    loss_k, grads_k = model.block_grad(
        w, x, y, CFG["num_q_heads"], CFG["num_kv_heads"], CFG["head_dim"],
        PARAMS)

    def oracle_loss(w_):
        out = block_with_oracle(x, w_)
        return jnp.mean((out - y) ** 2)

    loss_o, grads_o = jax.value_and_grad(oracle_loss)(w)
    np.testing.assert_allclose(float(loss_k), float(loss_o), rtol=1e-5)
    for gk, go, name in zip(grads_k, grads_o, w._fields):
        np.testing.assert_allclose(
            np.asarray(gk), np.asarray(go), atol=1e-4, rtol=1e-3,
            err_msg=name)


def test_sgd_reduces_loss():
    """A few SGD steps through the Pallas fwd+bwd must reduce the loss."""
    w, x = setup(seed=5)
    y = jax.random.normal(jax.random.PRNGKey(100), x.shape) * 0.1
    lr = 0.05
    losses = []
    for _ in range(4):
        loss, grads = model.block_grad(
            w, x, y, CFG["num_q_heads"], CFG["num_kv_heads"],
            CFG["head_dim"], PARAMS)
        losses.append(float(loss))
        w = jax.tree_util.tree_map(lambda p, g: p - lr * g, w, grads)
    assert losses[-1] < losses[0], losses


def test_causal_block():
    w, x = setup(seed=7)
    params = PARAMS._replace(causal=True)
    y = model.transformer_block(
        x, w, CFG["num_q_heads"], CFG["num_kv_heads"], CFG["head_dim"],
        params)
    assert y.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(y)))


@pytest.mark.parametrize("policy", [
    "naive_block_first", "swizzled_head_first"])
def test_block_policy_invariant(policy):
    """Mapping policy must not change model numerics."""
    w, x = setup(seed=11)
    outs = []
    for p in ("naive_head_first", policy):
        params = PARAMS._replace(policy=p)
        outs.append(np.asarray(model.transformer_block(
            x, w, CFG["num_q_heads"], CFG["num_kv_heads"],
            CFG["head_dim"], params)))
    assert np.array_equal(outs[0], outs[1])
