"""Tests for the workgroup-mapping policies (paper Figs. 3, 7-11).

Covers: bijectivity of every policy over the full grid, the locality
invariants each policy promises (which XCD sees which heads), golden
vectors for the paper's illustrative configuration (8 heads, 128 blocks,
4 XCDs — Figs. 7-10), and cross-checks against the Rust implementation's
golden vectors (kept in rust/src/mapping/golden.rs, generated from here).
"""

import itertools

import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import swizzle


def full_grid(policy, batch, heads, blocks, xcd):
    """Decode the whole grid: list of (z, h, b) in dispatch-slot order."""
    total = batch * heads * blocks
    return [
        swizzle.decode(policy, w, batch, heads, blocks, xcd)
        for w in range(total)
    ]


def xcd_assignment(policy, batch, heads, blocks, xcd):
    """Map (z, h, b) -> XCD under chunked round-robin, chunk = 1."""
    out = {}
    for w, work in enumerate(full_grid(policy, batch, heads, blocks, xcd)):
        out[work] = swizzle.xcd_of(w, xcd)
    return out


DIVISIBLE_CONFIGS = [
    # (batch, heads, blocks, xcd) — paper-like configurations
    (1, 8, 128, 4),    # the illustration config of Figs. 7-10
    (1, 8, 16, 8),
    (2, 16, 8, 8),
    (1, 128, 32, 8),   # DeepSeek-V3-like head count on MI300X
    (4, 64, 4, 8),
    (1, 8, 7, 4),      # blocks not divisible by xcd
    (3, 32, 5, 8),
]


@pytest.mark.parametrize("policy", swizzle.POLICIES)
@pytest.mark.parametrize("cfg", DIVISIBLE_CONFIGS)
def test_bijective(policy, cfg):
    """Every policy must be a bijection dispatch-slot -> (z, h, b)."""
    batch, heads, blocks, xcd = cfg
    grid = full_grid(policy, batch, heads, blocks, xcd)
    assert len(set(grid)) == len(grid) == batch * heads * blocks
    for z, h, b in grid:
        assert 0 <= z < batch and 0 <= h < heads and 0 <= b < blocks


@pytest.mark.parametrize("cfg", DIVISIBLE_CONFIGS)
def test_swizzled_head_first_confines_heads(cfg):
    """SHF invariant: all blocks of a (batch, head) land on ONE XCD."""
    batch, heads, blocks, xcd = cfg
    assign = xcd_assignment("swizzled_head_first", batch, heads, blocks, xcd)
    for z in range(batch):
        for h in range(heads):
            xcds = {assign[(z, h, b)] for b in range(blocks)}
            assert len(xcds) == 1, f"head {h} split across XCDs {xcds}"


@pytest.mark.parametrize("cfg", DIVISIBLE_CONFIGS)
def test_swizzled_head_first_balances_heads(cfg):
    """SHF distributes heads evenly: heads/xcd per XCD."""
    batch, heads, blocks, xcd = cfg
    assign = xcd_assignment("swizzled_head_first", batch, heads, blocks, xcd)
    per_xcd = {}
    for (z, h, b), x in assign.items():
        per_xcd.setdefault(x, set()).add(h)
    for x, hs in per_xcd.items():
        assert len(hs) == heads // xcd


@pytest.mark.parametrize("cfg", DIVISIBLE_CONFIGS)
def test_naive_block_first_interleaves_acc_streams(cfg):
    """NBF anti-invariant (the locality loss the paper identifies): when
    heads > xcd, an XCD's *consecutive* slots alternate between different
    heads (ACCs), so its L2 must hold heads/xcd K/V streams concurrently.
    (When xcd | heads, each head IS pinned to XCD h % xcd — Fig. 7's
    caption — but interleaved with heads/xcd - 1 other ACCs.)"""
    batch, heads, blocks, xcd = cfg
    if heads <= xcd or blocks < 2:
        pytest.skip("needs > xcd heads to interleave")
    grid = full_grid("naive_block_first", batch, heads, blocks, xcd)
    # XCD0's first heads/xcd slots are all DIFFERENT heads, same block.
    xcd0 = [grid[w] for w in range(0, xcd * (heads // xcd), xcd)]
    assert len({h for (_, h, _) in xcd0}) == heads // xcd
    assert len({b for (_, _, b) in xcd0}) == 1


@pytest.mark.parametrize("cfg", DIVISIBLE_CONFIGS)
def test_naive_head_first_stripes_blocks(cfg):
    """NHF: consecutive blocks of one head land on consecutive XCDs."""
    batch, heads, blocks, xcd = cfg
    if blocks < xcd:
        pytest.skip("needs >= xcd blocks to stripe")
    assign = xcd_assignment("naive_head_first", batch, heads, blocks, xcd)
    xcds = [assign[(0, 0, b)] for b in range(min(blocks, xcd))]
    assert xcds == list(range(xcd))


def test_swizzled_block_first_pins_head_groups():
    """SBF (Fig. 8): XCD x serves heads [x*hpx, (x+1)*hpx) — and with MHA
    serves ALL of them interleaved (multiple ACCs per XCD at once)."""
    heads, blocks, xcd = 8, 128, 4
    assign = xcd_assignment("swizzled_block_first", 1, heads, blocks, xcd)
    hpx = heads // xcd
    for h in range(heads):
        expected_xcd = h // hpx
        xcds = {assign[(0, h, b)] for b in range(blocks)}
        assert xcds == {expected_xcd}
    # Interleaving: the first two slots of XCD0 are different heads.
    grid = full_grid("swizzled_block_first", 1, heads, blocks, xcd)
    xcd0_slots = [grid[w] for w in range(0, 4 * xcd, xcd)]
    assert xcd0_slots[0][1] != xcd0_slots[1][1]


def test_paper_figure_layout():
    """Golden check of Figs. 7-10 captions (8 qheads, 128 blocks, 4 XCDs):
    NBF/SBF/SHF head->XCD layouts as printed in the paper."""
    heads, blocks, xcd = 8, 128, 4

    def heads_on_xcd(policy):
        assign = xcd_assignment(policy, 1, heads, blocks, xcd)
        out = [set() for _ in range(xcd)]
        for (z, h, b), x in assign.items():
            out[x].add(h)
        return [sorted(s) for s in out]

    # Fig. 7: XCD0: HQ 0,4 | XCD1: HQ 1,5 | XCD2: HQ 2,6 | XCD3: HQ 3,7
    assert heads_on_xcd("naive_block_first") == [
        [0, 4], [1, 5], [2, 6], [3, 7]]
    # Fig. 8: XCD0: HQ 0,1 | XCD1: HQ 2,3 | XCD2: HQ 4,5 | XCD3: HQ 6,7
    assert heads_on_xcd("swizzled_block_first") == [
        [0, 1], [2, 3], [4, 5], [6, 7]]
    # Fig. 9: every XCD sees all heads
    assert heads_on_xcd("naive_head_first") == [list(range(8))] * 4
    # Fig. 10: XCD0: HQ 0,1 | XCD1: HQ 2,3 | XCD2: HQ 4,5 | XCD3: HQ 6,7
    assert heads_on_xcd("swizzled_head_first") == [
        [0, 1], [2, 3], [4, 5], [6, 7]]


def test_shf_one_acc_at_a_time():
    """SHF services one ACC (head) at a time per XCD: the sequence of heads
    seen by an XCD's consecutive local slots is non-decreasing in runs of
    `blocks` slots."""
    heads, blocks, xcd = 8, 16, 4
    grid = full_grid("swizzled_head_first", 1, heads, blocks, xcd)
    for x in range(xcd):
        local = [grid[w] for w in range(x, len(grid), xcd)]
        head_seq = [h for (_, h, _) in local]
        # runs of `blocks` identical heads
        for i in range(0, len(head_seq), blocks):
            assert len(set(head_seq[i:i + blocks])) == 1
        # and within a run blocks are in order 0..blocks-1
        blk_seq = [b for (_, _, b) in local[:blocks]]
        assert blk_seq == list(range(blocks))


def test_chiplet_swizzle_matches_paper_fig3():
    """Fig. 3 arithmetic: grid=16, 4 XCDs."""
    grid, xcd = 16, 4
    remapped = [swizzle.chiplet_swizzle(w, grid, xcd) for w in range(grid)]
    assert sorted(remapped) == list(range(grid))  # bijective
    # wid 0,4,8,12 (which round-robin to XCD0) map to logical 0,1,2,3
    assert [remapped[w] for w in (0, 4, 8, 12)] == [0, 1, 2, 3]
    # wid 1,5,9,13 (XCD1) -> logical 4..7
    assert [remapped[w] for w in (1, 5, 9, 13)] == [4, 5, 6, 7]


@settings(max_examples=60, deadline=None)
@given(
    batch=st.integers(1, 4),
    heads_mult=st.integers(1, 16),
    blocks=st.integers(1, 64),
    xcd=st.sampled_from([2, 4, 8]),
    policy=st.sampled_from(swizzle.POLICIES),
)
def test_bijective_property(batch, heads_mult, blocks, xcd, policy):
    """Property: bijectivity holds for arbitrary divisible configs."""
    heads = heads_mult * xcd
    grid = full_grid(policy, batch, heads, blocks, xcd)
    assert len(set(grid)) == batch * heads * blocks


@settings(max_examples=40, deadline=None)
@given(
    heads_mult=st.integers(1, 8),
    blocks=st.integers(1, 32),
    xcd=st.sampled_from([2, 4, 8]),
)
def test_shf_locality_property(heads_mult, blocks, xcd):
    """Property: SHF never splits a head across XCDs."""
    heads = heads_mult * xcd
    assign = xcd_assignment("swizzled_head_first", 1, heads, blocks, xcd)
    for h in range(heads):
        assert len({assign[(0, h, b)] for b in range(blocks)}) == 1


def test_indivisible_heads_raises():
    with pytest.raises(ValueError):
        swizzle.decode("swizzled_head_first", 0, 1, 6, 4, 8)
    with pytest.raises(ValueError):
        swizzle.decode("swizzled_block_first", 0, 1, 6, 4, 8)


# ---------------------------------------------------------------------------
# Flash-decode split-KV grid (splits reuse the block dimension).
# ---------------------------------------------------------------------------


def decode_full_grid(policy, batch, heads, splits, xcd):
    total = batch * heads * splits
    return [
        swizzle.decode_split_kv(policy, w, batch, heads, splits, xcd)
        for w in range(total)
    ]


@pytest.mark.parametrize("policy", swizzle.POLICIES)
@pytest.mark.parametrize("splits", [1, 2, 4, 8])
def test_decode_bijective(policy, splits):
    grid = decode_full_grid(policy, 2, 16, splits, 8)
    assert len(set(grid)) == len(grid) == 2 * 16 * splits


@pytest.mark.parametrize("splits", [2, 4, 8])
def test_decode_shf_confines_head_splits(splits):
    """SHF decode invariant: every split of one head's KV stream lands on
    ONE XCD (chunk = 1), so its partial results never cross L2 domains."""
    batch, heads, xcd = 2, 64, 8
    by_head = {}
    for w, (z, h, s) in enumerate(decode_full_grid(
            "swizzled_head_first", batch, heads, splits, xcd)):
        by_head.setdefault((z, h), set()).add(swizzle.xcd_of(w, xcd))
    assert all(len(v) == 1 for v in by_head.values())


def test_decode_nhf_replicates_group_streams():
    """NHF decode anti-invariant (the `decode` figure's mechanism): with
    GQA-8 and a split count that does not divide into the XCD
    round-robin, every (kv head, split) KV slice is streamed by WGs on
    several XCDs — replicated into several L2s."""
    heads, h_k, splits, xcd = 64, 8, 2, 8
    group = heads // h_k
    per_stream = {}
    for w, (z, h, s) in enumerate(decode_full_grid(
            "naive_head_first", 1, heads, splits, xcd)):
        per_stream.setdefault((z, h // group, s), set()).add(
            swizzle.xcd_of(w, xcd))
    assert all(len(v) == 4 for v in per_stream.values())


def test_decode_golden_matches_rust():
    """The decode golden vectors pinned in rust/src/mapping/golden.rs
    (batch=2, heads=8, splits=4, num_xcds=4) — generated from here."""
    grid = decode_full_grid("swizzled_head_first", 2, 8, 4, 4)
    assert grid[:8] == [
        (0, 0, 0), (0, 2, 0), (0, 4, 0), (0, 6, 0),
        (0, 0, 1), (0, 2, 1), (0, 4, 1), (0, 6, 1),
    ]
    assert grid[8 * 4 - 1] == (0, 7, 3)
    assert grid[8 * 4] == (1, 0, 0)
    grid = decode_full_grid("swizzled_block_first", 2, 8, 4, 4)
    assert grid[:8] == [
        (0, 0, 0), (0, 2, 0), (0, 4, 0), (0, 6, 0),
        (0, 1, 0), (0, 3, 0), (0, 5, 0), (0, 7, 0),
    ]


# ---------------------------------------------------------------------------
# The composed mapping algebra (docs/TUNING.md): the four legacy policies
# are the lin+inherit plane of <rr|swz>-<block|head>-<lin|saw>-
# <inherit|grouped>; the Rust mirror is mapping::MappingSpec.
# ---------------------------------------------------------------------------

ALL_SPEC_NAMES = [
    "-".join(point) for point in itertools.product(*swizzle.SPEC_AXES)
]


def test_algebra_has_16_points_and_parses_round_trip():
    assert len(ALL_SPEC_NAMES) == 16
    for name in ALL_SPEC_NAMES:
        assert "-".join(swizzle.parse_spec(name)) == name


@pytest.mark.parametrize("policy", swizzle.POLICIES)
@pytest.mark.parametrize("cfg", DIVISIBLE_CONFIGS)
def test_legacy_decoders_lockstep_with_their_algebra_points(policy, cfg):
    """The verbatim per-policy decoders and decode_spec on the policy's
    lin+inherit point must agree slot-for-slot — the same pin the Rust
    side keeps in rust/tests/mapping_algebra.rs."""
    batch, heads, blocks, xcd = cfg
    spec = swizzle.spec_of(policy)
    assert spec[2:] == ("lin", "inherit")
    for w in range(batch * heads * blocks):
        legacy = swizzle.decode(policy, w, batch, heads, blocks, xcd)
        composed = swizzle.decode_spec(spec, w, batch, heads, blocks, xcd)
        assert legacy == composed, (policy, w)
        # And on the split-KV grid: inherit means identical arithmetic.
        legacy = swizzle.decode_split_kv(policy, w, batch, heads, blocks, xcd)
        composed = swizzle.decode_spec(spec, w, batch, heads, blocks, xcd,
                                       is_split_grid=True)
        assert legacy == composed, (policy, w)


@pytest.mark.parametrize("name", ALL_SPEC_NAMES)
@pytest.mark.parametrize("split_grid", [False, True])
def test_every_algebra_point_is_bijective(name, split_grid):
    batch, heads, blocks, xcd = 2, 8, 6, 4
    spec = swizzle.parse_spec(name)
    grid = [
        swizzle.decode_spec(spec, w, batch, heads, blocks, xcd,
                            is_split_grid=split_grid)
        for w in range(batch * heads * blocks)
    ]
    assert len(set(grid)) == len(grid) == batch * heads * blocks
    for z, h, b in grid:
        assert 0 <= z < batch and 0 <= h < heads and 0 <= b < blocks


@settings(max_examples=60, deadline=None)
@given(
    batch=st.integers(1, 3),
    heads_mult=st.integers(1, 8),
    blocks=st.integers(1, 32),
    xcd=st.sampled_from([2, 4, 8]),
    name=st.sampled_from(ALL_SPEC_NAMES),
    split_grid=st.booleans(),
)
def test_algebra_bijective_property(batch, heads_mult, blocks, xcd, name,
                                    split_grid):
    """Property: bijectivity holds across the whole searched space, on
    prefill and split-KV grids, for arbitrary divisible geometries (the
    rr half also for non-divisible ones — heads_mult*xcd - 1 heads)."""
    spec = swizzle.parse_spec(name)
    heads = heads_mult * xcd
    if spec[0] == "rr" and heads > 1:
        heads -= 1  # exercise the non-divisible space where it is legal
    total = batch * heads * blocks
    grid = [
        swizzle.decode_spec(spec, w, batch, heads, blocks, xcd,
                            is_split_grid=split_grid)
        for w in range(total)
    ]
    assert len(set(grid)) == total


def test_sawtooth_reverses_odd_heads_only():
    """saw: odd heads walk blocks descending (b -> blocks-1-b), even
    heads are untouched — head assignment and block sets unchanged."""
    batch, heads, blocks, xcd = 1, 8, 16, 4
    for lin_name in ("rr-block-lin-inherit", "swz-head-lin-inherit"):
        saw_name = lin_name.replace("-lin-", "-saw-")
        lin, saw = swizzle.parse_spec(lin_name), swizzle.parse_spec(saw_name)
        for w in range(batch * heads * blocks):
            z, h, b = swizzle.decode_spec(lin, w, batch, heads, blocks, xcd)
            zs, hs, bs = swizzle.decode_spec(saw, w, batch, heads, blocks, xcd)
            assert (zs, hs) == (z, h)
            assert bs == (blocks - 1 - b if h % 2 == 1 else b)


def test_grouped_split_placement_reads_only_split_grids():
    """grouped: a no-op on prefill grids; on split-KV grids it forces
    head-first traversal (all splits of one head contiguous)."""
    batch, heads, splits, xcd = 1, 8, 4, 4
    inh = swizzle.parse_spec("rr-block-lin-inherit")
    grp = swizzle.parse_spec("rr-block-lin-grouped")
    hf = swizzle.parse_spec("rr-head-lin-inherit")
    for w in range(batch * heads * splits):
        assert swizzle.decode_spec(grp, w, batch, heads, splits, xcd) == \
            swizzle.decode_spec(inh, w, batch, heads, splits, xcd)
        assert swizzle.decode_spec(grp, w, batch, heads, splits, xcd,
                                   is_split_grid=True) == \
            swizzle.decode_spec(hf, w, batch, heads, splits, xcd,
                                is_split_grid=True)


def test_spec_parse_errors_name_the_axis():
    with pytest.raises(ValueError, match="4"):
        swizzle.parse_spec("rr-block-lin")
    with pytest.raises(ValueError, match=r"lin\|saw"):
        swizzle.parse_spec("rr-block-zig-inherit")
    with pytest.raises(ValueError, match=r"rr\|swz"):
        swizzle.parse_spec("naive-block-lin-inherit")
    with pytest.raises(ValueError, match="divisible"):
        swizzle.decode_spec(swizzle.parse_spec("swz-head-saw-inherit"),
                            0, 1, 6, 4, 8)


def test_chiplet_swizzle_bijective_on_every_grid():
    """The balanced remap (first grid % xcd XCDs take one extra id) stays
    bijective for non-divisible grids and reduces to the historical
    formula on divisible ones — the Rust mirror pins the same property."""
    for xcd in (2, 4, 8):
        for grid in range(1, 65):
            remapped = [swizzle.chiplet_swizzle(w, grid, xcd)
                        for w in range(grid)]
            assert sorted(remapped) == list(range(grid)), (grid, xcd)
            if grid % xcd == 0:
                per = grid // xcd
                for w in range(grid):
                    assert remapped[w] == (w % xcd) * per + w // xcd
