"""AOT compile path: lower L2/L1 entry points to XLA HLO *text* artifacts.

Run once by ``make artifacts``; the Rust runtime
(``rust/src/runtime``) loads the text with ``HloModuleProto::from_text_file``,
compiles it on the PJRT CPU client, and executes it on the request path.
Python is never imported at runtime.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README).

Outputs (under ``artifacts/``):
  * ``<name>.hlo.txt``   — one per entry-point variant
  * ``manifest.json``    — input/output specs, attention config, and golden
    output checksums (computed with the pure-jnp oracle on deterministic
    hash-generated inputs) that the Rust serving example verifies against.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import fa2, ref

# ---------------------------------------------------------------------------
# Deterministic input generation, mirrored bit-for-bit in Rust
# (rust/src/runtime/inputs.rs).  Knuth multiplicative hash of (seed + index)
# mapped to [-0.5, 0.5).
# ---------------------------------------------------------------------------

_HASH_MULT = np.uint32(2654435761)


def det_input(seed: int, shape, dtype=np.float32):
    """Deterministic pseudo-random tensor, reproducible from Rust."""
    n = int(np.prod(shape))
    idx = np.arange(n, dtype=np.uint64) + np.uint64(seed)
    h = (idx * np.uint64(_HASH_MULT)) & np.uint64(0xFFFFFFFF)
    vals = h.astype(np.float64) / 4294967296.0 - 0.5
    return vals.reshape(shape).astype(dtype)


def _hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(a):
    return {"shape": list(a.shape), "dtype": str(a.dtype)}


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------


def attn_fwd_entry(causal, policy, num_xcd, block_m, block_n):
    """(q, k, v) -> (o,) through the Pallas FA2 forward kernel."""

    def f(q, k, v):
        o, _ = fa2.fa2_forward(
            q, k, v,
            causal=causal, block_m=block_m, block_n=block_n,
            policy=policy, num_xcd=num_xcd,
        )
        return (o,)

    return f


def block_fwd_entry(num_q_heads, num_kv_heads, head_dim, params):
    """(x, *weights) -> (y,) through one transformer block."""

    def f(x, wq, wk, wv, wo, w1, w2):
        w = model.LayerWeights(wq, wk, wv, wo, w1, w2)
        return (model.transformer_block(
            x, w, num_q_heads, num_kv_heads, head_dim, params),)

    return f


def block_sgd_entry(num_q_heads, num_kv_heads, head_dim, params, lr=2e-4):
    """One SGD training step: (x, y, *w) -> (loss, *updated_w).

    The gradient flows through the Pallas FA2 forward AND backward
    kernels (custom_vjp), so this artifact exercises the full L1 stack.
    """

    def f(x, y, wq, wk, wv, wo, w1, w2):
        w = model.LayerWeights(wq, wk, wv, wo, w1, w2)
        loss, grads = model.block_grad(
            w, x, y, num_q_heads, num_kv_heads, head_dim, params)
        new_w = jax.tree_util.tree_map(lambda p, g: p - lr * g, w, grads)
        return (loss, *new_w)

    return f


# ---------------------------------------------------------------------------
# Artifact catalogue
# ---------------------------------------------------------------------------


def _attn_variant(name, z, h_q, h_k, n, d, causal=False, dtype=jnp.float32,
                  block_m=64, block_n=64, policy="swizzled_head_first",
                  num_xcd=8):
    q = det_input(1, (z, h_q, n, d))
    k = det_input(2, (z, h_k, n, d))
    v = det_input(3, (z, h_k, n, d))
    oref = np.asarray(ref.attention_ref(q, k, v, causal=causal))
    entry = attn_fwd_entry(causal, policy, num_xcd, block_m, block_n)
    specs = [jax.ShapeDtypeStruct(t.shape, dtype) for t in (q, k, v)]
    lowered = jax.jit(entry).lower(*specs)
    return {
        "name": name,
        "kind": "attn_fwd",
        "text": _hlo_text(lowered),
        "inputs": [_spec(t) for t in (q, k, v)],
        "input_seeds": [1, 2, 3],
        "outputs": [{"shape": [z, h_q, n, d], "dtype": "float32"}],
        "attn": {
            "batch": z, "h_q": h_q, "h_k": h_k, "n_ctx": n, "d_head": d,
            "causal": causal, "block_m": block_m, "block_n": block_n,
            "policy": policy, "num_xcd": num_xcd,
        },
        "golden": {
            "abs_sum": float(np.abs(oref).sum()),
            "mean": float(oref.mean()),
            "l2": float(np.sqrt((oref.astype(np.float64) ** 2).sum())),
        },
    }


def build_catalogue(quick=False):
    arts = []
    # Serving variants: the shapes the Rust coordinator buckets requests
    # into.  Small enough to execute quickly on the CPU PJRT client.
    arts.append(_attn_variant("attn_mha_z1_h8_n128_d64", 1, 8, 8, 128, 64))
    arts.append(_attn_variant("attn_mha_z1_h8_n256_d64", 1, 8, 8, 256, 64))
    if not quick:
        arts.append(_attn_variant(
            "attn_mha_causal_z1_h8_n256_d64", 1, 8, 8, 256, 64, causal=True))
        arts.append(_attn_variant(
            "attn_gqa_z1_hq8_hk2_n256_d64", 1, 8, 2, 256, 64))
        arts.append(_attn_variant("attn_mha_z2_h8_n256_d64", 2, 8, 8, 256, 64))
        # DeepSeek-V3-like head-count/dim ratio scaled down (D_HEAD=56
        # analogue; kept MXU-tile-friendly while exercising d != 64).
        arts.append(_attn_variant(
            "attn_mha_z1_h16_n128_d32", 1, 16, 16, 128, 32))

    # Transformer block forward + one SGD step (exercises fwd+bwd kernels).
    z, n, hq, hk, dh, dm = 1, 128, 4, 2, 32, 128
    params = model.DEFAULT_PARAMS._replace(block_m=64, block_n=64, num_xcd=4)
    w = model.init_layer(jax.random.PRNGKey(0), dm, hq, hk, dh)
    x = jax.ShapeDtypeStruct((z, n, dm), jnp.float32)
    wspecs = [jax.ShapeDtypeStruct(t.shape, t.dtype) for t in w]

    lowered = jax.jit(block_fwd_entry(hq, hk, dh, params)).lower(x, *wspecs)
    arts.append({
        "name": "block_fwd_z1_n128_dm128",
        "kind": "block_fwd",
        "text": _hlo_text(lowered),
        "inputs": [{"shape": [z, n, dm], "dtype": "float32"}]
        + [_spec(t) for t in w],
        "input_seeds": [10, 11, 12, 13, 14, 15, 16],
        "outputs": [{"shape": [z, n, dm], "dtype": "float32"}],
        "model": {"d_model": dm, "h_q": hq, "h_k": hk, "d_head": dh, "n": n},
    })

    if not quick:
        lowered = jax.jit(block_sgd_entry(hq, hk, dh, params)).lower(
            x, x, *wspecs)
        arts.append({
            "name": "block_sgd_z1_n128_dm128",
            "kind": "block_sgd",
            "text": _hlo_text(lowered),
            "inputs": [{"shape": [z, n, dm], "dtype": "float32"}] * 2
            + [_spec(t) for t in w],
            "input_seeds": [20, 21, 22, 23, 24, 25, 26, 27],
            "outputs": [{"shape": [], "dtype": "float32"}]
            + [_spec(t) for t in w],
            "model": {"d_model": dm, "h_q": hq, "h_k": hk, "d_head": dh, "n": n},
        })
    return arts


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--quick", action="store_true",
                    help="emit only the two core serving variants")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = {"format": "hlo-text-v1", "artifacts": []}
    for art in build_catalogue(quick=args.quick):
        fname = f"{art['name']}.hlo.txt"
        path = os.path.join(args.out, fname)
        with open(path, "w") as f:
            f.write(art.pop("text"))
        art["file"] = fname
        manifest["artifacts"].append(art)
        print(f"wrote {path}")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
