"""Layer 2: the JAX compute graph — attention layers and a transformer block.

This is the paper's "model" layer: multi-head / grouped-query attention
built on the Layer-1 Pallas kernels, differentiable end-to-end through a
``jax.custom_vjp`` that routes the backward pass through the Pallas FA2
backward kernels (the configuration benchmarked in paper Sec. 4.6).

Everything here is build-time only: ``aot.py`` lowers selected entry
points to HLO text once, and the Rust coordinator executes the compiled
artifacts — Python is never on the request path.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import fa2, fa2_bwd


class AttnParams(NamedTuple):
    """Static kernel configuration threaded through the custom_vjp."""

    causal: bool
    sm_scale: float | None
    block_m: int
    block_n: int
    policy: str
    num_xcd: int


DEFAULT_PARAMS = AttnParams(
    causal=False,
    sm_scale=None,
    block_m=fa2.DEFAULT_BLOCK_M,
    block_n=fa2.DEFAULT_BLOCK_N,
    policy="swizzled_head_first",
    num_xcd=fa2.DEFAULT_NUM_XCD,
)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash_attention(q, k, v, params: AttnParams = DEFAULT_PARAMS):
    """Differentiable FlashAttention2 (forward + backward both in Pallas)."""
    o, _ = fa2.fa2_forward(
        q,
        k,
        v,
        causal=params.causal,
        sm_scale=params.sm_scale,
        block_m=params.block_m,
        block_n=params.block_n,
        policy=params.policy,
        num_xcd=params.num_xcd,
    )
    return o


def _fa_fwd(q, k, v, params):
    o, lse = fa2.fa2_forward(
        q,
        k,
        v,
        causal=params.causal,
        sm_scale=params.sm_scale,
        block_m=params.block_m,
        block_n=params.block_n,
        policy=params.policy,
        num_xcd=params.num_xcd,
    )
    return o, (q, k, v, o, lse)


def _fa_bwd(params, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = fa2_bwd.fa2_backward(
        q,
        k,
        v,
        o,
        lse,
        do,
        causal=params.causal,
        sm_scale=params.sm_scale,
        block_m=params.block_m,
        block_n=params.block_n,
        policy=params.policy,
        num_xcd=params.num_xcd,
    )
    return dq.astype(q.dtype), dk, dv


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ---------------------------------------------------------------------------
# Attention layers (projections + kernel), MHA and GQA.
# ---------------------------------------------------------------------------


class LayerWeights(NamedTuple):
    """One transformer block's weights.

    wq: (D_MODEL, H_Q*D_HEAD); wk/wv: (D_MODEL, H_K*D_HEAD);
    wo: (H_Q*D_HEAD, D_MODEL); w1: (D_MODEL, D_FF); w2: (D_FF, D_MODEL).
    """

    wq: jax.Array
    wk: jax.Array
    wv: jax.Array
    wo: jax.Array
    w1: jax.Array
    w2: jax.Array


def init_layer(key, d_model, num_q_heads, num_kv_heads, head_dim, d_ff=None,
               dtype=jnp.float32):
    """Xavier-ish init of one block's weights."""
    d_ff = d_ff or 4 * d_model
    ks = jax.random.split(key, 6)

    def w(k, shape):
        fan_in = shape[0]
        return (jax.random.normal(k, shape) / jnp.sqrt(fan_in)).astype(dtype)

    return LayerWeights(
        wq=w(ks[0], (d_model, num_q_heads * head_dim)),
        wk=w(ks[1], (d_model, num_kv_heads * head_dim)),
        wv=w(ks[2], (d_model, num_kv_heads * head_dim)),
        wo=w(ks[3], (num_q_heads * head_dim, d_model)),
        w1=w(ks[4], (d_model, d_ff)),
        w2=w(ks[5], (d_ff, d_model)),
    )


def _split_heads(x, num_heads, head_dim):
    z, n, _ = x.shape
    return x.reshape(z, n, num_heads, head_dim).transpose(0, 2, 1, 3)


def _merge_heads(x):
    z, h, n, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(z, n, h * d)


def attention_layer(x, w: LayerWeights, num_q_heads, num_kv_heads, head_dim,
                    params: AttnParams = DEFAULT_PARAMS):
    """Self-attention sub-block: QKV projection -> FA2 -> output projection."""
    q = _split_heads(x @ w.wq, num_q_heads, head_dim)
    k = _split_heads(x @ w.wk, num_kv_heads, head_dim)
    v = _split_heads(x @ w.wv, num_kv_heads, head_dim)
    o = flash_attention(q, k, v, params)
    return _merge_heads(o.astype(x.dtype)) @ w.wo


def _rms_norm(x, eps=1e-6):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)


def transformer_block(x, w: LayerWeights, num_q_heads, num_kv_heads, head_dim,
                      params: AttnParams = DEFAULT_PARAMS):
    """Pre-norm transformer block: x + Attn(norm(x)); x + MLP(norm(x))."""
    x = x + attention_layer(
        _rms_norm(x), w, num_q_heads, num_kv_heads, head_dim, params
    )
    h = _rms_norm(x) @ w.w1
    return x + (jax.nn.gelu(h) @ w.w2)


def block_loss(w: LayerWeights, x, y, num_q_heads, num_kv_heads, head_dim,
               params: AttnParams = DEFAULT_PARAMS):
    """Mean-squared-error training loss through one block (for grads)."""
    out = transformer_block(x, w, num_q_heads, num_kv_heads, head_dim, params)
    return jnp.mean((out - y) ** 2)


def block_grad(w, x, y, num_q_heads, num_kv_heads, head_dim,
               params: AttnParams = DEFAULT_PARAMS):
    """Loss + weight gradients; the backward runs the Pallas bwd kernels."""
    return jax.value_and_grad(block_loss)(
        w, x, y, num_q_heads, num_kv_heads, head_dim, params
    )
