"""Workgroup-id remapping policies from the paper (Figs. 3, 7-11).

The hardware dispatcher on a chiplet GPU assigns dispatch slot ``wid`` to
XCD ``wid % num_xcd`` (chunked round-robin, chunk size 1 — paper Sec. 2.2).
A *mapping policy* decides which logical unit of work ``(batch, head,
row_block)`` a given dispatch slot executes.  Remapping the slot -> work
function is the paper's entire mechanism: it is how software controls
*where* (which XCD, hence which private L2) each piece of work runs.

All arithmetic here is pure ``//`` and ``%`` so it works identically on
Python ints, numpy ints, and traced JAX scalars (it is used inside the
Pallas kernel's index_map as well as in host-side tests).  The same
formulas are re-implemented in Rust (``rust/src/mapping``) and the two are
cross-checked by ``python/tests/test_swizzle.py`` golden vectors.

Conventions
-----------
* ``num_blocks``  = ceil(seqlen_q / BLOCK_M)  — row blocks per head.
* Batch is the outermost dimension in every policy (the paper's Fig. 11
  computes ``batch_offset = (wid // (blocks_per_head * NUM_Q_HEADS)) %
  BATCH`` which is batch-outermost; its ``wid_per_batch = wid // BATCH``
  line is a typo for ``wid % (heads * blocks)`` — see DESIGN.md).
* Swizzled policies require ``num_heads % num_xcd == 0`` (true for every
  configuration the paper evaluates: H in {8..128}, XCDs in {4, 8}).
"""

from __future__ import annotations

POLICIES = (
    "naive_block_first",
    "swizzled_block_first",
    "naive_head_first",
    "swizzled_head_first",
)


def chiplet_swizzle(wgid, grid, num_xcd):
    """GEMM-style chiplet swizzle (paper Fig. 3).

    Remaps a linear workgroup id so that ids which the round-robin
    dispatcher sends to the same XCD become *contiguous* in logical space:
    XCD ``x`` processes logical ids ``[x * grid/num_xcd, ...)`` in order.

    Non-divisible grids (``grid % num_xcd != 0``) are balanced: the first
    ``grid % num_xcd`` XCDs own one extra id each (exactly the
    round-robin dispatcher's share), keeping the remap bijective instead
    of colliding as a truncating ``grid // num_xcd`` stride would
    (mirrors ``rust/src/mapping::chiplet_swizzle``).
    """
    wgids_per_xcd = grid // num_xcd
    extra = grid % num_xcd  # XCDs [0, extra) own one extra id
    xcd = wgid % num_xcd
    local_wgid = wgid // num_xcd
    return xcd * wgids_per_xcd + min(xcd, extra) + local_wgid


def decode_naive_block_first(wid, batch, num_heads, num_blocks, num_xcd):
    """Block-first iteration, no swizzle (paper Fig. 7).

    Dispatch order: block0 of every head, then block1 of every head, ...
    Round-robin then stripes *heads* across XCDs, splitting every ACC.
    """
    del batch, num_xcd
    per_batch = num_heads * num_blocks
    z = wid // per_batch
    r = wid % per_batch
    b = r // num_heads
    h = r % num_heads
    return z, h, b


def decode_swizzled_block_first(wid, batch, num_heads, num_blocks, num_xcd):
    """Block-first iteration + chiplet swizzle (paper Fig. 8, AITER's scheme).

    XCD ``x`` is pinned to the contiguous head group
    ``[x*heads_per_xcd, (x+1)*heads_per_xcd)`` and iterates block-first
    *within* that group: h0 b0, h1 b0, ..., h0 b1, h1 b1, ...
    Locality is preserved only when the number of head groups sharing data
    (GQA groups) matches ``num_xcd``; for MHA each XCD serves
    ``heads_per_xcd`` ACCs simultaneously, splitting its L2.
    """
    per_batch = num_heads * num_blocks
    heads_per_xcd = num_heads // num_xcd
    z = wid // per_batch
    r = wid % per_batch
    x = r % num_xcd          # XCD this slot lands on (round-robin)
    j = r // num_xcd         # local slot index within the XCD
    h = x * heads_per_xcd + j % heads_per_xcd
    b = j // heads_per_xcd
    return z, h, b


def decode_naive_head_first(wid, batch, num_heads, num_blocks, num_xcd):
    """Head-first iteration, no swizzle (paper Fig. 9, Triton default).

    Dispatch order: all blocks of head0, then all blocks of head1, ...
    Round-robin stripes each head's *blocks* across every XCD: the live
    ACC's K/V get replicated into all eight L2s instead of one.
    """
    del batch, num_xcd
    per_batch = num_heads * num_blocks
    z = wid // per_batch
    r = wid % per_batch
    h = r // num_blocks
    b = r % num_blocks
    return z, h, b


def decode_swizzled_head_first(wid, batch, num_heads, num_blocks, num_xcd):
    """Swizzled Head-first mapping — the paper's contribution (Figs. 10-11).

    XCD ``x`` processes heads ``[x*heads_per_xcd, (x+1)*heads_per_xcd)``
    *one head at a time*, in block order: every row block of a head is
    serviced by the same XCD, so the head's K/V tensors live in exactly one
    L2 and are reused by all of its row blocks.
    """
    per_batch = num_heads * num_blocks
    heads_per_xcd = num_heads // num_xcd
    z = wid // per_batch
    r = wid % per_batch
    x = r % num_xcd          # XCD this slot lands on
    j = r // num_xcd         # local slot index within the XCD
    h = x * heads_per_xcd + j // num_blocks
    b = j % num_blocks
    return z, h, b


_DECODERS = {
    "naive_block_first": decode_naive_block_first,
    "swizzled_block_first": decode_swizzled_block_first,
    "naive_head_first": decode_naive_head_first,
    "swizzled_head_first": decode_swizzled_head_first,
}


# ---------------------------------------------------------------------------
# Composed mapping algebra (mirrors rust/src/mapping/spec.rs).
#
# Every mapping is a point ``assign x traversal x order x split``, written
# as a dash-joined spec string, e.g. ``swz-head-saw-inherit``:
#   assign    rr | swz            round-robin vs chiplet-swizzled heads
#   traversal block | head        which dimension varies fastest per XCD
#   order     lin | saw           intra-head block order: linear, or
#                                 sawtooth (odd heads walk blocks in
#                                 reverse — boustrophedon wavefronts)
#   split     inherit | grouped   flash-decode split placement: reuse the
#                                 traversal, or force head-first on split
#                                 grids only (splits of one head
#                                 contiguous per XCD)
# The four legacy policies are the ``lin`` + ``inherit`` plane.
# ---------------------------------------------------------------------------

SPEC_AXES = (("rr", "swz"), ("block", "head"), ("lin", "saw"),
             ("inherit", "grouped"))

_LEGACY_SPECS = {
    "naive_block_first": ("rr", "block", "lin", "inherit"),
    "swizzled_block_first": ("swz", "block", "lin", "inherit"),
    "naive_head_first": ("rr", "head", "lin", "inherit"),
    "swizzled_head_first": ("swz", "head", "lin", "inherit"),
}


def parse_spec(name):
    """Parse a dash-joined composed spec into its 4-axis tuple."""
    parts = tuple(name.split("-"))
    if len(parts) != len(SPEC_AXES):
        raise ValueError(
            f"composed mapping spec '{name}' must have {len(SPEC_AXES)} "
            "dash-joined axes: <rr|swz>-<block|head>-<lin|saw>-"
            "<inherit|grouped>"
        )
    for value, valid in zip(parts, SPEC_AXES):
        if value not in valid:
            raise ValueError(
                f"unknown axis value '{value}' in spec '{name}' "
                f"(expected one of {'|'.join(valid)})"
            )
    return parts


def spec_of(policy):
    """The 4-axis algebra point of a policy name (legacy or composed)."""
    if policy in _LEGACY_SPECS:
        return _LEGACY_SPECS[policy]
    return parse_spec(policy)


def decode_spec(spec, wid, batch, num_heads, num_blocks, num_xcd,
                is_split_grid=False):
    """Decode one dispatch slot under an algebra point (4-axis tuple).

    On the ``lin`` + ``inherit`` plane both extra axes are identities and
    the arithmetic reduces exactly to the legacy per-policy decoders
    above (cross-checked in test_swizzle.py). ``is_split_grid`` marks
    the block dimension as a flash-decode KV split; only the ``grouped``
    split placement reads it, forcing head-first traversal there.
    """
    assign, traversal, order, split = spec
    del batch
    if assign == "swz" and num_heads % num_xcd != 0:
        raise ValueError(
            f"spec {'-'.join(spec)} requires num_heads ({num_heads}) "
            f"divisible by num_xcd ({num_xcd}); see DESIGN.md"
        )
    if is_split_grid and split == "grouped":
        traversal = "head"
    per_batch = num_heads * num_blocks
    z = wid // per_batch
    r = wid % per_batch
    if traversal == "block":
        if assign == "rr":
            h, b = r % num_heads, r // num_heads
        else:
            hpx = num_heads // num_xcd
            x, j = r % num_xcd, r // num_xcd
            h, b = x * hpx + j % hpx, j // hpx
    else:
        if assign == "rr":
            h, b = r // num_blocks, r % num_blocks
        else:
            hpx = num_heads // num_xcd
            x, j = r % num_xcd, r // num_xcd
            h, b = x * hpx + j // num_blocks, j % num_blocks
    if order == "saw" and h % 2 == 1:
        b = num_blocks - 1 - b
    return z, h, b


def decode(policy, wid, batch, num_heads, num_blocks, num_xcd):
    """Map dispatch slot ``wid`` -> logical work ``(batch, head, row_block)``.

    ``policy`` is a legacy name (kept on the verbatim per-policy decoders
    above) or a composed spec string routed through ``decode_spec``.
    """
    if policy in _DECODERS:
        if policy in ("swizzled_block_first", "swizzled_head_first"):
            if num_heads % num_xcd != 0:
                raise ValueError(
                    f"{policy} requires num_heads ({num_heads}) divisible by "
                    f"num_xcd ({num_xcd}); see DESIGN.md"
                )
        return _DECODERS[policy](wid, batch, num_heads, num_blocks, num_xcd)
    return decode_spec(parse_spec(policy), wid, batch, num_heads, num_blocks,
                       num_xcd)


def xcd_of(wid, num_xcd):
    """XCD a dispatch slot lands on under chunked round-robin, chunk=1."""
    return wid % num_xcd


def decode_split_kv(policy, wid, batch, num_heads, num_splits, num_xcd):
    """Map a *flash-decode* dispatch slot -> ``(batch, head, kv_split)``.

    The split-KV decode grid (one query token per (batch, head), KV
    streamed in ``num_splits`` contiguous slices) reuses the prefill
    policy arithmetic verbatim with the block dimension reinterpreted as
    the split index — so every policy's locality invariant carries over:
    ``swizzled_head_first`` keeps all splits of one head's KV stream (and
    its partial results) on a single XCD, while ``naive_head_first``
    stripes them across XCDs, replicating each GQA group's shared KV
    slices into several L2s whenever ``num_splits % num_xcd != 0``.

    Mirrored in Rust by ``Mapping::for_kernel(_, _, DecodeSplitKv, _)``
    (which marks the grid so the ``grouped`` split-placement axis can see
    it) and pinned by the decode golden vectors in
    ``rust/src/mapping/golden.rs``.
    """
    if policy in _DECODERS:
        return decode(policy, wid, batch, num_heads, num_splits, num_xcd)
    return decode_spec(parse_spec(policy), wid, batch, num_heads, num_splits,
                       num_xcd, is_split_grid=True)
