"""Pure-jnp correctness oracles for the FlashAttention2 kernels.

Naive (materialize-S) attention, forward and backward, for MHA and GQA.
These are the numerical ground truth every Pallas kernel variant is tested
against (``python/tests/test_kernel.py``) and the source of the golden
checksums the Rust serving example verifies (``examples/serve_attention.rs``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def expand_kv(k, num_q_heads):
    """Broadcast GQA K/V heads up to the query head count.

    k: (Z, H_K, N, D) -> (Z, H_Q, N, D) by repeating each KV head over its
    query-head group (group size = H_Q // H_K).
    """
    z, h_k, n, d = k.shape
    if h_k == num_q_heads:
        return k
    assert num_q_heads % h_k == 0, (num_q_heads, h_k)
    group = num_q_heads // h_k
    return jnp.repeat(k, group, axis=1)


def attention_ref(q, k, v, causal=False, sm_scale=None):
    """Reference attention forward.

    q: (Z, H_Q, N, D); k, v: (Z, H_K, N, D) with H_K | H_Q (GQA) or
    H_K == H_Q (MHA).  Returns (Z, H_Q, N, D) in float32.
    """
    z, h_q, n, d = q.shape
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    k = expand_kv(k, h_q)
    v = expand_kv(v, h_q)
    q32, k32, v32 = (t.astype(jnp.float32) for t in (q, k, v))
    s = jnp.einsum("zhnd,zhmd->zhnm", q32, k32) * sm_scale
    if causal:
        mask = jnp.tril(jnp.ones((n, n), dtype=bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("zhnm,zhmd->zhnd", p, v)


def attention_lse_ref(q, k, v, causal=False, sm_scale=None):
    """Row-wise log-sum-exp of the (scaled, masked) score matrix.

    Matches the ``lse`` side-output of the Pallas forward kernel, which the
    backward pass consumes.  Returns (Z, H_Q, N) float32.
    """
    z, h_q, n, d = q.shape
    if sm_scale is None:
        sm_scale = 1.0 / (d ** 0.5)
    k = expand_kv(k, h_q)
    s = jnp.einsum(
        "zhnd,zhmd->zhnm", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * sm_scale
    if causal:
        mask = jnp.tril(jnp.ones((n, n), dtype=bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    return jax.scipy.special.logsumexp(s, axis=-1)


def attention_bwd_ref(q, k, v, do, causal=False, sm_scale=None):
    """Reference gradients (dq, dk, dv) via jax.vjp of the naive forward.

    Shapes mirror the inputs; GQA gradients for K/V are summed over each
    query-head group, matching Eq. (2) of the paper generalized to GQA.
    """

    def f(q_, k_, v_):
        return attention_ref(q_, k_, v_, causal=causal, sm_scale=sm_scale)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(do.astype(jnp.float32))
