"""FlashAttention2 forward as a Pallas kernel with swizzled grid mapping.

This is Layer 1 of the stack: the paper's compute hot-spot.  The kernel
implements the standard FA2 forward (online softmax over BLOCK_N column
tiles of K/V, one BLOCK_M row block of Q per grid step) and — the paper's
contribution — decodes its 1-D grid index through one of the four
workgroup-mapping policies of ``swizzle.py`` so that the *dispatch order*
of row blocks matches what a chiplet GPU's round-robin scheduler would
place on each XCD.

Hardware adaptation (see DESIGN.md §Hardware-Adaptation): the paper's
Triton workgroup becomes one Pallas grid step; per-XCD L2 tiling becomes
the BlockSpec HBM->VMEM schedule (Q row block resident in VMEM, K/V
streamed in BLOCK_N tiles); MFMA matmuls become MXU-targeted ``jnp.dot``
with float32 accumulation.  ``interpret=True`` always: the CPU PJRT plugin
cannot execute Mosaic custom-calls, so real-TPU performance is estimated
analytically (DESIGN.md §Perf) while numerics are validated here.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import swizzle

# Large negative finite used for causal masking.  Using -inf would produce
# NaNs through exp(-inf - (-inf)) in fully-masked accumulator updates.
_MASK_VALUE = -1.0e30

DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 64
DEFAULT_NUM_XCD = 8  # MI300X (paper Table 1)


def _fwd_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    lse_ref,
    *,
    seqlen: int,
    block_m: int,
    block_n: int,
    sm_scale: float,
    causal: bool,
    block_index_fn,
):
    """One grid step == one paper workgroup: one (batch, head, row-block)."""
    wid = pl.program_id(0)
    b = block_index_fn(wid)  # row-block index of this workgroup

    q = q_ref[0, 0].astype(jnp.float32) * sm_scale  # (BLOCK_M, D)
    d = q.shape[-1]

    m_i = jnp.full((block_m,), _MASK_VALUE, jnp.float32)
    l_i = jnp.zeros((block_m,), jnp.float32)
    acc = jnp.zeros((block_m, d), jnp.float32)

    num_kv_blocks = seqlen // block_n
    if causal:
        # Only K/V tiles up to (and including) the diagonal contribute.
        hi = ((b + 1) * block_m + block_n - 1) // block_n
        hi = jnp.minimum(hi, num_kv_blocks)
    else:
        hi = num_kv_blocks

    def body(i, carry):
        m_prev, l_prev, acc_prev = carry
        k = pl.load(
            k_ref, (0, 0, pl.dslice(i * block_n, block_n), slice(None))
        ).astype(jnp.float32)
        v = pl.load(
            v_ref, (0, 0, pl.dslice(i * block_n, block_n), slice(None))
        ).astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        if causal:
            rows = b * block_m + jax.lax.broadcasted_iota(
                jnp.int32, (block_m, block_n), 0
            )
            cols = i * block_n + jax.lax.broadcasted_iota(
                jnp.int32, (block_m, block_n), 1
            )
            s = jnp.where(rows >= cols, s, _MASK_VALUE)
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + p.sum(axis=1)
        acc_new = acc_prev * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc_new

    m_i, l_i, acc = jax.lax.fori_loop(0, hi, body, (m_i, l_i, acc))

    o = acc / l_i[:, None]
    o_ref[0, 0] = o.astype(o_ref.dtype)
    lse_ref[0, 0] = m_i + jnp.log(l_i)


def _check_shapes(q, k, v, block_m, block_n):
    z, h_q, n, d = q.shape
    zk, h_k, nk, dk = k.shape
    assert k.shape == v.shape, (k.shape, v.shape)
    assert z == zk and n == nk and d == dk, (q.shape, k.shape)
    assert h_q % h_k == 0, f"GQA requires H_K | H_Q, got {h_q}, {h_k}"
    assert n % block_m == 0, f"seqlen {n} must be divisible by BLOCK_M {block_m}"
    assert n % block_n == 0, f"seqlen {n} must be divisible by BLOCK_N {block_n}"
    return z, h_q, h_k, n, d


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal",
        "sm_scale",
        "block_m",
        "block_n",
        "policy",
        "num_xcd",
        "interpret",
    ),
)
def fa2_forward(
    q,
    k,
    v,
    *,
    causal: bool = False,
    sm_scale: float | None = None,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    policy: str = "swizzled_head_first",
    num_xcd: int = DEFAULT_NUM_XCD,
    interpret: bool = True,
):
    """FlashAttention2 forward pass.

    Args:
      q: (Z, H_Q, N, D); k, v: (Z, H_K, N, D) with H_K | H_Q.
      causal: apply a lower-triangular mask.
      policy: workgroup mapping policy (see ``swizzle.POLICIES``) — controls
        the *dispatch order* of the grid, i.e. which XCD each (head,
        row-block) would land on under round-robin hardware scheduling.
      num_xcd: NUMA domains assumed by the swizzle (8 for MI300X).

    Returns:
      (o, lse): o is (Z, H_Q, N, D) in q.dtype, lse is (Z, H_Q, N) float32.
    """
    z, h_q, h_k, n, d = _check_shapes(q, k, v, block_m, block_n)
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    group = h_q // h_k
    num_blocks = n // block_m

    def work_of(wid):
        return swizzle.decode(policy, wid, z, h_q, num_blocks, num_xcd)

    def q_map(wid):
        zz, hh, bb = work_of(wid)
        return (zz, hh, bb, 0)

    def kv_map(wid):
        zz, hh, _ = work_of(wid)
        return (zz, hh // group, 0, 0)

    def lse_map(wid):
        zz, hh, bb = work_of(wid)
        return (zz, hh, bb)

    kernel = functools.partial(
        _fwd_kernel,
        seqlen=n,
        block_m=block_m,
        block_n=block_n,
        sm_scale=sm_scale,
        causal=causal,
        block_index_fn=lambda wid: work_of(wid)[2],
    )

    o, lse = pl.pallas_call(
        kernel,
        grid=(z * h_q * num_blocks,),
        in_specs=[
            pl.BlockSpec((1, 1, block_m, d), q_map),
            pl.BlockSpec((1, 1, n, d), kv_map),
            pl.BlockSpec((1, 1, n, d), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_m, d), q_map),
            pl.BlockSpec((1, 1, block_m), lse_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((z, h_q, n, d), q.dtype),
            jax.ShapeDtypeStruct((z, h_q, n), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return o, lse


def attention(q, k, v, **kwargs):
    """Convenience wrapper returning only the attention output."""
    o, _ = fa2_forward(q, k, v, **kwargs)
    return o
