"""FlashAttention2 backward pass as Pallas kernels (paper Sec. 4.6).

Two kernels, mirroring AITER's FA2 backward structure that the paper
benchmarks:

* ``dkdv`` kernel — grid over (batch, q-head, K/V *column* block).  Each
  workgroup owns one BLOCK_N column block of K/V and iterates over all
  BLOCK_M row blocks of Q/dO, accumulating dK and dV.  Within one head all
  column-block workgroups share Q, dO, lse, delta — the same ACC spatial
  locality the forward pass has, which is why the paper's Swizzled
  Head-first mapping helps the backward pass too (Fig. 16).
* ``dq`` kernel — grid over (batch, q-head, Q *row* block), iterating over
  K/V column blocks, accumulating dQ.

Both grids are dispatched through the same workgroup-mapping policies as
the forward kernel (``swizzle.decode``), with ``num_blocks`` equal to the
respective block count.

GQA: gradients are computed per *query* head and the wrapper sums dK/dV
over each query-head group, matching ``jax.vjp`` of the naive reference.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import swizzle
from .fa2 import DEFAULT_BLOCK_M, DEFAULT_BLOCK_N, DEFAULT_NUM_XCD, _MASK_VALUE


def _dkdv_kernel(
    q_ref,
    k_ref,
    v_ref,
    do_ref,
    lse_ref,
    delta_ref,
    dk_ref,
    dv_ref,
    *,
    seqlen: int,
    block_m: int,
    block_n: int,
    sm_scale: float,
    causal: bool,
    block_index_fn,
):
    """One workgroup: one BLOCK_N column block of K/V for one (z, head)."""
    wid = pl.program_id(0)
    jb = block_index_fn(wid)  # column-block index

    k = k_ref[0, 0].astype(jnp.float32)  # (BLOCK_N, D)
    v = v_ref[0, 0].astype(jnp.float32)  # (BLOCK_N, D)
    d = k.shape[-1]

    dk = jnp.zeros((block_n, d), jnp.float32)
    dv = jnp.zeros((block_n, d), jnp.float32)

    num_row_blocks = seqlen // block_m
    if causal:
        # Row blocks strictly above the diagonal see none of this column.
        lo = (jb * block_n) // block_m
    else:
        lo = 0

    def body(i, carry):
        dk_prev, dv_prev = carry
        q = pl.load(
            q_ref, (0, 0, pl.dslice(i * block_m, block_m), slice(None))
        ).astype(jnp.float32)
        do = pl.load(
            do_ref, (0, 0, pl.dslice(i * block_m, block_m), slice(None))
        ).astype(jnp.float32)
        lse = pl.load(lse_ref, (0, 0, pl.dslice(i * block_m, block_m)))
        delta = pl.load(delta_ref, (0, 0, pl.dslice(i * block_m, block_m)))

        s = (
            jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
        )  # (BLOCK_M, BLOCK_N)
        if causal:
            rows = i * block_m + jax.lax.broadcasted_iota(
                jnp.int32, (block_m, block_n), 0
            )
            cols = jb * block_n + jax.lax.broadcasted_iota(
                jnp.int32, (block_m, block_n), 1
            )
            s = jnp.where(rows >= cols, s, _MASK_VALUE)
        p = jnp.exp(s - lse[:, None])  # exact softmax probabilities
        dv_new = dv_prev + jnp.dot(p.T, do, preferred_element_type=jnp.float32)
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * sm_scale
        dk_new = dk_prev + jnp.dot(ds.T, q, preferred_element_type=jnp.float32)
        return dk_new, dv_new

    dk, dv = jax.lax.fori_loop(lo, num_row_blocks, body, (dk, dv))
    dk_ref[0, 0] = dk.astype(dk_ref.dtype)
    dv_ref[0, 0] = dv.astype(dv_ref.dtype)


def _dq_kernel(
    q_ref,
    k_ref,
    v_ref,
    do_ref,
    lse_ref,
    delta_ref,
    dq_ref,
    *,
    seqlen: int,
    block_m: int,
    block_n: int,
    sm_scale: float,
    causal: bool,
    block_index_fn,
):
    """One workgroup: one BLOCK_M row block of dQ for one (z, head)."""
    wid = pl.program_id(0)
    ib = block_index_fn(wid)  # row-block index

    q = q_ref[0, 0].astype(jnp.float32)  # (BLOCK_M, D)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0]
    delta = delta_ref[0, 0]
    d = q.shape[-1]

    dq = jnp.zeros((block_m, d), jnp.float32)
    num_kv_blocks = seqlen // block_n
    if causal:
        hi = ((ib + 1) * block_m + block_n - 1) // block_n
        hi = jnp.minimum(hi, num_kv_blocks)
    else:
        hi = num_kv_blocks

    def body(j, dq_prev):
        k = pl.load(
            k_ref, (0, 0, pl.dslice(j * block_n, block_n), slice(None))
        ).astype(jnp.float32)
        v = pl.load(
            v_ref, (0, 0, pl.dslice(j * block_n, block_n), slice(None))
        ).astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
        if causal:
            rows = ib * block_m + jax.lax.broadcasted_iota(
                jnp.int32, (block_m, block_n), 0
            )
            cols = j * block_n + jax.lax.broadcasted_iota(
                jnp.int32, (block_m, block_n), 1
            )
            s = jnp.where(rows >= cols, s, _MASK_VALUE)
        p = jnp.exp(s - lse[:, None])
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None]) * sm_scale
        return dq_prev + jnp.dot(ds, k, preferred_element_type=jnp.float32)

    dq = jax.lax.fori_loop(0, hi, body, dq)
    dq_ref[0, 0] = dq.astype(dq_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal",
        "sm_scale",
        "block_m",
        "block_n",
        "policy",
        "num_xcd",
        "interpret",
    ),
)
def fa2_backward(
    q,
    k,
    v,
    o,
    lse,
    do,
    *,
    causal: bool = False,
    sm_scale: float | None = None,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    policy: str = "swizzled_head_first",
    num_xcd: int = DEFAULT_NUM_XCD,
    interpret: bool = True,
):
    """FA2 backward: returns (dq, dk, dv).

    q, o, do: (Z, H_Q, N, D); k, v: (Z, H_K, N, D); lse: (Z, H_Q, N).
    dk/dv are returned in K/V's GQA layout (summed over query-head groups).
    """
    z, h_q, n, d = q.shape
    h_k = k.shape[1]
    group = h_q // h_k
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    assert n % block_m == 0 and n % block_n == 0, (n, block_m, block_n)

    # Preprocess (the paper's "scalar operations"): delta_i = rowsum(dO * O).
    delta = jnp.sum(
        do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1
    )  # (Z, H_Q, N)

    k_exp = jnp.repeat(k, group, axis=1) if group > 1 else k
    v_exp = jnp.repeat(v, group, axis=1) if group > 1 else v

    # --- dK/dV kernel: grid over column blocks -------------------------
    num_col_blocks = n // block_n

    def col_work(wid):
        return swizzle.decode(policy, wid, z, h_q, num_col_blocks, num_xcd)

    def full_map(wid):
        zz, hh, _ = col_work(wid)
        return (zz, hh, 0, 0)

    def full_vec_map(wid):
        zz, hh, _ = col_work(wid)
        return (zz, hh, 0)

    def col_map(wid):
        zz, hh, bb = col_work(wid)
        return (zz, hh, bb, 0)

    dkdv_kernel = functools.partial(
        _dkdv_kernel,
        seqlen=n,
        block_m=block_m,
        block_n=block_n,
        sm_scale=sm_scale,
        causal=causal,
        block_index_fn=lambda wid: col_work(wid)[2],
    )
    dk_exp, dv_exp = pl.pallas_call(
        dkdv_kernel,
        grid=(z * h_q * num_col_blocks,),
        in_specs=[
            pl.BlockSpec((1, 1, n, d), full_map),  # q
            pl.BlockSpec((1, 1, block_n, d), col_map),  # k block
            pl.BlockSpec((1, 1, block_n, d), col_map),  # v block
            pl.BlockSpec((1, 1, n, d), full_map),  # do
            pl.BlockSpec((1, 1, n), full_vec_map),  # lse
            pl.BlockSpec((1, 1, n), full_vec_map),  # delta
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_n, d), col_map),
            pl.BlockSpec((1, 1, block_n, d), col_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((z, h_q, n, d), jnp.float32),
            jax.ShapeDtypeStruct((z, h_q, n, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_exp, v_exp, do, lse, delta)

    # --- dQ kernel: grid over row blocks -------------------------------
    num_row_blocks = n // block_m

    def row_work(wid):
        return swizzle.decode(policy, wid, z, h_q, num_row_blocks, num_xcd)

    def row_map(wid):
        zz, hh, bb = row_work(wid)
        return (zz, hh, bb, 0)

    def row_vec_map(wid):
        zz, hh, bb = row_work(wid)
        return (zz, hh, bb)

    def kv_full_map(wid):
        zz, hh, _ = row_work(wid)
        return (zz, hh, 0, 0)

    dq_kernel = functools.partial(
        _dq_kernel,
        seqlen=n,
        block_m=block_m,
        block_n=block_n,
        sm_scale=sm_scale,
        causal=causal,
        block_index_fn=lambda wid: row_work(wid)[2],
    )
    dq = pl.pallas_call(
        dq_kernel,
        grid=(z * h_q * num_row_blocks,),
        in_specs=[
            pl.BlockSpec((1, 1, block_m, d), row_map),  # q block
            pl.BlockSpec((1, 1, n, d), kv_full_map),  # k
            pl.BlockSpec((1, 1, n, d), kv_full_map),  # v
            pl.BlockSpec((1, 1, block_m, d), row_map),  # do block
            pl.BlockSpec((1, 1, block_m), row_vec_map),  # lse
            pl.BlockSpec((1, 1, block_m), row_vec_map),  # delta
        ],
        out_specs=[pl.BlockSpec((1, 1, block_m, d), row_map)],
        out_shape=[jax.ShapeDtypeStruct((z, h_q, n, d), q.dtype)],
        interpret=interpret,
    )(q, k_exp, v_exp, do, lse, delta)[0]

    # GQA: reduce expanded gradients over each query-head group.
    if group > 1:
        dk = dk_exp.reshape(z, h_k, group, n, d).sum(axis=2)
        dv = dv_exp.reshape(z, h_k, group, n, d).sum(axis=2)
    else:
        dk, dv = dk_exp, dv_exp
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)
