//! Training driver: runs the AOT-compiled transformer-block SGD step
//! (whose gradients flow through the Pallas FA2 forward AND backward
//! kernels) for a number of steps from Rust, logging the loss curve —
//! proof that the training path of the three-layer stack composes.
//!
//! The artifact `block_sgd_z1_n128_dm128` takes (x, y, *weights) and
//! returns (loss, *updated_weights); we feed the updated weights back in
//! each step, entirely in Rust on the PJRT CPU client.
//!
//! Run: `make artifacts && cargo run --release --example train_block`

use numa_attn::runtime::{inputs, Runtime};

fn main() -> anyhow::Result<()> {
    let artifact_dir = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );
    let steps: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20);

    let mut rt = Runtime::open(&artifact_dir)?;
    let name = "block_sgd_z1_n128_dm128";
    rt.load(name)?;
    let meta = rt.manifest().get(name).unwrap().clone();
    println!(
        "artifact {name}: {} inputs, {} outputs; training for {steps} steps",
        meta.inputs.len(),
        meta.outputs.len()
    );

    // Deterministic data + initial weights from the manifest seeds.
    let mut tensors: Vec<Vec<f32>> = meta
        .input_seeds
        .iter()
        .zip(&meta.inputs)
        .map(|(&seed, spec)| inputs::det_input(seed, spec.num_elements()))
        .collect();
    // Make the target y a (deterministic) function distinct from x.
    let y_len = tensors[1].len();
    tensors[1] = inputs::det_input(999, y_len).iter().map(|v| v * 0.1).collect();

    let mut losses = Vec::with_capacity(steps);
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let result = rt.execute(name, &tensors)?;
        let loss = result.outputs[0][0];
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}");
        losses.push(loss);
        // Feed updated weights back (outputs[1..] are the new weights).
        for (w, new_w) in tensors[2..].iter_mut().zip(&result.outputs[1..]) {
            w.clone_from(new_w);
        }
        println!("step {step:>3}: loss {loss:.6}");
    }
    let dt = t0.elapsed();
    println!(
        "\ntrained {steps} steps in {:.2} s ({:.1} ms/step)",
        dt.as_secs_f64(),
        dt.as_secs_f64() * 1e3 / steps as f64
    );
    anyhow::ensure!(
        losses[steps - 1] < losses[0],
        "loss did not decrease: {} -> {}",
        losses[0],
        losses[steps - 1]
    );
    println!(
        "loss decreased {:.6} -> {:.6} (the Pallas fwd+bwd kernels are training the block)",
        losses[0],
        losses[steps - 1]
    );
    Ok(())
}
