//! DeepSeek-V3 prefill case study (paper Sec. 4.5): MHA with 128 query
//! heads and 128 KV heads, D_HEAD = 56 — the configuration where head
//! count most exceeds the XCD count, across context lengths and batches.
//!
//! Run: `cargo run --release --example deepseek_prefill`

use numa_attn::attn::KernelKind;
use numa_attn::mapping::{Policy, ALL_POLICIES};
use numa_attn::metrics::Table;
use numa_attn::roofline;
use numa_attn::sim::{simulate, SimConfig};
use numa_attn::topology::presets;
use numa_attn::workload::presets as models;

fn main() {
    let topo = presets::mi300x();
    let model = models::deepseek_v3();
    println!(
        "model: {} (H_Q={}, H_K={}, D_HEAD={}) on {}\n",
        model.name, model.h_q, model.h_k, model.d_head, topo.name
    );

    let mut t = Table::new(&[
        "config", "NBF", "SBF", "NHF", "SHF(norm)", "SHF hit %", "SHF TFLOP/s",
    ]);
    for n_ctx in [2048usize, 8192, 32768, 131072] {
        for batch in [1usize, 8] {
            let cfg = model.attn(batch, n_ctx);
            let reports: Vec<_> = ALL_POLICIES
                .iter()
                .map(|&p| simulate(&topo, &cfg, &SimConfig::sampled(p, &topo, 2)))
                .collect();
            let shf = reports
                .iter()
                .find(|r| r.policy == Policy::SwizzledHeadFirst)
                .unwrap();
            let rel = |p: Policy| {
                let r = reports.iter().find(|r| r.policy == p).unwrap();
                format!("{:.3}", shf.est_total_sec / r.est_total_sec)
            };
            t.row(vec![
                format!("N={}K B={batch}", n_ctx / 1024),
                rel(Policy::NaiveBlockFirst),
                rel(Policy::SwizzledBlockFirst),
                rel(Policy::NaiveHeadFirst),
                "1.000".into(),
                format!("{:.1}", shf.l2_hit_pct()),
                format!("{:.0}", shf.achieved_tflops),
            ]);
        }
    }
    println!("{}", t.render());

    // Why D=56 lowers absolute performance (paper Sec. 4.5).
    let cfg56 = model.attn(1, 32768);
    let cfg128 = numa_attn::attn::AttnConfig::mha(1, 128, 32768, 128);
    let r56 = roofline::attention_roofline(&topo, &cfg56, KernelKind::Forward);
    let r128 = roofline::attention_roofline(&topo, &cfg128, KernelKind::Forward);
    println!(
        "arithmetic profile: D=56 matrix-core efficiency {:.2} (vs {:.2} at D=128); \
         ideal times {:.2} / {:.2} ms",
        cfg56.compute_efficiency_factor(),
        cfg128.compute_efficiency_factor(),
        r56.ideal_sec * 1e3,
        r128.ideal_sec * 1e3,
    );
}
