//! Mapping explorer: prints the paper's Figs. 7-10 head->XCD layouts for
//! any grid geometry, measures ACC spread, and sweeps a head-count axis
//! to show where each policy's locality breaks.
//!
//! Run: `cargo run --release --example mapping_explorer -- [--heads 8] [--blocks 128] [--xcds 4]`

use numa_attn::attn::acc::AccSpread;
use numa_attn::attn::AttnConfig;
use numa_attn::mapping::{Mapping, ALL_POLICIES};
use numa_attn::metrics::Table;
use numa_attn::sched::xcd_of_slot;
use numa_attn::sim::{simulate, SimConfig};
use numa_attn::topology::presets;
use numa_attn::util::args::Args;

fn main() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&raw, &[]).map_err(|e| anyhow::anyhow!(e))?;
    let heads: usize = args.get_or("heads", 8).map_err(|e| anyhow::anyhow!(e))?;
    let blocks: usize = args.get_or("blocks", 128).map_err(|e| anyhow::anyhow!(e))?;
    let xcds: usize = args.get_or("xcds", 4).map_err(|e| anyhow::anyhow!(e))?;

    // --- Figs. 7-10 layouts ------------------------------------------------
    println!("== head -> XCD layouts ({heads} q-heads, {blocks} row blocks, {xcds} XCDs) ==");
    for policy in ALL_POLICIES {
        println!("-- {} --", policy.label());
        match Mapping::new(policy, 1, heads, blocks, xcds) {
            Err(e) => println!("   (not applicable: {e})"),
            Ok(m) => {
                let mut per_xcd = vec![std::collections::BTreeSet::new(); xcds];
                for s in 0..m.grid_size() {
                    let w = m.decode(s);
                    per_xcd[xcd_of_slot(s, 1, xcds) as usize].insert(w.h);
                }
                for (x, hs) in per_xcd.iter().enumerate() {
                    let hs: Vec<String> = hs.iter().map(|h| format!("HQ{h}")).collect();
                    println!("   XCD{x}: {}", hs.join(","));
                }
                // ACC spread: does any head straddle XCDs?
                let cfg = AttnConfig::mha(1, heads, blocks * 128, 128);
                let spread = AccSpread::measure(
                    &cfg,
                    xcds,
                    (0..m.grid_size()).map(|s| (m.decode(s), xcd_of_slot(s, 1, xcds))),
                );
                println!(
                    "   ACC spread: co-located={} max ACCs/XCD={}",
                    spread.perfectly_colocated(),
                    spread.max_accs_per_xcd()
                );
            }
        }
    }

    // --- head-count sweep on the simulator ---------------------------------
    let topo = presets::mi300x();
    println!("\n== where locality breaks: H sweep at N_CTX=32K B=2 (MI300X) ==");
    let mut t = Table::new(&["H_Q", "NBF hit %", "NHF hit %", "SHF hit %", "SHF/NBF speedup"]);
    for h in [8usize, 16, 32, 64, 128] {
        let cfg = AttnConfig::mha(2, h, 32 * 1024, 128);
        let run = |p| simulate(&topo, &cfg, &SimConfig::sampled(p, &topo, 2));
        let nbf = run(numa_attn::mapping::Policy::NaiveBlockFirst);
        let nhf = run(numa_attn::mapping::Policy::NaiveHeadFirst);
        let shf = run(numa_attn::mapping::Policy::SwizzledHeadFirst);
        t.row(vec![
            h.to_string(),
            format!("{:.1}", nbf.l2_hit_pct()),
            format!("{:.1}", nhf.l2_hit_pct()),
            format!("{:.1}", shf.l2_hit_pct()),
            format!("{:.2}x", nbf.est_total_sec / shf.est_total_sec),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
