//! Quickstart: simulate one attention workload on MI300X under all four
//! workgroup-mapping policies and print the paper's metrics, then show
//! the Fig. 2 microcosm — two workgroups that share K/V tiles either on
//! the same XCD (hits) or on different dies (redundant HBM fetches).
//!
//! Run: `cargo run --release --example quickstart`

use numa_attn::attn::AttnConfig;
use numa_attn::coordinator::advise;
use numa_attn::mapping::ALL_POLICIES;
use numa_attn::metrics::Table;
use numa_attn::sim::{simulate, SimConfig};
use numa_attn::topology::presets;

fn main() {
    let topo = presets::mi300x();
    println!("topology: {} ({} XCDs, {} CUs, {} MiB L2/XCD)\n",
        topo.name, topo.num_xcds, topo.total_cus(),
        topo.l2_bytes_per_xcd / (1024 * 1024));

    // Llama-70B-like MHA slice: 64 heads, 32K context, batch 2.
    let cfg = AttnConfig::mha(2, 64, 32 * 1024, 128);
    println!("workload: MHA H={} N_CTX={} B={} D={} (grid = {} workgroups)\n",
        cfg.h_q, cfg.n_ctx, cfg.batch, cfg.d_head,
        cfg.grid_size(numa_attn::attn::KernelKind::Forward));

    let mut t = Table::new(&["policy", "L2 hit %", "HBM GB", "est time (ms)", "rel perf"]);
    let mut best = f64::INFINITY;
    let reports: Vec<_> = ALL_POLICIES
        .iter()
        .map(|&p| simulate(&topo, &cfg, &SimConfig::sampled(p, &topo, 2)))
        .collect();
    for r in &reports {
        best = best.min(r.est_total_sec);
    }
    for r in &reports {
        t.row(vec![
            r.policy.label().into(),
            format!("{:.1}", r.l2_hit_pct()),
            format!("{:.2}", r.hbm.bytes_read as f64 / 1e9),
            format!("{:.2}", r.est_total_sec * 1e3),
            format!("{:.3}", best / r.est_total_sec),
        ]);
    }
    println!("{}", t.render());

    // The advisor: what a serving deployment should configure.
    let advice = advise(&topo, &cfg);
    println!("advisor recommendation: {}", advice.recommended.label());

    // Fig. 2 microcosm: same-die vs cross-die placement of two WGs that
    // share K/V (one head, two row blocks).
    let tiny = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 1, 2048, 128) };
    let same_die = {
        let mut topo1 = topo.clone();
        topo1.num_xcds = 1;
        topo1.cus_per_xcd = 2;
        simulate(&topo1, &tiny, &SimConfig::forward(numa_attn::mapping::Policy::NaiveHeadFirst))
    };
    let cross_die = {
        let mut topo2 = topo.clone();
        topo2.num_xcds = 2;
        topo2.cus_per_xcd = 1;
        simulate(&topo2, &tiny, &SimConfig::forward(numa_attn::mapping::Policy::NaiveHeadFirst))
    };
    println!(
        "\nFig. 2 microcosm (16 WGs sharing one head's K/V):\n  same die : {:5.1}% L2 hits, {:6.1} MB from HBM\n  cross die: {:5.1}% L2 hits, {:6.1} MB from HBM (redundant fetches)",
        same_die.l2_hit_pct(),
        same_die.hbm.bytes_read as f64 / 1e6,
        cross_die.l2_hit_pct(),
        cross_die.hbm.bytes_read as f64 / 1e6,
    );
}
