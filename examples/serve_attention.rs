//! END-TO-END driver (DESIGN.md §6): the full three-layer stack on a real
//! small serving workload.
//!
//!   1. loads the AOT artifacts produced by `make artifacts` (JAX/Pallas
//!      FlashAttention2 lowered to HLO text — Python is NOT running now);
//!   2. verifies every artifact against the Python oracle's golden
//!      checksums (deterministic inputs regenerated in Rust);
//!   3. starts the Rust coordinator (router + continuous batcher + PJRT
//!      CPU worker) and serves a mixed-length batch of prefill requests,
//!      reporting latency/throughput and batching metrics;
//!   4. for each serving bucket's attention geometry, projects the
//!      MI300X mapping-policy decision with the chiplet simulator — the
//!      paper's contribution surfacing as a deployment feature.
//!
//! Run: `make artifacts && cargo run --release --example serve_attention`

use std::time::Instant;

use numa_attn::coordinator::{advise, AttentionService, BatcherConfig, ServiceConfig};
use numa_attn::metrics::Table;
use numa_attn::runtime::Runtime;
use numa_attn::topology::presets;
use numa_attn::workload::RequestGenerator;

fn main() -> anyhow::Result<()> {
    let artifact_dir = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );

    // --- 1+2. load + verify the AOT artifacts --------------------------
    println!("== loading AOT artifacts from {} ==", artifact_dir.display());
    let mut rt = Runtime::open(&artifact_dir)?;
    rt.load_all()?;
    println!("platform: {}; artifacts: {:?}", rt.platform(), rt.loaded_names());
    for art in rt.manifest().artifacts.clone() {
        if art.golden.is_some() {
            let (got, want) = rt.verify(&art.name, 1e-3)?;
            println!("  golden {}: abs_sum {got:.3} == {want:.3} OK", art.name);
        }
    }
    drop(rt); // the service opens its own runtime on its worker thread

    // --- 3. serve a mixed-length prefill workload ----------------------
    println!("\n== serving 64 mixed-length prefill requests ==");
    let service = AttentionService::start(ServiceConfig {
        artifact_dir: artifact_dir.clone(),
        batcher: BatcherConfig {
            max_batch: 4,
            max_wait: std::time::Duration::from_millis(2),
        },
    })?;
    let lengths = service.router().bucket_lengths();
    println!("router buckets (n_ctx): {lengths:?}");

    let mut gen = RequestGenerator::new(42, lengths);
    let requests = gen.take(64);
    let t0 = Instant::now();
    let waiters: Vec<_> = requests
        .iter()
        .map(|r| service.submit(r.clone()).expect("submit"))
        .collect();
    let mut ok = 0usize;
    let mut checksum_total = 0.0f64;
    for w in waiters {
        let resp = w.wait()?;
        assert!(resp.checksum.is_finite() && resp.checksum > 0.0);
        checksum_total += resp.checksum;
        ok += 1;
    }
    let elapsed = t0.elapsed();
    println!(
        "served {ok}/64 requests in {:.1} ms -> {:.1} req/s (output checksum total {:.2})",
        elapsed.as_secs_f64() * 1e3,
        64.0 / elapsed.as_secs_f64(),
        checksum_total
    );
    let m = service.shutdown();
    println!(
        "batches: {} (stacked batch-2 executions: {}), queue wait p99: {} us, exec mean: {:.0} us, errors: {}",
        m.batches, m.stacked_executions, m.queue_wait.p99_us, m.exec.mean_us, m.errors
    );
    anyhow::ensure!(m.errors == 0, "serving errors");

    // --- 4. NUMA mapping projection per bucket --------------------------
    println!("\n== MI300X mapping-policy projection per serving bucket ==");
    let topo = presets::mi300x();
    let rt = Runtime::open(&artifact_dir)?;
    let mut t = Table::new(&["bucket", "recommended", "policy", "hit %", "rel perf"]);
    for art in rt.manifest().attention_artifacts() {
        let Some(attn) = &art.attn else { continue };
        if attn.batch != 1 || attn.causal {
            continue;
        }
        // Project at production scale: same head geometry, long context.
        let prod = numa_attn::attn::AttnConfig::gqa(1, attn.h_q.max(topo.num_xcds * 2), attn.h_k.max(8), 32 * 1024, attn.d_head);
        let advice = advise(&topo, &prod);
        for (p, hit, rel) in &advice.projections {
            t.row(vec![
                art.name.clone(),
                advice.recommended.label().into(),
                p.label().into(),
                format!("{hit:.1}"),
                format!("{rel:.3}"),
            ]);
        }
    }
    println!("{}", t.render());
    println!("end-to-end OK");
    Ok(())
}
