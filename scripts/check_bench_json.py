#!/usr/bin/env python3
"""Validator for the pinned `bench-v1` perf-trajectory JSON files.

The self-checking benches write `BENCH_<suite>.json` at the repo root
(format: docs/PERF.md): `cargo bench --bench sim_hotpath` pins
`BENCH_sim_hotpath.json`, `cargo bench --bench disagg_serving` pins
`BENCH_disagg.json`, `cargo bench --bench mapping_tune` pins
`BENCH_tune.json`. This script checks that a file is a structurally
valid `bench-v1` document — every case carries name / iters / mean_ms /
min_ms / max_ms / metrics, with sane values (iters >= 1,
0 < min <= mean <= max) — and then applies the headline contracts of
the suite the document declares:

  * suite `sim_hotpath`: the end-to-end engine-throughput case
    ("engine: ... (SHF)") reports `accesses_per_sec` >= 10e6 — the
    >=10M demand tile-accesses/s/core floor from DESIGN.md §Perf (hard
    failure: the Table-2 sweep stops fitting in minutes below it); the
    decode-reduce case reports `speedup_vs_reference`, the event-driven
    engine vs the reference per-tick scan on the same workload (below
    10x warns rather than fails — the ratio depends on the runner's
    scheduling noise, and the hard floor is enforced where it is
    measured, in the self-checking bench run);
  * suite `disagg`: the headline case ("disagg: 1p+1d (SHF)") reports
    `ttft_speedup_vs_colocated` >= 1.0 and `tokens_ratio_vs_colocated`
    >= 1.0 — the docs/DISAGG.md claim that the split deployment cuts
    the interactive first-token tail without losing decode throughput
    to the handoff (hard failures: the bench asserts the same ordering
    where it is measured);
  * suite `faults`: the outage case ("faults: mid-serve outage,
    rebalance + recovery (SHF)") reports `rebalances` >= 1 (the fault
    cells actually fired and re-formed the shard plan),
    `degraded_tokens_per_sec` < `healthy_tokens_per_sec` (losing a
    device visibly slows the degraded interval), and `recovery_ratio`
    >= 0.95 — the docs/SERVING.md §9 claim that the post-recovery
    window restores at least 95% of the pre-failure busy-time rate
    (hard failures: the bench asserts the same ordering where it is
    measured);
  * suite `tune`: every sweep case ("tune: ...") reports
    `speedup_vs_shf` >= 1.0 — the autotuner's strict argmin can never
    lose to a baseline inside its own search space (hard failure:
    anything below 1.0 means the search or the baseline selection is
    broken) — and at least one case reports `speedup_vs_shf` > 1.0,
    the docs/TUNING.md claim that the composed mapping algebra strictly
    beats swizzled_head_first somewhere in the sweep;
  * any other suite: structural validation only.

Usage: python3 scripts/check_bench_json.py [path/to/BENCH_<suite>.json]
Exits non-zero listing every violation.
"""

import json
import sys
from pathlib import Path

ACCESSES_FLOOR = 10e6
SPEEDUP_FLOOR = 10.0
THROUGHPUT_CASE = "engine: H=64 N=32K sampled (SHF)"
SPEEDUP_CASE_PREFIX = "engine: decode-reduce"

DISAGG_HEADLINE_CASE = "disagg: 1p+1d (SHF)"
DISAGG_RATIO_METRICS = ("ttft_speedup_vs_colocated", "tokens_ratio_vs_colocated")

TUNE_CASE_PREFIX = "tune: "
TUNE_SPEEDUP_METRIC = "speedup_vs_shf"

FAULTS_OUTAGE_CASE = "faults: mid-serve outage, rebalance + recovery (SHF)"
FAULTS_RECOVERY_FLOOR = 0.95

REQUIRED_CASE_FIELDS = ("name", "iters", "mean_ms", "min_ms", "max_ms", "metrics")


def fail(errors, msg):
    errors.append(msg)


def check(doc, errors, warnings):
    if not isinstance(doc, dict):
        fail(errors, "top level is not a JSON object")
        return
    if doc.get("schema") != "bench-v1":
        fail(errors, f"schema is {doc.get('schema')!r}, expected 'bench-v1'")
    if not isinstance(doc.get("suite"), str) or not doc.get("suite"):
        fail(errors, "missing or empty 'suite' string")
    cases = doc.get("cases")
    if not isinstance(cases, list) or not cases:
        fail(errors, "missing or empty 'cases' array")
        return

    names = []
    for i, case in enumerate(cases):
        where = f"cases[{i}]"
        if not isinstance(case, dict):
            fail(errors, f"{where}: not an object")
            continue
        for field in REQUIRED_CASE_FIELDS:
            if field not in case:
                fail(errors, f"{where}: missing field {field!r}")
        name = case.get("name")
        if not isinstance(name, str) or not name:
            fail(errors, f"{where}: missing or empty case name")
            continue
        names.append(name)
        where = f"case {name!r}"
        iters = case.get("iters")
        if not isinstance(iters, int) or iters < 1:
            fail(errors, f"{where}: iters must be an integer >= 1, got {iters!r}")
        timings = {}
        for field in ("mean_ms", "min_ms", "max_ms"):
            v = case.get(field)
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                fail(errors, f"{where}: {field} must be a number, got {v!r}")
            else:
                timings[field] = float(v)
        if len(timings) == 3:
            if timings["min_ms"] <= 0:
                fail(errors, f"{where}: min_ms must be > 0")
            if not (timings["min_ms"] <= timings["mean_ms"] <= timings["max_ms"]):
                fail(errors, f"{where}: expected min_ms <= mean_ms <= max_ms, got {timings}")
        metrics = case.get("metrics")
        if not isinstance(metrics, dict):
            fail(errors, f"{where}: metrics must be an object")
            metrics = {}
        for k, v in metrics.items():
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                fail(errors, f"{where}: metric {k!r} must be a number, got {v!r}")

        if doc.get("suite") == "sim_hotpath":
            if name == THROUGHPUT_CASE:
                aps = metrics.get("accesses_per_sec")
                if not isinstance(aps, (int, float)):
                    fail(errors, f"{where}: missing 'accesses_per_sec' metric")
                elif aps < ACCESSES_FLOOR:
                    fail(
                        errors,
                        f"{where}: accesses_per_sec {aps:.3g} below the "
                        f"{ACCESSES_FLOOR:.0e} floor (DESIGN.md §Perf)",
                    )
            if name.startswith(SPEEDUP_CASE_PREFIX) and not name.startswith("engine-reference"):
                speedup = metrics.get("speedup_vs_reference")
                if not isinstance(speedup, (int, float)):
                    fail(errors, f"{where}: missing 'speedup_vs_reference' metric")
                elif speedup < SPEEDUP_FLOOR:
                    warnings.append(
                        f"{where}: speedup_vs_reference {speedup:.2f}x below the "
                        f"{SPEEDUP_FLOOR:.0f}x target (noisy runner?)"
                    )
        if doc.get("suite") == "tune" and name.startswith(TUNE_CASE_PREFIX):
            speedup = metrics.get(TUNE_SPEEDUP_METRIC)
            if not isinstance(speedup, (int, float)):
                fail(errors, f"{where}: missing {TUNE_SPEEDUP_METRIC!r} metric")
            elif speedup < 1.0:
                fail(
                    errors,
                    f"{where}: {TUNE_SPEEDUP_METRIC} {speedup:.4f} below 1.0 — the "
                    "tuned mapping lost to a baseline inside its own search space "
                    "(docs/TUNING.md)",
                )
        if doc.get("suite") == "faults" and name == FAULTS_OUTAGE_CASE:
            rebalances = metrics.get("rebalances")
            if not isinstance(rebalances, (int, float)):
                fail(errors, f"{where}: missing 'rebalances' metric")
            elif rebalances < 1:
                fail(
                    errors,
                    f"{where}: rebalances {rebalances} — the outage never re-formed "
                    "the shard plan (docs/SERVING.md §9)",
                )
            degraded = metrics.get("degraded_tokens_per_sec")
            healthy = metrics.get("healthy_tokens_per_sec")
            if not isinstance(degraded, (int, float)) or not isinstance(healthy, (int, float)):
                fail(
                    errors,
                    f"{where}: missing 'degraded_tokens_per_sec' / "
                    "'healthy_tokens_per_sec' metrics",
                )
            elif not degraded < healthy:
                fail(
                    errors,
                    f"{where}: degraded rate {degraded:.0f} not below healthy "
                    f"{healthy:.0f} — the degraded interval is invisible "
                    "(docs/SERVING.md §9)",
                )
            recovery = metrics.get("recovery_ratio")
            if not isinstance(recovery, (int, float)):
                fail(errors, f"{where}: missing 'recovery_ratio' metric")
            elif recovery < FAULTS_RECOVERY_FLOOR:
                fail(
                    errors,
                    f"{where}: recovery_ratio {recovery:.4f} below the "
                    f"{FAULTS_RECOVERY_FLOOR} floor — recovery never restored the "
                    "pre-failure rate (docs/SERVING.md §9)",
                )
        if doc.get("suite") == "disagg" and name == DISAGG_HEADLINE_CASE:
            for metric in DISAGG_RATIO_METRICS:
                ratio = metrics.get(metric)
                if not isinstance(ratio, (int, float)):
                    fail(errors, f"{where}: missing {metric!r} metric")
                elif ratio < 1.0:
                    fail(
                        errors,
                        f"{where}: {metric} {ratio:.3f} below 1.0 — disaggregation "
                        "lost its headline ordering (docs/DISAGG.md)",
                    )

    if doc.get("suite") == "sim_hotpath":
        if THROUGHPUT_CASE not in names:
            fail(errors, f"throughput case {THROUGHPUT_CASE!r} not present")
        if not any(n.startswith(SPEEDUP_CASE_PREFIX) for n in names):
            fail(errors, f"no case named {SPEEDUP_CASE_PREFIX!r}...")
    if doc.get("suite") == "disagg" and DISAGG_HEADLINE_CASE not in names:
        fail(errors, f"headline case {DISAGG_HEADLINE_CASE!r} not present")
    if doc.get("suite") == "faults" and FAULTS_OUTAGE_CASE not in names:
        fail(errors, f"outage case {FAULTS_OUTAGE_CASE!r} not present")
    if doc.get("suite") == "tune":
        speedups = [
            case.get("metrics", {}).get(TUNE_SPEEDUP_METRIC)
            for case in cases
            if isinstance(case, dict)
            and isinstance(case.get("name"), str)
            and case["name"].startswith(TUNE_CASE_PREFIX)
        ]
        if not speedups:
            fail(errors, f"no case named {TUNE_CASE_PREFIX!r}...")
        numeric = [s for s in speedups if isinstance(s, (int, float))]
        if numeric and not any(s > 1.0 for s in numeric):
            fail(
                errors,
                f"no sweep case has {TUNE_SPEEDUP_METRIC} > 1.0 — the composed "
                "algebra never strictly beat swizzled_head_first (docs/TUNING.md)",
            )


def main(argv):
    path = Path(argv[1]) if len(argv) > 1 else Path(__file__).resolve().parent.parent / (
        "BENCH_sim_hotpath.json"
    )
    if not path.is_file():
        print(f"check_bench_json: {path} not found", file=sys.stderr)
        return 1
    try:
        doc = json.loads(path.read_text())
    except json.JSONDecodeError as e:
        print(f"check_bench_json: {path} is not valid JSON: {e}", file=sys.stderr)
        return 1

    errors, warnings = [], []
    check(doc, errors, warnings)
    for w in warnings:
        print(f"check_bench_json: WARNING: {w}")
    if errors:
        for e in errors:
            print(f"check_bench_json: FAIL: {e}", file=sys.stderr)
        return 1
    ncases = len(doc.get("cases", []))
    print(f"check_bench_json: OK ({path.name}: {ncases} cases, {len(warnings)} warnings)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
