#!/usr/bin/env python3
"""Approximate missing_docs linter for offline development.

Walks rust/src and flags public items (fn/struct/enum/trait/type/const/
static, struct fields, variants of pub enums) that are not immediately
preceded by a doc comment. `pub mod x;` declarations count as documented
when the module file opens with `//!`. It mirrors rustc's `missing_docs`
lint closely enough to burn warnings down without a toolchain; CI's
`cargo doc` step (RUSTDOCFLAGS="-D warnings") is the source of truth.
"""

import re
import sys
from pathlib import Path

ITEM = re.compile(
    r"^(\s*)pub\s+(?:unsafe\s+|async\s+|extern\s+\"C\"\s+)*"
    r"(fn|struct|enum|trait|type|const|static|mod)\s+([A-Za-z_][A-Za-z0-9_]*)"
)
FIELD = re.compile(r"^(\s*)pub\s+([a-z_][A-Za-z0-9_]*)\s*:")
VARIANT = re.compile(r"^(\s+)([A-Z][A-Za-z0-9_]*)\s*(\{|\(|,|\s*=)")
RESTRICTED = re.compile(r"^\s*pub\s*\(")


def has_doc(lines, i):
    j = i - 1
    while j >= 0:
        s = lines[j].strip()
        if s.startswith("///"):
            return True
        if s.startswith("#["):
            j -= 1
            continue
        return False
    return False


def mod_file_has_inner_docs(path, name):
    for cand in (path.parent / f"{name}.rs", path.parent / name / "mod.rs",
                 path.parent / path.stem / f"{name}.rs",
                 path.parent / path.stem / name / "mod.rs"):
        if cand.exists():
            head = cand.read_text().lstrip()
            return head.startswith("//!")
    return False


def main():
    root = Path(sys.argv[1] if len(sys.argv) > 1 else "rust/src")
    problems = []
    for path in sorted(root.rglob("*.rs")):
        lines = path.read_text().splitlines()
        depth = 0
        exempt_stack = []
        enum_regions = []  # (start_depth, active) for pub enums
        in_pub_enum_depth = None
        for i, line in enumerate(lines):
            if re.match(r"^\s*#\[cfg\(test\)\]", line):
                for k in range(i + 1, min(i + 3, len(lines))):
                    if re.match(r"^\s*(pub\s+)?mod\s+\w+", lines[k]):
                        exempt_stack.append(depth)
                        break
            opens = line.count("{") - line.count("}")
            in_test = bool(exempt_stack)
            if not in_test and not RESTRICTED.match(line):
                m = ITEM.match(line)
                f = FIELD.match(line)
                if m:
                    kind, name = m.group(2), m.group(3)
                    documented = has_doc(lines, i)
                    if kind == "mod" and line.rstrip().endswith(";"):
                        documented = documented or mod_file_has_inner_docs(path, name)
                    if not documented:
                        problems.append(f"{path}:{i+1}: pub {kind} {name}")
                    if kind == "enum" and "{" in line:
                        in_pub_enum_depth = depth
                elif f and not has_doc(lines, i):
                    problems.append(f"{path}:{i+1}: pub field {f.group(2)}")
                elif (
                    in_pub_enum_depth is not None
                    and depth == in_pub_enum_depth + 1
                    and VARIANT.match(line)
                    and not has_doc(lines, i)
                ):
                    problems.append(
                        f"{path}:{i+1}: enum variant {VARIANT.match(line).group(2)}"
                    )
            depth += opens
            if in_pub_enum_depth is not None and depth <= in_pub_enum_depth:
                in_pub_enum_depth = None
            if exempt_stack and depth <= exempt_stack[-1] and "}" in line:
                exempt_stack.pop()
    for p in problems:
        print(p)
    print(f"{len(problems)} potentially undocumented public items")


if __name__ == "__main__":
    main()
