#!/usr/bin/env python3
"""Intra-repo markdown link checker.

Walks the repo's markdown set (README.md, DESIGN.md, ROADMAP.md, PAPER*,
docs/*.md, ...) and validates every `[text](target)` link whose target
is a repo path: the file (or directory) must exist, and a `#fragment`
into a markdown file must match a real heading's GitHub-style anchor.
External links (http/https/mailto) are skipped — this checker never
touches the network, so it can run in CI alongside
check_missing_docs.py.

Usage: python3 scripts/check_doc_links.py [repo_root]
Exits non-zero listing every broken link.
"""

import re
import sys
from pathlib import Path

# Inline links like [text](target); images ![alt](target) share the tail.
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING = re.compile(r"^#{1,6}\s+(.*)$")
EXTERNAL = ("http://", "https://", "mailto:")


def anchor_of(heading: str) -> str:
    """GitHub-style slug: lowercase, spaces to dashes, punctuation out."""
    text = re.sub(r"[`*_]", "", heading.strip()).lower()
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.replace(" ", "-")


_ANCHOR_CACHE = {}


def anchors_in(path: Path) -> set:
    cached = _ANCHOR_CACHE.get(path)
    if cached is not None:
        return cached
    anchors = set()
    in_fence = False
    for line in path.read_text().splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING.match(line)
        if m:
            anchors.add(anchor_of(m.group(1)))
    _ANCHOR_CACHE[path] = anchors
    return anchors


def markdown_files(root: Path):
    for pattern in ("*.md", "docs/*.md", "examples/*.md", "scripts/*.md",
                    "rust/*.md", "python/*.md"):
        yield from sorted(root.glob(pattern))


def check_file(md: Path, root: Path) -> list:
    problems = []
    in_fence = False
    for lineno, line in enumerate(md.read_text().splitlines(), 1):
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for target in LINK.findall(line):
            if target.startswith(EXTERNAL):
                continue
            path_part, _, fragment = target.partition("#")
            where = f"{md.relative_to(root)}:{lineno}"
            if not path_part:  # pure '#fragment' into this file
                dest = md
            else:
                dest = (md.parent / path_part).resolve()
                try:
                    dest.relative_to(root.resolve())
                except ValueError:
                    problems.append(f"{where}: link escapes the repo: {target}")
                    continue
                if not dest.exists():
                    problems.append(f"{where}: broken link target: {target}")
                    continue
            if fragment and dest.suffix == ".md" and dest.exists():
                # GitHub de-duplicates repeat anchors with -1/-2 suffixes;
                # strip one trailing -N before matching.
                frag = re.sub(r"-\d+$", "", fragment)
                anchors = anchors_in(dest)
                if fragment not in anchors and frag not in anchors:
                    problems.append(
                        f"{where}: missing anchor #{fragment} in {dest.name}")
    return problems


def main():
    root = Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()
    files = list(markdown_files(root))
    if not files:
        print(f"check_doc_links: no markdown files under {root}", file=sys.stderr)
        return 1
    problems = []
    for md in files:
        problems.extend(check_file(md, root))
    if problems:
        print(f"check_doc_links: {len(problems)} broken link(s):")
        for p in problems:
            print(f"  {p}")
        return 1
    nlinks = sum(
        len(LINK.findall(md.read_text())) for md in files)
    print(f"check_doc_links: OK — {len(files)} file(s), {nlinks} link(s) scanned")
    return 0


if __name__ == "__main__":
    sys.exit(main())
