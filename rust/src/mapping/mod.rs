//! Workgroup-mapping policies (paper Sec. 3.2-3.3, Figs. 3 & 7-11).
//!
//! A policy defines which logical work item `(batch, head, block)` a given
//! *dispatch slot* executes. The hardware dispatcher assigns slots to
//! XCDs in chunked round-robin order ([`crate::sched`]), so the policy is
//! the software's only lever over *where* work runs — exactly the
//! swizzling mechanism of the paper.
//!
//! The arithmetic here mirrors `python/compile/kernels/swizzle.py`
//! line-for-line; `golden` tests pin the two implementations together.

mod golden;

use std::fmt;
use std::str::FromStr;

use crate::attn::{AttnConfig, KernelKind, WorkItem};

/// The four mapping strategies the paper evaluates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Policy {
    /// Fig. 7: block-first iteration, round-robin XCDs. Splits every
    /// XCD's L2 across H_Q/num_xcds concurrent ACC streams.
    NaiveBlockFirst,
    /// Fig. 8: block-first + chiplet swizzle (AITER's scheme). Pins
    /// contiguous head groups per XCD; optimal for GQA when groups ==
    /// XCDs, still interleaves multiple ACCs per XCD for MHA.
    SwizzledBlockFirst,
    /// Fig. 9: head-first iteration, round-robin XCDs (Triton default).
    /// One ACC live at a time but replicated into every XCD's L2.
    NaiveHeadFirst,
    /// Figs. 10-11: the paper's contribution. Head-first + spatial
    /// swizzle: every block of a head lands on one XCD; each XCD services
    /// one ACC at a time.
    SwizzledHeadFirst,
}

/// The four policies in the paper's presentation order.
pub const ALL_POLICIES: [Policy; 4] = [
    Policy::NaiveBlockFirst,
    Policy::SwizzledBlockFirst,
    Policy::NaiveHeadFirst,
    Policy::SwizzledHeadFirst,
];

impl Policy {
    /// Stable snake_case identifier (CLI/JSON).
    pub fn name(&self) -> &'static str {
        match self {
            Policy::NaiveBlockFirst => "naive_block_first",
            Policy::SwizzledBlockFirst => "swizzled_block_first",
            Policy::NaiveHeadFirst => "naive_head_first",
            Policy::SwizzledHeadFirst => "swizzled_head_first",
        }
    }

    /// Short label used in figure output (matches the paper's legends).
    pub fn label(&self) -> &'static str {
        match self {
            Policy::NaiveBlockFirst => "Naive Block-first",
            Policy::SwizzledBlockFirst => "Swizzled Block-first",
            Policy::NaiveHeadFirst => "Naive Head-first",
            Policy::SwizzledHeadFirst => "Swizzled Head-first",
        }
    }

    /// Does this policy's swizzle arithmetic require `num_xcds | h_q`?
    pub fn requires_divisible_heads(&self) -> bool {
        matches!(self, Policy::SwizzledBlockFirst | Policy::SwizzledHeadFirst)
    }
}

impl fmt::Display for Policy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for Policy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "naive_block_first" | "nbf" => Ok(Policy::NaiveBlockFirst),
            "swizzled_block_first" | "sbf" => Ok(Policy::SwizzledBlockFirst),
            "naive_head_first" | "nhf" => Ok(Policy::NaiveHeadFirst),
            "swizzled_head_first" | "shf" => Ok(Policy::SwizzledHeadFirst),
            other => Err(format!(
                "unknown policy '{other}' (expected one of nbf/sbf/nhf/shf or full names)"
            )),
        }
    }
}

/// GEMM-style chiplet swizzle (paper Fig. 3): remaps a linear workgroup id
/// so ids that round-robin to the same XCD become contiguous logically.
pub fn chiplet_swizzle(wgid: usize, grid: usize, num_xcd: usize) -> usize {
    let wgids_per_xcd = grid / num_xcd;
    let xcd = wgid % num_xcd;
    let local_wgid = wgid / num_xcd;
    xcd * wgids_per_xcd + local_wgid
}

/// A mapping instance bound to a grid geometry: decodes dispatch slots to
/// work items in O(1) with no allocation (the simulator hot path).
#[derive(Debug, Clone, Copy)]
pub struct Mapping {
    /// The mapping strategy.
    pub policy: Policy,
    /// Batch size (outermost grid dimension).
    pub batch: usize,
    /// Query heads.
    pub heads: usize,
    /// Block-dimension extent (row/column blocks or KV splits).
    pub blocks: usize,
    /// XCDs the swizzle arithmetic targets.
    pub num_xcds: usize,
}

impl Mapping {
    /// A mapping over an explicit grid geometry; rejects degenerate
    /// dimensions and (for swizzled policies) indivisible head counts.
    pub fn new(
        policy: Policy,
        batch: usize,
        heads: usize,
        blocks: usize,
        num_xcds: usize,
    ) -> Result<Self, String> {
        if batch == 0 || heads == 0 || blocks == 0 || num_xcds == 0 {
            return Err("mapping dimensions must be > 0".into());
        }
        if policy.requires_divisible_heads() && heads % num_xcds != 0 {
            return Err(format!(
                "{policy} requires num_heads ({heads}) divisible by num_xcds ({num_xcds})"
            ));
        }
        Ok(Mapping { policy, batch, heads, blocks, num_xcds })
    }

    /// Build a mapping for an attention kernel grid.
    pub fn for_kernel(
        policy: Policy,
        cfg: &AttnConfig,
        kernel: KernelKind,
        num_xcds: usize,
    ) -> Result<Self, String> {
        Self::new(policy, cfg.batch, cfg.h_q, cfg.blocks_for(kernel), num_xcds)
    }

    /// Total dispatch slots.
    pub fn grid_size(&self) -> usize {
        self.batch * self.heads * self.blocks
    }

    /// Decode dispatch slot -> logical (batch, head, block).
    ///
    /// Mirrors `swizzle.decode` in Python; batch is outermost everywhere
    /// (the paper Fig. 11's `wid_per_batch = wid // BATCH` line is a typo
    /// for `wid % (heads*blocks)` — see DESIGN.md).
    #[inline]
    pub fn decode(&self, slot: usize) -> WorkItem {
        debug_assert!(slot < self.grid_size());
        let per_batch = self.heads * self.blocks;
        let z = (slot / per_batch) as u32;
        let r = slot % per_batch;
        let (h, b) = match self.policy {
            Policy::NaiveBlockFirst => (r % self.heads, r / self.heads),
            Policy::SwizzledBlockFirst => {
                let hpx = self.heads / self.num_xcds;
                let x = r % self.num_xcds;
                let j = r / self.num_xcds;
                (x * hpx + j % hpx, j / hpx)
            }
            Policy::NaiveHeadFirst => (r / self.blocks, r % self.blocks),
            Policy::SwizzledHeadFirst => {
                let hpx = self.heads / self.num_xcds;
                let x = r % self.num_xcds;
                let j = r / self.num_xcds;
                (x * hpx + j / self.blocks, j % self.blocks)
            }
        };
        WorkItem { z, h: h as u32, b: b as u32 }
    }

    /// Decode the whole grid in slot order (tests / `explain`).
    pub fn decode_all(&self) -> Vec<WorkItem> {
        (0..self.grid_size()).map(|s| self.decode(s)).collect()
    }
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeSet;

    use super::*;
    use crate::attn::acc::AccSpread;
    use crate::sched::xcd_of_slot;

    fn spread(policy: Policy, cfg: &AttnConfig, xcds: usize) -> AccSpread {
        let m = Mapping::for_kernel(policy, cfg, KernelKind::Forward, xcds).unwrap();
        AccSpread::measure(
            cfg,
            xcds,
            (0..m.grid_size()).map(|s| (m.decode(s), xcd_of_slot(s, 1, xcds))),
        )
    }

    #[test]
    fn bijective_all_policies() {
        for policy in ALL_POLICIES {
            for (b, h, nb, x) in [(1, 8, 16, 4), (2, 16, 7, 8), (3, 8, 1, 2), (1, 128, 32, 8)] {
                let m = Mapping::new(policy, b, h, nb, x).unwrap();
                let set: BTreeSet<_> = m.decode_all().into_iter().map(|w| (w.z, w.h, w.b)).collect();
                assert_eq!(set.len(), m.grid_size(), "{policy} {b}x{h}x{nb}/{x}");
            }
        }
    }

    #[test]
    fn shf_confines_each_head_to_one_xcd() {
        let cfg = AttnConfig::mha(2, 16, 2048, 128);
        let s = spread(Policy::SwizzledHeadFirst, &cfg, 8);
        assert!(s.perfectly_colocated());
    }

    #[test]
    fn nhf_replicates_each_head_everywhere() {
        let cfg = AttnConfig::mha(1, 8, 8192, 128); // 64 blocks each
        let s = spread(Policy::NaiveHeadFirst, &cfg, 8);
        for (_, n) in &s.xcds_per_acc {
            assert_eq!(*n, 8, "each head striped across all XCDs");
        }
    }

    #[test]
    fn block_first_interleaves_many_accs_per_xcd() {
        let cfg = AttnConfig::mha(1, 128, 8192, 128);
        let nbf = spread(Policy::NaiveBlockFirst, &cfg, 8);
        let shf = spread(Policy::SwizzledHeadFirst, &cfg, 8);
        assert_eq!(nbf.max_accs_per_xcd(), 16); // 128 heads / 8 XCDs
        assert_eq!(shf.max_accs_per_xcd(), 16); // over the whole grid...
        // ...but SHF still perfectly co-locates each ACC:
        assert!(shf.perfectly_colocated());
        assert!(nbf.perfectly_colocated()); // NBF pins heads too (h % X)!
        // The difference is CONCURRENCY, covered by sim tests: NBF's
        // consecutive slots on one XCD alternate heads, SHF's don't.
        let m = Mapping::new(Policy::NaiveBlockFirst, 1, 128, 64, 8).unwrap();
        let h0 = m.decode(0).h;
        let h1 = m.decode(8).h; // next slot on XCD0
        assert_ne!(h0, h1);
        let m = Mapping::new(Policy::SwizzledHeadFirst, 1, 128, 64, 8).unwrap();
        assert_eq!(m.decode(0).h, m.decode(8).h);
    }

    #[test]
    fn sbf_gqa_pins_groups_when_groups_match_xcds() {
        // Paper Sec. 4.4: H_K == num XCDs makes SBF co-locate perfectly.
        let cfg = AttnConfig::gqa(1, 64, 8, 8192, 128);
        let s = spread(Policy::SwizzledBlockFirst, &cfg, 8);
        assert!(s.perfectly_colocated());
        assert_eq!(s.max_accs_per_xcd(), 1);
        // NBF spreads each group everywhere instead.
        let s = spread(Policy::NaiveBlockFirst, &cfg, 8);
        assert!(!s.perfectly_colocated());
    }

    #[test]
    fn decode_grid_shf_confines_head_splits_to_one_xcd() {
        // Split-KV decode: the block dimension is the KV split. SHF must
        // keep every split of one head's KV stream on a single XCD
        // (chunk = 1) so a head's partials never cross L2 domains.
        let cfg = AttnConfig::gqa(2, 64, 8, 65536, 128);
        for num_splits in [2usize, 4, 8] {
            let kernel = KernelKind::DecodeSplitKv { num_splits };
            let m = Mapping::for_kernel(Policy::SwizzledHeadFirst, &cfg, kernel, 8).unwrap();
            assert_eq!(m.blocks, num_splits);
            let s = AccSpread::measure(
                &cfg,
                8,
                (0..m.grid_size()).map(|s| (m.decode(s), xcd_of_slot(s, 1, 8))),
            );
            assert!(s.perfectly_colocated(), "num_splits={num_splits}");
        }
    }

    #[test]
    fn decode_grid_nhf_replicates_group_streams() {
        // The decode anti-invariant the figure quantifies: with splits
        // not a multiple of the XCD count, NHF lands the same (kv head,
        // split) stream on several XCDs.
        let cfg = AttnConfig::gqa(1, 64, 8, 65536, 128);
        let kernel = KernelKind::DecodeSplitKv { num_splits: 2 };
        let m = Mapping::for_kernel(Policy::NaiveHeadFirst, &cfg, kernel, 8).unwrap();
        let s = AccSpread::measure(
            &cfg,
            8,
            (0..m.grid_size()).map(|s| (m.decode(s), xcd_of_slot(s, 1, 8))),
        );
        assert!(!s.perfectly_colocated());
        // Python cross-check: every (batch, kv head) lands on 8 XCDs
        // (4 per split — see python/tests/test_swizzle.py).
        for (_, n) in &s.xcds_per_acc {
            assert_eq!(*n, 8);
        }
    }

    #[test]
    fn chiplet_swizzle_fig3() {
        let remapped: Vec<usize> = (0..16).map(|w| chiplet_swizzle(w, 16, 4)).collect();
        let mut sorted = remapped.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..16).collect::<Vec<_>>());
        assert_eq!(
            [remapped[0], remapped[4], remapped[8], remapped[12]],
            [0, 1, 2, 3]
        );
        assert_eq!(
            [remapped[1], remapped[5], remapped[9], remapped[13]],
            [4, 5, 6, 7]
        );
    }

    #[test]
    fn indivisible_heads_rejected_for_swizzled() {
        assert!(Mapping::new(Policy::SwizzledHeadFirst, 1, 6, 4, 8).is_err());
        assert!(Mapping::new(Policy::SwizzledBlockFirst, 1, 6, 4, 8).is_err());
        assert!(Mapping::new(Policy::NaiveHeadFirst, 1, 6, 4, 8).is_ok());
        assert!(Mapping::new(Policy::NaiveBlockFirst, 1, 6, 4, 8).is_ok());
    }

    #[test]
    fn policy_parsing() {
        assert_eq!("shf".parse::<Policy>().unwrap(), Policy::SwizzledHeadFirst);
        assert_eq!(
            "naive_block_first".parse::<Policy>().unwrap(),
            Policy::NaiveBlockFirst
        );
        assert!("bogus".parse::<Policy>().is_err());
        for p in ALL_POLICIES {
            assert_eq!(p.name().parse::<Policy>().unwrap(), p);
        }
    }
}
