//! Workgroup-mapping policies (paper Sec. 3.2-3.3, Figs. 3 & 7-11).
//!
//! A policy defines which logical work item `(batch, head, block)` a given
//! *dispatch slot* executes. The hardware dispatcher assigns slots to
//! XCDs in chunked round-robin order ([`crate::sched`]), so the policy is
//! the software's only lever over *where* work runs — exactly the
//! swizzling mechanism of the paper.
//!
//! The paper's four named policies are points in a larger composable
//! algebra ([`spec::MappingSpec`]): head assignment × traversal ×
//! intra-head block order × split placement. The legacy enum variants
//! are kept as the canonical names for the `lin`+`inherit` plane and
//! decode byte-for-byte as before; [`Policy::Composed`] opens the other
//! 12 points to the [`crate::coordinator::tuner`] search.
//!
//! The arithmetic here mirrors `python/compile/kernels/swizzle.py`
//! line-for-line; `golden` tests pin the two implementations together.

mod golden;
pub mod spec;

use std::fmt;
use std::str::FromStr;

pub use spec::{
    BlockOrder, HeadAssign, MappingSpec, SplitPlacement, Traversal, ALL_SPECS, SPEC_SYNTAX,
};

use crate::attn::{AttnConfig, KernelKind, WorkItem};

/// A mapping strategy: one of the paper's four named policies, or any
/// other point of the composed algebra ([`MappingSpec`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Policy {
    /// Fig. 7: block-first iteration, round-robin XCDs. Splits every
    /// XCD's L2 across H_Q/num_xcds concurrent ACC streams.
    /// Algebra point `rr-block-lin-inherit`.
    NaiveBlockFirst,
    /// Fig. 8: block-first + chiplet swizzle (AITER's scheme). Pins
    /// contiguous head groups per XCD; optimal for GQA when groups ==
    /// XCDs, still interleaves multiple ACCs per XCD for MHA.
    /// Algebra point `swz-block-lin-inherit`.
    SwizzledBlockFirst,
    /// Fig. 9: head-first iteration, round-robin XCDs (Triton default).
    /// One ACC live at a time but replicated into every XCD's L2.
    /// Algebra point `rr-head-lin-inherit`.
    NaiveHeadFirst,
    /// Figs. 10-11: the paper's contribution. Head-first + spatial
    /// swizzle: every block of a head lands on one XCD; each XCD services
    /// one ACC at a time. Algebra point `swz-head-lin-inherit`.
    SwizzledHeadFirst,
    /// Any non-legacy point of the algebra (sawtooth order and/or
    /// grouped split placement). Constructed via [`Policy::from_spec`],
    /// which canonicalizes legacy-plane points onto the variants above.
    Composed(MappingSpec),
}

/// The four policies in the paper's presentation order.
pub const ALL_POLICIES: [Policy; 4] = [
    Policy::NaiveBlockFirst,
    Policy::SwizzledBlockFirst,
    Policy::NaiveHeadFirst,
    Policy::SwizzledHeadFirst,
];

impl Policy {
    /// Stable snake_case / spec identifier (CLI/JSON). Legacy variants
    /// keep their historical names; composed points use the dash-joined
    /// spec syntax, e.g. `swz-head-saw-inherit`.
    pub fn name(&self) -> String {
        match self {
            Policy::NaiveBlockFirst => "naive_block_first".into(),
            Policy::SwizzledBlockFirst => "swizzled_block_first".into(),
            Policy::NaiveHeadFirst => "naive_head_first".into(),
            Policy::SwizzledHeadFirst => "swizzled_head_first".into(),
            Policy::Composed(spec) => spec.name(),
        }
    }

    /// Short label used in figure output (matches the paper's legends
    /// for the four named policies; spec syntax otherwise).
    pub fn label(&self) -> String {
        match self {
            Policy::NaiveBlockFirst => "Naive Block-first".into(),
            Policy::SwizzledBlockFirst => "Swizzled Block-first".into(),
            Policy::NaiveHeadFirst => "Naive Head-first".into(),
            Policy::SwizzledHeadFirst => "Swizzled Head-first".into(),
            Policy::Composed(spec) => spec.name(),
        }
    }

    /// The policy's point in the mapping algebra.
    pub fn spec(&self) -> MappingSpec {
        match self {
            Policy::NaiveBlockFirst => MappingSpec::new(
                HeadAssign::RoundRobin,
                Traversal::BlockFirst,
                BlockOrder::Linear,
                SplitPlacement::Inherit,
            ),
            Policy::SwizzledBlockFirst => MappingSpec::new(
                HeadAssign::Swizzled,
                Traversal::BlockFirst,
                BlockOrder::Linear,
                SplitPlacement::Inherit,
            ),
            Policy::NaiveHeadFirst => MappingSpec::new(
                HeadAssign::RoundRobin,
                Traversal::HeadFirst,
                BlockOrder::Linear,
                SplitPlacement::Inherit,
            ),
            Policy::SwizzledHeadFirst => MappingSpec::new(
                HeadAssign::Swizzled,
                Traversal::HeadFirst,
                BlockOrder::Linear,
                SplitPlacement::Inherit,
            ),
            Policy::Composed(spec) => *spec,
        }
    }

    /// Canonicalize a spec onto a policy: the `lin`+`inherit` plane maps
    /// back to the legacy named variants (so equality/hashing — and
    /// therefore the driver's memo cache — never distinguish a legacy
    /// policy from its algebra point), everything else is `Composed`.
    pub fn from_spec(spec: MappingSpec) -> Policy {
        if spec.is_legacy_point() {
            match (spec.assign, spec.traversal) {
                (HeadAssign::RoundRobin, Traversal::BlockFirst) => Policy::NaiveBlockFirst,
                (HeadAssign::Swizzled, Traversal::BlockFirst) => Policy::SwizzledBlockFirst,
                (HeadAssign::RoundRobin, Traversal::HeadFirst) => Policy::NaiveHeadFirst,
                (HeadAssign::Swizzled, Traversal::HeadFirst) => Policy::SwizzledHeadFirst,
            }
        } else {
            Policy::Composed(spec)
        }
    }

    /// All 16 canonical points of the algebra: the four legacy policies
    /// (paper order) followed by the 12 composed points in
    /// [`ALL_SPECS`] enumeration order. This is the tuner's search
    /// space and the property-test domain.
    pub fn all_canonical() -> Vec<Policy> {
        let mut out: Vec<Policy> = ALL_POLICIES.to_vec();
        out.extend(
            ALL_SPECS
                .iter()
                .filter(|s| !s.is_legacy_point())
                .map(|s| Policy::Composed(*s)),
        );
        out
    }

    /// Does this policy's swizzle arithmetic require `num_xcds | h_q`?
    pub fn requires_divisible_heads(&self) -> bool {
        self.spec().assign == HeadAssign::Swizzled
    }
}

impl fmt::Display for Policy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.name())
    }
}

impl FromStr for Policy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "naive_block_first" | "nbf" => Ok(Policy::NaiveBlockFirst),
            "swizzled_block_first" | "sbf" => Ok(Policy::SwizzledBlockFirst),
            "naive_head_first" | "nhf" => Ok(Policy::NaiveHeadFirst),
            "swizzled_head_first" | "shf" => Ok(Policy::SwizzledHeadFirst),
            other => {
                // Dash-joined strings are composed specs; canonicalize so
                // e.g. "swz-head-lin-inherit" parses to SwizzledHeadFirst.
                if other.contains('-') {
                    return MappingSpec::parse(other).map(Policy::from_spec);
                }
                Err(format!(
                    "unknown policy '{other}' (expected one of nbf/sbf/nhf/shf, a full \
                     legacy name like 'swizzled_head_first', or a composed mapping spec \
                     {SPEC_SYNTAX})"
                ))
            }
        }
    }
}

/// GEMM-style chiplet swizzle (paper Fig. 3): remaps a linear workgroup id
/// so ids that round-robin to the same XCD become contiguous logically.
///
/// Non-divisible grids (`grid % num_xcd != 0`) are balanced: the first
/// `grid % num_xcd` XCDs own one extra id each (exactly the round-robin
/// dispatcher's share), so the remap stays bijective instead of
/// colliding as the truncating `grid / num_xcd` stride would.
pub fn chiplet_swizzle(wgid: usize, grid: usize, num_xcd: usize) -> usize {
    let wgids_per_xcd = grid / num_xcd;
    let extra = grid % num_xcd; // XCDs [0, extra) own one extra id
    let xcd = wgid % num_xcd;
    let local_wgid = wgid / num_xcd;
    xcd * wgids_per_xcd + xcd.min(extra) + local_wgid
}

/// A mapping instance bound to a grid geometry: decodes dispatch slots to
/// work items in O(1) with no allocation (the simulator hot path).
#[derive(Debug, Clone, Copy)]
pub struct Mapping {
    /// The mapping strategy.
    pub policy: Policy,
    /// Batch size (outermost grid dimension).
    pub batch: usize,
    /// Query heads.
    pub heads: usize,
    /// Block-dimension extent (row/column blocks or KV splits).
    pub blocks: usize,
    /// XCDs the swizzle arithmetic targets.
    pub num_xcds: usize,
    /// Is the block dimension a flash-decode KV split (set by
    /// [`Mapping::for_kernel`] for `DecodeSplitKv` grids)? Only the
    /// [`SplitPlacement`] axis reads this.
    pub is_split_grid: bool,
}

impl Mapping {
    /// A mapping over an explicit grid geometry; rejects degenerate
    /// dimensions and (for swizzled policies) indivisible head counts.
    /// The grid is treated as a prefill grid (`is_split_grid = false`);
    /// use [`Mapping::split_grid`] or [`Mapping::for_kernel`] for
    /// flash-decode split grids.
    pub fn new(
        policy: Policy,
        batch: usize,
        heads: usize,
        blocks: usize,
        num_xcds: usize,
    ) -> Result<Self, String> {
        if batch == 0 || heads == 0 || blocks == 0 || num_xcds == 0 {
            return Err("mapping dimensions must be > 0".into());
        }
        if policy.requires_divisible_heads() && heads % num_xcds != 0 {
            return Err(format!(
                "{policy} requires num_heads ({heads}) divisible by num_xcds ({num_xcds})"
            ));
        }
        Ok(Mapping { policy, batch, heads, blocks, num_xcds, is_split_grid: false })
    }

    /// Mark (or unmark) the block dimension as a flash-decode KV split.
    pub fn split_grid(mut self, is_split_grid: bool) -> Self {
        self.is_split_grid = is_split_grid;
        self
    }

    /// Build a mapping for an attention kernel grid.
    pub fn for_kernel(
        policy: Policy,
        cfg: &AttnConfig,
        kernel: KernelKind,
        num_xcds: usize,
    ) -> Result<Self, String> {
        Self::new(policy, cfg.batch, cfg.h_q, cfg.blocks_for(kernel), num_xcds)
            .map(|m| m.split_grid(matches!(kernel, KernelKind::DecodeSplitKv { .. })))
    }

    /// Total dispatch slots.
    pub fn grid_size(&self) -> usize {
        self.batch * self.heads * self.blocks
    }

    /// Decode dispatch slot -> logical (batch, head, block).
    ///
    /// Mirrors `swizzle.decode` in Python; batch is outermost everywhere
    /// (the paper Fig. 11's `wid_per_batch = wid // BATCH` line is a typo
    /// for `wid % (heads*blocks)` — see DESIGN.md). Routed through the
    /// policy's [`MappingSpec`]: the legacy variants sit on the
    /// `lin`+`inherit` plane where both extra axes are identities, so
    /// their arithmetic is bit-identical to the historical enum match.
    #[inline]
    pub fn decode(&self, slot: usize) -> WorkItem {
        debug_assert!(slot < self.grid_size());
        let spec = self.policy.spec();
        let per_batch = self.heads * self.blocks;
        let z = (slot / per_batch) as u32;
        let r = slot % per_batch;
        // Grouped split placement overrides the traversal on split grids
        // only: all splits of one head contiguous in local slot order.
        let traversal = if self.is_split_grid && spec.split == SplitPlacement::Grouped {
            Traversal::HeadFirst
        } else {
            spec.traversal
        };
        let (h, b) = match (spec.assign, traversal) {
            (HeadAssign::RoundRobin, Traversal::BlockFirst) => (r % self.heads, r / self.heads),
            (HeadAssign::Swizzled, Traversal::BlockFirst) => {
                let hpx = self.heads / self.num_xcds;
                let x = r % self.num_xcds;
                let j = r / self.num_xcds;
                (x * hpx + j % hpx, j / hpx)
            }
            (HeadAssign::RoundRobin, Traversal::HeadFirst) => (r / self.blocks, r % self.blocks),
            (HeadAssign::Swizzled, Traversal::HeadFirst) => {
                let hpx = self.heads / self.num_xcds;
                let x = r % self.num_xcds;
                let j = r / self.num_xcds;
                (x * hpx + j / self.blocks, j % self.blocks)
            }
        };
        // Sawtooth wavefront reordering: odd heads walk blocks in
        // reverse, so consecutive heads meet at a shared block boundary.
        let b = match spec.order {
            BlockOrder::Linear => b,
            BlockOrder::Sawtooth => {
                if h % 2 == 1 {
                    self.blocks - 1 - b
                } else {
                    b
                }
            }
        };
        WorkItem { z, h: h as u32, b: b as u32 }
    }

    /// Decode the whole grid in slot order (tests / `explain`).
    pub fn decode_all(&self) -> Vec<WorkItem> {
        (0..self.grid_size()).map(|s| self.decode(s)).collect()
    }
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeSet;

    use super::*;
    use crate::attn::acc::AccSpread;
    use crate::sched::xcd_of_slot;

    fn spread(policy: Policy, cfg: &AttnConfig, xcds: usize) -> AccSpread {
        let m = Mapping::for_kernel(policy, cfg, KernelKind::Forward, xcds).unwrap();
        AccSpread::measure(
            cfg,
            xcds,
            (0..m.grid_size()).map(|s| (m.decode(s), xcd_of_slot(s, 1, xcds))),
        )
    }

    #[test]
    fn bijective_all_policies() {
        for policy in ALL_POLICIES {
            for (b, h, nb, x) in [(1, 8, 16, 4), (2, 16, 7, 8), (3, 8, 1, 2), (1, 128, 32, 8)] {
                let m = Mapping::new(policy, b, h, nb, x).unwrap();
                let set: BTreeSet<_> =
                    m.decode_all().into_iter().map(|w| (w.z, w.h, w.b)).collect();
                assert_eq!(set.len(), m.grid_size(), "{policy} {b}x{h}x{nb}/{x}");
            }
        }
    }

    #[test]
    fn bijective_full_algebra() {
        // Satellite: every searched MappingSpec decodes slots bijectively
        // onto the work grid — no dropped or duplicated WorkItem — on
        // both prefill and split grids, including non-divisible blocks,
        // odd batches, and single-block grids.
        for policy in Policy::all_canonical() {
            for (b, h, nb, x) in [(1, 8, 16, 4), (2, 16, 7, 8), (3, 8, 1, 2), (1, 128, 32, 8)] {
                for is_split in [false, true] {
                    let m = Mapping::new(policy, b, h, nb, x).unwrap().split_grid(is_split);
                    let grid = m.decode_all();
                    let set: BTreeSet<_> = grid.iter().map(|w| (w.z, w.h, w.b)).collect();
                    assert_eq!(
                        set.len(),
                        m.grid_size(),
                        "{policy} {b}x{h}x{nb}/{x} split={is_split}"
                    );
                    for w in grid {
                        assert!(
                            (w.z as usize) < b && (w.h as usize) < h && (w.b as usize) < nb,
                            "{policy}: out-of-range {w:?}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn legacy_policies_equal_their_algebra_points() {
        // The lin+inherit plane decodes bit-identically whether reached
        // through the named variant or a directly-constructed Composed
        // point (from_spec canonicalizes; Composed bypasses it).
        for legacy in ALL_POLICIES {
            let composed = Policy::Composed(legacy.spec());
            for (b, h, nb, x) in [(1, 8, 16, 4), (2, 16, 7, 8), (1, 64, 4, 8)] {
                for is_split in [false, true] {
                    let ml = Mapping::new(legacy, b, h, nb, x).unwrap().split_grid(is_split);
                    let mc = Mapping::new(composed, b, h, nb, x).unwrap().split_grid(is_split);
                    assert_eq!(ml.decode_all(), mc.decode_all(), "{legacy}");
                }
            }
            assert_eq!(Policy::from_spec(legacy.spec()), legacy);
        }
    }

    #[test]
    fn sawtooth_reverses_odd_heads_only() {
        let lin = Mapping::new(Policy::NaiveHeadFirst, 1, 4, 5, 4).unwrap();
        let saw =
            Mapping::new("rr-head-saw-inherit".parse::<Policy>().unwrap(), 1, 4, 5, 4).unwrap();
        for slot in 0..lin.grid_size() {
            let wl = lin.decode(slot);
            let ws = saw.decode(slot);
            assert_eq!((wl.z, wl.h), (ws.z, ws.h), "sawtooth only permutes blocks");
            if wl.h % 2 == 0 {
                assert_eq!(ws.b, wl.b);
            } else {
                assert_eq!(ws.b, 4 - wl.b);
            }
        }
    }

    #[test]
    fn grouped_split_placement_only_affects_split_grids() {
        let p: Policy = "swz-block-lin-grouped".parse().unwrap();
        let base = Policy::SwizzledBlockFirst;
        // Prefill grid: identical to the inherit/legacy arithmetic.
        let mp = Mapping::new(p, 1, 16, 6, 8).unwrap();
        let mb = Mapping::new(base, 1, 16, 6, 8).unwrap();
        assert_eq!(mp.decode_all(), mb.decode_all());
        // Split grid: traversal flips to head-first — all splits of one
        // head contiguous in an XCD's local slot order.
        let ms = Mapping::new(p, 1, 16, 6, 8).unwrap().split_grid(true);
        let shf_like = Mapping::new(Policy::SwizzledHeadFirst, 1, 16, 6, 8).unwrap();
        assert_eq!(ms.decode_all(), shf_like.decode_all());
    }

    #[test]
    fn for_kernel_marks_split_grids() {
        let cfg = AttnConfig::gqa(1, 64, 8, 65536, 128);
        let m = Mapping::for_kernel(
            Policy::SwizzledHeadFirst,
            &cfg,
            KernelKind::DecodeSplitKv { num_splits: 4 },
            8,
        )
        .unwrap();
        assert!(m.is_split_grid);
        let m =
            Mapping::for_kernel(Policy::SwizzledHeadFirst, &cfg, KernelKind::Forward, 8).unwrap();
        assert!(!m.is_split_grid);
    }

    #[test]
    fn shf_confines_each_head_to_one_xcd() {
        let cfg = AttnConfig::mha(2, 16, 2048, 128);
        let s = spread(Policy::SwizzledHeadFirst, &cfg, 8);
        assert!(s.perfectly_colocated());
    }

    #[test]
    fn sawtooth_preserves_shf_locality() {
        // The order axis permutes blocks *within* a head, so it cannot
        // change which XCD a head lands on.
        let cfg = AttnConfig::mha(2, 16, 2048, 128);
        let s = spread("swz-head-saw-inherit".parse().unwrap(), &cfg, 8);
        assert!(s.perfectly_colocated());
    }

    #[test]
    fn nhf_replicates_each_head_everywhere() {
        let cfg = AttnConfig::mha(1, 8, 8192, 128); // 64 blocks each
        let s = spread(Policy::NaiveHeadFirst, &cfg, 8);
        for (_, n) in &s.xcds_per_acc {
            assert_eq!(*n, 8, "each head striped across all XCDs");
        }
    }

    #[test]
    fn block_first_interleaves_many_accs_per_xcd() {
        let cfg = AttnConfig::mha(1, 128, 8192, 128);
        let nbf = spread(Policy::NaiveBlockFirst, &cfg, 8);
        let shf = spread(Policy::SwizzledHeadFirst, &cfg, 8);
        assert_eq!(nbf.max_accs_per_xcd(), 16); // 128 heads / 8 XCDs
        assert_eq!(shf.max_accs_per_xcd(), 16); // over the whole grid...
        // ...but SHF still perfectly co-locates each ACC:
        assert!(shf.perfectly_colocated());
        assert!(nbf.perfectly_colocated()); // NBF pins heads too (h % X)!
        // The difference is CONCURRENCY, covered by sim tests: NBF's
        // consecutive slots on one XCD alternate heads, SHF's don't.
        let m = Mapping::new(Policy::NaiveBlockFirst, 1, 128, 64, 8).unwrap();
        let h0 = m.decode(0).h;
        let h1 = m.decode(8).h; // next slot on XCD0
        assert_ne!(h0, h1);
        let m = Mapping::new(Policy::SwizzledHeadFirst, 1, 128, 64, 8).unwrap();
        assert_eq!(m.decode(0).h, m.decode(8).h);
    }

    #[test]
    fn sbf_gqa_pins_groups_when_groups_match_xcds() {
        // Paper Sec. 4.4: H_K == num XCDs makes SBF co-locate perfectly.
        let cfg = AttnConfig::gqa(1, 64, 8, 8192, 128);
        let s = spread(Policy::SwizzledBlockFirst, &cfg, 8);
        assert!(s.perfectly_colocated());
        assert_eq!(s.max_accs_per_xcd(), 1);
        // NBF spreads each group everywhere instead.
        let s = spread(Policy::NaiveBlockFirst, &cfg, 8);
        assert!(!s.perfectly_colocated());
    }

    #[test]
    fn decode_grid_shf_confines_head_splits_to_one_xcd() {
        // Split-KV decode: the block dimension is the KV split. SHF must
        // keep every split of one head's KV stream on a single XCD
        // (chunk = 1) so a head's partials never cross L2 domains.
        let cfg = AttnConfig::gqa(2, 64, 8, 65536, 128);
        for num_splits in [2usize, 4, 8] {
            let kernel = KernelKind::DecodeSplitKv { num_splits };
            let m = Mapping::for_kernel(Policy::SwizzledHeadFirst, &cfg, kernel, 8).unwrap();
            assert_eq!(m.blocks, num_splits);
            let s = AccSpread::measure(
                &cfg,
                8,
                (0..m.grid_size()).map(|s| (m.decode(s), xcd_of_slot(s, 1, 8))),
            );
            assert!(s.perfectly_colocated(), "num_splits={num_splits}");
        }
    }

    #[test]
    fn decode_grid_nhf_replicates_group_streams() {
        // The decode anti-invariant the figure quantifies: with splits
        // not a multiple of the XCD count, NHF lands the same (kv head,
        // split) stream on several XCDs.
        let cfg = AttnConfig::gqa(1, 64, 8, 65536, 128);
        let kernel = KernelKind::DecodeSplitKv { num_splits: 2 };
        let m = Mapping::for_kernel(Policy::NaiveHeadFirst, &cfg, kernel, 8).unwrap();
        let s = AccSpread::measure(
            &cfg,
            8,
            (0..m.grid_size()).map(|s| (m.decode(s), xcd_of_slot(s, 1, 8))),
        );
        assert!(!s.perfectly_colocated());
        // Python cross-check: every (batch, kv head) lands on 8 XCDs
        // (4 per split — see python/tests/test_swizzle.py).
        for (_, n) in &s.xcds_per_acc {
            assert_eq!(*n, 8);
        }
    }

    #[test]
    fn chiplet_swizzle_fig3() {
        let remapped: Vec<usize> = (0..16).map(|w| chiplet_swizzle(w, 16, 4)).collect();
        let mut sorted = remapped.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..16).collect::<Vec<_>>());
        assert_eq!(
            [remapped[0], remapped[4], remapped[8], remapped[12]],
            [0, 1, 2, 3]
        );
        assert_eq!(
            [remapped[1], remapped[5], remapped[9], remapped[13]],
            [4, 5, 6, 7]
        );
    }

    #[test]
    fn chiplet_swizzle_balanced_on_non_divisible_grids() {
        // Satellite audit: the truncating grid/num_xcd stride used to
        // collide ids on non-divisible grids (e.g. grid=10, X=4 sent
        // wgids 8 and 1 both to logical 2). The balanced remap gives the
        // first grid%X XCDs one extra id — exactly the round-robin
        // dispatcher's share — and stays bijective for every grid.
        for num_xcd in [2usize, 4, 8] {
            for grid in 1..=64 {
                let remapped: Vec<usize> =
                    (0..grid).map(|w| chiplet_swizzle(w, grid, num_xcd)).collect();
                let mut sorted = remapped.clone();
                sorted.sort_unstable();
                assert_eq!(sorted, (0..grid).collect::<Vec<_>>(), "grid={grid} X={num_xcd}");
                // Each XCD's ids stay contiguous and in dispatch order.
                for x in 0..num_xcd.min(grid) {
                    let mine: Vec<usize> = (x..grid)
                        .step_by(num_xcd)
                        .map(|w| chiplet_swizzle(w, grid, num_xcd))
                        .collect();
                    for pair in mine.windows(2) {
                        assert_eq!(pair[1], pair[0] + 1, "grid={grid} X={num_xcd} xcd={x}");
                    }
                }
            }
        }
    }

    #[test]
    fn indivisible_heads_rejected_for_swizzled() {
        assert!(Mapping::new(Policy::SwizzledHeadFirst, 1, 6, 4, 8).is_err());
        assert!(Mapping::new(Policy::SwizzledBlockFirst, 1, 6, 4, 8).is_err());
        assert!(Mapping::new(Policy::NaiveHeadFirst, 1, 6, 4, 8).is_ok());
        assert!(Mapping::new(Policy::NaiveBlockFirst, 1, 6, 4, 8).is_ok());
        // The swz axis carries the same constraint for composed points.
        let p: Policy = "swz-head-saw-inherit".parse().unwrap();
        assert!(Mapping::new(p, 1, 6, 4, 8).is_err());
        let p: Policy = "rr-head-saw-inherit".parse().unwrap();
        assert!(Mapping::new(p, 1, 6, 4, 8).is_ok());
    }

    #[test]
    fn policy_parsing() {
        assert_eq!("shf".parse::<Policy>().unwrap(), Policy::SwizzledHeadFirst);
        assert_eq!(
            "naive_block_first".parse::<Policy>().unwrap(),
            Policy::NaiveBlockFirst
        );
        assert!("bogus".parse::<Policy>().is_err());
        for p in ALL_POLICIES {
            assert_eq!(p.name().parse::<Policy>().unwrap(), p);
        }
    }

    #[test]
    fn composed_spec_parsing_round_trips_and_canonicalizes() {
        // Every canonical point (legacy + composed) round-trips through
        // its name; legacy-plane spec strings canonicalize onto the
        // named variants rather than creating shadow Composed points.
        for p in Policy::all_canonical() {
            assert_eq!(p.name().parse::<Policy>().unwrap(), p, "{p}");
        }
        assert_eq!(
            "swz-head-lin-inherit".parse::<Policy>().unwrap(),
            Policy::SwizzledHeadFirst
        );
        assert_eq!(
            "rr-block-lin-inherit".parse::<Policy>().unwrap(),
            Policy::NaiveBlockFirst
        );
        let err = "zzz".parse::<Policy>().unwrap_err();
        assert!(err.contains("nbf/sbf/nhf/shf"), "{err}");
        assert!(err.contains("swz-head-saw-inherit"), "{err}");
        let err = "swz-head-zig-inherit".parse::<Policy>().unwrap_err();
        assert!(err.contains("lin|saw"), "{err}");
    }
}
