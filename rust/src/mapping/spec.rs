//! The composable mapping algebra: three orthogonal axes that span (and
//! extend) the paper's four policies.
//!
//! Every mapping the simulator can schedule is a point
//! `assign × traversal × order × split`:
//!
//! * [`HeadAssign`] — *where* heads land: round-robin dispatch order
//!   (`rr`, the paper's "naive" policies) or chiplet-swizzled so each
//!   XCD owns a contiguous head group (`swz`, paper Fig. 3).
//! * [`Traversal`] — *what varies fastest* between consecutive slots of
//!   an XCD: the head (`block`-first, paper Figs. 7-8) or the block
//!   (`head`-first, Figs. 9-11).
//! * [`BlockOrder`] — *intra-head block order*: `lin`ear ascending, or
//!   `saw`tooth wavefront reordering (odd heads walk their blocks in
//!   reverse), so consecutive heads on one XCD meet at a shared block
//!   boundary and re-hit the tiles the previous head just touched.
//! * [`SplitPlacement`] — how flash-decode KV splits land relative to
//!   head homes: `inherit` the traversal axis unchanged, or `grouped`,
//!   which forces head-first traversal on split grids only (all splits
//!   of one head contiguous) while leaving prefill grids untouched.
//!
//! The four legacy [`super::Policy`] variants are the `lin` + `inherit`
//! plane of the space; [`super::Policy::from_spec`] canonicalizes those
//! points back onto the named variants so the algebra stays
//! byte-for-byte compatible with the historical enum (golden-pinned in
//! `mapping/golden.rs` and `tests/mapping_algebra.rs`). Mirrored in
//! `python/compile/kernels/swizzle.py`.

use std::fmt;

/// Head-assignment axis: round-robin (naive) vs chiplet-swizzled.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HeadAssign {
    /// Dispatch order = logical order; the round-robin dispatcher
    /// stripes consecutive logical ids across XCDs (paper "naive").
    RoundRobin,
    /// Chiplet swizzle: each XCD owns a contiguous head group
    /// (paper Fig. 3 / "swizzled"). Requires `num_xcds | h_q`.
    Swizzled,
}

/// Traversal axis: which grid dimension varies fastest per XCD.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Traversal {
    /// Block-first: consecutive slots advance the head (Figs. 7-8).
    BlockFirst,
    /// Head-first: consecutive slots advance the block (Figs. 9-11).
    HeadFirst,
}

/// Intra-head block-order axis (the first axis beyond the paper).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BlockOrder {
    /// Blocks in ascending order — the paper's (only) order.
    Linear,
    /// Sawtooth wavefront reordering: odd heads walk their blocks
    /// descending (`b_eff = blocks-1-b`), so back-to-back heads on one
    /// XCD meet at a shared block boundary (boustrophedon; GB10-style
    /// wavefront remap). Bijective per head for any block count.
    Sawtooth,
}

/// Split-placement axis: how DecodeSplitKv splits land vs head homes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SplitPlacement {
    /// Split grids reuse the traversal axis verbatim (the historical
    /// behavior: splits reinterpret the block dimension).
    Inherit,
    /// Force head-first traversal on split grids only: all splits of
    /// one head are contiguous in local slot order even when the
    /// prefill traversal is block-first. Prefill grids are untouched.
    Grouped,
}

impl HeadAssign {
    /// Spec-string token (`rr` / `swz`).
    pub fn token(&self) -> &'static str {
        match self {
            HeadAssign::RoundRobin => "rr",
            HeadAssign::Swizzled => "swz",
        }
    }
}

impl Traversal {
    /// Spec-string token (`block` / `head`).
    pub fn token(&self) -> &'static str {
        match self {
            Traversal::BlockFirst => "block",
            Traversal::HeadFirst => "head",
        }
    }
}

impl BlockOrder {
    /// Spec-string token (`lin` / `saw`).
    pub fn token(&self) -> &'static str {
        match self {
            BlockOrder::Linear => "lin",
            BlockOrder::Sawtooth => "saw",
        }
    }
}

impl SplitPlacement {
    /// Spec-string token (`inherit` / `grouped`).
    pub fn token(&self) -> &'static str {
        match self {
            SplitPlacement::Inherit => "inherit",
            SplitPlacement::Grouped => "grouped",
        }
    }
}

/// One point in the mapping algebra; see the module docs for the axes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MappingSpec {
    /// Head-assignment axis.
    pub assign: HeadAssign,
    /// Traversal axis.
    pub traversal: Traversal,
    /// Intra-head block-order axis.
    pub order: BlockOrder,
    /// Flash-decode split-placement axis.
    pub split: SplitPlacement,
}

/// The composed-spec string syntax, quoted by parse errors and docs.
pub const SPEC_SYNTAX: &str =
    "<rr|swz>-<block|head>-<lin|saw>-<inherit|grouped> (e.g. 'swz-head-saw-inherit')";

/// All 16 points of the algebra, in deterministic enumeration order
/// (assign, then traversal, then order, then split — each axis in
/// declaration order). The `lin`+`inherit` plane (4 points) is the
/// legacy [`super::Policy`] enum.
pub const ALL_SPECS: [MappingSpec; 16] = build_all_specs();

const fn build_all_specs() -> [MappingSpec; 16] {
    const ASSIGNS: [HeadAssign; 2] = [HeadAssign::RoundRobin, HeadAssign::Swizzled];
    const TRAVERSALS: [Traversal; 2] = [Traversal::BlockFirst, Traversal::HeadFirst];
    const ORDERS: [BlockOrder; 2] = [BlockOrder::Linear, BlockOrder::Sawtooth];
    const SPLITS: [SplitPlacement; 2] = [SplitPlacement::Inherit, SplitPlacement::Grouped];
    let mut out = [MappingSpec {
        assign: HeadAssign::RoundRobin,
        traversal: Traversal::BlockFirst,
        order: BlockOrder::Linear,
        split: SplitPlacement::Inherit,
    }; 16];
    let mut i = 0;
    while i < 16 {
        out[i] = MappingSpec {
            assign: ASSIGNS[i / 8],
            traversal: TRAVERSALS[(i / 4) % 2],
            order: ORDERS[(i / 2) % 2],
            split: SPLITS[i % 2],
        };
        i += 1;
    }
    out
}

impl MappingSpec {
    /// Construct a spec from its four axes.
    pub const fn new(
        assign: HeadAssign,
        traversal: Traversal,
        order: BlockOrder,
        split: SplitPlacement,
    ) -> Self {
        MappingSpec { assign, traversal, order, split }
    }

    /// Stable dash-joined identifier, e.g. `swz-head-saw-inherit`.
    /// Round-trips through [`MappingSpec::parse`].
    pub fn name(&self) -> String {
        format!(
            "{}-{}-{}-{}",
            self.assign.token(),
            self.traversal.token(),
            self.order.token(),
            self.split.token()
        )
    }

    /// Is this spec on the legacy plane (`lin` order, `inherit` split)?
    pub fn is_legacy_point(&self) -> bool {
        self.order == BlockOrder::Linear && self.split == SplitPlacement::Inherit
    }

    /// Parse the dash-joined spec syntax ([`SPEC_SYNTAX`]).
    pub fn parse(s: &str) -> Result<Self, String> {
        let parts: Vec<&str> = s.split('-').collect();
        if parts.len() != 4 {
            return Err(format!(
                "composed mapping spec '{s}' must have 4 dash-joined axes: {SPEC_SYNTAX}"
            ));
        }
        let assign = match parts[0] {
            "rr" => HeadAssign::RoundRobin,
            "swz" => HeadAssign::Swizzled,
            other => {
                return Err(format!(
                    "unknown head-assign '{other}' in spec '{s}' (expected rr|swz)"
                ))
            }
        };
        let traversal = match parts[1] {
            "block" => Traversal::BlockFirst,
            "head" => Traversal::HeadFirst,
            other => {
                return Err(format!(
                    "unknown traversal '{other}' in spec '{s}' (expected block|head)"
                ))
            }
        };
        let order = match parts[2] {
            "lin" => BlockOrder::Linear,
            "saw" => BlockOrder::Sawtooth,
            other => {
                return Err(format!(
                    "unknown block order '{other}' in spec '{s}' (expected lin|saw)"
                ))
            }
        };
        let split = match parts[3] {
            "inherit" => SplitPlacement::Inherit,
            "grouped" => SplitPlacement::Grouped,
            other => {
                return Err(format!(
                    "unknown split placement '{other}' in spec '{s}' (expected inherit|grouped)"
                ))
            }
        };
        Ok(MappingSpec { assign, traversal, order, split })
    }
}

impl fmt::Display for MappingSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sixteen_distinct_points() {
        let names: std::collections::BTreeSet<String> =
            ALL_SPECS.iter().map(|s| s.name()).collect();
        assert_eq!(names.len(), 16);
        // Exactly 4 points sit on the legacy plane.
        assert_eq!(ALL_SPECS.iter().filter(|s| s.is_legacy_point()).count(), 4);
    }

    #[test]
    fn spec_names_round_trip() {
        for spec in ALL_SPECS {
            assert_eq!(MappingSpec::parse(&spec.name()).unwrap(), spec);
        }
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "swz-head-saw",             // missing axis
            "swz-head-saw-inherit-x",   // extra axis
            "zzz-head-saw-inherit",     // bad assign
            "swz-diag-saw-inherit",     // bad traversal
            "swz-head-zig-inherit",     // bad order
            "swz-head-saw-scattered",   // bad split
        ] {
            assert!(MappingSpec::parse(bad).is_err(), "{bad} should not parse");
        }
    }
}
