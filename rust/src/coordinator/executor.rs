//! Step executors: how the continuous-batching decode serving loop
//! prices one step's kernel launches (docs/CLUSTER.md §4).
//!
//! [`super::service::serve_decode`] historically called the simulation
//! driver directly, which welded the loop to exactly one device. The loop
//! is now generic over a [`StepExecutor`]:
//!
//! * [`SingleDeviceExecutor`] preserves the historical behavior
//!   *byte-for-byte* — same jobs, same driver calls, same
//!   floating-point accumulation order (pinned by
//!   `tests/cluster_serving.rs` against the tp = 1 cluster path and by
//!   `tests/serving_loop.rs` across worker counts).
//! * [`ClusterExecutor`] fans every launch across the shards of a
//!   [`ShardPlan`]: each device runs the shard-local geometry on its own
//!   topology (level-2 NUMA mapping unchanged within the shard), the
//!   step advances by the *slowest* device
//!   ([`crate::sim::merge_parallel`]), and an interconnect all-gather of
//!   the sharded outputs is charged on top
//!   ([`ClusterTopology::all_gather_sec`]).
//!
//! Both executors consult the advisor per distinct (batch, KV-bucket)
//! geometry and price launches through the shared driver's report cache;
//! the cluster executor advises on the *shard-local* geometry, so the
//! split count fills one device's workgroup slots, not the cluster's.

use std::collections::BTreeMap;

use crate::cluster::{ClusterTopology, ShardPlan};
use crate::driver::{SimDriver, SimJob};
use crate::mapping::{Mapping, Policy};
use crate::sched::xcd_of_slot;
use crate::sim::{merge_parallel, SimConfig};
use crate::topology::Topology;

use super::advisor;
use super::batcher::PrefillChunk;
use super::service::ServeConfig;

/// Steady-state sample generations for prefill-kernel pricing (matches
/// the figure sweeps' sampling depth).
const GENERATIONS: usize = 2;

/// Prices the kernel launches of one decode-serving step. The serving
/// loop is generic over this trait; implementations own the advisor
/// state (split-count advice per geometry) and the launch accounting.
pub trait StepExecutor {
    /// The mapping policy every launch this executor prices is mapped
    /// with — the one the resulting [`super::ServeStats`] is stamped
    /// with, so a run can never be labeled with a policy it didn't use.
    fn policy(&self) -> Policy;

    /// Price the prefill kernels of this step's newly admitted sessions
    /// (prompt lengths in admission order). Returns one duration in
    /// seconds per session, in the same order — the loop accumulates
    /// them in order, so implementations control nothing about summation.
    fn prefill_charges(&mut self, prompts: &[usize]) -> Vec<f64>;

    /// Price this step's chunked-prefill launches (docs/SERVING.md §6):
    /// each chunk extends one session's prefilled prompt prefix from
    /// `start` to `end` tokens and is priced as the chunk's row fraction
    /// of the forward kernel at the *prefix* geometry — the chunk's Q
    /// row blocks each stream the whole prefilled prefix (FA2's
    /// row-block work partitioning), so a full-prompt chunk degenerates
    /// to exactly the monolithic [`Self::prefill_charges`] job. Returns
    /// one duration in seconds per chunk, in the same order.
    fn chunk_charges(&mut self, chunks: &[PrefillChunk]) -> Vec<f64>;

    /// Price this step's decode launches: one `(kv_bucket, batch)` group
    /// per entry, in ascending bucket order. Returns one duration in
    /// seconds per group, in the same order.
    fn decode_charges(&mut self, groups: &[(usize, usize)]) -> Vec<f64>;

    /// Times the advisor has been consulted (== first sightings of a
    /// (batch, KV-bucket) geometry).
    fn consults(&self) -> usize;

    /// Distinct decode geometries launched so far.
    fn distinct_geometries(&self) -> usize;

    /// Aggregate L2 (hits, misses) across every decode launch priced so
    /// far — the serving report's `decode_l2_hit_pct` source.
    fn decode_l2(&self) -> (u64, u64);

    /// NUMA placement score for one newly inserted KV block
    /// (docs/KVCACHE.md): of the deployment's KV heads, how many have
    /// block `block_idx` land in the same XCD this executor's mapping
    /// policy pins the head's *first* block to — `(affine, total)`.
    /// Head-first swizzles keep a head's whole KV stream in one XCD
    /// (100%); Naive Head-first round-robins a head's blocks across
    /// dies (~1/num_xcds). On a cluster the score is taken on the
    /// shard-local geometry of the device that owns each KV head.
    fn kv_block_affinity(&mut self, block_idx: usize) -> (usize, usize);
}

/// Per-KV-head XCD-affinity tables for one device: entry `[k][r]` says
/// whether a KV block at residue `r` (block index mod `num_xcds`) lands
/// in the same XCD as KV head `k`'s block 0. The home XCD comes from
/// decoding a one-batch `num_xcds`-block dispatch grid of the policy
/// and reading each slot's XCD off the dispatcher's round-robin
/// ([`xcd_of_slot`]); a KV head is represented by the first query head
/// of its GQA group (the whole group co-locates under every policy the
/// serve path admits).
fn kv_affinity_tables(policy: Policy, h_q: usize, h_k: usize, topo: &Topology) -> Vec<Vec<bool>> {
    let x = topo.num_xcds;
    let map = Mapping::new(policy, 1, h_q, x, x)
        .expect("serve paths assert policy applicability before pricing");
    let mut home = vec![vec![0u32; x]; h_q];
    for s in 0..map.grid_size() {
        let w = map.decode(s);
        home[w.h as usize][w.b as usize] = xcd_of_slot(s, topo.dispatch_chunk, x);
    }
    let g = h_q / h_k;
    (0..h_k).map(|k| (0..x).map(|r| home[k * g][r] == home[k * g][0]).collect()).collect()
}

/// The advisor/accounting state both executors embed — ONE definition of
/// the per-(batch, KV-bucket) advice memo, the consult counter, and the
/// decode L2 accumulators, so the two pricing paths cannot drift in
/// their bookkeeping semantics.
#[derive(Default)]
struct AdviceState {
    // (batch size, KV bucket) -> advised split count. A miss here IS the
    // "KV crossed a bucket boundary / batch changed" re-advise event; the
    // driver's report cache makes the advisor projections behind it free
    // on repeats (DESIGN.md §8).
    advice: BTreeMap<(usize, usize), usize>,
    consults: usize,
    l2_hits: u64,
    l2_misses: u64,
}

impl AdviceState {
    /// The advised split count for a geometry key, calling `advise`
    /// (and counting a consult) exactly once per distinct key.
    fn splits_for(&mut self, key: (usize, usize), advise: impl FnOnce() -> usize) -> usize {
        match self.advice.get(&key) {
            Some(&s) => s,
            None => {
                self.consults += 1;
                let s = advise();
                self.advice.insert(key, s);
                s
            }
        }
    }

    /// Accumulate one decode launch's L2 statistics.
    fn record_l2(&mut self, hits: u64, misses: u64) {
        self.l2_hits += hits;
        self.l2_misses += misses;
    }
}

/// The historical single-device execution path, factored behind
/// [`StepExecutor`] with byte-identical output.
pub struct SingleDeviceExecutor<'a> {
    driver: &'a SimDriver,
    topo: &'a Topology,
    cfg: &'a ServeConfig,
    policy: Policy,
    state: AdviceState,
    // Lazily built on the first KV-block placement query, so executors
    // for runs without the paged pool never decode the affinity grid.
    kv_aff: Option<Vec<Vec<bool>>>,
}

impl<'a> SingleDeviceExecutor<'a> {
    /// An executor pricing every launch on one device.
    pub fn new(
        driver: &'a SimDriver,
        topo: &'a Topology,
        cfg: &'a ServeConfig,
        policy: Policy,
    ) -> Self {
        SingleDeviceExecutor {
            driver,
            topo,
            cfg,
            policy,
            state: AdviceState::default(),
            kv_aff: None,
        }
    }
}

impl StepExecutor for SingleDeviceExecutor<'_> {
    fn policy(&self) -> Policy {
        self.policy
    }

    fn prefill_charges(&mut self, prompts: &[usize]) -> Vec<f64> {
        let jobs: Vec<SimJob> = prompts
            .iter()
            .map(|&p| {
                let attn = self.cfg.geometry(1, p.clamp(1, self.cfg.kv_cap));
                let sim = SimConfig::sampled(self.policy, self.topo, GENERATIONS);
                SimJob::forward(self.topo, &attn, sim)
            })
            .collect();
        self.driver.run_all(jobs).iter().map(|r| r.est_total_sec).collect()
    }

    fn chunk_charges(&mut self, chunks: &[PrefillChunk]) -> Vec<f64> {
        // One forward job per chunk at the chunk's PREFIX geometry,
        // scaled by the chunk's row fraction: the chunk's Q rows each
        // stream the whole prefilled prefix, so a chunk of (end - start)
        // tokens over an end-token prefix costs that fraction of the
        // prefix kernel. A full-prompt chunk has fraction exactly 1.0 —
        // the identical job and charge as the monolithic path (pinned by
        // the golden-equivalence tests). A chunk entirely past the KV
        // capacity collapses to an empty span: a free no-op, no job at
        // all. Prefix geometries repeat across sessions and steps, so
        // pricing rides the shared report cache.
        let mut jobs = Vec::with_capacity(chunks.len());
        let mut spans = Vec::with_capacity(chunks.len());
        for c in chunks {
            let (start, end) = self.cfg.chunk_span(c);
            spans.push((start, end));
            if start < end {
                let attn = self.cfg.geometry(1, end);
                let sim = SimConfig::sampled(self.policy, self.topo, GENERATIONS);
                jobs.push(SimJob::forward(self.topo, &attn, sim));
            }
        }
        let reports = self.driver.run_all(jobs);
        let mut next = reports.iter();
        spans
            .into_iter()
            .map(|(start, end)| {
                if start == end {
                    return 0.0;
                }
                let r = next.next().expect("one report per non-empty chunk");
                r.est_total_sec * ((end - start) as f64 / end as f64)
            })
            .collect()
    }

    fn decode_charges(&mut self, groups: &[(usize, usize)]) -> Vec<f64> {
        let mut jobs = Vec::with_capacity(groups.len());
        for &(bucket, count) in groups {
            let attn = self.cfg.geometry(count, bucket);
            let (driver, topo) = (self.driver, self.topo);
            let splits = self.state.splits_for((count, bucket), || {
                advisor::advise_decode_with(driver, topo, &attn, None).num_splits.unwrap_or(1)
            });
            jobs.push(SimJob::decode(self.topo, &attn, SimConfig::decode(self.policy, splits)));
        }
        self.driver
            .run_all(jobs)
            .iter()
            .map(|r| {
                self.state.record_l2(r.l2.hits, r.l2.misses);
                r.est_total_sec
            })
            .collect()
    }

    fn consults(&self) -> usize {
        self.state.consults
    }

    fn distinct_geometries(&self) -> usize {
        self.state.advice.len()
    }

    fn decode_l2(&self) -> (u64, u64) {
        (self.state.l2_hits, self.state.l2_misses)
    }

    fn kv_block_affinity(&mut self, block_idx: usize) -> (usize, usize) {
        let (policy, h_q, h_k, topo) = (self.policy, self.cfg.h_q, self.cfg.h_k, self.topo);
        let tables = self.kv_aff.get_or_insert_with(|| kv_affinity_tables(policy, h_q, h_k, topo));
        let affine = tables.iter().filter(|t| t[block_idx % t.len()]).count();
        (affine, tables.len())
    }
}

/// The cluster execution path: every launch fans out across the shard
/// plan's devices, the step advances by the slowest device, and the
/// interconnect all-gather of the sharded outputs is charged on top.
///
/// Device 0 is the *planner*: split-count advice is computed against its
/// topology and applied cluster-wide (every preset builds homogeneous
/// clusters, where this is exact; on a heterogeneous cluster the other
/// devices still price their own kernels on their own topologies, but
/// share device 0's split count — and policy applicability is checked
/// per device by [`super::service::serve_decode_cluster_with`]).
pub struct ClusterExecutor<'a> {
    driver: &'a SimDriver,
    cluster: &'a ClusterTopology,
    plan: &'a ShardPlan,
    cfg: &'a ServeConfig,
    policy: Policy,
    // Advice is keyed like the single-device executor's — per global
    // (batch, KV bucket) — but computed on the shard-LOCAL geometry, so
    // the split count fills ONE device's slots.
    state: AdviceState,
    // Per GLOBAL KV head: the affinity table of its owning device's
    // shard-local mapping (lazy, like the single-device executor's).
    kv_aff: Option<Vec<Vec<bool>>>,
}

impl<'a> ClusterExecutor<'a> {
    /// An executor fanning every launch across `plan.tp` devices of
    /// `cluster`. The plan's TP degree must equal the cluster size:
    /// shards map 1:1 onto devices.
    pub fn new(
        driver: &'a SimDriver,
        cluster: &'a ClusterTopology,
        plan: &'a ShardPlan,
        cfg: &'a ServeConfig,
        policy: Policy,
    ) -> Self {
        cluster.validate().expect("valid cluster topology");
        assert_eq!(
            plan.tp,
            cluster.num_devices(),
            "shard plan tp must equal the cluster's device count"
        );
        ClusterExecutor {
            driver,
            cluster,
            plan,
            cfg,
            policy,
            state: AdviceState::default(),
            kv_aff: None,
        }
    }

    /// The devices' merged launch cost plus the output all-gather for
    /// `tokens` query tokens per device.
    fn fan_out(
        &self,
        jobs: Vec<SimJob>,
        launches: usize,
        tokens: &[usize],
    ) -> Vec<(f64, u64, u64)> {
        debug_assert_eq!(jobs.len(), launches * self.cluster.num_devices());
        let reports = self.driver.run_all(jobs);
        let base = self.cfg.base_geometry();
        reports
            .chunks(self.cluster.num_devices())
            .zip(tokens)
            .map(|(chunk, &toks)| {
                let merged = merge_parallel(chunk);
                let gather =
                    self.cluster.all_gather_sec(self.plan.output_bytes_per_device(&base, toks));
                (merged.est_total_sec + gather, merged.l2.hits, merged.l2.misses)
            })
            .collect()
    }
}

impl StepExecutor for ClusterExecutor<'_> {
    fn policy(&self) -> Policy {
        self.policy
    }

    fn prefill_charges(&mut self, prompts: &[usize]) -> Vec<f64> {
        let n_dev = self.cluster.num_devices();
        let mut jobs = Vec::with_capacity(prompts.len() * n_dev);
        let mut tokens = Vec::with_capacity(prompts.len());
        for &p in prompts {
            let toks = p.clamp(1, self.cfg.kv_cap);
            tokens.push(toks);
            let attn = self.cfg.geometry(1, toks);
            for d in 0..n_dev {
                let sim = SimConfig::sampled(self.policy, self.cluster.device(d), GENERATIONS);
                jobs.push(SimJob::sharded_forward(self.cluster, self.plan, d, &attn, sim));
            }
        }
        self.fan_out(jobs, prompts.len(), &tokens).into_iter().map(|(sec, _, _)| sec).collect()
    }

    fn chunk_charges(&mut self, chunks: &[PrefillChunk]) -> Vec<f64> {
        // The single-device row-fraction pricing, fanned across the
        // shard plan: each device runs the chunk's shard-local prefix
        // kernel, the step advances by the slowest device scaled to the
        // chunk's row fraction, and the all-gather moves only the
        // chunk's own output rows (one gather latency per chunk launch —
        // chunking is not free on an interconnect). A full-prompt chunk
        // reproduces the monolithic sharded charge bit-for-bit, and an
        // empty-span chunk (entirely past the KV capacity) is the same
        // free no-op as on the single-device path — no jobs, no gather.
        let n_dev = self.cluster.num_devices();
        let base = self.cfg.base_geometry();
        let mut jobs = Vec::with_capacity(chunks.len() * n_dev);
        let mut spans = Vec::with_capacity(chunks.len());
        for c in chunks {
            let (start, end) = self.cfg.chunk_span(c);
            spans.push((start, end));
            if start < end {
                let attn = self.cfg.geometry(1, end);
                for d in 0..n_dev {
                    let sim =
                        SimConfig::sampled(self.policy, self.cluster.device(d), GENERATIONS);
                    jobs.push(SimJob::sharded_forward(self.cluster, self.plan, d, &attn, sim));
                }
            }
        }
        let reports = self.driver.run_all(jobs);
        let mut offset = 0;
        let mut out = Vec::with_capacity(spans.len());
        for (start, end) in spans {
            if start == end {
                out.push(0.0);
                continue;
            }
            let merged = merge_parallel(&reports[offset..offset + n_dev]);
            offset += n_dev;
            let gather = self
                .cluster
                .all_gather_sec(self.plan.output_bytes_per_device(&base, end - start));
            out.push(merged.est_total_sec * ((end - start) as f64 / end as f64) + gather);
        }
        out
    }

    fn decode_charges(&mut self, groups: &[(usize, usize)]) -> Vec<f64> {
        let n_dev = self.cluster.num_devices();
        let mut jobs = Vec::with_capacity(groups.len() * n_dev);
        let mut tokens = Vec::with_capacity(groups.len());
        for &(bucket, count) in groups {
            let attn = self.cfg.geometry(count, bucket);
            let (driver, cluster, plan) = (self.driver, self.cluster, self.plan);
            let splits = self.state.splits_for((count, bucket), || {
                let local = plan.local_attn(&attn);
                advisor::advise_decode_with(driver, cluster.device(0), &local, None)
                    .num_splits
                    .unwrap_or(1)
            });
            // One token emitted per active session in the group: the
            // all-gather moves `count` sharded output rows.
            tokens.push(count);
            for d in 0..n_dev {
                jobs.push(SimJob::sharded_decode(
                    self.cluster,
                    self.plan,
                    d,
                    &attn,
                    SimConfig::decode(self.policy, splits),
                ));
            }
        }
        self.fan_out(jobs, groups.len(), &tokens)
            .into_iter()
            .map(|(sec, hits, misses)| {
                self.state.record_l2(hits, misses);
                sec
            })
            .collect()
    }

    fn consults(&self) -> usize {
        self.state.consults
    }

    fn distinct_geometries(&self) -> usize {
        self.state.advice.len()
    }

    fn decode_l2(&self) -> (u64, u64) {
        (self.state.l2_hits, self.state.l2_misses)
    }

    fn kv_block_affinity(&mut self, block_idx: usize) -> (usize, usize) {
        let (policy, plan, cluster) = (self.policy, self.plan, self.cluster);
        let local = plan.local_attn(&self.cfg.base_geometry());
        let tables = self.kv_aff.get_or_insert_with(|| {
            // Each global KV head is scored on ITS device's shard-local
            // mapping: under `ShardPlan` the block already lands on the
            // owning device (level-1 NUMA); the table decides the XCD
            // within it (level 2).
            (0..plan.h_k)
                .map(|k| {
                    let topo = cluster.device(plan.device_of_kv_head(k));
                    let device_tables = kv_affinity_tables(policy, local.h_q, local.h_k, topo);
                    device_tables[plan.kv_local_index(k)].clone()
                })
                .collect()
        });
        let affine = tables.iter().filter(|t| t[block_idx % t.len()]).count();
        (affine, tables.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ShardStrategy;
    use crate::topology::presets;

    fn fast_topo() -> Topology {
        Topology {
            cus_per_xcd: 8,
            l2_bytes_per_xcd: 1024 * 1024,
            hbm_bytes_per_sec: 1.1e12,
            ..presets::mi300x()
        }
    }

    fn tiny_serve() -> ServeConfig {
        ServeConfig {
            h_q: 16,
            h_k: 8,
            d_head: 64,
            kv_cap: 8192,
            kv_bucket: 2048,
            ..ServeConfig::default()
        }
    }

    #[test]
    fn single_and_tp1_cluster_charges_are_bit_identical() {
        let driver = SimDriver::new(2);
        let topo = fast_topo();
        let cfg = tiny_serve();
        let cluster = ClusterTopology::node_of(&topo, 1);
        let plan = ShardPlan::new(&cfg.base_geometry(), 1, ShardStrategy::Contiguous).unwrap();
        let mut single = SingleDeviceExecutor::new(&driver, &topo, &cfg, Policy::SwizzledHeadFirst);
        let mut tp1 =
            ClusterExecutor::new(&driver, &cluster, &plan, &cfg, Policy::SwizzledHeadFirst);

        let a = single.prefill_charges(&[2048, 4000]);
        let b = tp1.prefill_charges(&[2048, 4000]);
        assert_eq!(a.len(), 2);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "tp=1 prefill charge diverged");
        }

        let groups = [(2048usize, 2usize), (4096, 1)];
        let a = single.decode_charges(&groups);
        let b = tp1.decode_charges(&groups);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "tp=1 decode charge diverged");
        }
        assert_eq!(single.consults(), 2);
        assert_eq!(tp1.consults(), 2);
        assert_eq!(single.distinct_geometries(), tp1.distinct_geometries());
        assert_eq!(single.decode_l2(), tp1.decode_l2());
    }

    #[test]
    fn cluster_executor_shards_shrink_device_work() {
        let driver = SimDriver::new(2);
        let topo = fast_topo();
        let cfg = tiny_serve();
        let base = cfg.base_geometry();
        let plan2 = ShardPlan::new(&base, 2, ShardStrategy::Contiguous).unwrap();
        let cluster2 = ClusterTopology::node_of(&topo, 2);
        let mut tp1 = SingleDeviceExecutor::new(&driver, &topo, &cfg, Policy::SwizzledHeadFirst);
        let mut tp2 =
            ClusterExecutor::new(&driver, &cluster2, &plan2, &cfg, Policy::SwizzledHeadFirst);
        // A long prefill: the sharded kernel runs on half the heads per
        // device, so even with the all-gather charge the step is shorter.
        let full = tp1.prefill_charges(&[8192])[0];
        let sharded = tp2.prefill_charges(&[8192])[0];
        assert!(
            sharded < full,
            "tp=2 prefill ({sharded:.3e} s) should beat tp=1 ({full:.3e} s)"
        );
        // Decode charges exist and both shards' L2 traffic is accounted.
        let t = tp2.decode_charges(&[(8192, 2)]);
        assert_eq!(t.len(), 1);
        assert!(t[0] > 0.0);
        let (h, m) = tp2.decode_l2();
        assert!(h + m > 0, "decode L2 accounting is live");
        assert_eq!(tp2.consults(), 1);
    }

    #[test]
    fn full_prompt_chunk_prices_like_monolithic_prefill() {
        // The degenerate contract the golden-equivalence tests build on:
        // a single chunk covering the whole prompt is the SAME forward
        // job at row fraction 1.0, so its charge is bit-identical to the
        // monolithic prefill charge — on both executors.
        let driver = SimDriver::new(2);
        let topo = fast_topo();
        let cfg = tiny_serve();
        let mut single = SingleDeviceExecutor::new(&driver, &topo, &cfg, Policy::SwizzledHeadFirst);
        let mono = single.prefill_charges(&[2048]);
        let whole = single.chunk_charges(&[PrefillChunk { id: 0, start: 0, end: 2048 }]);
        assert_eq!(mono[0].to_bits(), whole[0].to_bits(), "full-prompt chunk diverged");

        // Streaming the same prompt in two chunks prices the two
        // rectangles (rows x prefix), which undercut the full square.
        let halves = single.chunk_charges(&[
            PrefillChunk { id: 1, start: 0, end: 1024 },
            PrefillChunk { id: 1, start: 1024, end: 2048 },
        ]);
        assert!(halves.iter().all(|&t| t > 0.0));
        let sum: f64 = halves.iter().sum();
        assert!(sum < mono[0], "chunked {sum:.3e} s >= monolithic {:.3e} s", mono[0]);

        // A chunk entirely past the KV capacity is a free no-op.
        let beyond = single.chunk_charges(&[PrefillChunk {
            id: 2,
            start: cfg.kv_cap,
            end: cfg.kv_cap + 512,
        }]);
        assert_eq!(beyond[0], 0.0);

        let cluster = ClusterTopology::node_of(&topo, 2);
        let plan = ShardPlan::new(&cfg.base_geometry(), 2, ShardStrategy::Contiguous).unwrap();
        let mut tp2 =
            ClusterExecutor::new(&driver, &cluster, &plan, &cfg, Policy::SwizzledHeadFirst);
        let mono = tp2.prefill_charges(&[2048]);
        let mixed = tp2.chunk_charges(&[
            PrefillChunk { id: 0, start: 0, end: 2048 },
            // Entirely past the KV capacity: free on the cluster too —
            // no shard jobs, and crucially no phantom all-gather latency.
            PrefillChunk { id: 1, start: cfg.kv_cap, end: cfg.kv_cap + 512 },
        ]);
        assert_eq!(mono[0].to_bits(), mixed[0].to_bits(), "tp=2 full-prompt chunk diverged");
        assert_eq!(mixed[1], 0.0, "beyond-capacity chunk must be free on a cluster");
    }

    #[test]
    fn kv_block_affinity_separates_swizzled_from_naive() {
        let driver = SimDriver::new(1);
        let topo = fast_topo();
        let cfg = tiny_serve();
        let x = topo.num_xcds;
        // SHF pins each head's whole KV stream to one XCD: every block
        // index is affine for every KV head.
        let mut shf = SingleDeviceExecutor::new(&driver, &topo, &cfg, Policy::SwizzledHeadFirst);
        for j in 0..2 * x {
            assert_eq!(shf.kv_block_affinity(j), (cfg.h_k, cfg.h_k), "block {j}");
        }
        // NHF round-robins a head's blocks across dies: only block
        // residue 0 shares the head's home XCD.
        let mut nhf = SingleDeviceExecutor::new(&driver, &topo, &cfg, Policy::NaiveHeadFirst);
        for j in 0..2 * x {
            let expect = if j % x == 0 { cfg.h_k } else { 0 };
            assert_eq!(nhf.kv_block_affinity(j), (expect, cfg.h_k), "block {j}");
        }
        // On a cluster the score runs on the shard-local geometry —
        // SHF's full affinity survives sharding.
        let cluster = ClusterTopology::node_of(&topo, 2);
        let plan = ShardPlan::new(&cfg.base_geometry(), 2, ShardStrategy::Contiguous).unwrap();
        let mut tp2 =
            ClusterExecutor::new(&driver, &cluster, &plan, &cfg, Policy::SwizzledHeadFirst);
        for j in 0..2 * x {
            assert_eq!(tp2.kv_block_affinity(j), (cfg.h_k, cfg.h_k), "tp=2 block {j}");
        }
    }

    #[test]
    #[should_panic(expected = "device count")]
    fn cluster_executor_rejects_tp_device_mismatch() {
        let driver = SimDriver::new(1);
        let topo = fast_topo();
        let cfg = tiny_serve();
        let cluster = ClusterTopology::node_of(&topo, 4);
        let plan = ShardPlan::new(&cfg.base_geometry(), 2, ShardStrategy::Contiguous).unwrap();
        let _ = ClusterExecutor::new(&driver, &cluster, &plan, &cfg, Policy::SwizzledHeadFirst);
    }
}
