//! NUMA mapping advisor: given an attention geometry, recommend the
//! workgroup-mapping policy an MI300X deployment should configure and
//! back it with a quick simulator projection. This is how the paper's
//! contribution surfaces as a first-class serving feature: the
//! coordinator doesn't just run attention, it knows *how* the kernel
//! should be scheduled for the shapes it is serving.
//!
//! For the decode regime (one query row per request) the advisor also
//! picks the KV split count: [`pick_num_splits`] lifts the split-KV grid
//! until it fills the device's workgroup slots, and [`advise_decode`]
//! projects the mapping policies over the resulting two-phase pass.
//!
//! The decode serving loop ([`super::serve_decode`]) is the advisor's
//! in-the-loop consumer: it re-consults [`advise_decode`] whenever a
//! session's growing KV cache crosses a bucket boundary (or the active
//! batch changes size), and because the projections run through the
//! shared driver's report cache, re-advising a geometry the process has
//! already seen costs zero engine runs (DESIGN.md §8).

use crate::attn::AttnConfig;
use crate::driver::{self, SimDriver, SimJob};
use crate::mapping::{Policy, ALL_POLICIES};
use crate::sim::SimConfig;
use crate::topology::Topology;

/// Advisor output for one attention geometry.
#[derive(Debug, Clone)]
pub struct Advice {
    /// The mapping policy the deployment should configure.
    pub recommended: Policy,
    /// (policy, projected aggregate L2 hit %, projected relative perf).
    pub projections: Vec<(Policy, f64, f64)>,
    /// True when the recommendation is degenerate (single XCD or fewer
    /// heads than XCDs — everything performs the same).
    pub indifferent: bool,
    /// For decode advice: the KV split count the projections used
    /// (chosen by [`pick_num_splits`] unless the caller fixed it).
    /// `None` for prefill/backward advice.
    pub num_splits: Option<usize>,
}

/// Simulate all applicable policies and rank them, using the process-wide
/// shared driver: the four projections fan out across its workers, and a
/// repeated call on the same (topology, geometry) is answered entirely
/// from the report cache — zero new engine runs.
pub fn advise(topo: &Topology, cfg: &AttnConfig) -> Advice {
    advise_with(driver::global(), topo, cfg)
}

/// [`advise`] through an explicit driver (tests and embedders that want
/// their own cache or thread budget).
pub fn advise_with(driver: &SimDriver, topo: &Topology, cfg: &AttnConfig) -> Advice {
    let policies = applicable_policies(topo, cfg);
    let jobs: Vec<SimJob> = policies
        .iter()
        .map(|&p| SimJob::forward(topo, cfg, SimConfig::sampled(p, topo, 2)))
        .collect();
    let reports = driver.run_all(jobs);
    rank(topo, &policies, &reports, None)
}

/// Decode advisor: pick a KV split count for the geometry (unless the
/// caller fixes one), project all applicable policies over the two-phase
/// split-KV pass, and recommend. Uses the process-wide shared driver, so
/// repeated decode advice on a known geometry is served from the report
/// cache like [`advise`].
pub fn advise_decode(topo: &Topology, cfg: &AttnConfig, num_splits: Option<usize>) -> Advice {
    advise_decode_with(driver::global(), topo, cfg, num_splits)
}

/// [`advise_decode`] through an explicit driver.
pub fn advise_decode_with(
    driver: &SimDriver,
    topo: &Topology,
    cfg: &AttnConfig,
    num_splits: Option<usize>,
) -> Advice {
    // Caller-fixed split counts obey the same bound pick_num_splits
    // applies to its own choice.
    let splits = cfg.clamp_num_splits(num_splits.unwrap_or_else(|| pick_num_splits(topo, cfg)));
    let policies = applicable_policies(topo, cfg);
    let jobs: Vec<SimJob> = policies
        .iter()
        .map(|&p| SimJob::decode(topo, cfg, SimConfig::decode(p, splits)))
        .collect();
    let reports = driver.run_all(jobs);
    rank(topo, &policies, &reports, Some(splits))
}

/// KV split count for a decode geometry: the smallest power of two that
/// lifts the phase-1 grid (batch × heads × splits) to at least the
/// device's workgroup slot count — one query row per request leaves most
/// XCDs idle otherwise — capped so every split still owns at least one
/// KV column block.
pub fn pick_num_splits(topo: &Topology, cfg: &AttnConfig) -> usize {
    let target = topo.total_wg_slots();
    let base = (cfg.batch * cfg.h_q).max(1);
    let max_splits = cfg.num_col_blocks().max(1);
    let mut splits = 1usize;
    while base * splits < target && splits < max_splits {
        splits *= 2;
    }
    cfg.clamp_num_splits(splits)
}

/// Policies whose swizzle arithmetic is applicable to this geometry —
/// the one place the divisible-heads rule lives (the CLI and the
/// advisor must agree on which policies run).
pub fn applicable_policies(topo: &Topology, cfg: &AttnConfig) -> Vec<Policy> {
    ALL_POLICIES
        .iter()
        .copied()
        .filter(|p| !(p.requires_divisible_heads() && cfg.h_q % topo.num_xcds != 0))
        .collect()
}

/// Rank projections by estimated time with a 2% noise band (steady-state
/// sampling jitter); within the band prefer lower HBM traffic —
/// replication is wasted power and bandwidth headroom even when
/// latency-hidden.
fn rank(
    topo: &Topology,
    policies: &[Policy],
    reports: &[crate::sim::SimReport],
    num_splits: Option<usize>,
) -> Advice {
    let mut results: Vec<(Policy, f64, f64)> = Vec::new();
    let mut best: Option<(Policy, f64, u64)> = None;
    for (&p, r) in policies.iter().zip(reports) {
        results.push((p, r.l2_hit_pct(), r.est_total_sec));
        let better = match best {
            None => true,
            Some((_, t, b)) => {
                r.est_total_sec < t * 0.98
                    || (r.est_total_sec < t * 1.02 && r.hbm.bytes_read < b)
            }
        };
        if better {
            best = Some((p, r.est_total_sec, r.hbm.bytes_read));
        }
    }
    let (recommended, best_sec, _) = best.expect("at least one naive policy always applies");
    let spread = results
        .iter()
        .map(|(_, _, t)| t / best_sec)
        .fold(1.0f64, f64::max);
    let projections = results
        .into_iter()
        .map(|(p, hit, t)| (p, hit, best_sec / t))
        .collect();
    Advice {
        recommended,
        projections,
        indifferent: topo.num_xcds == 1 || spread < 1.02,
        num_splits,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets;

    fn fast_topo() -> Topology {
        Topology { cus_per_xcd: 8, l2_bytes_per_xcd: 1024 * 1024, hbm_bytes_per_sec: 1.1e12, ..presets::mi300x() }
    }

    #[test]
    fn recommends_shf_for_many_head_mha() {
        let topo = presets::mi300x();
        let cfg = AttnConfig::mha(1, 64, 16384, 128);
        let a = advise(&topo, &cfg);
        assert_eq!(a.recommended, Policy::SwizzledHeadFirst);
        assert_eq!(a.projections.len(), 4);
        // relative perf of the recommendation is 1.0
        let rec = a.projections.iter().find(|(p, _, _)| *p == a.recommended).unwrap();
        assert!((rec.2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn repeat_advice_is_free() {
        // Second advise on the same (topology, geometry) must perform
        // zero new engine runs: all projections come from the cache.
        let driver = SimDriver::new(2);
        let topo = fast_topo();
        let cfg = AttnConfig::mha(1, 16, 4096, 64);
        let first = advise_with(&driver, &topo, &cfg);
        let runs_after_first = driver.cache().misses();
        assert_eq!(runs_after_first, 4, "one engine run per policy");
        let second = advise_with(&driver, &topo, &cfg);
        assert_eq!(driver.cache().misses(), runs_after_first, "zero new engine runs");
        assert_eq!(driver.cache().hits(), 4);
        assert_eq!(first.recommended, second.recommended);
        assert_eq!(first.projections.len(), second.projections.len());
        for (a, b) in first.projections.iter().zip(&second.projections) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.1.to_bits(), b.1.to_bits());
            assert_eq!(a.2.to_bits(), b.2.to_bits());
        }
    }

    #[test]
    fn pick_num_splits_fills_the_device() {
        let topo = presets::mi300x(); // 304 WG slots
        // Llama-3 70B decode, batch 1: 64 WGs without splitting.
        let cfg = AttnConfig::gqa(1, 64, 8, 65536, 128);
        let s = pick_num_splits(&topo, &cfg);
        assert!(s.is_power_of_two());
        assert!(cfg.batch * cfg.h_q * s >= topo.total_wg_slots(), "grid fills CUs");
        assert_eq!(s, 8); // 64 -> 128 -> 256 -> 512 >= 304
        // A large batch already fills the device: no splitting needed.
        let big = AttnConfig::gqa(8, 64, 8, 65536, 128);
        assert_eq!(pick_num_splits(&topo, &big), 1);
        // Short contexts cap the split count at one column block each.
        let short = AttnConfig::gqa(1, 8, 8, 256, 128); // 4 col blocks
        assert!(pick_num_splits(&topo, &short) <= short.num_col_blocks());
        // A caller-fixed oversized count is clamped the same way.
        let a = advise_decode_with(&SimDriver::new(1), &topo, &short, Some(1000));
        assert_eq!(a.num_splits, Some(short.num_col_blocks()));
    }

    #[test]
    fn decode_advice_projects_all_policies_and_caches() {
        let driver = SimDriver::new(2);
        let topo = fast_topo();
        let cfg = AttnConfig::gqa(1, 16, 8, 4096, 128);
        let a = advise_decode_with(&driver, &topo, &cfg, Some(2));
        assert_eq!(a.num_splits, Some(2));
        assert_eq!(a.projections.len(), 4);
        assert!(a.projections.iter().any(|(p, _, _)| *p == a.recommended));
        let runs = driver.cache().misses();
        assert_eq!(runs, 4, "one decode pass per policy");
        // Repeat advice with the same fixed split count is free.
        let b = advise_decode_with(&driver, &topo, &cfg, Some(2));
        assert_eq!(driver.cache().misses(), runs, "zero new engine runs");
        assert_eq!(a.recommended, b.recommended);
        // Prefill advice carries no split count.
        assert_eq!(advise_with(&driver, &topo, &cfg).num_splits, None);
    }

    #[test]
    fn skips_swizzled_when_heads_indivisible() {
        let topo = fast_topo();
        let cfg = AttnConfig::mha(1, 12, 4096, 64); // 12 % 8 != 0
        let a = advise(&topo, &cfg);
        assert_eq!(a.projections.len(), 2); // only the naive policies
        assert!(!a.recommended.requires_divisible_heads());
    }

    #[test]
    fn unified_gpu_is_indifferent() {
        let mut topo = presets::unified_single_die();
        topo.cus_per_xcd = 16;
        let cfg = AttnConfig::mha(1, 16, 4096, 128);
        let a = advise(&topo, &cfg);
        assert!(a.indifferent);
    }
}
