//! NUMA mapping advisor: given an attention geometry, recommend the
//! workgroup-mapping policy an MI300X deployment should configure and
//! back it with a quick simulator projection. This is how the paper's
//! contribution surfaces as a first-class serving feature: the
//! coordinator doesn't just run attention, it knows *how* the kernel
//! should be scheduled for the shapes it is serving.

use crate::attn::AttnConfig;
use crate::driver::{self, SimDriver, SimJob};
use crate::mapping::{Policy, ALL_POLICIES};
use crate::sim::SimConfig;
use crate::topology::Topology;

/// Advisor output for one attention geometry.
#[derive(Debug, Clone)]
pub struct Advice {
    pub recommended: Policy,
    /// (policy, projected aggregate L2 hit %, projected relative perf).
    pub projections: Vec<(Policy, f64, f64)>,
    /// True when the recommendation is degenerate (single XCD or fewer
    /// heads than XCDs — everything performs the same).
    pub indifferent: bool,
}

/// Simulate all applicable policies and rank them, using the process-wide
/// shared driver: the four projections fan out across its workers, and a
/// repeated call on the same (topology, geometry) is answered entirely
/// from the report cache — zero new engine runs.
pub fn advise(topo: &Topology, cfg: &AttnConfig) -> Advice {
    advise_with(driver::global(), topo, cfg)
}

/// [`advise`] through an explicit driver (tests and embedders that want
/// their own cache or thread budget).
pub fn advise_with(driver: &SimDriver, topo: &Topology, cfg: &AttnConfig) -> Advice {
    let policies: Vec<Policy> = ALL_POLICIES
        .iter()
        .copied()
        .filter(|p| !(p.requires_divisible_heads() && cfg.h_q % topo.num_xcds != 0))
        .collect();
    let jobs: Vec<SimJob> = policies
        .iter()
        .map(|&p| SimJob::forward(topo, cfg, SimConfig::sampled(p, topo, 2)))
        .collect();
    let reports = driver.run_all(jobs);

    let mut results: Vec<(Policy, f64, f64)> = Vec::new();
    // Rank by estimated time with a 2% noise band (steady-state sampling
    // jitter); within the band prefer lower HBM traffic — replication is
    // wasted power and bandwidth headroom even when latency-hidden.
    let mut best: Option<(Policy, f64, u64)> = None;
    for (&p, r) in policies.iter().zip(&reports) {
        results.push((p, r.l2_hit_pct(), r.est_total_sec));
        let better = match best {
            None => true,
            Some((_, t, b)) => {
                r.est_total_sec < t * 0.98
                    || (r.est_total_sec < t * 1.02 && r.hbm.bytes_read < b)
            }
        };
        if better {
            best = Some((p, r.est_total_sec, r.hbm.bytes_read));
        }
    }
    let (recommended, best_sec, _) = best.expect("at least one naive policy always applies");
    let spread = results
        .iter()
        .map(|(_, _, t)| t / best_sec)
        .fold(1.0f64, f64::max);
    let projections = results
        .into_iter()
        .map(|(p, hit, t)| (p, hit, best_sec / t))
        .collect();
    Advice {
        recommended,
        projections,
        indifferent: topo.num_xcds == 1 || spread < 1.02,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets;

    fn fast_topo() -> Topology {
        Topology { cus_per_xcd: 8, l2_bytes_per_xcd: 1024 * 1024, hbm_bytes_per_sec: 1.1e12, ..presets::mi300x() }
    }

    #[test]
    fn recommends_shf_for_many_head_mha() {
        let topo = presets::mi300x();
        let cfg = AttnConfig::mha(1, 64, 16384, 128);
        let a = advise(&topo, &cfg);
        assert_eq!(a.recommended, Policy::SwizzledHeadFirst);
        assert_eq!(a.projections.len(), 4);
        // relative perf of the recommendation is 1.0
        let rec = a.projections.iter().find(|(p, _, _)| *p == a.recommended).unwrap();
        assert!((rec.2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn repeat_advice_is_free() {
        // Second advise on the same (topology, geometry) must perform
        // zero new engine runs: all projections come from the cache.
        let driver = SimDriver::new(2);
        let topo = fast_topo();
        let cfg = AttnConfig::mha(1, 16, 4096, 64);
        let first = advise_with(&driver, &topo, &cfg);
        let runs_after_first = driver.cache().misses();
        assert_eq!(runs_after_first, 4, "one engine run per policy");
        let second = advise_with(&driver, &topo, &cfg);
        assert_eq!(driver.cache().misses(), runs_after_first, "zero new engine runs");
        assert_eq!(driver.cache().hits(), 4);
        assert_eq!(first.recommended, second.recommended);
        assert_eq!(first.projections.len(), second.projections.len());
        for (a, b) in first.projections.iter().zip(&second.projections) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.1.to_bits(), b.1.to_bits());
            assert_eq!(a.2.to_bits(), b.2.to_bits());
        }
    }

    #[test]
    fn skips_swizzled_when_heads_indivisible() {
        let topo = fast_topo();
        let cfg = AttnConfig::mha(1, 12, 4096, 64); // 12 % 8 != 0
        let a = advise(&topo, &cfg);
        assert_eq!(a.projections.len(), 2); // only the naive policies
        assert!(!a.recommended.requires_divisible_heads());
    }

    #[test]
    fn unified_gpu_is_indifferent() {
        let mut topo = presets::unified_single_die();
        topo.cus_per_xcd = 16;
        let cfg = AttnConfig::mha(1, 16, 4096, 128);
        let a = advise(&topo, &cfg);
        assert!(a.indifferent);
    }
}
