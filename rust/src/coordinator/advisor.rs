//! NUMA mapping advisor: given an attention geometry, recommend the
//! workgroup-mapping policy an MI300X deployment should configure and
//! back it with a quick simulator projection. This is how the paper's
//! contribution surfaces as a first-class serving feature: the
//! coordinator doesn't just run attention, it knows *how* the kernel
//! should be scheduled for the shapes it is serving.

use crate::attn::{AttnConfig, KernelKind};
use crate::mapping::{Policy, ALL_POLICIES};
use crate::sim::{self, SimConfig};
use crate::topology::Topology;

/// Advisor output for one attention geometry.
#[derive(Debug, Clone)]
pub struct Advice {
    pub recommended: Policy,
    /// (policy, projected aggregate L2 hit %, projected relative perf).
    pub projections: Vec<(Policy, f64, f64)>,
    /// True when the recommendation is degenerate (single XCD or fewer
    /// heads than XCDs — everything performs the same).
    pub indifferent: bool,
}

/// Simulate all applicable policies on `topo` and rank them.
pub fn advise(topo: &Topology, cfg: &AttnConfig) -> Advice {
    let mut results: Vec<(Policy, f64, f64)> = Vec::new();
    // Rank by estimated time with a 2% noise band (steady-state sampling
    // jitter); within the band prefer lower HBM traffic — replication is
    // wasted power and bandwidth headroom even when latency-hidden.
    let mut best: Option<(Policy, f64, u64)> = None;
    for &p in &ALL_POLICIES {
        if p.requires_divisible_heads() && cfg.h_q % topo.num_xcds != 0 {
            continue;
        }
        let sc = SimConfig {
            kernel: KernelKind::Forward,
            ..SimConfig::sampled(p, topo, 2)
        };
        let r = sim::simulate(topo, cfg, &sc);
        results.push((p, r.l2_hit_pct(), r.est_total_sec));
        let better = match best {
            None => true,
            Some((_, t, b)) => {
                r.est_total_sec < t * 0.98
                    || (r.est_total_sec < t * 1.02 && r.hbm.bytes_read < b)
            }
        };
        if better {
            best = Some((p, r.est_total_sec, r.hbm.bytes_read));
        }
    }
    let (recommended, best_sec, _) = best.expect("at least one naive policy always applies");
    let spread = results
        .iter()
        .map(|(_, _, t)| t / best_sec)
        .fold(1.0f64, f64::max);
    let projections = results
        .into_iter()
        .map(|(p, hit, t)| (p, hit, best_sec / t))
        .collect();
    Advice {
        recommended,
        projections,
        indifferent: topo.num_xcds == 1 || spread < 1.02,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets;

    fn fast_topo() -> Topology {
        Topology { cus_per_xcd: 8, l2_bytes_per_xcd: 1024 * 1024, hbm_bytes_per_sec: 1.1e12, ..presets::mi300x() }
    }

    #[test]
    fn recommends_shf_for_many_head_mha() {
        let topo = presets::mi300x();
        let cfg = AttnConfig::mha(1, 64, 16384, 128);
        let a = advise(&topo, &cfg);
        assert_eq!(a.recommended, Policy::SwizzledHeadFirst);
        assert_eq!(a.projections.len(), 4);
        // relative perf of the recommendation is 1.0
        let rec = a.projections.iter().find(|(p, _, _)| *p == a.recommended).unwrap();
        assert!((rec.2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn skips_swizzled_when_heads_indivisible() {
        let topo = fast_topo();
        let cfg = AttnConfig::mha(1, 12, 4096, 64); // 12 % 8 != 0
        let a = advise(&topo, &cfg);
        assert_eq!(a.projections.len(), 2); // only the naive policies
        assert!(!a.recommended.requires_divisible_heads());
    }

    #[test]
    fn unified_gpu_is_indifferent() {
        let mut topo = presets::unified_single_die();
        topo.cus_per_xcd = 16;
        let cfg = AttnConfig::mha(1, 16, 4096, 128);
        let a = advise(&topo, &cfg);
        assert!(a.indifferent);
    }
}
