//! Cluster fault injection for the decode serving loop
//! (docs/SERVING.md §9): seeded device fail/recover plans, mid-serve
//! rebalancing onto the surviving devices, and degraded-interval
//! reporting.
//!
//! The model follows from tensor parallelism: every active session's KV
//! cache is sharded across *all* serving devices
//! ([`crate::cluster::ShardPlan`]), so losing any one device invalidates
//! the whole active set — there is no per-device subset of sessions to
//! salvage. A fault transition therefore:
//!
//! 1. force-releases the active sessions' KV-pool leases (when the paged
//!    pool is on) and re-queues them through the [`SessionRouter`] — they
//!    re-admit in arrival order with their prefill restarted (emitted
//!    tokens stay counted, so conservation is checked on *completions*);
//! 2. re-forms the shard plan at the widest valid tensor-parallel width
//!    that fits the survivors (a valid width divides the model's KV heads
//!    and keeps the policy applicable on the shard-local geometry);
//! 3. prices the transition: a point-to-point transfer of the evicted
//!    KV bytes plus one output all-gather barrier on the new cluster.
//!
//! Transitions take effect at decode-step boundaries — a step in flight
//! when the fault lands completes at its pre-fault price, exactly as a
//! kernel already dispatched would. With every device down the clock
//! jumps to the next recovery; with an empty fault plan the run delegates
//! to [`serve_decode_cluster_with`] and is byte-identical to the
//! historical cluster serving output (pinned by `tests/cluster_serving.rs`).

use std::collections::BTreeMap;

use crate::cluster::{ClusterTopology, PoolKind, ShardPlan, ShardStrategy};
use crate::driver::{self, SimDriver};
use crate::mapping::Policy;
use crate::mem::prompt_keys;
use crate::metrics::Table;
use crate::topology::Topology;
use crate::util::json::Json;
use crate::util::rng::SplitMix64;
use crate::workload::sweeps::CLUSTER_TP;

use super::advisor;
use super::batcher::{PrefillChunk, StepBatcher};
use super::executor::{ClusterExecutor, StepExecutor};
use super::router::SessionRouter;
use super::service::{
    cluster_scenarios, fmt_ms, ms_json, pctl_or_nan, serve_decode_cluster_with, ServeConfig,
    ServeStats,
};

/// Stream-splitting constant for the seeded fault plan, XORed into the
/// user seed so fault draws never correlate with the arrival/mix/share
/// streams of [`crate::workload::SessionGenerator`].
const FAULT_STREAM: u64 = 0xFA17_C0DE_BAD5_EED5;

/// One planned outage: `device` drops at `fail_sec` (simulated seconds)
/// and comes back at `recover_sec`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Device index within the serving cluster (`0..tp`).
    pub device: usize,
    /// Simulated time the device drops.
    pub fail_sec: f64,
    /// Simulated time the device returns (strictly after `fail_sec`).
    pub recover_sec: f64,
}

/// A deterministic cluster fault plan: the full outage schedule, known
/// up front (this is a simulator — reproducibility beats surprise).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Planned outages, in no particular order.
    pub events: Vec<FaultEvent>,
}

/// One health transition derived from a [`FaultEvent`] endpoint.
#[derive(Debug, Clone, Copy)]
struct Transition {
    time: f64,
    device: usize,
    /// `true` = recovery, `false` = failure.
    up: bool,
}

impl FaultPlan {
    /// True when the plan schedules no outages (the byte-pinned
    /// delegation path).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse the CLI/INI event list: comma-separated
    /// `device:fail_sec:recover_sec` triples. Empty (or all-whitespace)
    /// input is the empty plan.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut events = Vec::new();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let fields: Vec<&str> = part.split(':').collect();
            if fields.len() != 3 {
                return Err(format!(
                    "[faults] event '{part}' must be device:fail_sec:recover_sec"
                ));
            }
            let device = fields[0]
                .trim()
                .parse::<usize>()
                .map_err(|_| format!("[faults] bad device index in '{part}'"))?;
            let fail_sec = fields[1]
                .trim()
                .parse::<f64>()
                .map_err(|_| format!("[faults] bad fail_sec in '{part}'"))?;
            let recover_sec = fields[2]
                .trim()
                .parse::<f64>()
                .map_err(|_| format!("[faults] bad recover_sec in '{part}'"))?;
            events.push(FaultEvent { device, fail_sec, recover_sec });
        }
        Ok(FaultPlan { events })
    }

    /// Render the plan back to the [`FaultPlan::parse`] grammar.
    pub fn render(&self) -> String {
        self.events
            .iter()
            .map(|e| format!("{}:{}:{}", e.device, e.fail_sec, e.recover_sec))
            .collect::<Vec<_>>()
            .join(",")
    }

    /// A seeded plan of `count` outages over `horizon_sec` of simulated
    /// time across `devices` devices. The horizon is partitioned into
    /// `count` equal slots and each outage stays inside its own slot, so
    /// same-device outages can never overlap and the plan always passes
    /// [`FaultPlan::validate`]. Device draws use
    /// [`SplitMix64::gen_range_unbiased`] — new code takes the unbiased
    /// mapping; only the frozen [`SplitMix64::gen_range`] traces keep
    /// the historical modulo.
    pub fn seeded(seed: u64, devices: usize, count: usize, horizon_sec: f64) -> FaultPlan {
        assert!(devices > 0, "seeded fault plan needs at least one device");
        assert!(
            horizon_sec.is_finite() && horizon_sec > 0.0,
            "seeded fault plan needs a positive horizon"
        );
        let mut rng = SplitMix64::new(seed ^ FAULT_STREAM);
        let slot = horizon_sec / count.max(1) as f64;
        let events = (0..count)
            .map(|i| {
                let device = rng.gen_range_unbiased(devices as u64) as usize;
                let fail_sec = i as f64 * slot + rng.next_f64() * 0.5 * slot;
                let outage = (0.1 + 0.8 * rng.next_f64()) * 0.5 * slot;
                FaultEvent { device, fail_sec, recover_sec: fail_sec + outage }
            })
            .collect();
        FaultPlan { events }
    }

    /// Check the plan against a cluster of `devices` devices: indices in
    /// range, finite non-negative times, recovery strictly after failure,
    /// and no overlapping (or touching) outages on one device — a device
    /// cannot fail while already down.
    pub fn validate(&self, devices: usize) -> Result<(), String> {
        if devices == 0 {
            return Err("[faults] the cluster needs at least one device".into());
        }
        for e in &self.events {
            if e.device >= devices {
                return Err(format!(
                    "[faults] device {} is outside the cluster (valid devices are 0..{})",
                    e.device, devices
                ));
            }
            if !e.fail_sec.is_finite() || e.fail_sec < 0.0 {
                return Err(format!(
                    "[faults] fail_sec {} on device {} must be finite and >= 0",
                    e.fail_sec, e.device
                ));
            }
            if !e.recover_sec.is_finite() || e.recover_sec <= e.fail_sec {
                return Err(format!(
                    "[faults] recover_sec {} on device {} must be finite and after fail_sec {}",
                    e.recover_sec, e.device, e.fail_sec
                ));
            }
        }
        let mut by_dev: BTreeMap<usize, Vec<(f64, f64)>> = BTreeMap::new();
        for e in &self.events {
            by_dev.entry(e.device).or_default().push((e.fail_sec, e.recover_sec));
        }
        for (d, mut spans) in by_dev {
            spans.sort_by(|a, b| a.0.total_cmp(&b.0));
            for w in spans.windows(2) {
                if w[1].0 <= w[0].1 {
                    return Err(format!(
                        "[faults] device {d} outages [{}, {}] and [{}, {}] overlap: a device \
                         cannot fail while already down",
                        w[0].0, w[0].1, w[1].0, w[1].1
                    ));
                }
            }
        }
        Ok(())
    }

    /// The plan's health transitions, sorted by time (failures before
    /// recoveries at equal instants, then by device) — the deterministic
    /// order the serving loop applies them in.
    fn timeline(&self) -> Vec<Transition> {
        let mut t: Vec<Transition> = self
            .events
            .iter()
            .flat_map(|e| {
                [
                    Transition { time: e.fail_sec, device: e.device, up: false },
                    Transition { time: e.recover_sec, device: e.device, up: true },
                ]
            })
            .collect();
        t.sort_by(|a, b| {
            a.time.total_cmp(&b.time).then(a.up.cmp(&b.up)).then(a.device.cmp(&b.device))
        });
        t
    }
}

/// The `[faults]` INI / `--faults` CLI surface: either an explicit event
/// list (wins when non-empty) or a seeded plan, resolved against the
/// cluster size at run time.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Explicit plan in the [`FaultPlan::parse`] grammar; empty = unset.
    pub events: String,
    /// Seed of the generated plan (`[faults] seed`).
    pub seed: u64,
    /// Outages to generate (`[faults] count`); `0` = no seeded plan.
    pub count: usize,
    /// Simulated horizon the seeded outages spread over
    /// (`[faults] horizon_sec`).
    pub horizon_sec: f64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec { events: String::new(), seed: 13, count: 0, horizon_sec: 0.1 }
    }
}

impl FaultSpec {
    /// True when neither an explicit nor a seeded plan is configured —
    /// the serving paths then skip fault injection entirely.
    pub fn is_none(&self) -> bool {
        self.events.trim().is_empty() && self.count == 0
    }

    /// Resolve to a concrete validated [`FaultPlan`] for a cluster of
    /// `devices` devices.
    pub fn resolve(&self, devices: usize) -> Result<FaultPlan, String> {
        let plan = if !self.events.trim().is_empty() {
            FaultPlan::parse(&self.events)?
        } else if self.count > 0 {
            if devices == 0 {
                return Err("[faults] the cluster needs at least one device".into());
            }
            if !self.horizon_sec.is_finite() || self.horizon_sec <= 0.0 {
                return Err(format!(
                    "[faults] horizon_sec ({}) must be > 0 for a seeded plan",
                    self.horizon_sec
                ));
            }
            FaultPlan::seeded(self.seed, devices, self.count, self.horizon_sec)
        } else {
            FaultPlan::default()
        };
        plan.validate(devices)?;
        Ok(plan)
    }
}

/// One serving interval at a fixed tensor-parallel width, delimited by
/// fault transitions: the `serve_burst` figure's time axis.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultWindow {
    /// Simulated start of the window.
    pub start_sec: f64,
    /// Simulated end of the window.
    pub end_sec: f64,
    /// Serving width during the window (`0` = total blackout).
    pub width: usize,
    /// Decode tokens emitted in the window.
    pub tokens: u64,
    /// Busy simulated seconds (step + reshard charges; idle jumps to
    /// arrivals or recoveries excluded).
    pub busy_sec: f64,
    /// `tokens / busy_sec` (NaN when the window never served).
    pub tokens_per_sec: f64,
    /// 99th-percentile TTFT of first tokens emitted in the window, ms
    /// (NaN when none were).
    pub ttft_p99_ms: f64,
}

impl FaultWindow {
    /// JSON rendering (stable key order); NaN sentinels render `null`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("start_sec", Json::num(self.start_sec)),
            ("end_sec", Json::num(self.end_sec)),
            ("width", Json::num(self.width as f64)),
            ("tokens", Json::num(self.tokens as f64)),
            ("busy_sec", Json::num(self.busy_sec)),
            ("tokens_per_sec", ms_json(self.tokens_per_sec)),
            ("ttft_p99_ms", ms_json(self.ttft_p99_ms)),
        ])
    }
}

/// Fault-injection extras riding on one serving run's [`ServeStats`].
#[derive(Debug, Clone, PartialEq)]
pub struct FaultExtras {
    /// Health transitions applied (each fail and each recovery counts).
    pub events_applied: usize,
    /// Transitions that changed the serving width (shard-plan re-forms).
    pub rebalances: usize,
    /// KV-pool leases force-released by evictions (0 with the pool off).
    pub forced_releases: usize,
    /// Session evictions re-queued through the router (a session evicted
    /// twice counts twice).
    pub requeued: usize,
    /// Wall-simulated seconds spent below full width.
    pub degraded_sec: f64,
    /// Busy-time decode throughput over the full-width windows (NaN when
    /// the run never served at full width).
    pub healthy_tokens_per_sec: f64,
    /// Busy-time decode throughput over the below-width windows (NaN
    /// when the run never degraded while serving).
    pub degraded_tokens_per_sec: f64,
    /// Throughput of the last full-width window over the first — how
    /// much of the healthy rate recovery restored (NaN without two
    /// full-width serving windows).
    pub recovery_ratio: f64,
    /// Serving windows in time order.
    pub windows: Vec<FaultWindow>,
}

impl FaultExtras {
    /// JSON rendering (stable key order); NaN sentinels render `null`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("events_applied", Json::num(self.events_applied as f64)),
            ("rebalances", Json::num(self.rebalances as f64)),
            ("forced_releases", Json::num(self.forced_releases as f64)),
            ("requeued", Json::num(self.requeued as f64)),
            ("degraded_sec", Json::num(self.degraded_sec)),
            ("healthy_tokens_per_sec", ms_json(self.healthy_tokens_per_sec)),
            ("degraded_tokens_per_sec", ms_json(self.degraded_tokens_per_sec)),
            ("recovery_ratio", ms_json(self.recovery_ratio)),
            ("windows", Json::arr(self.windows.iter().map(FaultWindow::to_json))),
        ])
    }
}

/// [`ServeStats`] plus the fault extras. With an empty plan `faults` is
/// `None` and [`FaultyServeStats::to_json`] is byte-identical to the
/// plain [`ServeStats::to_json`] — the golden-pin contract.
#[derive(Debug, Clone)]
pub struct FaultyServeStats {
    /// The base serving stats (same semantics as a fault-free run; token
    /// counts include pre-eviction partial progress).
    pub serve: ServeStats,
    /// Fault accounting, present only when the plan scheduled outages.
    pub faults: Option<FaultExtras>,
}

impl FaultyServeStats {
    /// JSON rendering: exactly [`ServeStats::to_json`] with an empty
    /// plan, else the same object with a trailing `"faults"` key.
    pub fn to_json(&self) -> Json {
        match &self.faults {
            None => self.serve.to_json(),
            Some(f) => {
                let mut obj = match self.serve.to_json() {
                    Json::Obj(pairs) => pairs,
                    _ => unreachable!("ServeStats::to_json returns an object"),
                };
                obj.push(("faults".into(), f.to_json()));
                Json::Obj(obj)
            }
        }
    }
}

/// Event log of one faulty serving run, for the invariant suite
/// (`tests/failure_injection.rs`): exactly-once completion, eviction /
/// re-admission pairing, and lease conservation are all checked off
/// this rather than aggregate counters. Empty on the empty-plan
/// delegation path.
#[derive(Debug, Clone, Default)]
pub struct FaultTrace {
    /// Session ids in admission order (re-admissions repeat the id).
    pub admissions: Vec<u64>,
    /// Session ids in retirement order.
    pub completions: Vec<u64>,
    /// Session ids evicted by fault transitions, in eviction order.
    pub evictions: Vec<u64>,
    /// Applied transitions: (simulated apply time, device, is-recovery).
    pub transitions: Vec<(f64, usize, bool)>,
    /// KV-pool block references still held when the run ended (0 with
    /// the pool off — and 0 with it on, unless a lease leaked).
    pub leases_at_end: usize,
}

/// [`serve_decode_faulty_with`] through the process-wide shared driver.
pub fn serve_decode_faulty(
    device: &Topology,
    tp: usize,
    cfg: &ServeConfig,
    policy: Policy,
    plan: &FaultPlan,
) -> FaultyServeStats {
    serve_decode_faulty_with(driver::global(), device, tp, cfg, policy, plan)
}

/// Run the continuous-batching decode serving loop on a `tp`-device
/// cluster of `device`s under a fault plan (module docs have the fault
/// model). An empty plan delegates to [`serve_decode_cluster_with`] —
/// byte-identical output, `faults: None`.
pub fn serve_decode_faulty_with(
    driver: &SimDriver,
    device: &Topology,
    tp: usize,
    cfg: &ServeConfig,
    policy: Policy,
    plan: &FaultPlan,
) -> FaultyServeStats {
    serve_decode_faulty_traced(driver, device, tp, cfg, policy, plan).0
}

/// [`serve_decode_faulty_with`] plus the [`FaultTrace`] event log the
/// invariant suite audits.
pub fn serve_decode_faulty_traced(
    driver: &SimDriver,
    device: &Topology,
    tp: usize,
    cfg: &ServeConfig,
    policy: Policy,
    plan: &FaultPlan,
) -> (FaultyServeStats, FaultTrace) {
    plan.validate(tp).expect("valid fault plan");
    let base = cfg.base_geometry();
    if plan.is_empty() {
        let cluster = ClusterTopology::node_of(device, tp);
        let shard = ShardPlan::new(&base, tp, ShardStrategy::Contiguous)
            .expect("tp must divide the served model's KV heads");
        let serve = serve_decode_cluster_with(driver, &cluster, &shard, cfg, policy);
        return (FaultyServeStats { serve, faults: None }, FaultTrace::default());
    }
    cfg.validate().expect("valid serve config");

    // Every tensor-parallel width the run can rebalance to, ascending: it
    // must divide the KV heads (never split across devices) and keep the
    // policy applicable on the shard-local geometry of every member.
    let widths: Vec<usize> = (1..=tp)
        .filter(|&w| {
            base.h_k % w == 0 && {
                let p = ShardPlan::new(&base, w, ShardStrategy::Contiguous)
                    .expect("w divides h_k by construction");
                advisor::applicable_policies(device, &p.local_attn(&base)).contains(&policy)
            }
        })
        .collect();
    assert!(
        widths.last() == Some(&tp),
        "policy {policy} is not applicable at the full width tp={tp}"
    );
    assert!(
        widths.first() == Some(&1),
        "policy {policy} must stay applicable on a lone survivor (width 1)"
    );
    // Pre-built per-width clusters/plans, then the executors borrowing
    // them: advisor state and L2/consult accounting persist per width
    // across the outage/recovery cycles that revisit it.
    let setups: Vec<(ClusterTopology, ShardPlan)> = widths
        .iter()
        .map(|&w| {
            (
                ClusterTopology::node_of(device, w),
                ShardPlan::new(&base, w, ShardStrategy::Contiguous).expect("valid width"),
            )
        })
        .collect();
    let mut execs: Vec<ClusterExecutor> = setups
        .iter()
        .map(|(cl, sp)| ClusterExecutor::new(driver, cl, sp, cfg, policy))
        .collect();

    let timeline = plan.timeline();
    let mut next_tr = 0usize;
    let mut healthy = vec![true; tp];
    // Index into `widths` of the current serving width; None = blackout.
    let mut cur: Option<usize> = Some(widths.len() - 1);

    let router = SessionRouter::new(false);
    let mut source = cfg.session_source();
    let sessions = source.take_sessions(cfg.session_budget());
    let mut batcher = StepBatcher::new(sessions, cfg.max_active, cfg.chunk_tokens);
    let mut pool = cfg.kv_pool();

    let mut now_sec = 0.0f64;
    let mut prefill_sec = 0.0f64;
    let mut prefill_tokens = 0u64;
    let mut kv_shared_tokens = 0u64;
    let mut kv_affine_blocks = 0u64;
    let mut kv_total_blocks = 0u64;
    let mut tokens = 0u64;
    let mut steps = 0usize;
    let mut tpot_ms: Vec<f64> = Vec::new();
    let mut ttft_ms: Vec<f64> = Vec::new();

    let mut trace = FaultTrace::default();
    let mut events_applied = 0usize;
    let mut rebalances = 0usize;
    let mut forced_releases = 0usize;
    let mut requeued = 0usize;
    let mut windows: Vec<FaultWindow> = Vec::new();
    let mut win_start = 0.0f64;
    let mut win_tokens = 0u64;
    let mut win_busy = 0.0f64;
    let mut win_ttft: Vec<f64> = Vec::new();

    while steps < cfg.max_steps && !batcher.done() {
        // 1. Fault transitions due at this step boundary. The evicted KV
        //    bytes are priced at the pre-eviction lengths — that is what
        //    must move off (or back onto) the re-formed shards.
        if next_tr < timeline.len() && timeline[next_tr].time <= now_sec {
            let kv_tokens: usize =
                batcher.active().iter().map(|a| a.kv_len(cfg.kv_cap)).sum();
            let evicted_bytes = (kv_tokens * cfg.h_k * cfg.d_head * cfg.dtype_bytes) as f64;
            while next_tr < timeline.len() && timeline[next_tr].time <= now_sec {
                let t = timeline[next_tr];
                healthy[t.device] = t.up;
                trace.transitions.push((now_sec, t.device, t.up));
                events_applied += 1;
                next_tr += 1;
            }
            let evicted = batcher.requeue_active();
            requeued += evicted.len();
            for s in &evicted {
                trace.evictions.push(s.id);
                // Re-queued sessions go back through the router; on this
                // colocated cluster the route is always the decode pool.
                debug_assert_eq!(
                    router.route(s).decode,
                    PoolKind::Decode,
                    "colocated re-admission routes to the decode pool"
                );
                if let Some(pool) = pool.as_mut() {
                    pool.release(s.id);
                    forced_releases += 1;
                }
            }
            windows.push(FaultWindow {
                start_sec: win_start,
                end_sec: now_sec,
                width: cur.map_or(0, |i| widths[i]),
                tokens: win_tokens,
                busy_sec: win_busy,
                tokens_per_sec: if win_busy > 0.0 {
                    win_tokens as f64 / win_busy
                } else {
                    f64::NAN
                },
                ttft_p99_ms: pctl_or_nan(&win_ttft, 0.99),
            });
            win_start = now_sec;
            win_tokens = 0;
            win_busy = 0.0;
            win_ttft.clear();

            let survivors = healthy.iter().filter(|&&h| h).count();
            let new_cur = widths.iter().rposition(|&w| w <= survivors);
            if new_cur != cur {
                rebalances += 1;
            }
            cur = new_cur;
            if let Some(i) = cur {
                let (cl, sp) = &setups[i];
                let reshard =
                    cl.transfer_sec(evicted_bytes) + cl.all_gather_sec(sp.output_bytes_per_device(&base, 1));
                now_sec += reshard;
                win_busy += reshard;
            }
            continue;
        }
        // 2. Blackout: no survivors can serve — the clock jumps straight
        //    to the next transition (the earliest recovery); none left
        //    means the run ends truncated.
        if cur.is_none() {
            match timeline.get(next_tr) {
                Some(t) => now_sec = now_sec.max(t.time),
                None => break,
            }
            continue;
        }
        let ci = cur.expect("blackout handled above");
        if batcher.active().is_empty() {
            // Idle: jump simulated time forward to the next arrival —
            // but never past a pending fault transition.
            match batcher.next_arrival_sec() {
                Some(t) => {
                    let target = now_sec.max(t);
                    if let Some(tr) = timeline.get(next_tr) {
                        if tr.time < target {
                            now_sec = now_sec.max(tr.time);
                            continue;
                        }
                    }
                    now_sec = target;
                }
                None => break,
            }
        }
        // 3. One serving step, mirroring the fault-free loop body in
        //    `run_serve_loop` (admission → paged-pool leases → prefill
        //    composition → bucketed decode → TTFT/TPOT sampling).
        let newly = batcher.admit(now_sec);
        trace.admissions.extend(newly.iter().map(|s| s.id));
        let mut credited: Vec<usize> = Vec::new();
        if let Some(pool) = pool.as_mut() {
            for s in &newly {
                let keys = prompt_keys(s.id, s.prefill, s.shared_prefix, cfg.kv_block_tokens);
                let got = pool.acquire(s.id, &keys);
                for &j in &got.inserted {
                    let (affine, total) = execs[ci].kv_block_affinity(j);
                    kv_affine_blocks += affine as u64;
                    kv_total_blocks += total as u64;
                }
                let t = (got.credited_blocks * cfg.kv_block_tokens).min(s.prefill);
                kv_shared_tokens += t as u64;
                credited.push(t);
            }
        }
        let mut step_sec = 0.0f64;
        if cfg.chunk_tokens == 0 {
            if pool.is_some() {
                let chunks: Vec<PrefillChunk> = newly
                    .iter()
                    .zip(&credited)
                    .filter(|(s, &c)| c < s.prefill)
                    .map(|(s, &c)| PrefillChunk { id: s.id, start: c, end: s.prefill })
                    .collect();
                if !chunks.is_empty() {
                    prefill_tokens += chunks.iter().map(|c| c.tokens() as u64).sum::<u64>();
                    for t in execs[ci].chunk_charges(&chunks) {
                        prefill_sec += t;
                        step_sec += t;
                    }
                }
            } else if !newly.is_empty() {
                let prompts: Vec<usize> = newly.iter().map(|s| s.prefill).collect();
                prefill_tokens += prompts.iter().map(|&p| p as u64).sum::<u64>();
                for t in execs[ci].prefill_charges(&prompts) {
                    prefill_sec += t;
                    step_sec += t;
                }
            }
        } else {
            for (s, &c) in newly.iter().zip(&credited) {
                if c > 0 {
                    batcher.credit_prefix(s.id, c);
                }
            }
            let budget = if cfg.step_token_budget == 0 {
                usize::MAX
            } else {
                cfg.step_token_budget
            };
            let decoding = batcher.decoding();
            let chunks = batcher.plan_chunks(budget.saturating_sub(decoding));
            if !chunks.is_empty() {
                prefill_tokens += chunks.iter().map(|c| c.tokens() as u64).sum::<u64>();
                for t in execs[ci].chunk_charges(&chunks) {
                    prefill_sec += t;
                    step_sec += t;
                }
            }
        }
        let mut grouped: BTreeMap<usize, usize> = BTreeMap::new();
        for a in batcher.active().iter().filter(|a| a.prefill_complete()) {
            *grouped.entry(cfg.bucket_of(a.kv_len(cfg.kv_cap))).or_insert(0) += 1;
        }
        let groups: Vec<(usize, usize)> = grouped.into_iter().collect();
        for t in execs[ci].decode_charges(&groups) {
            step_sec += t;
        }
        now_sec += step_sec;
        for a in batcher.active() {
            if a.prefill_complete() && a.generated == 0 {
                let sample = (now_sec - a.session.arrival_sec) * 1e3;
                ttft_ms.push(sample);
                win_ttft.push(sample);
            }
        }
        let emitted = batcher.advance_step();
        let retired = batcher.drain_retired();
        for &id in &retired {
            if let Some(pool) = pool.as_mut() {
                pool.release(id);
            }
        }
        trace.completions.extend(retired);
        tokens += emitted as u64;
        win_tokens += emitted as u64;
        win_busy += step_sec;
        tpot_ms.extend(std::iter::repeat(step_sec * 1e3).take(emitted));
        steps += 1;
    }
    windows.push(FaultWindow {
        start_sec: win_start,
        end_sec: now_sec,
        width: cur.map_or(0, |i| widths[i]),
        tokens: win_tokens,
        busy_sec: win_busy,
        tokens_per_sec: if win_busy > 0.0 { win_tokens as f64 / win_busy } else { f64::NAN },
        ttft_p99_ms: pctl_or_nan(&win_ttft, 0.99),
    });
    trace.leases_at_end = pool.as_ref().map_or(0, |p| p.total_refs());

    let (l2_hits, l2_misses) = execs.iter().fold((0u64, 0u64), |(h, m), e| {
        let (eh, em) = e.decode_l2();
        (h + eh, m + em)
    });
    let serve = ServeStats {
        policy,
        sessions_completed: batcher.completed(),
        tokens,
        steps,
        sim_sec: now_sec,
        tokens_per_sec: if now_sec > 0.0 { tokens as f64 / now_sec } else { 0.0 },
        tpot_p50_ms: pctl_or_nan(&tpot_ms, 0.50),
        tpot_p99_ms: pctl_or_nan(&tpot_ms, 0.99),
        ttft_p50_ms: pctl_or_nan(&ttft_ms, 0.50),
        ttft_p99_ms: pctl_or_nan(&ttft_ms, 0.99),
        prefill_sec,
        prefill_tokens,
        decode_l2_hit_pct: if l2_hits + l2_misses > 0 {
            100.0 * l2_hits as f64 / (l2_hits + l2_misses) as f64
        } else {
            0.0
        },
        advisor_consults: execs.iter().map(|e| e.consults()).sum(),
        distinct_geometries: execs.iter().map(|e| e.distinct_geometries()).sum(),
        kv_shared_tokens,
        kv_xcd_affinity_pct: if kv_total_blocks > 0 {
            100.0 * kv_affine_blocks as f64 / kv_total_blocks as f64
        } else {
            0.0
        },
        truncated: !batcher.done(),
    };

    let rate = |pick: &dyn Fn(&FaultWindow) -> bool| {
        let (t, b) = windows
            .iter()
            .filter(|w| pick(w))
            .fold((0u64, 0.0f64), |(t, b), w| (t + w.tokens, b + w.busy_sec));
        if b > 0.0 {
            t as f64 / b
        } else {
            f64::NAN
        }
    };
    let full: Vec<&FaultWindow> =
        windows.iter().filter(|w| w.width == tp && w.busy_sec > 0.0).collect();
    let extras = FaultExtras {
        events_applied,
        rebalances,
        forced_releases,
        requeued,
        degraded_sec: windows
            .iter()
            .filter(|w| w.width < tp)
            .map(|w| w.end_sec - w.start_sec)
            .sum(),
        healthy_tokens_per_sec: rate(&|w: &FaultWindow| w.width == tp),
        degraded_tokens_per_sec: rate(&|w: &FaultWindow| w.width < tp),
        recovery_ratio: if full.len() >= 2 {
            full[full.len() - 1].tokens_per_sec / full[0].tokens_per_sec
        } else {
            f64::NAN
        },
        windows,
    };
    (FaultyServeStats { serve, faults: Some(extras) }, trace)
}

/// One fault-report row: a cluster scenario at full sweep width, each
/// applicable policy served under the same fault plan.
#[derive(Debug, Clone)]
pub struct FaultRow {
    /// Scenario label (shared with the cluster sweep).
    pub label: String,
    /// One [`FaultyServeStats`] per applicable policy.
    pub stats: Vec<FaultyServeStats>,
}

/// The fault-injection report `cluster --faults` emits: the cluster
/// sweep's full-width scenarios re-served under the resolved fault plan.
#[derive(Debug, Clone)]
pub struct FaultReport {
    /// Scenario rows in sweep order.
    pub rows: Vec<FaultRow>,
    /// The resolved plan every row ran under.
    pub plan: FaultPlan,
}

impl FaultReport {
    /// Stats for (row label, policy), for assertions in tests/benches.
    pub fn stats(&self, label: &str, policy: Policy) -> Option<&FaultyServeStats> {
        self.rows
            .iter()
            .find(|r| r.label == label)?
            .stats
            .iter()
            .find(|s| s.serve.policy == policy)
    }

    /// Aligned-table rendering (one table per scenario).
    pub fn render(&self) -> String {
        let fmt_rate = |v: f64| if v.is_nan() { "n/a".into() } else { format!("{v:.0}") };
        let mut out = format!("== faults — plan [{}] ==\n", self.plan.render());
        for row in &self.rows {
            let mut t = Table::new(&[
                "policy",
                "tokens/s",
                "healthy t/s",
                "degraded t/s",
                "recovery",
                "rebalances",
                "requeued",
                "TTFT p99 (ms)",
                "sessions",
            ]);
            for s in &row.stats {
                let f = s.faults.as_ref();
                t.row(vec![
                    s.serve.policy.label().into(),
                    format!("{:.0}", s.serve.tokens_per_sec),
                    f.map_or("-".into(), |f| fmt_rate(f.healthy_tokens_per_sec)),
                    f.map_or("-".into(), |f| fmt_rate(f.degraded_tokens_per_sec)),
                    f.map_or("-".into(), |f| {
                        if f.recovery_ratio.is_nan() {
                            "n/a".into()
                        } else {
                            format!("{:.2}", f.recovery_ratio)
                        }
                    }),
                    f.map_or(0, |f| f.rebalances).to_string(),
                    f.map_or(0, |f| f.requeued).to_string(),
                    fmt_ms(s.serve.ttft_p99_ms),
                    format!(
                        "{}{}",
                        s.serve.sessions_completed,
                        if s.serve.truncated { "*" } else { "" }
                    ),
                ]);
            }
            out.push_str(&format!("== faults — {} ==\n{}", row.label, t.render()));
        }
        if self.rows.iter().any(|r| r.stats.iter().any(|s| s.serve.truncated)) {
            out.push_str("(* = step budget exhausted before the trace drained)\n");
        }
        out
    }

    /// JSON rendering for `cluster --faults --json` (stable order).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("plan", Json::str(self.plan.render())),
            (
                "rows",
                Json::arr(self.rows.iter().map(|r| {
                    Json::obj(vec![
                        ("label", Json::str(r.label.clone())),
                        (
                            "policies",
                            Json::arr(r.stats.iter().map(FaultyServeStats::to_json)),
                        ),
                    ])
                })),
            ),
        ])
    }
}

/// Build the fault report: the cluster sweep's scenarios at the full
/// sweep width ([`CLUSTER_TP`]'s endpoint), each applicable policy
/// served under the spec's resolved plan. A policy must stay applicable
/// at *every* rebalance width to qualify — a run must never be forced
/// onto a policy it did not start with.
pub fn fault_report(
    driver: &SimDriver,
    device: &Topology,
    quick: bool,
    spec: &FaultSpec,
) -> Result<FaultReport, String> {
    let tp = *CLUSTER_TP.last().expect("cluster sweep has TP degrees");
    let plan = spec.resolve(tp)?;
    let rows = cluster_scenarios(quick)
        .into_iter()
        .filter(|sc| sc.tp == tp)
        .map(|sc| {
            let base = sc.cfg.base_geometry();
            let stats = advisor::applicable_policies(device, &base)
                .into_iter()
                .filter(|p| {
                    (1..=tp).filter(|w| base.h_k % w == 0).all(|w| {
                        let sp = ShardPlan::new(&base, w, ShardStrategy::Contiguous)
                            .expect("w divides h_k by construction");
                        advisor::applicable_policies(device, &sp.local_attn(&base)).contains(p)
                    })
                })
                .map(|p| serve_decode_faulty_with(driver, device, tp, &sc.cfg, p, &plan))
                .collect();
            FaultRow { label: sc.label, stats }
        })
        .collect();
    Ok(FaultReport { rows, plan })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets;

    fn fast_topo() -> Topology {
        Topology {
            cus_per_xcd: 8,
            l2_bytes_per_xcd: 1024 * 1024,
            hbm_bytes_per_sec: 1.1e12,
            ..presets::mi300x()
        }
    }

    fn tiny_serve() -> ServeConfig {
        ServeConfig {
            h_q: 16,
            h_k: 8,
            d_head: 64,
            kv_cap: 8192,
            kv_bucket: 2048,
            arrival_per_sec: 2000.0,
            prefill_lengths: vec![1024, 2048],
            decode_tokens: vec![4, 12],
            sessions: 6,
            max_active: 3,
            max_steps: 400,
            seed: 9,
            ..ServeConfig::default()
        }
    }

    #[test]
    fn plan_parse_render_round_trips_and_rejects_garbage() {
        let plan = FaultPlan::parse("1:0.5:0.75, 0:1:2").unwrap();
        assert_eq!(plan.events.len(), 2);
        assert_eq!(plan.events[0], FaultEvent { device: 1, fail_sec: 0.5, recover_sec: 0.75 });
        assert_eq!(FaultPlan::parse(&plan.render()).unwrap(), plan);
        assert!(FaultPlan::parse("").unwrap().is_empty());
        assert!(FaultPlan::parse("  ,  ").unwrap().is_empty());
        assert!(FaultPlan::parse("1:0.5").unwrap_err().contains("device:fail_sec:recover_sec"));
        assert!(FaultPlan::parse("x:0.5:1").unwrap_err().contains("bad device"));
        assert!(FaultPlan::parse("0:a:1").unwrap_err().contains("bad fail_sec"));
    }

    #[test]
    fn plan_validation_rejects_bad_schedules() {
        let ok = FaultPlan::parse("1:0.5:0.75").unwrap();
        ok.validate(2).unwrap();
        assert!(ok.validate(1).unwrap_err().contains("outside the cluster"));
        assert!(FaultPlan::parse("0:-1:2")
            .unwrap()
            .validate(2)
            .unwrap_err()
            .contains("must be finite and >= 0"));
        assert!(FaultPlan::parse("0:2:2")
            .unwrap()
            .validate(2)
            .unwrap_err()
            .contains("after fail_sec"));
        // Overlapping (and even touching) outages on one device.
        assert!(FaultPlan::parse("0:0:1,0:0.5:2")
            .unwrap()
            .validate(2)
            .unwrap_err()
            .contains("overlap"));
        assert!(FaultPlan::parse("0:0:1,0:1:2")
            .unwrap()
            .validate(2)
            .unwrap_err()
            .contains("overlap"));
        // Distinct devices may overlap freely.
        FaultPlan::parse("0:0:1,1:0.5:2").unwrap().validate(2).unwrap();
    }

    #[test]
    fn seeded_plans_are_deterministic_and_valid() {
        let a = FaultPlan::seeded(7, 4, 3, 0.5);
        let b = FaultPlan::seeded(7, 4, 3, 0.5);
        assert_eq!(a, b, "same seed, same plan");
        assert_eq!(a.events.len(), 3);
        a.validate(4).unwrap();
        let c = FaultPlan::seeded(8, 4, 3, 0.5);
        assert_ne!(a, c, "different seeds diverge");
        // The spec surface resolves seeded plans the same way.
        let spec = FaultSpec { count: 3, seed: 7, ..FaultSpec::default() };
        assert!(!spec.is_none());
        assert_eq!(spec.resolve(4).unwrap(), a);
        assert!(FaultSpec::default().is_none());
        assert!(FaultSpec::default().resolve(4).unwrap().is_empty());
        let bad = FaultSpec { count: 1, horizon_sec: 0.0, ..FaultSpec::default() };
        assert!(bad.resolve(4).unwrap_err().contains("horizon_sec"));
    }

    #[test]
    fn empty_plan_is_byte_identical_to_the_cluster_path() {
        let driver = SimDriver::new(2);
        let topo = fast_topo();
        let cfg = tiny_serve();
        let cluster = ClusterTopology::node_of(&topo, 2);
        let shard = ShardPlan::new(&cfg.base_geometry(), 2, ShardStrategy::Contiguous).unwrap();
        let base =
            serve_decode_cluster_with(&driver, &cluster, &shard, &cfg, Policy::SwizzledHeadFirst);
        let faulty = serve_decode_faulty_with(
            &driver,
            &topo,
            2,
            &cfg,
            Policy::SwizzledHeadFirst,
            &FaultPlan::default(),
        );
        assert!(faulty.faults.is_none());
        assert_eq!(faulty.to_json().render(), base.to_json().render());
    }

    #[test]
    fn faults_fire_rebalance_and_conserve_sessions() {
        let driver = SimDriver::new(2);
        let topo = fast_topo();
        // Decode-dominated workload: near-simultaneous arrivals, short
        // prompts, long decode budgets — the run is a dense run of
        // near-uniform decode steps, so an outage spanning 30% of the
        // clean run is guaranteed to contain step boundaries (the fault
        // fires) and to end well before the trace drains (the recovery
        // fires too).
        let cfg = ServeConfig {
            arrival_per_sec: 1.0e6,
            prefill_lengths: vec![64],
            decode_tokens: vec![200],
            sessions: 4,
            max_active: 4,
            max_steps: 4000,
            ..tiny_serve()
        };
        let clean = serve_decode_faulty_with(
            &driver,
            &topo,
            2,
            &cfg,
            Policy::SwizzledHeadFirst,
            &FaultPlan::default(),
        );
        let t = clean.serve.sim_sec;
        let plan = FaultPlan {
            events: vec![FaultEvent { device: 1, fail_sec: 0.35 * t, recover_sec: 0.65 * t }],
        };
        let (stats, trace) = serve_decode_faulty_traced(
            &driver,
            &topo,
            2,
            &cfg,
            Policy::SwizzledHeadFirst,
            &plan,
        );
        let f = stats.faults.as_ref().expect("non-empty plan records extras");
        assert_eq!(f.events_applied, 2, "one fail + one recovery");
        assert_eq!(f.rebalances, 2, "width 2 -> 1 -> 2");
        assert!(f.requeued > 0, "the fault landed mid-serve");
        assert!(f.degraded_sec > 0.0);
        assert_eq!(trace.evictions.len(), f.requeued);
        assert_eq!(trace.transitions.len(), 2);
        assert_eq!(trace.leases_at_end, 0);
        // Windows partition the run: full width, degraded, full width.
        let widths: Vec<usize> = f.windows.iter().map(|w| w.width).collect();
        assert_eq!(widths, vec![2, 1, 2]);
        // No session lost or double-served: every session completes
        // exactly once, and every eviction pairs with one re-admission.
        assert!(!stats.serve.truncated);
        assert_eq!(stats.serve.sessions_completed, cfg.sessions);
        let mut completed = trace.completions.clone();
        completed.sort_unstable();
        assert_eq!(completed, (0..cfg.sessions as u64).collect::<Vec<_>>());
        for id in 0..cfg.sessions as u64 {
            let admitted = trace.admissions.iter().filter(|&&a| a == id).count();
            let evicted = trace.evictions.iter().filter(|&&e| e == id).count();
            assert_eq!(admitted, 1 + evicted, "session {id} re-admits once per eviction");
        }
        // Re-served decode work inflates the token count past the clean
        // trace's budget exactly when evictions hit decoding sessions.
        assert!(stats.serve.tokens >= clean.serve.tokens);
        // The JSON carries the extras under a trailing "faults" key.
        let json = stats.to_json().render();
        assert!(json.contains("\"faults\""));
        assert!(json.contains("\"windows\""));
    }

    #[test]
    fn blackout_jumps_to_recovery_and_still_drains() {
        let driver = SimDriver::new(2);
        let topo = fast_topo();
        let cfg = tiny_serve();
        // Both devices down from t=0 (before the first arrival): the
        // loop must jump the clock to the recoveries and then serve the
        // whole backlog.
        let plan = FaultPlan::parse("0:0:0.0002,1:0:0.0003").unwrap();
        let (stats, trace) = serve_decode_faulty_traced(
            &driver,
            &topo,
            2,
            &cfg,
            Policy::SwizzledHeadFirst,
            &plan,
        );
        let f = stats.faults.as_ref().unwrap();
        assert_eq!(f.events_applied, 4);
        assert!(f.rebalances >= 2, "blackout and both recoveries re-form the plan");
        assert!(!stats.serve.truncated);
        assert_eq!(stats.serve.sessions_completed, cfg.sessions);
        assert!(f.windows.iter().any(|w| w.width == 0), "a blackout window is recorded");
        assert!(stats.serve.sim_sec >= 0.0003, "the clock jumped past the last recovery");
        assert_eq!(trace.leases_at_end, 0);
    }
}
