//! Request routing, two layers:
//!
//! * [`Router`] — bucket incoming prefill requests by context length
//!   onto the fixed-shape attention artifacts the AOT step produced
//!   (the live PJRT service path).
//! * [`SessionRouter`] — route decode serving sessions through a
//!   disaggregated deployment (docs/DISAGG.md): which pool prefills the
//!   prompt and which pool decodes, as a pure function of the session
//!   and the deployment shape. admit → prefill pool → KV handoff →
//!   decode pool.

use std::collections::BTreeMap;

use crate::cluster::PoolKind;
use crate::runtime::Manifest;
use crate::workload::{Request, Session};

/// Maps a request's n_ctx to the artifact that serves it.
#[derive(Debug, Clone)]
pub struct Router {
    /// n_ctx -> artifact name (batch-1 attention artifacts only).
    buckets: BTreeMap<usize, String>,
}

impl Router {
    /// Build from a manifest: one bucket per batch-1 `attn_fwd` artifact,
    /// keyed by its n_ctx.
    pub fn from_manifest(manifest: &Manifest) -> Self {
        let mut buckets = BTreeMap::new();
        for a in manifest.attention_artifacts() {
            if let Some(attn) = &a.attn {
                if attn.batch == 1 && !attn.causal {
                    buckets.entry(attn.n_ctx).or_insert_with(|| a.name.clone());
                }
            }
        }
        Router { buckets }
    }

    /// Number of context-length buckets.
    pub fn num_buckets(&self) -> usize {
        self.buckets.len()
    }

    /// The bucket boundary lengths, ascending.
    pub fn bucket_lengths(&self) -> Vec<usize> {
        self.buckets.keys().copied().collect()
    }

    /// Artifact serving exactly `n_ctx`, if any.
    pub fn exact(&self, n_ctx: usize) -> Option<&str> {
        self.buckets.get(&n_ctx).map(|s| s.as_str())
    }

    /// Route a request: smallest bucket with capacity >= n_ctx
    /// (prompts are padded up to the bucket length).
    pub fn route(&self, req: &Request) -> Result<&str, RouteError> {
        self.buckets
            .range(req.n_ctx..)
            .next()
            .map(|(_, name)| name.as_str())
            .ok_or(RouteError::TooLong {
                n_ctx: req.n_ctx,
                max: self.buckets.keys().next_back().copied().unwrap_or(0),
            })
    }
}

/// Where a session's two serving phases run in a disaggregated
/// deployment (docs/DISAGG.md).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SessionRoute {
    /// Pool that prefills the session's prompt.
    pub prefill: PoolKind,
    /// Pool that decodes the session's tokens (and so owns its KV cache
    /// after the handoff).
    pub decode: PoolKind,
}

/// Routes decode serving sessions onto device pools. The assignment is
/// a *total function* of (session, deployment shape): it never depends
/// on arrival interleaving, queue depth, or any other runtime state —
/// pinned by the router property tests in `tests/properties.rs`. With a
/// prefill pool present, every session prefills there and decodes in
/// the decode pool (its KV blocks move across the interconnect at
/// handoff); colocated deployments run both phases on the decode pool
/// and hand off for free.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SessionRouter {
    disaggregated: bool,
}

impl SessionRouter {
    /// A router for a deployment with (`disaggregated = true`) or
    /// without (`false`, colocated) a dedicated prefill pool.
    pub fn new(disaggregated: bool) -> Self {
        SessionRouter { disaggregated }
    }

    /// True when a dedicated prefill pool exists.
    pub fn disaggregated(&self) -> bool {
        self.disaggregated
    }

    /// The pools serving this session's phases. Deliberately ignores
    /// everything about the session except that it exists: in this
    /// deployment model every session of a shape takes the same path,
    /// so routing is reproducible no matter how arrivals interleave.
    pub fn route(&self, _session: &Session) -> SessionRoute {
        if self.disaggregated {
            SessionRoute { prefill: PoolKind::Prefill, decode: PoolKind::Decode }
        } else {
            SessionRoute { prefill: PoolKind::Decode, decode: PoolKind::Decode }
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
/// Why a request could not be routed.
pub enum RouteError {
    /// The request's context exceeds every bucket.
    TooLong { n_ctx: usize, max: usize },
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::TooLong { n_ctx, max } => {
                write!(f, "request n_ctx {n_ctx} exceeds largest bucket {max}")
            }
        }
    }
}

impl std::error::Error for RouteError {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{ArtifactMeta, AttnMeta, TensorSpec};

    fn manifest() -> Manifest {
        let mk = |name: &str, n_ctx: usize, batch: usize, causal: bool| ArtifactMeta {
            name: name.into(),
            kind: "attn_fwd".into(),
            file: format!("{name}.hlo.txt"),
            inputs: vec![TensorSpec { shape: vec![batch, 8, n_ctx, 64], dtype: "float32".into() }],
            input_seeds: vec![1],
            outputs: vec![TensorSpec { shape: vec![batch, 8, n_ctx, 64], dtype: "float32".into() }],
            attn: Some(AttnMeta {
                batch,
                h_q: 8,
                h_k: 8,
                n_ctx,
                d_head: 64,
                causal,
                block_m: 64,
                block_n: 64,
                policy: "swizzled_head_first".into(),
                num_xcd: 8,
            }),
            golden: None,
        };
        Manifest {
            format: "hlo-text-v1".into(),
            artifacts: vec![
                mk("a128", 128, 1, false),
                mk("a256", 256, 1, false),
                mk("a256c", 256, 1, true),  // causal: not routable
                mk("a256b2", 256, 2, false), // batch 2: not a bucket
            ],
        }
    }

    fn req(n_ctx: usize) -> Request {
        Request { id: 0, n_ctx, seed: 1 }
    }

    #[test]
    fn buckets_from_manifest() {
        let r = Router::from_manifest(&manifest());
        assert_eq!(r.num_buckets(), 2);
        assert_eq!(r.bucket_lengths(), vec![128, 256]);
    }

    #[test]
    fn routes_exact_and_padded() {
        let r = Router::from_manifest(&manifest());
        assert_eq!(r.route(&req(128)).unwrap(), "a128");
        assert_eq!(r.route(&req(100)).unwrap(), "a128");
        assert_eq!(r.route(&req(129)).unwrap(), "a256");
        assert_eq!(r.route(&req(256)).unwrap(), "a256");
    }

    #[test]
    fn rejects_oversized() {
        let r = Router::from_manifest(&manifest());
        let err = r.route(&req(512)).unwrap_err();
        assert_eq!(err, RouteError::TooLong { n_ctx: 512, max: 256 });
    }

    #[test]
    fn session_router_is_shape_determined() {
        use crate::workload::SloClass;
        let s = Session {
            id: 7,
            arrival_sec: 1.5,
            prefill: 2048,
            decode_tokens: 16,
            shared_prefix: 0,
            slo: SloClass::Interactive,
        };
        let disagg = SessionRouter::new(true);
        assert!(disagg.disaggregated());
        assert_eq!(
            disagg.route(&s),
            SessionRoute { prefill: PoolKind::Prefill, decode: PoolKind::Decode }
        );
        let colo = SessionRouter::new(false);
        assert_eq!(
            colo.route(&s),
            SessionRoute { prefill: PoolKind::Decode, decode: PoolKind::Decode }
        );
        // The route ignores per-session fields entirely.
        let t = Session { id: 99, slo: SloClass::Batch, prefill: 64, ..s.clone() };
        assert_eq!(disagg.route(&s), disagg.route(&t));
    }
}
