//! Batching cores for the two serving paths, both pure data structures
//! so the policies are unit testable:
//!
//! * [`BatcherCore`] — wall-clock request batching for the live PJRT
//!   service: groups routed requests per bucket and releases a batch
//!   when it is full or its oldest member has waited `max_wait`
//!   (`service.rs` drives it from the worker loop).
//! * [`StepBatcher`] — *iteration-level* continuous batching for the
//!   simulated decode serving loop ([`crate::coordinator::serve_decode`],
//!   docs/SERVING.md): the active batch is re-formed every decode step
//!   as sessions arrive and finish, vLLM-style, instead of holding a
//!   batch together until every member completes.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::time::{Duration, Instant};

use crate::workload::{Request, Session};

#[derive(Debug, Clone, Copy)]
/// Batching policy: how large and how long a batch may grow.
pub struct BatcherConfig {
    /// Max requests per released batch (per bucket).
    pub max_batch: usize,
    /// Max time the oldest queued request may wait before release.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// A released batch for one artifact bucket.
#[derive(Debug)]
pub struct Batch {
    /// Artifact every request in this batch routes to.
    pub artifact: String,
    /// The batched requests with their enqueue times.
    pub requests: Vec<(Request, Instant)>,
}

#[derive(Debug)]
struct Pending {
    queue: VecDeque<(Request, Instant)>,
}

/// Per-bucket batching state machine.
#[derive(Debug)]
pub struct BatcherCore {
    cfg: BatcherConfig,
    pending: HashMap<String, Pending>,
}

impl BatcherCore {
    /// An empty batcher with the given policy.
    pub fn new(cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch > 0);
        BatcherCore { cfg, pending: HashMap::new() }
    }

    /// Requests currently waiting across all buckets.
    pub fn queued(&self) -> usize {
        self.pending.values().map(|p| p.queue.len()).sum()
    }

    /// Enqueue a routed request. Returns a batch if the bucket filled.
    pub fn push(&mut self, artifact: &str, req: Request, now: Instant) -> Option<Batch> {
        let p = self
            .pending
            .entry(artifact.to_string())
            .or_insert_with(|| Pending { queue: VecDeque::new() });
        p.queue.push_back((req, now));
        if p.queue.len() >= self.cfg.max_batch {
            return self.release(artifact);
        }
        None
    }

    /// Release every bucket whose oldest request exceeded `max_wait`.
    pub fn poll_expired(&mut self, now: Instant) -> Vec<Batch> {
        let expired: Vec<String> = self
            .pending
            .iter()
            .filter(|(_, p)| {
                p.queue
                    .front()
                    .is_some_and(|(_, t)| now.duration_since(*t) >= self.cfg.max_wait)
            })
            .map(|(k, _)| k.clone())
            .collect();
        expired.into_iter().filter_map(|k| self.release(&k)).collect()
    }

    /// Force-release a bucket (drain on shutdown).
    pub fn release(&mut self, artifact: &str) -> Option<Batch> {
        let p = self.pending.get_mut(artifact)?;
        if p.queue.is_empty() {
            return None;
        }
        let n = p.queue.len().min(self.cfg.max_batch);
        let requests: Vec<(Request, Instant)> = p.queue.drain(..n).collect();
        Some(Batch { artifact: artifact.to_string(), requests })
    }

    /// Drain everything (shutdown path).
    pub fn drain_all(&mut self) -> Vec<Batch> {
        let keys: Vec<String> = self.pending.keys().cloned().collect();
        let mut out = Vec::new();
        for k in keys {
            while let Some(b) = self.release(&k) {
                out.push(b);
            }
        }
        out
    }

    /// Earliest deadline across buckets (for the service's sleep timer).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.pending
            .values()
            .filter_map(|p| p.queue.front().map(|(_, t)| *t + self.cfg.max_wait))
            .min()
    }
}

/// A session admitted to the decode loop, with its prefill and
/// generation progress. Under chunked prefill (docs/SERVING.md §6) a
/// session admits with `prefill_done = 0` and streams its prompt in
/// chunks before it may decode; with chunking off the prompt is charged
/// monolithically at admission and `prefill_done` starts complete.
#[derive(Debug, Clone)]
pub struct ActiveSession {
    /// The admitted session.
    pub session: Session,
    /// Prompt tokens prefilled so far (== `session.prefill` once the
    /// session has entered its decode phase).
    pub prefill_done: usize,
    /// Decode tokens generated so far.
    pub generated: usize,
}

impl ActiveSession {
    /// Current KV-cache length, clamped to the deployment's capacity.
    pub fn kv_len(&self, kv_cap: usize) -> usize {
        self.session.kv_len(self.generated, kv_cap)
    }

    /// True once the whole prompt has been prefilled (the session is in
    /// its decode phase and emits one token per step).
    pub fn prefill_complete(&self) -> bool {
        self.prefill_done >= self.session.prefill
    }

    /// Prompt tokens still waiting to be prefilled.
    pub fn prefill_remaining(&self) -> usize {
        self.session.prefill.saturating_sub(self.prefill_done)
    }

    /// True once the session has generated its full decode budget.
    pub fn done(&self) -> bool {
        self.generated >= self.session.decode_tokens
    }
}

/// One chunked-prefill launch planned for a step: extends session `id`'s
/// prefilled prompt prefix from `start` to `end` tokens (raw prompt
/// positions; the executor clamps to the KV capacity when pricing).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefillChunk {
    /// Session the chunk belongs to.
    pub id: u64,
    /// Prompt tokens already prefilled before this chunk.
    pub start: usize,
    /// Prompt tokens prefilled after this chunk (`start < end`).
    pub end: usize,
}

impl PrefillChunk {
    /// Prompt tokens this chunk streams.
    pub fn tokens(&self) -> usize {
        self.end - self.start
    }
}

/// Priority key of a session in the SLO admission queue: class rank
/// first (interactive before batch), then arrival time, then id. The
/// f64 arrival is compared by IEEE-754 bit pattern, which preserves
/// order for the non-negative trace clocks the generator emits — and
/// makes the whole ordering total and deterministic.
fn slo_key(s: &Session) -> (u8, u64, u64) {
    (s.slo.rank(), s.arrival_sec.to_bits(), s.id)
}

#[derive(Debug, Clone)]
struct SloEntry {
    key: (u8, u64, u64),
    session: Session,
}

impl PartialEq for SloEntry {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
    }
}

impl Eq for SloEntry {}

impl PartialOrd for SloEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for SloEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.key.cmp(&other.key)
    }
}

/// SLO-aware admission queue for disaggregated serving
/// (docs/DISAGG.md): a deterministic min-heap over arrived sessions,
/// popping [`crate::workload::SloClass::Interactive`] sessions before
/// `Batch` ones, ties broken by arrival time then id. Differentially
/// pinned against a naive sorted-vector model in `tests/properties.rs`.
#[derive(Debug, Default)]
pub struct SloQueue {
    heap: BinaryHeap<Reverse<SloEntry>>,
}

impl SloQueue {
    /// An empty queue.
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue an arrived session.
    pub fn push(&mut self, session: Session) {
        self.heap.push(Reverse(SloEntry { key: slo_key(&session), session }));
    }

    /// Dequeue the highest-priority session (interactive first, then
    /// earliest arrival, then lowest id).
    pub fn pop(&mut self) -> Option<Session> {
        self.heap.pop().map(|Reverse(e)| e.session)
    }

    /// The session [`Self::pop`] would return, without removing it.
    pub fn peek(&self) -> Option<&Session> {
        self.heap.peek().map(|Reverse(e)| &e.session)
    }

    /// Sessions queued.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// Iteration-level continuous batcher over simulated decode steps.
///
/// Holds the arrival-ordered backlog of not-yet-admitted sessions and the
/// active set currently generating. Every decode step the serving loop
/// (1) admits arrived sessions up to `max_active` ([`Self::admit`]),
/// (2) reads the active set to form this step's kernel launches —
/// optionally planning chunked-prefill launches under a token budget
/// ([`Self::plan_chunks`]) — and
/// (3) calls [`Self::advance_step`] to emit one token per decode-phase
/// session and retire the finished ones — freeing their slots for the
/// next arrivals. No session ever waits for an unrelated session's
/// completion, which is the continuous-batching property
/// (docs/SERVING.md §3).
#[derive(Debug)]
pub struct StepBatcher {
    max_active: usize,
    chunk_tokens: usize,
    backlog: VecDeque<Session>,
    /// Arrived-but-unadmitted sessions under SLO-aware admission
    /// ([`Self::admit_slo`]); always empty under plain [`Self::admit`].
    slo_queue: SloQueue,
    active: Vec<ActiveSession>,
    completed: usize,
    retired: Vec<u64>,
}

impl StepBatcher {
    /// A batcher over an arrival-ordered trace (re-sorted defensively;
    /// ties break on session id so the order is total and deterministic).
    /// `chunk_tokens = 0` is monolithic prefill: admission marks the
    /// whole prompt prefilled (the loop charges it in the admission
    /// step); `chunk_tokens > 0` admits sessions with an empty prefix
    /// and streams prompts through [`Self::plan_chunks`].
    pub fn new(mut sessions: Vec<Session>, max_active: usize, chunk_tokens: usize) -> Self {
        assert!(max_active > 0, "max_active must be > 0");
        sessions.sort_by(|a, b| {
            a.arrival_sec.total_cmp(&b.arrival_sec).then(a.id.cmp(&b.id))
        });
        StepBatcher {
            max_active,
            chunk_tokens,
            backlog: sessions.into(),
            slo_queue: SloQueue::new(),
            active: Vec::new(),
            completed: 0,
            retired: Vec::new(),
        }
    }

    /// Credit a just-admitted session's leading `tokens` prompt tokens
    /// as already prefilled — the paged KV pool found them resident
    /// (docs/KVCACHE.md), so no prefill chunk will ever cover them.
    /// Clamps to the prompt length; crediting the whole prompt moves
    /// the session straight to its decode phase. Only meaningful under
    /// chunked prefill (monolithic admission already marks the prompt
    /// complete; the loop discounts its charge instead).
    pub fn credit_prefix(&mut self, id: u64, tokens: usize) {
        if let Some(a) = self.active.iter_mut().find(|a| a.session.id == id) {
            a.prefill_done = a.prefill_done.max(tokens.min(a.session.prefill));
        }
    }

    /// Admit every backlog session that has arrived by `now_sec`, oldest
    /// first, until the active set reaches `max_active`. Returns the
    /// newly admitted sessions (with chunking off the serving loop
    /// charges their whole prefill; with chunking on they enter with an
    /// empty prefilled prefix and stream through [`Self::plan_chunks`]).
    pub fn admit(&mut self, now_sec: f64) -> Vec<Session> {
        let mut newly = Vec::new();
        while self.active.len() < self.max_active {
            match self.backlog.front() {
                Some(s) if s.arrival_sec <= now_sec => {
                    let s = self.backlog.pop_front().unwrap();
                    newly.push(s.clone());
                    let prefill_done = if self.chunk_tokens == 0 { s.prefill } else { 0 };
                    self.active.push(ActiveSession { session: s, prefill_done, generated: 0 });
                }
                _ => break,
            }
        }
        newly
    }

    /// SLO-aware admission (docs/DISAGG.md): every backlog session that
    /// has arrived by `now_sec` moves into the priority queue, then the
    /// queue pops into free slots — interactive sessions first, ties by
    /// arrival then id. With every session in one class this admits the
    /// exact set plain [`Self::admit`] would (the queue key degenerates
    /// to arrival order), which is what the no-SLO golden pins rely on.
    /// Never mix `admit` and `admit_slo` on one batcher: plain `admit`
    /// bypasses sessions already staged in the queue.
    pub fn admit_slo(&mut self, now_sec: f64) -> Vec<Session> {
        while self.backlog.front().is_some_and(|s| s.arrival_sec <= now_sec) {
            let s = self.backlog.pop_front().unwrap();
            self.slo_queue.push(s);
        }
        let mut newly = Vec::new();
        while self.active.len() < self.max_active {
            match self.slo_queue.pop() {
                Some(s) => {
                    newly.push(s.clone());
                    let prefill_done = if self.chunk_tokens == 0 { s.prefill } else { 0 };
                    self.active.push(ActiveSession { session: s, prefill_done, generated: 0 });
                }
                None => break,
            }
        }
        newly
    }

    /// The sessions decoding this step, in admission order.
    pub fn active(&self) -> &[ActiveSession] {
        &self.active
    }

    /// Sessions in their decode phase (prompt fully prefilled) — the set
    /// that forms this step's decode launches and emits tokens. With
    /// chunking off this is the whole active set.
    pub fn decoding(&self) -> usize {
        self.active.iter().filter(|a| a.prefill_complete()).count()
    }

    /// Plan this step's chunked-prefill launches under a prompt-token
    /// budget: walk the active set in admission order, give each
    /// still-prefilling session one chunk of up to `chunk_tokens` (less
    /// only when its prompt runs out), and stop at the first chunk that
    /// does not fit the remaining budget. Chunks are never *split* to
    /// fit — that would leave ragged prefix lengths that defeat the
    /// report cache's geometry sharing; instead the budget rolls over to
    /// the next step, so every session's prefix walks `chunk_tokens`
    /// multiples up to its prompt length. Advances each chunked
    /// session's `prefill_done`, so the returned chunks are exactly the
    /// prompt tokens executed this step — every prompt token appears in
    /// exactly one chunk across the session's lifetime (pinned by
    /// `tests/serving_invariants.rs`). Returns an empty plan when
    /// chunking is off.
    pub fn plan_chunks(&mut self, budget_tokens: usize) -> Vec<PrefillChunk> {
        self.plan_chunks_where(budget_tokens, |_| false)
    }

    /// [`Self::plan_chunks`] with a preemption filter (docs/DISAGG.md):
    /// sessions for which `skip` returns true are passed over without a
    /// chunk — their prefix cursor does not move and they consume no
    /// budget, so the skipped chunk is re-planned (identically, from the
    /// same `start`) on the next step that stops skipping it. With a
    /// never-skip filter this is exactly `plan_chunks`.
    pub fn plan_chunks_where(
        &mut self,
        budget_tokens: usize,
        skip: impl Fn(&ActiveSession) -> bool,
    ) -> Vec<PrefillChunk> {
        let mut out = Vec::new();
        if self.chunk_tokens == 0 {
            return out;
        }
        let mut left = budget_tokens;
        for a in &mut self.active {
            if a.prefill_complete() || skip(a) {
                continue;
            }
            let take = self.chunk_tokens.min(a.prefill_remaining());
            if take > left {
                break;
            }
            out.push(PrefillChunk {
                id: a.session.id,
                start: a.prefill_done,
                end: a.prefill_done + take,
            });
            a.prefill_done += take;
            left -= take;
        }
        out
    }

    /// Evict the whole active set back into the backlog — the fault
    /// path's re-queue (docs/SERVING.md §9): when devices drop mid-step,
    /// every in-flight session loses its (device-resident) KV state, so
    /// progress resets — prefill restarts from zero and the decode
    /// counter rewinds; already-emitted tokens stay counted in the
    /// loop's totals, so conservation checks must use completions, not
    /// token counts, across a fault. Re-queued sessions keep their
    /// original arrival times and ids and the backlog re-sorts to
    /// arrival order, so post-fault admission is deterministic. Returns
    /// the evicted sessions in admission order (the caller releases
    /// their KV leases and re-routes them).
    pub fn requeue_active(&mut self) -> Vec<Session> {
        let evicted: Vec<Session> =
            self.active.drain(..).map(|a| a.session).collect();
        for s in &evicted {
            self.backlog.push_back(s.clone());
        }
        let mut sorted: Vec<Session> = std::mem::take(&mut self.backlog).into();
        sorted.sort_by(|a, b| {
            a.arrival_sec.total_cmp(&b.arrival_sec).then(a.id.cmp(&b.id))
        });
        self.backlog = sorted.into();
        evicted
    }

    /// Drain every prefill-complete active session — the disaggregated
    /// prefill pool's handoff point (docs/DISAGG.md): sessions leave
    /// this batcher the moment their prompt is fully prefilled and
    /// continue their decode phase in the decode pool, so they neither
    /// emit tokens nor count as completed here. Admission order is
    /// preserved. The colocated loop never calls this.
    pub fn take_prefilled(&mut self) -> Vec<Session> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            if self.active[i].prefill_complete() {
                out.push(self.active.remove(i).session);
            } else {
                i += 1;
            }
        }
        out
    }

    /// Arrival time of the next backlog session (for jumping simulated
    /// time across idle gaps), `None` when the backlog is drained.
    pub fn next_arrival_sec(&self) -> Option<f64> {
        self.backlog.front().map(|s| s.arrival_sec)
    }

    /// One decode step: every decode-phase session generates one token;
    /// finished sessions retire, freeing their slots. Sessions still
    /// streaming their prompt neither emit nor retire. Returns the
    /// number of tokens emitted (the decode-phase count at entry).
    pub fn advance_step(&mut self) -> usize {
        let mut emitted = 0;
        for a in &mut self.active {
            if a.prefill_complete() {
                a.generated += 1;
                emitted += 1;
            }
        }
        let before = self.active.len();
        let retired = &mut self.retired;
        self.active.retain(|a| {
            let keep = !a.done();
            if !keep {
                retired.push(a.session.id);
            }
            keep
        });
        self.completed += before - self.active.len();
        emitted
    }

    /// Session ids retired since the last drain (in retirement order) —
    /// the serving loop releases their KV-pool leases here.
    pub fn drain_retired(&mut self) -> Vec<u64> {
        std::mem::take(&mut self.retired)
    }

    /// Sessions retired so far.
    pub fn completed(&self) -> usize {
        self.completed
    }

    /// Sessions still waiting for admission (not-yet-arrived backlog
    /// plus anything staged in the SLO queue).
    pub fn backlog_len(&self) -> usize {
        self.backlog.len() + self.slo_queue.len()
    }

    /// True once every session has been admitted and retired.
    pub fn done(&self) -> bool {
        self.backlog.is_empty() && self.slo_queue.is_empty() && self.active.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::SloClass;

    fn req(id: u64) -> Request {
        Request { id, n_ctx: 128, seed: id | 1 }
    }

    fn sess(id: u64, arrival: f64, decode: usize) -> Session {
        Session {
            id,
            arrival_sec: arrival,
            prefill: 1024,
            decode_tokens: decode,
            shared_prefix: 0,
            slo: SloClass::Batch,
        }
    }

    fn sess_slo(id: u64, arrival: f64, slo: SloClass) -> Session {
        Session { slo, ..sess(id, arrival, 4) }
    }

    #[test]
    fn step_batcher_admits_in_arrival_order_up_to_cap() {
        let trace = vec![sess(0, 0.0, 4), sess(1, 0.0, 4), sess(2, 0.5, 4), sess(3, 9.0, 4)];
        let mut b = StepBatcher::new(trace, 2, 0);
        let newly = b.admit(0.6);
        assert_eq!(newly.iter().map(|s| s.id).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(b.active().len(), 2, "capacity caps admission");
        assert_eq!(b.backlog_len(), 2);
        assert_eq!(b.next_arrival_sec(), Some(0.5), "session 2 arrived but has no slot");
        // Nothing new admitted while full.
        assert!(b.admit(0.7).is_empty());
    }

    #[test]
    fn step_batcher_continuous_refill_and_completion() {
        let trace = vec![sess(0, 0.0, 2), sess(1, 0.0, 5), sess(2, 0.0, 5)];
        let mut b = StepBatcher::new(trace, 2, 0);
        b.admit(0.0);
        assert_eq!(b.advance_step(), 2); // ids 0, 1 emit a token each
        assert_eq!(b.advance_step(), 2); // id 0 finishes here
        assert_eq!(b.completed(), 1);
        assert_eq!(b.active().len(), 1);
        // The freed slot admits session 2 without waiting for session 1.
        let newly = b.admit(0.0);
        assert_eq!(newly.len(), 1);
        assert_eq!(newly[0].id, 2);
        let mut steps = 0;
        while !b.done() {
            b.advance_step();
            b.admit(0.0);
            steps += 1;
            assert!(steps < 20, "loop must terminate");
        }
        assert_eq!(b.completed(), 3);
        assert_eq!(b.advance_step(), 0, "idle steps emit nothing");
    }

    #[test]
    fn step_batcher_kv_grows_per_token() {
        let mut b = StepBatcher::new(vec![sess(0, 0.0, 3)], 1, 0);
        b.admit(0.0);
        assert_eq!(b.active()[0].kv_len(1 << 20), 1024);
        assert!(b.active()[0].prefill_complete(), "monolithic admission completes prefill");
        b.advance_step();
        assert_eq!(b.active()[0].kv_len(1 << 20), 1025);
        assert_eq!(b.active()[0].kv_len(1025), 1025);
        assert_eq!(b.active()[0].kv_len(512), 512, "capacity clamp");
    }

    #[test]
    fn chunked_sessions_stream_prompts_before_decoding() {
        // prefill = 1024, chunk = 512: two chunks before the first token.
        let mut b = StepBatcher::new(vec![sess(0, 0.0, 2)], 1, 512);
        b.admit(0.0);
        assert!(!b.active()[0].prefill_complete());
        assert_eq!(b.decoding(), 0);
        assert_eq!(b.advance_step(), 0, "prefilling sessions emit nothing");

        let c1 = b.plan_chunks(usize::MAX);
        assert_eq!(c1, vec![PrefillChunk { id: 0, start: 0, end: 512 }]);
        assert_eq!(b.advance_step(), 0);

        let c2 = b.plan_chunks(usize::MAX);
        assert_eq!(c2, vec![PrefillChunk { id: 0, start: 512, end: 1024 }]);
        assert!(b.active()[0].prefill_complete());
        assert_eq!(b.decoding(), 1);
        assert_eq!(b.advance_step(), 1, "decode starts the step prefill completes");
        assert!(b.plan_chunks(usize::MAX).is_empty(), "nothing left to prefill");
        assert_eq!(b.advance_step(), 1);
        assert!(b.done());
        assert_eq!(b.completed(), 1);
    }

    #[test]
    fn chunk_budget_caps_the_step_and_respects_admission_order() {
        let mut b = StepBatcher::new(vec![sess(0, 0.0, 1), sess(1, 0.0, 1)], 2, 512);
        b.admit(0.0);
        // Budget 700: session 0 gets its full 512-token chunk; session
        // 1's chunk does not fit the 188 tokens left, and chunks are
        // never split to fit (ragged prefixes would defeat the report
        // cache), so it waits for the next step.
        let chunks = b.plan_chunks(700);
        assert_eq!(chunks, vec![PrefillChunk { id: 0, start: 0, end: 512 }]);
        // Zero budget plans nothing (decode tokens consumed it all).
        assert!(b.plan_chunks(0).is_empty());
        // Uncapped: both sessions stream one chunk, in admission order;
        // a chunk never exceeds the session's remaining prompt.
        let chunks = b.plan_chunks(usize::MAX);
        assert_eq!(
            chunks,
            vec![
                PrefillChunk { id: 0, start: 512, end: 1024 },
                PrefillChunk { id: 1, start: 0, end: 512 },
            ]
        );
        assert_eq!(chunks.iter().map(PrefillChunk::tokens).sum::<usize>(), 1024);
        assert!(b.active()[0].prefill_complete());
        let tail = b.plan_chunks(usize::MAX);
        assert_eq!(tail, vec![PrefillChunk { id: 1, start: 512, end: 1024 }]);
        assert!(b.active().iter().all(ActiveSession::prefill_complete));
    }

    #[test]
    fn credit_prefix_skips_resident_prompt_and_retired_ids_drain() {
        // prefill = 1024, chunk = 512, pool credited the first 512.
        let mut b = StepBatcher::new(vec![sess(0, 0.0, 1), sess(1, 0.0, 2)], 2, 512);
        b.admit(0.0);
        b.credit_prefix(0, 512);
        b.credit_prefix(7, 512); // unknown id: no-op
        let chunks = b.plan_chunks(usize::MAX);
        assert_eq!(
            chunks,
            vec![
                PrefillChunk { id: 0, start: 512, end: 1024 },
                PrefillChunk { id: 1, start: 0, end: 512 },
            ],
            "credited prefix is never re-planned"
        );
        // Credit never regresses progress and clamps to the prompt.
        b.credit_prefix(1, 256);
        b.credit_prefix(1, 4096);
        assert!(b.active().iter().all(ActiveSession::prefill_complete));
        assert_eq!(b.advance_step(), 2);
        assert_eq!(b.drain_retired(), vec![0], "session 0 retired after its 1 token");
        assert_eq!(b.advance_step(), 1);
        assert_eq!(b.drain_retired(), vec![1]);
        assert!(b.drain_retired().is_empty(), "drain is one-shot");
        assert!(b.done());
    }

    #[test]
    fn slo_queue_orders_class_then_arrival_then_id() {
        let mut q = SloQueue::new();
        q.push(sess_slo(3, 0.5, SloClass::Batch));
        q.push(sess_slo(1, 0.9, SloClass::Interactive));
        q.push(sess_slo(2, 0.1, SloClass::Batch));
        q.push(sess_slo(0, 0.9, SloClass::Interactive)); // id tie-break
        assert_eq!(q.len(), 4);
        assert_eq!(q.peek().unwrap().id, 0);
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|s| s.id).collect();
        // Interactive first (arrival tie broken by id), then batch by arrival.
        assert_eq!(order, vec![0, 1, 2, 3]);
        assert!(q.is_empty());
    }

    #[test]
    fn admit_slo_prioritizes_interactive_and_matches_admit_when_uniform() {
        // An interactive session that arrived *later* than two batch
        // sessions jumps the queue when only one slot is free.
        let trace = vec![
            sess_slo(0, 0.0, SloClass::Batch),
            sess_slo(1, 0.1, SloClass::Batch),
            sess_slo(2, 0.2, SloClass::Interactive),
        ];
        let mut b = StepBatcher::new(trace, 1, 0);
        let newly = b.admit_slo(0.5);
        assert_eq!(newly.iter().map(|s| s.id).collect::<Vec<_>>(), vec![2]);
        assert_eq!(b.backlog_len(), 2, "bypassed sessions stay staged in the queue");
        assert!(!b.done());
        b.advance_step();
        // All-one-class traces admit exactly like plain admit().
        let uni: Vec<Session> = (0..4).map(|i| sess(i, 0.1 * i as f64, 2)).collect();
        let mut a = StepBatcher::new(uni.clone(), 2, 0);
        let mut s = StepBatcher::new(uni, 2, 0);
        let ids = |v: Vec<Session>| v.iter().map(|x| x.id).collect::<Vec<_>>();
        assert_eq!(ids(a.admit(1.0)), ids(s.admit_slo(1.0)));
        a.advance_step();
        s.advance_step();
        assert_eq!(ids(a.admit(1.0)), ids(s.admit_slo(1.0)));
    }

    #[test]
    fn plan_chunks_where_skips_without_spending_budget_and_replans_identically() {
        let mut b = StepBatcher::new(vec![sess(0, 0.0, 1), sess(1, 0.0, 1)], 2, 512);
        b.admit(0.0);
        // Budget 512 with session 0 preempted: session 1 takes the
        // budget session 0 would have consumed.
        let chunks = b.plan_chunks_where(512, |a| a.session.id == 0);
        assert_eq!(chunks, vec![PrefillChunk { id: 1, start: 0, end: 512 }]);
        // Lifting the preemption re-plans session 0's chunk from the
        // same start — exactly once, never duplicated.
        let chunks = b.plan_chunks_where(512, |_| false);
        assert_eq!(chunks, vec![PrefillChunk { id: 0, start: 0, end: 512 }]);
        // A never-skip filter is plan_chunks.
        let rest = b.plan_chunks(usize::MAX);
        assert_eq!(
            rest,
            vec![
                PrefillChunk { id: 0, start: 512, end: 1024 },
                PrefillChunk { id: 1, start: 512, end: 1024 },
            ]
        );
    }

    #[test]
    fn requeue_active_resets_progress_and_restores_arrival_order() {
        let trace = vec![sess(0, 0.0, 4), sess(1, 0.1, 4), sess(2, 0.2, 4), sess(3, 9.0, 4)];
        let mut b = StepBatcher::new(trace, 2, 0);
        b.admit(0.5);
        b.advance_step(); // ids 0, 1 each emit one token
        let evicted = b.requeue_active();
        assert_eq!(evicted.iter().map(|s| s.id).collect::<Vec<_>>(), vec![0, 1]);
        assert!(b.active().is_empty());
        assert_eq!(b.backlog_len(), 4);
        assert_eq!(b.completed(), 0, "eviction is not completion");
        // Re-admission runs in arrival order, ahead of the never-admitted
        // later arrivals, and progress restarts from zero.
        let newly = b.admit(0.5);
        assert_eq!(newly.iter().map(|s| s.id).collect::<Vec<_>>(), vec![0, 1]);
        assert!(b.active().iter().all(|a| a.generated == 0));
        // Each session re-admits exactly once: drain to completion and
        // count retirements.
        let mut guard = 0;
        while !b.done() {
            b.advance_step();
            b.admit(10.0);
            guard += 1;
            assert!(guard < 40, "loop must terminate");
        }
        assert_eq!(b.completed(), 4);
        // An empty active set requeues nothing.
        assert!(b.requeue_active().is_empty());
    }

    #[test]
    fn take_prefilled_drains_ready_sessions_without_completing_them() {
        let mut b = StepBatcher::new(vec![sess(0, 0.0, 4), sess(1, 0.0, 4)], 2, 512);
        b.admit(0.0);
        assert!(b.take_prefilled().is_empty(), "nothing prefilled yet");
        b.credit_prefix(0, 1024);
        let handed = b.take_prefilled();
        assert_eq!(handed.iter().map(|s| s.id).collect::<Vec<_>>(), vec![0]);
        assert_eq!(b.active().len(), 1, "session 1 still streaming");
        assert_eq!(b.completed(), 0, "handoff is not completion");
        b.plan_chunks(usize::MAX);
        b.plan_chunks(usize::MAX);
        assert_eq!(b.take_prefilled().len(), 1);
        assert!(b.done(), "drained batcher is done");
        // Monolithic admission hands off immediately after the charge.
        let mut m = StepBatcher::new(vec![sess(2, 0.0, 4)], 1, 0);
        m.admit(0.0);
        assert_eq!(m.take_prefilled().len(), 1);
    }

    #[test]
    fn releases_when_full() {
        let mut b = BatcherCore::new(BatcherConfig { max_batch: 3, max_wait: Duration::from_secs(10) });
        let t = Instant::now();
        assert!(b.push("a", req(0), t).is_none());
        assert!(b.push("a", req(1), t).is_none());
        let batch = b.push("a", req(2), t).unwrap();
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn buckets_are_independent() {
        let mut b = BatcherCore::new(BatcherConfig { max_batch: 2, max_wait: Duration::from_secs(10) });
        let t = Instant::now();
        b.push("a", req(0), t);
        b.push("b", req(1), t);
        assert_eq!(b.queued(), 2);
        let batch = b.push("a", req(2), t).unwrap();
        assert_eq!(batch.artifact, "a");
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn expiry_releases_partial_batch() {
        let mut b = BatcherCore::new(BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) });
        let t0 = Instant::now();
        b.push("a", req(0), t0);
        assert!(b.poll_expired(t0).is_empty());
        let later = t0 + Duration::from_millis(6);
        let batches = b.poll_expired(later);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].requests.len(), 1);
    }

    #[test]
    fn next_deadline_is_oldest() {
        let mut b = BatcherCore::new(BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) });
        let t0 = Instant::now();
        b.push("a", req(0), t0);
        b.push("b", req(1), t0 + Duration::from_millis(1));
        assert_eq!(b.next_deadline().unwrap(), t0 + Duration::from_millis(5));
    }

    #[test]
    fn drain_all_flushes_everything() {
        let mut b = BatcherCore::new(BatcherConfig { max_batch: 2, max_wait: Duration::from_secs(1) });
        let t = Instant::now();
        for i in 0..5 {
            b.push("a", req(i), t);
        }
        // push released 2 batches of 2 already (at i=1 and i=3).
        let drained = b.drain_all();
        let total: usize = drained.iter().map(|x| x.requests.len()).sum();
        assert_eq!(total, 1);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn release_caps_at_max_batch() {
        let mut b = BatcherCore::new(BatcherConfig { max_batch: 2, max_wait: Duration::from_secs(10) });
        let t = Instant::now();
        // Fill without triggering auto-release by using distinct buckets…
        // simpler: push 2 (auto-release), then 1 more and force release.
        b.push("a", req(0), t);
        let auto = b.push("a", req(1), t).unwrap();
        assert_eq!(auto.requests.len(), 2);
        b.push("a", req(2), t);
        let manual = b.release("a").unwrap();
        assert_eq!(manual.requests.len(), 1);
    }
}
