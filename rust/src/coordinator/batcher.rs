//! Continuous batching core: groups routed requests per bucket and
//! releases a batch when it is full or its oldest member has waited
//! `max_wait`. Pure data structure (no tokio) so the policy is unit
//! testable; `service.rs` drives it from the async loop.

use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

use crate::workload::Request;

#[derive(Debug, Clone, Copy)]
/// Batching policy: how large and how long a batch may grow.
pub struct BatcherConfig {
    /// Max requests per released batch (per bucket).
    pub max_batch: usize,
    /// Max time the oldest queued request may wait before release.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

/// A released batch for one artifact bucket.
#[derive(Debug)]
pub struct Batch {
    /// Artifact every request in this batch routes to.
    pub artifact: String,
    /// The batched requests with their enqueue times.
    pub requests: Vec<(Request, Instant)>,
}

#[derive(Debug)]
struct Pending {
    queue: VecDeque<(Request, Instant)>,
}

/// Per-bucket batching state machine.
#[derive(Debug)]
pub struct BatcherCore {
    cfg: BatcherConfig,
    pending: HashMap<String, Pending>,
}

impl BatcherCore {
    /// An empty batcher with the given policy.
    pub fn new(cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch > 0);
        BatcherCore { cfg, pending: HashMap::new() }
    }

    /// Requests currently waiting across all buckets.
    pub fn queued(&self) -> usize {
        self.pending.values().map(|p| p.queue.len()).sum()
    }

    /// Enqueue a routed request. Returns a batch if the bucket filled.
    pub fn push(&mut self, artifact: &str, req: Request, now: Instant) -> Option<Batch> {
        let p = self
            .pending
            .entry(artifact.to_string())
            .or_insert_with(|| Pending { queue: VecDeque::new() });
        p.queue.push_back((req, now));
        if p.queue.len() >= self.cfg.max_batch {
            return self.release(artifact);
        }
        None
    }

    /// Release every bucket whose oldest request exceeded `max_wait`.
    pub fn poll_expired(&mut self, now: Instant) -> Vec<Batch> {
        let expired: Vec<String> = self
            .pending
            .iter()
            .filter(|(_, p)| {
                p.queue
                    .front()
                    .is_some_and(|(_, t)| now.duration_since(*t) >= self.cfg.max_wait)
            })
            .map(|(k, _)| k.clone())
            .collect();
        expired.into_iter().filter_map(|k| self.release(&k)).collect()
    }

    /// Force-release a bucket (drain on shutdown).
    pub fn release(&mut self, artifact: &str) -> Option<Batch> {
        let p = self.pending.get_mut(artifact)?;
        if p.queue.is_empty() {
            return None;
        }
        let n = p.queue.len().min(self.cfg.max_batch);
        let requests: Vec<(Request, Instant)> = p.queue.drain(..n).collect();
        Some(Batch { artifact: artifact.to_string(), requests })
    }

    /// Drain everything (shutdown path).
    pub fn drain_all(&mut self) -> Vec<Batch> {
        let keys: Vec<String> = self.pending.keys().cloned().collect();
        let mut out = Vec::new();
        for k in keys {
            while let Some(b) = self.release(&k) {
                out.push(b);
            }
        }
        out
    }

    /// Earliest deadline across buckets (for the service's sleep timer).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.pending
            .values()
            .filter_map(|p| p.queue.front().map(|(_, t)| *t + self.cfg.max_wait))
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request { id, n_ctx: 128, seed: id | 1 }
    }

    #[test]
    fn releases_when_full() {
        let mut b = BatcherCore::new(BatcherConfig { max_batch: 3, max_wait: Duration::from_secs(10) });
        let t = Instant::now();
        assert!(b.push("a", req(0), t).is_none());
        assert!(b.push("a", req(1), t).is_none());
        let batch = b.push("a", req(2), t).unwrap();
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn buckets_are_independent() {
        let mut b = BatcherCore::new(BatcherConfig { max_batch: 2, max_wait: Duration::from_secs(10) });
        let t = Instant::now();
        b.push("a", req(0), t);
        b.push("b", req(1), t);
        assert_eq!(b.queued(), 2);
        let batch = b.push("a", req(2), t).unwrap();
        assert_eq!(batch.artifact, "a");
        assert_eq!(b.queued(), 1);
    }

    #[test]
    fn expiry_releases_partial_batch() {
        let mut b = BatcherCore::new(BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) });
        let t0 = Instant::now();
        b.push("a", req(0), t0);
        assert!(b.poll_expired(t0).is_empty());
        let later = t0 + Duration::from_millis(6);
        let batches = b.poll_expired(later);
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].requests.len(), 1);
    }

    #[test]
    fn next_deadline_is_oldest() {
        let mut b = BatcherCore::new(BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(5) });
        let t0 = Instant::now();
        b.push("a", req(0), t0);
        b.push("b", req(1), t0 + Duration::from_millis(1));
        assert_eq!(b.next_deadline().unwrap(), t0 + Duration::from_millis(5));
    }

    #[test]
    fn drain_all_flushes_everything() {
        let mut b = BatcherCore::new(BatcherConfig { max_batch: 2, max_wait: Duration::from_secs(1) });
        let t = Instant::now();
        for i in 0..5 {
            b.push("a", req(i), t);
        }
        // push released 2 batches of 2 already (at i=1 and i=3).
        let drained = b.drain_all();
        let total: usize = drained.iter().map(|x| x.requests.len()).sum();
        assert_eq!(total, 1);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn release_caps_at_max_batch() {
        let mut b = BatcherCore::new(BatcherConfig { max_batch: 2, max_wait: Duration::from_secs(10) });
        let t = Instant::now();
        // Fill without triggering auto-release by using distinct buckets…
        // simpler: push 2 (auto-release), then 1 more and force release.
        b.push("a", req(0), t);
        let auto = b.push("a", req(1), t).unwrap();
        assert_eq!(auto.requests.len(), 2);
        b.push("a", req(2), t);
        let manual = b.release("a").unwrap();
        assert_eq!(manual.requests.len(), 1);
    }
}
