//! Mapping autotuner: search the composed [`MappingSpec`] algebra for
//! the best mapping per (topology, workload), instead of trusting the
//! advisor's fixed four-policy heuristic.
//!
//! The search space is [`Policy::all_canonical`] pruned to the points
//! that are *behaviorally distinct on the workload's grid*:
//!
//! * swizzled points are dropped when `h_q % num_xcds != 0` (the same
//!   applicability rule as [`super::advisor::applicable_policies`]);
//! * `grouped` split placement is a no-op on prefill/backward grids, so
//!   non-decode workloads search only the `inherit` plane (8 points);
//! * on decode grids `grouped` forces head-first traversal, so
//!   `*-head-*-grouped` duplicates `*-head-*-inherit` and is dropped
//!   (12 points remain).
//!
//! Every candidate is priced through the memoized driver
//! ([`crate::driver::SimDriver`]): re-tuning a (topology, workload) the
//! process has already seen is answered entirely from the report cache,
//! and the legacy points share cache entries with the advisor's own
//! projections. Ranking is a *strict* deterministic argmin on
//! `est_total_sec` (first candidate wins ties, candidates enumerate in
//! [`Policy::all_canonical`] order with the legacy points first) — so
//! the tuned mapping is never worse than SwizzledHeadFirst on any row
//! where SHF applies, by construction. Docs: docs/TUNING.md.

use crate::attn::AttnConfig;
use crate::driver::{self, SimDriver, SimJob};
use crate::mapping::{Policy, SplitPlacement, Traversal, ALL_POLICIES};
use crate::sim::SimConfig;
use crate::topology::Topology;
use crate::util::json::Json;
use crate::workload::sweeps::fmt_ctx;

use super::advisor::Advice;

/// Search strategy over the pruned algebra.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchMode {
    /// Price every point in the pruned space.
    Exhaustive,
    /// Two-stage beam: price the legacy plane first, keep the best
    /// `width` points, then price only the survivors' order × split
    /// expansions. Cheaper than exhaustive when the space grows; the
    /// beam rule is "a good assign × traversal stays good when the
    /// extra axes move" (docs/TUNING.md).
    Beam {
        /// Legacy-plane survivors expanded in stage two.
        width: usize,
    },
}

/// Which kernel pass a tuning row prices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TuneKernel {
    /// Forward kernel, exact whole-grid run.
    Forward,
    /// Backward pair (dK/dV + dQ).
    Backward,
    /// Two-phase split-KV decode with this split count (clamped to the
    /// geometry's column blocks like [`super::advisor::advise_decode`]).
    Decode {
        /// Requested KV split count.
        num_splits: usize,
    },
}

/// One labelled workload the tuner prices.
#[derive(Debug, Clone)]
pub struct TuneRequest {
    /// Row label (sweep-style, e.g. `gqa8 B=1 N=64K S=8 decode`).
    pub label: String,
    /// Attention geometry.
    pub cfg: AttnConfig,
    /// Kernel pass to search over.
    pub kernel: TuneKernel,
}

/// Tuning result for one workload row.
#[derive(Debug, Clone)]
pub struct TuneRow {
    /// Row label from the request.
    pub label: String,
    /// The winning mapping (strict argmin over the priced candidates).
    pub best: Policy,
    /// Projected seconds of the winning mapping.
    pub best_sec: f64,
    /// The reference policy the speedup column compares against: SHF
    /// where it applies, else the best legacy point in the space.
    pub baseline: Policy,
    /// Projected seconds of the baseline policy.
    pub baseline_sec: f64,
    /// Every priced candidate in enumeration order with its projected
    /// seconds (the beam prices a subset of the exhaustive space).
    pub candidates: Vec<(Policy, f64)>,
}

impl TuneRow {
    /// Tuned-over-baseline speedup; >= 1.0 whenever the baseline is in
    /// the priced set (the argmin is never worse than any candidate).
    pub fn speedup(&self) -> f64 {
        self.baseline_sec / self.best_sec
    }

    /// JSON rendering for `tune --json` (bit-stable across thread
    /// counts: candidate order is enumeration order, never timing).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::str(self.label.clone())),
            ("best", Json::str(self.best.name())),
            ("best_sec", Json::num(self.best_sec)),
            ("baseline", Json::str(self.baseline.name())),
            ("baseline_sec", Json::num(self.baseline_sec)),
            ("speedup_vs_baseline", Json::num(self.speedup())),
            (
                "candidates",
                Json::arr(self.candidates.iter().map(|(p, t)| {
                    Json::obj(vec![
                        ("policy", Json::str(p.name())),
                        ("est_total_sec", Json::num(*t)),
                    ])
                })),
            ),
        ])
    }
}

/// The pruned, behaviorally-distinct search space for a workload (see
/// the module docs for the three pruning rules). Enumeration order is
/// [`Policy::all_canonical`]: legacy points first, so deterministic
/// tie-breaks favor the paper's named policies.
pub fn search_space(topo: &Topology, cfg: &AttnConfig, kernel: TuneKernel) -> Vec<Policy> {
    let decode_grid = matches!(kernel, TuneKernel::Decode { .. });
    Policy::all_canonical()
        .into_iter()
        .filter(|p| !(p.requires_divisible_heads() && cfg.h_q % topo.num_xcds != 0))
        .filter(|p| {
            let s = p.spec();
            if s.split == SplitPlacement::Grouped {
                // No-op off decode grids; duplicates `inherit` when the
                // traversal is already head-first.
                return decode_grid && s.traversal != Traversal::HeadFirst;
            }
            true
        })
        .collect()
}

fn job_for(topo: &Topology, cfg: &AttnConfig, kernel: TuneKernel, policy: Policy) -> SimJob {
    match kernel {
        TuneKernel::Forward => SimJob::forward(topo, cfg, SimConfig::forward(policy)),
        TuneKernel::Backward => SimJob::backward(topo, cfg, SimConfig::backward(policy)),
        TuneKernel::Decode { num_splits } => {
            let splits = cfg.clamp_num_splits(num_splits);
            SimJob::decode(topo, cfg, SimConfig::decode(policy, splits))
        }
    }
}

/// Price `candidates` for one request and rank by strict argmin on
/// `est_total_sec` (first candidate wins ties).
fn price(
    driver: &SimDriver,
    topo: &Topology,
    req: &TuneRequest,
    candidates: &[Policy],
) -> Vec<(Policy, f64)> {
    let jobs: Vec<SimJob> = candidates
        .iter()
        .map(|&p| job_for(topo, &req.cfg, req.kernel, p))
        .collect();
    let reports = driver.run_all(jobs);
    candidates
        .iter()
        .zip(&reports)
        .map(|(&p, r)| (p, r.est_total_sec))
        .collect()
}

fn argmin(priced: &[(Policy, f64)]) -> (Policy, f64) {
    let mut best = priced[0];
    for &(p, t) in &priced[1..] {
        if t < best.1 {
            best = (p, t);
        }
    }
    best
}

fn row_from(req: &TuneRequest, priced: Vec<(Policy, f64)>) -> TuneRow {
    let (best, best_sec) = argmin(&priced);
    // SHF where it applies (it is always priced then: stage one covers
    // the legacy plane), else the best legacy point priced.
    let (baseline, baseline_sec) = priced
        .iter()
        .copied()
        .find(|(p, _)| *p == Policy::SwizzledHeadFirst)
        .unwrap_or_else(|| {
            argmin(
                &priced
                    .iter()
                    .copied()
                    .filter(|(p, _)| ALL_POLICIES.contains(p))
                    .collect::<Vec<_>>(),
            )
        });
    TuneRow { label: req.label.clone(), best, best_sec, baseline, baseline_sec, candidates: priced }
}

/// Tune one workload row through an explicit driver.
pub fn tune_with(
    driver: &SimDriver,
    topo: &Topology,
    req: &TuneRequest,
    mode: SearchMode,
) -> TuneRow {
    let space = search_space(topo, &req.cfg, req.kernel);
    match mode {
        SearchMode::Exhaustive => row_from(req, price(driver, topo, req, &space)),
        SearchMode::Beam { width } => {
            let width = width.max(1);
            // Stage one: the legacy plane (always in the space).
            let legacy: Vec<Policy> =
                space.iter().copied().filter(|p| ALL_POLICIES.contains(p)).collect();
            let mut priced = price(driver, topo, req, &legacy);
            let mut survivors = priced.clone();
            survivors.sort_by(|a, b| a.1.partial_cmp(&b.1).expect("engine times are finite"));
            survivors.truncate(width);
            // Stage two: the survivors' order × split expansions, in
            // space enumeration order (deterministic regardless of the
            // stage-one sort).
            let expansions: Vec<Policy> = space
                .iter()
                .copied()
                .filter(|p| !legacy.contains(p))
                .filter(|p| {
                    let s = p.spec();
                    survivors.iter().any(|(surv, _)| {
                        let ss = surv.spec();
                        ss.assign == s.assign && ss.traversal == s.traversal
                    })
                })
                .collect();
            priced.extend(price(driver, topo, req, &expansions));
            row_from(req, priced)
        }
    }
}

/// [`tune_with`] through the process-wide shared driver.
pub fn tune(topo: &Topology, req: &TuneRequest, mode: SearchMode) -> TuneRow {
    tune_with(driver::global(), topo, req, mode)
}

/// The default tuning sweep: the decode and causal-forward regimes where
/// intra-head order and split placement actually move the engine (plus a
/// non-causal control row where every order is stream-identical and the
/// tuner must simply re-derive SHF). `quick` keeps the two headline
/// rows for CI smokes.
pub fn default_requests(quick: bool) -> Vec<TuneRequest> {
    let gqa8 = |b: usize, n: usize| AttnConfig::gqa(b, 64, 8, n, 128);
    let causal = |mut cfg: AttnConfig| {
        cfg.causal = true;
        cfg
    };
    let mut rows = vec![
        TuneRequest {
            label: format!("gqa8 B=1 N={} S=8 decode", fmt_ctx(65536)),
            cfg: gqa8(1, 65536),
            kernel: TuneKernel::Decode { num_splits: 8 },
        },
        TuneRequest {
            label: format!("mha-16 N={} causal fwd", fmt_ctx(8192)),
            cfg: causal(AttnConfig::mha(1, 16, 8192, 128)),
            kernel: TuneKernel::Forward,
        },
    ];
    if !quick {
        rows.extend([
            TuneRequest {
                label: format!("gqa8 B=1 N={} S=8 decode", fmt_ctx(131072)),
                cfg: gqa8(1, 131072),
                kernel: TuneKernel::Decode { num_splits: 8 },
            },
            TuneRequest {
                label: format!("gqa8 B=2 N={} S=4 decode", fmt_ctx(65536)),
                cfg: gqa8(2, 65536),
                kernel: TuneKernel::Decode { num_splits: 4 },
            },
            TuneRequest {
                label: format!("mha-64 B=1 N={} S=8 decode", fmt_ctx(65536)),
                cfg: AttnConfig::mha(1, 64, 65536, 128),
                kernel: TuneKernel::Decode { num_splits: 8 },
            },
            TuneRequest {
                label: format!("gqa8 N={} causal fwd", fmt_ctx(16384)),
                cfg: causal(gqa8(1, 16384)),
                kernel: TuneKernel::Forward,
            },
            TuneRequest {
                label: format!("mha-16 B=2 N={} bwd", fmt_ctx(8192)),
                cfg: AttnConfig::mha(2, 16, 8192, 128),
                kernel: TuneKernel::Backward,
            },
            TuneRequest {
                label: format!("mha-64 N={} fwd", fmt_ctx(16384)),
                cfg: AttnConfig::mha(1, 64, 16384, 128),
                kernel: TuneKernel::Forward,
            },
        ]);
    }
    rows
}

/// Tune the default sweep ([`default_requests`]) row by row.
pub fn tune_sweep(
    driver: &SimDriver,
    topo: &Topology,
    mode: SearchMode,
    quick: bool,
) -> Vec<TuneRow> {
    default_requests(quick)
        .iter()
        .map(|req| tune_with(driver, topo, req, mode))
        .collect()
}

/// Advisor entry point backed by the tuner: like
/// [`super::advisor::advise`] but recommending over the full pruned
/// algebra instead of the four legacy policies, with a strict argmin
/// (no 2% indifference band on the *choice* — the band still feeds the
/// `indifferent` flag). Uses the same sampled forward jobs as `advise`,
/// so the legacy points share its cache entries.
pub fn advise_tuned(topo: &Topology, cfg: &AttnConfig) -> Advice {
    advise_tuned_with(driver::global(), topo, cfg)
}

/// [`advise_tuned`] through an explicit driver.
pub fn advise_tuned_with(driver: &SimDriver, topo: &Topology, cfg: &AttnConfig) -> Advice {
    let policies = search_space(topo, cfg, TuneKernel::Forward);
    let jobs: Vec<SimJob> = policies
        .iter()
        .map(|&p| SimJob::forward(topo, cfg, SimConfig::sampled(p, topo, 2)))
        .collect();
    let reports = driver.run_all(jobs);
    let priced: Vec<(Policy, f64)> = policies
        .iter()
        .zip(&reports)
        .map(|(&p, r)| (p, r.est_total_sec))
        .collect();
    let (recommended, best_sec) = argmin(&priced);
    let spread = priced.iter().map(|(_, t)| t / best_sec).fold(1.0f64, f64::max);
    let projections = policies
        .iter()
        .zip(&reports)
        .map(|(&p, r)| (p, r.l2_hit_pct(), best_sec / r.est_total_sec))
        .collect();
    Advice {
        recommended,
        projections,
        indifferent: topo.num_xcds == 1 || spread < 1.02,
        num_splits: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets;

    fn fast_topo() -> Topology {
        Topology {
            cus_per_xcd: 8,
            l2_bytes_per_xcd: 1024 * 1024,
            hbm_bytes_per_sec: 1.1e12,
            ..presets::mi300x()
        }
    }

    fn decode_req() -> TuneRequest {
        TuneRequest {
            label: "gqa decode".into(),
            cfg: AttnConfig::gqa(1, 16, 8, 4096, 128),
            kernel: TuneKernel::Decode { num_splits: 4 },
        }
    }

    #[test]
    fn space_prunes_by_grid_kind() {
        let topo = fast_topo();
        let cfg = AttnConfig::mha(1, 16, 4096, 128);
        // Prefill: inherit plane only — 2 assign x 2 traversal x 2 order.
        let fwd = search_space(&topo, &cfg, TuneKernel::Forward);
        assert_eq!(fwd.len(), 8);
        assert!(fwd.iter().all(|p| p.spec().split == SplitPlacement::Inherit));
        // Decode: grouped survives only for block-first traversal.
        let dec = search_space(&topo, &cfg, TuneKernel::Decode { num_splits: 2 });
        assert_eq!(dec.len(), 12);
        // Indivisible heads drop the swizzled half.
        let odd = AttnConfig::mha(1, 12, 4096, 128);
        assert_eq!(search_space(&topo, &odd, TuneKernel::Forward).len(), 4);
        // Legacy points lead the enumeration (deterministic tie-break).
        assert_eq!(&fwd[..4], &ALL_POLICIES[..]);
    }

    #[test]
    fn exhaustive_never_loses_to_shf_and_memoizes() {
        let driver = SimDriver::new(2);
        let topo = fast_topo();
        let req = decode_req();
        let row = tune_with(&driver, &topo, &req, SearchMode::Exhaustive);
        assert_eq!(row.candidates.len(), 12);
        assert_eq!(driver.cache().misses(), 12, "one engine pass per candidate");
        assert_eq!(row.baseline, Policy::SwizzledHeadFirst);
        assert!(row.best_sec <= row.baseline_sec, "argmin beats every candidate");
        assert!(row.speedup() >= 1.0);
        // Re-tuning the same workload is free.
        let again = tune_with(&driver, &topo, &req, SearchMode::Exhaustive);
        assert_eq!(driver.cache().misses(), 12, "zero new engine runs");
        assert_eq!(again.best, row.best);
        assert_eq!(again.best_sec.to_bits(), row.best_sec.to_bits());
    }

    #[test]
    fn beam_prices_a_subset_and_agrees_on_the_baseline() {
        let driver = SimDriver::new(2);
        let topo = fast_topo();
        let req = decode_req();
        let beam = tune_with(&driver, &topo, &req, SearchMode::Beam { width: 2 });
        // Stage one (4 legacy) + the two survivors' expansions: at most
        // the exhaustive space, at least the legacy plane.
        assert!(beam.candidates.len() >= 4);
        assert!(beam.candidates.len() <= 12);
        assert_eq!(beam.baseline, Policy::SwizzledHeadFirst);
        assert!(beam.speedup() >= 1.0);
        // The exhaustive winner is at least as good as the beam's.
        let ex = tune_with(&driver, &topo, &req, SearchMode::Exhaustive);
        assert!(ex.best_sec <= beam.best_sec);
        // Beam candidates are a subset of the exhaustive space.
        let space = search_space(&topo, &req.cfg, req.kernel);
        assert!(beam.candidates.iter().all(|(p, _)| space.contains(p)));
    }

    #[test]
    fn serial_and_parallel_tuning_agree_bit_for_bit() {
        let topo = fast_topo();
        let req = decode_req();
        let a = tune_with(&SimDriver::new(1), &topo, &req, SearchMode::Exhaustive);
        let b = tune_with(&SimDriver::new(8), &topo, &req, SearchMode::Exhaustive);
        assert_eq!(a.to_json().render(), b.to_json().render());
    }

    #[test]
    fn advise_tuned_covers_the_algebra_and_caches() {
        let driver = SimDriver::new(2);
        let topo = fast_topo();
        let cfg = AttnConfig::mha(1, 16, 4096, 64);
        let a = advise_tuned_with(&driver, &topo, &cfg);
        assert_eq!(a.projections.len(), 8);
        assert_eq!(driver.cache().misses(), 8, "one sampled run per point");
        assert!(a.projections.iter().any(|(p, _, _)| *p == a.recommended));
        assert_eq!(a.num_splits, None);
        // The recommendation's relative perf is exactly 1.0.
        let rec = a.projections.iter().find(|(p, _, _)| *p == a.recommended).unwrap();
        assert!((rec.2 - 1.0).abs() < 1e-12);
        // The legacy points share the advisor's own cache entries: a
        // plain advise() after advise_tuned() performs zero engine runs.
        let before = driver.cache().misses();
        super::super::advisor::advise_with(&driver, &topo, &cfg);
        assert_eq!(driver.cache().misses(), before, "legacy jobs already cached");
        // Repeat tuned advice is free and bit-identical.
        let b = advise_tuned_with(&driver, &topo, &cfg);
        assert_eq!(driver.cache().misses(), before);
        assert_eq!(a.recommended, b.recommended);
        for (x, y) in a.projections.iter().zip(&b.projections) {
            assert_eq!(x.0, y.0);
            assert_eq!(x.2.to_bits(), y.2.to_bits());
        }
    }

    #[test]
    fn default_requests_quick_is_a_prefix() {
        let quick = default_requests(true);
        let full = default_requests(false);
        assert_eq!(quick.len(), 2);
        assert!(full.len() > quick.len());
        for (q, f) in quick.iter().zip(&full) {
            assert_eq!(q.label, f.label);
        }
    }
}
