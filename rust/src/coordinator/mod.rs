//! The serving coordinator (Layer 3): the deployment context the paper's
//! optimization targets, in two regimes (docs/SERVING.md is the
//! end-to-end handbook):
//!
//! * **Live prefill path** — an async attention-prefill service over the
//!   PJRT runtime, in the style of a vLLM-like router/batcher:
//!
//! ```text
//! client -> Router (bucket by n_ctx -> artifact)
//!        -> Batcher (group per bucket, max_batch/max_wait)
//!        -> Worker (PJRT execute on CPU)
//!        -> response (+ latency metrics)
//! ```
//!
//! * **Decode serving loop** ([`serve_decode`]) — iteration-level
//!   continuous batching over simulated decode steps: sessions arrive on
//!   a seeded schedule, the [`batcher::StepBatcher`] re-forms the active
//!   batch every step, each step is priced by simulator reports from the
//!   shared driver's cache, and the advisor re-picks the KV split count
//!   as caches grow across bucket boundaries. With
//!   [`ServeConfig::chunk_tokens`] set, prompts stream in row-block
//!   chunks composed with decode into mixed steps under a token budget
//!   (chunked prefill, docs/SERVING.md §6) instead of stalling the world
//!   at admission. This is the regime that dominates production traffic
//!   (decode over growing KV caches) and the first consumer that
//!   exercises the report cache across hundreds of related geometries in
//!   one run.
//!
//! Launch *pricing* inside the decode loop is pluggable
//! ([`executor::StepExecutor`]): the historical single-device path and
//! the tensor-parallel cluster path ([`serve_decode_cluster`],
//! docs/CLUSTER.md) share one loop, with the cluster executor fanning
//! every launch across a [`crate::cluster::ShardPlan`]'s devices and
//! charging the interconnect all-gather on top.
//!
//! The [`advisor`] ties both paths back to the paper: for each served
//! attention geometry it recommends the mapping policy a real MI300X
//! deployment should configure the kernel with, backed by a quick
//! simulator projection executed through the shared simulation driver
//! ([`crate::driver`]) — repeated advice on a geometry the coordinator
//! has already seen is served from the driver's report cache. The
//! [`tuner`] graduates that heuristic to a search result: it prices the
//! full composed mapping algebra ([`crate::mapping::MappingSpec`]) per
//! (topology, workload) through the same memoized driver and exposes
//! [`tuner::advise_tuned`] for callers that want the searched optimum
//! (docs/TUNING.md).

pub mod advisor;
pub mod batcher;
pub mod disagg;
pub mod executor;
pub mod faults;
pub mod router;
pub mod service;
pub mod tuner;

pub use advisor::{
    advise, advise_decode, advise_decode_with, advise_with, applicable_policies, pick_num_splits,
    Advice,
};
pub use tuner::{
    advise_tuned, advise_tuned_with, default_requests, search_space, tune, tune_sweep, tune_with,
    SearchMode, TuneKernel, TuneRequest, TuneRow,
};
pub use batcher::{
    ActiveSession, Batch, BatcherCore, BatcherConfig, PrefillChunk, SloQueue, StepBatcher,
};
pub use disagg::{
    disagg_applicable_policies, disagg_report, disagg_row, disagg_scenarios, serve_decode_disagg,
    serve_decode_disagg_traced, serve_decode_disagg_with, ClassStats, DisaggConfig, DisaggExtras,
    DisaggReport, DisaggRow, DisaggScenario, DisaggStats, DisaggTrace, HandoffRecord,
    PreemptionRecord, StepAudit,
};
pub use executor::{ClusterExecutor, SingleDeviceExecutor, StepExecutor};
pub use faults::{
    fault_report, serve_decode_faulty, serve_decode_faulty_traced, serve_decode_faulty_with,
    FaultEvent, FaultExtras, FaultPlan, FaultReport, FaultRow, FaultSpec, FaultTrace, FaultWindow,
    FaultyServeStats,
};
pub use router::{Router, SessionRoute, SessionRouter};
pub use service::{
    cluster_row, cluster_scenarios, serve_cluster_report, serve_decode, serve_decode_cluster,
    serve_decode_cluster_with, serve_decode_with, serve_report, serve_row, serve_scenarios,
    AttentionService,
    ClusterReport, ClusterRow, ClusterScenario, ServeConfig, ServeReport, ServeRow, ServeScenario,
    ServeStats, ServiceConfig, ServiceMetrics, Waiter,
};
