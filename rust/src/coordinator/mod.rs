//! The serving coordinator (Layer 3): an async attention-prefill service
//! over the PJRT runtime, in the style of a vLLM-like router/batcher —
//! the deployment context the paper's optimization targets (prefill
//! attention dominates long-context serving).
//!
//! Request path (all Rust; Python ran once at build time):
//!
//! ```text
//! client -> Router (bucket by n_ctx -> artifact)
//!        -> Batcher (group per bucket, max_batch/max_wait)
//!        -> Worker (PJRT execute on CPU)
//!        -> response (+ latency metrics)
//! ```
//!
//! The [`advisor`] ties the serving layer back to the paper: for each
//! bucket's attention geometry it recommends the mapping policy a real
//! MI300X deployment should configure the kernel with, backed by a quick
//! simulator projection executed through the shared simulation driver
//! ([`crate::driver`]) — repeated advice on a geometry the coordinator
//! has already seen is served from the driver's report cache.

pub mod advisor;
pub mod batcher;
pub mod router;
pub mod service;

pub use advisor::{
    advise, advise_decode, advise_decode_with, advise_with, applicable_policies, pick_num_splits,
    Advice,
};
pub use batcher::{Batch, BatcherCore, BatcherConfig};
pub use router::Router;
pub use service::{AttentionService, ServiceConfig, ServiceMetrics, Waiter};
