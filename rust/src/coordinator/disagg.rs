//! Disaggregated prefill/decode serving (docs/DISAGG.md): the
//! DistServe/Splitwise-style production architecture in which prompt
//! processing and token generation run on *separate* device pools,
//! connected by the cluster layer's ring-link interconnect model.
//!
//! The pipeline per session: admit → prefill pool (SLO-priority
//! admission, chunked or monolithic prompt streaming) → KV handoff (the
//! session's KV blocks move to the decode pool as a point-to-point
//! interconnect transfer, with blocks already resident on the decode
//! side credited to zero bytes) → decode pool (continuous-batching
//! decode to completion). Each pool is a [`ClusterExecutor`] over a
//! [`ClusterTopology`] tagged with its [`PoolKind`]; the two pools
//! advance independent simulated clocks in event lockstep — the pool
//! whose clock trails runs its next step first, so a decode step can
//! never consume a handoff the prefill timeline has not produced yet.
//!
//! Why this pays: prefill is compute-bound and decode is
//! bandwidth-bound, so colocating them makes long prompts stall every
//! decode stream (the TTFT/TPOT interference the chunked-prefill work
//! only softens). Splitting the pools removes the interference
//! entirely, lets the prefill pool admit interactive sessions ahead of
//! batch ones ([`crate::coordinator::batcher::SloQueue`]), and lets it
//! preempt batch prefill chunks when the interactive TTFT objective is
//! at risk — at the price of the KV handoff, which is exactly what the
//! interconnect transfer charge models. A colocated configuration
//! (`prefill_devices = 0`) delegates wholly to the historical
//! `serve`/`cluster` paths and reproduces their output byte for byte
//! (the golden pins in `tests/serving_loop.rs`/`tests/cluster_serving.rs`).

use std::collections::BTreeMap;

use crate::cluster::{ClusterTopology, PoolKind, ShardPlan, ShardStrategy};
use crate::driver::{self, SimDriver};
use crate::mapping::Policy;
use crate::mem::{block_bytes, prompt_keys, KvPool};
use crate::metrics::Table;
use crate::topology::Topology;
use crate::util::json::Json;
use crate::workload::{Session, SessionGenerator, SloClass};

use super::advisor;
use super::batcher::{PrefillChunk, StepBatcher};
use super::executor::{ClusterExecutor, StepExecutor};
use super::router::SessionRouter;
use super::service::{
    fmt_ms, ms_json, pctl_or_nan, serve_decode_cluster_with, serve_decode_with, ServeConfig,
    ServeStats,
};

/// Configuration of one disaggregated serving run: the base serving
/// knobs plus the pool split, interconnect, and SLO policy. Maps to the
/// `[disagg]` INI section ([`crate::config::DISAGG_KEYS`]).
#[derive(Debug, Clone)]
pub struct DisaggConfig {
    /// The base serving configuration (geometry, trace, loop knobs) —
    /// the `[serve]`/`[attention]` sections of an experiment file.
    pub serve: ServeConfig,
    /// Devices in the prefill pool. `0` = colocated: no prefill pool,
    /// the decode pool serves both phases through the historical
    /// `serve`/`cluster` code paths, byte for byte.
    pub prefill_devices: usize,
    /// Devices in the decode pool (each pool shards its launches at
    /// `tp = pool size`; both sizes must divide the model's KV heads).
    pub decode_devices: usize,
    /// Interconnect bandwidth between (and within) pools in GB/s — the
    /// rate a session's KV blocks cross at handoff.
    pub link_gbs: f64,
    /// Interconnect hop latency in microseconds.
    pub link_latency_us: f64,
    /// Percentage of sessions drawn as [`SloClass::Interactive`]
    /// (dedicated RNG stream; `0` disables SLO classes entirely and the
    /// trace is the exact no-SLO trace).
    pub interactive_pct: f64,
    /// Interactive TTFT objective in ms. When an interactive session's
    /// prefill has been pending for more than half this objective, the
    /// prefill pool preempts batch chunk streaming for the step
    /// (docs/DISAGG.md §5). `0` disables preemption.
    pub ttft_slo_ms: f64,
}

impl Default for DisaggConfig {
    fn default() -> Self {
        DisaggConfig {
            serve: ServeConfig::default(),
            prefill_devices: 1,
            decode_devices: 1,
            link_gbs: crate::cluster::DEFAULT_LINK_BYTES_PER_SEC / 1e9,
            link_latency_us: crate::cluster::DEFAULT_LINK_LATENCY_SEC * 1e6,
            interactive_pct: 30.0,
            ttft_slo_ms: 0.0,
        }
    }
}

impl DisaggConfig {
    /// True when no dedicated prefill pool exists (the historical
    /// colocated deployment).
    pub fn colocated(&self) -> bool {
        self.prefill_devices == 0
    }

    /// Interconnect bandwidth in bytes/second.
    pub fn link_bytes_per_sec(&self) -> f64 {
        self.link_gbs * 1e9
    }

    /// Interconnect hop latency in seconds.
    pub fn link_latency_sec(&self) -> f64 {
        self.link_latency_us * 1e-6
    }

    /// Check the knobs are internally consistent on top of
    /// [`ServeConfig::validate`].
    pub fn validate(&self) -> Result<(), String> {
        self.serve.validate()?;
        if self.decode_devices == 0 {
            return Err("decode_devices must be > 0".into());
        }
        let pools =
            [("prefill_devices", self.prefill_devices), ("decode_devices", self.decode_devices)];
        for (what, n) in pools {
            if n > 0 && self.serve.h_k % n != 0 {
                return Err(format!(
                    "{what} ({n}) must divide h_k ({}): each pool shards at tp = pool size \
                     and KV heads are never split",
                    self.serve.h_k
                ));
            }
        }
        if self.link_gbs.is_nan() || self.link_gbs <= 0.0 {
            return Err("link_gbs must be > 0".into());
        }
        if self.link_latency_us.is_nan() || self.link_latency_us < 0.0 {
            return Err("link_latency_us must be >= 0".into());
        }
        if !(0.0..=100.0).contains(&self.interactive_pct) {
            return Err(format!("interactive_pct ({}) must be in [0, 100]", self.interactive_pct));
        }
        if self.ttft_slo_ms.is_nan() || self.ttft_slo_ms < 0.0 {
            return Err("ttft_slo_ms must be >= 0".into());
        }
        Ok(())
    }

    /// Full (uncredited) KV bytes of one session's handoff: the KV
    /// cache of its prompt, clamped to the deployment's KV capacity.
    pub fn session_kv_bytes(&self, prefill: usize) -> u64 {
        let tokens = prefill.min(self.serve.kv_cap) as u64;
        let per_token = 2 * self.serve.h_k as u64 * self.serve.d_head as u64;
        tokens * per_token * self.serve.dtype_bytes as u64
    }
}

/// Per-SLO-class latency/volume stats of one disaggregated run.
#[derive(Debug, Clone, Default)]
pub struct ClassStats {
    /// Sessions of this class that reached their first decode token.
    pub sessions: usize,
    /// Decode tokens emitted by this class.
    pub tokens: u64,
    /// Median time-to-first-token (ms): arrival → first decode token,
    /// across prefill, handoff, and decode-pool queueing.
    pub ttft_p50_ms: f64,
    /// 99th-percentile time-to-first-token (ms) — the SLO metric
    /// preemption protects for the interactive class.
    pub ttft_p99_ms: f64,
    /// Median time-per-output-token (ms) on the decode pool.
    pub tpot_p50_ms: f64,
    /// 99th-percentile time-per-output-token (ms).
    pub tpot_p99_ms: f64,
}

impl ClassStats {
    fn from_samples(ttft_ms: &[f64], tpot_ms: &[f64], tokens: u64) -> ClassStats {
        ClassStats {
            sessions: ttft_ms.len(),
            tokens,
            ttft_p50_ms: pctl_or_nan(ttft_ms, 0.50),
            ttft_p99_ms: pctl_or_nan(ttft_ms, 0.99),
            tpot_p50_ms: pctl_or_nan(tpot_ms, 0.50),
            tpot_p99_ms: pctl_or_nan(tpot_ms, 0.99),
        }
    }

    /// JSON rendering (stable key order). A class no session reached
    /// renders its latency stats as `null`, not a perfect 0.0 ms.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("sessions", Json::num(self.sessions as f64)),
            ("tokens", Json::num(self.tokens as f64)),
            ("ttft_p50_ms", ms_json(self.ttft_p50_ms)),
            ("ttft_p99_ms", ms_json(self.ttft_p99_ms)),
            ("tpot_p50_ms", ms_json(self.tpot_p50_ms)),
            ("tpot_p99_ms", ms_json(self.tpot_p99_ms)),
        ])
    }
}

/// The disaggregation-specific counters of one run — absent
/// (`None` in [`DisaggStats::extras`]) on a colocated run, whose JSON
/// must stay byte-identical to the historical serving output.
#[derive(Debug, Clone)]
pub struct DisaggExtras {
    /// Devices in the prefill pool.
    pub prefill_devices: usize,
    /// Devices in the decode pool.
    pub decode_devices: usize,
    /// Sessions handed off prefill → decode.
    pub handoffs: u64,
    /// Summed uncredited KV bytes of every handoff.
    pub handoff_total_bytes: u64,
    /// KV bytes actually moved over the interconnect.
    pub handoff_transferred_bytes: u64,
    /// KV bytes credited because the blocks were already resident on
    /// the decode side (shared prefixes) — never transferred.
    pub handoff_credited_bytes: u64,
    /// Summed interconnect transfer time of every handoff (overlaps
    /// pool compute; it delays only the session's decode admission).
    pub handoff_sec: f64,
    /// Steps on which batch chunk streaming was preempted to protect
    /// the interactive TTFT objective.
    pub preemptions: u64,
    /// Steps the prefill pool executed.
    pub prefill_steps: usize,
    /// Steps the decode pool executed.
    pub decode_steps: usize,
    /// Interactive-class latency stats.
    pub interactive: ClassStats,
    /// Batch-class latency stats.
    pub batch: ClassStats,
}

impl DisaggExtras {
    /// JSON rendering (stable key order).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("prefill_devices", Json::num(self.prefill_devices as f64)),
            ("decode_devices", Json::num(self.decode_devices as f64)),
            ("handoffs", Json::num(self.handoffs as f64)),
            ("handoff_total_bytes", Json::num(self.handoff_total_bytes as f64)),
            ("handoff_transferred_bytes", Json::num(self.handoff_transferred_bytes as f64)),
            ("handoff_credited_bytes", Json::num(self.handoff_credited_bytes as f64)),
            ("handoff_sec", Json::num(self.handoff_sec)),
            ("preemptions", Json::num(self.preemptions as f64)),
            ("prefill_steps", Json::num(self.prefill_steps as f64)),
            ("decode_steps", Json::num(self.decode_steps as f64)),
            ("interactive", self.interactive.to_json()),
            ("batch", self.batch.to_json()),
        ])
    }
}

/// Outcome of one disaggregated serving run: the base serving stats
/// (aggregated across both pools) plus the disaggregation extras.
#[derive(Debug, Clone)]
pub struct DisaggStats {
    /// The base serving stats: throughput, latency percentiles,
    /// conservation counters — same semantics as the colocated loop.
    pub serve: ServeStats,
    /// Disaggregation counters; `None` on a colocated run.
    pub extras: Option<DisaggExtras>,
}

impl DisaggStats {
    /// JSON rendering. A colocated run renders *exactly*
    /// [`ServeStats::to_json`] — the golden equivalence pins compare
    /// these bytes against the historical `serve`/`cluster` output.
    pub fn to_json(&self) -> Json {
        match &self.extras {
            None => self.serve.to_json(),
            Some(e) => {
                let mut obj = match self.serve.to_json() {
                    Json::Obj(pairs) => pairs,
                    _ => unreachable!("ServeStats::to_json returns an object"),
                };
                obj.push(("disagg".into(), e.to_json()));
                Json::Obj(obj)
            }
        }
    }
}

/// One session's prefill → decode KV handoff, as the invariant suite
/// sees it ([`serve_decode_disagg_traced`]).
#[derive(Debug, Clone)]
pub struct HandoffRecord {
    /// Session id.
    pub id: u64,
    /// The session's SLO class.
    pub slo: SloClass,
    /// Uncredited KV bytes of the session's blocks.
    pub total_bytes: u64,
    /// Bytes moved over the interconnect.
    pub transferred_bytes: u64,
    /// Bytes credited (already resident on the decode side).
    pub credited_bytes: u64,
    /// Prefill-pool clock when the handoff left.
    pub sent_sec: f64,
    /// When the transfer completes — the session may not decode before
    /// this instant (the no-early-decode invariant).
    pub ready_sec: f64,
    /// Decode-pool clock when the session was admitted to decode, once
    /// it was (`None` on a truncated run that never admitted it).
    pub admitted_sec: Option<f64>,
}

/// One batch-preemption event on the prefill pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PreemptionRecord {
    /// Prefill-pool step index of the event.
    pub step: usize,
    /// The batch session whose chunk streaming was paused.
    pub id: u64,
    /// The session's prefilled-prefix cursor at the pause — the exact
    /// `start` its next chunk must re-plan from (exactly once).
    pub cursor: usize,
}

/// One per-step conservation audit row: every session is in exactly one
/// of these places, so the counts must always sum to the trace size.
#[derive(Debug, Clone, Copy)]
pub struct StepAudit {
    /// Pool that executed the step.
    pub pool: PoolKind,
    /// Sessions not yet admitted to the prefill pool (backlog + SLO
    /// queue).
    pub backlog: usize,
    /// Sessions streaming prompts on the prefill pool.
    pub prefill_active: usize,
    /// Sessions in flight between the pools (handoff sent, decode
    /// admission pending).
    pub transit: usize,
    /// Sessions decoding on the decode pool.
    pub decode_active: usize,
    /// Sessions fully retired.
    pub completed: usize,
}

/// Everything the invariant suite needs to audit one disaggregated run
/// ([`serve_decode_disagg_traced`]): per-session handoff records, the
/// full chunk-plan history, preemption events, credited prefill tokens,
/// and a per-step conservation audit.
#[derive(Debug, Clone, Default)]
pub struct DisaggTrace {
    /// One record per handoff, in handoff order.
    pub handoffs: Vec<HandoffRecord>,
    /// Every prefill chunk the prefill pool planned, in plan order.
    pub chunks: Vec<PrefillChunk>,
    /// Every batch-preemption event.
    pub preemptions: Vec<PreemptionRecord>,
    /// Prompt tokens credited by the prefill-side KV pool per session.
    pub credited_prefill: Vec<(u64, usize)>,
    /// Per-step conservation audits.
    pub audits: Vec<StepAudit>,
    /// The generated trace (arrival order).
    pub sessions: Vec<Session>,
}

/// Run the disaggregated serving loop for one policy through the
/// process-wide shared driver ([`driver::global`]).
pub fn serve_decode_disagg(device: &Topology, cfg: &DisaggConfig, policy: Policy) -> DisaggStats {
    serve_decode_disagg_with(driver::global(), device, cfg, policy)
}

/// [`serve_decode_disagg`] through an explicit driver (tests, CLI
/// `--threads`).
pub fn serve_decode_disagg_with(
    driver: &SimDriver,
    device: &Topology,
    cfg: &DisaggConfig,
    policy: Policy,
) -> DisaggStats {
    serve_decode_disagg_traced(driver, device, cfg, policy).0
}

/// [`serve_decode_disagg_with`] returning the full audit trace the
/// invariant suite sweeps (`tests/serving_invariants.rs`). A colocated
/// configuration delegates to the historical single-device/cluster
/// serving paths (byte-identical stats, empty trace, `extras: None`).
pub fn serve_decode_disagg_traced(
    driver: &SimDriver,
    device: &Topology,
    cfg: &DisaggConfig,
    policy: Policy,
) -> (DisaggStats, DisaggTrace) {
    cfg.validate().expect("valid disagg config");
    if cfg.colocated() {
        let serve = if cfg.decode_devices == 1 {
            serve_decode_with(driver, device, &cfg.serve, policy)
        } else {
            let cluster = ClusterTopology::homogeneous(
                device,
                cfg.decode_devices,
                cfg.link_bytes_per_sec(),
                cfg.link_latency_sec(),
            );
            let plan = ShardPlan::new(
                &cfg.serve.base_geometry(),
                cfg.decode_devices,
                ShardStrategy::Contiguous,
            )
            .expect("validated: decode_devices divides h_k");
            serve_decode_cluster_with(driver, &cluster, &plan, &cfg.serve, policy)
        };
        return (DisaggStats { serve, extras: None }, DisaggTrace::default());
    }
    run_disagg_loop(driver, device, cfg, policy)
}

/// Build one pool's [`PoolKind`]-tagged cluster and its `tp = pool
/// size` shard plan, asserting the policy's applicability on the
/// shard-local geometry of every device (mirroring
/// [`serve_decode_cluster_with`]).
fn pool_topology(
    device: &Topology,
    cfg: &DisaggConfig,
    kind: PoolKind,
    n: usize,
    policy: Policy,
) -> (ClusterTopology, ShardPlan) {
    let cluster =
        ClusterTopology::pool_of(device, n, kind, cfg.link_bytes_per_sec(), cfg.link_latency_sec());
    let plan = ShardPlan::new(&cfg.serve.base_geometry(), n, ShardStrategy::Contiguous)
        .expect("validated: pool size divides h_k");
    let local = plan.local_attn(&cfg.serve.base_geometry());
    for (i, d) in cluster.devices.iter().enumerate() {
        assert!(
            advisor::applicable_policies(d, &local).contains(&policy),
            "policy {} is not applicable to the {kind}-pool shard-local h_q={} on \
             device {i}'s {} XCDs",
            policy.label(),
            local.h_q,
            d.num_xcds
        );
    }
    (cluster, plan)
}

/// A session in flight between the pools.
#[derive(Debug, Clone)]
struct Handoff {
    session: Session,
    ready_sec: f64,
    record_idx: usize,
}

/// A session decoding on the decode pool.
#[derive(Debug, Clone)]
struct DecodeSession {
    session: Session,
    generated: usize,
}

/// The two-pool event-lockstep loop body (docs/DISAGG.md §4). The pool
/// whose clock trails executes its next step first, so every handoff a
/// decode step could admit already exists: handoffs created later carry
/// `ready_sec >= prefill_clock > decode_clock`. Charges accumulate one
/// launch at a time in launch order, same discipline as the colocated
/// loop, so worker threads can never perturb the summation.
fn run_disagg_loop(
    driver: &SimDriver,
    device: &Topology,
    cfg: &DisaggConfig,
    policy: Policy,
) -> (DisaggStats, DisaggTrace) {
    let (prefill_cluster, prefill_plan) =
        pool_topology(device, cfg, PoolKind::Prefill, cfg.prefill_devices, policy);
    let (decode_cluster, decode_plan) =
        pool_topology(device, cfg, PoolKind::Decode, cfg.decode_devices, policy);
    let mut prefill_exec =
        ClusterExecutor::new(driver, &prefill_cluster, &prefill_plan, &cfg.serve, policy);
    let mut decode_exec =
        ClusterExecutor::new(driver, &decode_cluster, &decode_plan, &cfg.serve, policy);
    // The interconnect both pools hang off: the handoff transfer is a
    // point-to-point hop on the same ring-link model the all-gather
    // uses, so `decode_cluster.transfer_sec` prices it.
    let link = &decode_cluster;

    let serve = &cfg.serve;
    // A replayed trace supplies the sessions verbatim — arrival process,
    // mix, shared prefixes, and SLO classes all come from its rows, so
    // the generator knobs (including `interactive_pct`) are ignored.
    let sessions = match &serve.trace {
        Some(t) => t.sessions().to_vec(),
        None => {
            let mut gen = SessionGenerator::new(
                serve.seed,
                serve.arrival_per_sec,
                serve.prefill_lengths.clone(),
                serve.decode_tokens.clone(),
            );
            if serve.prefix_share_pct > 0.0 {
                gen = gen.with_prefix_sharing(serve.prefix_share_pct, serve.shared_span());
            }
            if cfg.interactive_pct > 0.0 {
                gen = gen.with_slo_classes(cfg.interactive_pct);
            }
            gen.take(serve.sessions)
        }
    };
    let total_sessions = sessions.len();
    // The session router: every session's phase placement is a pure
    // function of the deployment shape (property-pinned).
    let router = SessionRouter::new(true);
    for s in &sessions {
        let route = router.route(s);
        debug_assert_eq!((route.prefill, route.decode), (PoolKind::Prefill, PoolKind::Decode));
    }

    let mut trace = DisaggTrace { sessions: sessions.clone(), ..DisaggTrace::default() };
    let mut batcher = StepBatcher::new(sessions, serve.max_active, serve.chunk_tokens);
    // Each pool holds its own paged KV pool when sharing is enabled:
    // the prefill side credits resident prefixes against prefill
    // compute; the decode side credits resident blocks against the
    // handoff transfer (shared prefixes move across the link once, not
    // once per sharer).
    let pool_enabled = serve.kv_pool_enabled();
    let bb = block_bytes(serve.kv_block_tokens.max(1), serve.h_k, serve.d_head, serve.dtype_bytes);
    let mut prefill_pool = pool_enabled.then(|| {
        KvPool::new(
            block_bytes(serve.kv_block_tokens, serve.h_k, serve.d_head, serve.dtype_bytes),
            serve.kv_capacity_mb as u64 * 1024 * 1024,
        )
    });
    let mut decode_pool = pool_enabled.then(|| {
        KvPool::new(
            block_bytes(serve.kv_block_tokens, serve.h_k, serve.d_head, serve.dtype_bytes),
            serve.kv_capacity_mb as u64 * 1024 * 1024,
        )
    });

    let mut prefill_clock = 0.0f64;
    let mut decode_clock = 0.0f64;
    let mut prefill_done = false;
    let mut prefill_steps = 0usize;
    let mut decode_steps = 0usize;
    let mut truncated = false;

    let mut transit: Vec<Handoff> = Vec::new();
    let mut decode_active: Vec<DecodeSession> = Vec::new();
    let mut completed = 0usize;

    let mut prefill_sec = 0.0f64;
    let mut prefill_tokens = 0u64;
    let mut kv_shared_tokens = 0u64;
    let mut kv_affine_blocks = 0u64;
    let mut kv_total_blocks = 0u64;
    let mut tokens = 0u64;
    let mut handoff_sec = 0.0f64;
    let mut preemptions = 0u64;
    let mut tpot_ms: Vec<f64> = Vec::new();
    let mut ttft_ms: Vec<f64> = Vec::new();
    let mut class_tpot: [Vec<f64>; 2] = [Vec::new(), Vec::new()];
    let mut class_ttft: [Vec<f64>; 2] = [Vec::new(), Vec::new()];
    let mut class_tokens = [0u64; 2];
    let cls = |slo: SloClass| slo.rank() as usize;

    loop {
        if prefill_done && transit.is_empty() && decode_active.is_empty() {
            break;
        }
        if !prefill_done && (batcher.done() || prefill_steps >= serve.max_steps) {
            prefill_done = true;
            truncated |= !batcher.done();
            continue;
        }
        // Which pool steps next: the prefill pool when its clock trails
        // (or the decode pool has nothing it may run yet). A handoff is
        // only *known* runnable once its ready time is covered by the
        // prefill timeline — everything the prefill pool still produces
        // lands at `ready >= prefill_clock`.
        let min_ready = transit.iter().map(|h| h.ready_sec).fold(f64::INFINITY, f64::min);
        let decode_runnable = !decode_active.is_empty()
            || (!transit.is_empty() && (prefill_done || min_ready <= prefill_clock));
        let run_prefill = !prefill_done && (!decode_runnable || prefill_clock <= decode_clock);

        if run_prefill {
            // ---- one prefill-pool step ----
            if batcher.active().is_empty() {
                if let Some(t) = batcher.next_arrival_sec() {
                    prefill_clock = prefill_clock.max(t);
                }
            }
            let newly = batcher.admit_slo(prefill_clock);
            let mut credited: Vec<usize> = Vec::new();
            if let Some(pool) = prefill_pool.as_mut() {
                for s in &newly {
                    let keys = prompt_keys(s.id, s.prefill, s.shared_prefix, serve.kv_block_tokens);
                    let got = pool.acquire(s.id, &keys);
                    let t = (got.credited_blocks * serve.kv_block_tokens).min(s.prefill);
                    kv_shared_tokens += t as u64;
                    credited.push(t);
                    trace.credited_prefill.push((s.id, t));
                }
            }
            let mut step_sec = 0.0f64;
            if serve.chunk_tokens == 0 {
                // Monolithic prompt charges (credited suffix pricing
                // when the pool engages — same rule as the colocated
                // loop).
                if prefill_pool.is_some() {
                    let chunks: Vec<PrefillChunk> = newly
                        .iter()
                        .zip(&credited)
                        .filter(|(s, &c)| c < s.prefill)
                        .map(|(s, &c)| PrefillChunk { id: s.id, start: c, end: s.prefill })
                        .collect();
                    if !chunks.is_empty() {
                        prefill_tokens += chunks.iter().map(|c| c.tokens() as u64).sum::<u64>();
                        trace.chunks.extend(chunks.iter().copied());
                        for t in prefill_exec.chunk_charges(&chunks) {
                            prefill_sec += t;
                            step_sec += t;
                        }
                    }
                } else if !newly.is_empty() {
                    let prompts: Vec<usize> = newly.iter().map(|s| s.prefill).collect();
                    prefill_tokens += prompts.iter().map(|&p| p as u64).sum::<u64>();
                    trace.chunks.extend(
                        newly.iter().map(|s| PrefillChunk { id: s.id, start: 0, end: s.prefill }),
                    );
                    for t in prefill_exec.prefill_charges(&prompts) {
                        prefill_sec += t;
                        step_sec += t;
                    }
                }
            } else {
                for (s, &c) in newly.iter().zip(&credited) {
                    if c > 0 {
                        batcher.credit_prefix(s.id, c);
                    }
                }
                let budget = if serve.step_token_budget == 0 {
                    usize::MAX
                } else {
                    serve.step_token_budget
                };
                // SLO preemption (docs/DISAGG.md §5): when an
                // interactive session's prefill has aged past half the
                // TTFT objective, this step streams interactive chunks
                // only — batch cursors freeze in place and re-plan the
                // identical chunk once the pressure clears.
                let at_risk = cfg.ttft_slo_ms > 0.0
                    && batcher.active().iter().any(|a| {
                        a.session.slo == SloClass::Interactive
                            && !a.prefill_complete()
                            && (prefill_clock - a.session.arrival_sec) * 1e3
                                > 0.5 * cfg.ttft_slo_ms
                    });
                let chunks = if at_risk {
                    let skipped: Vec<(u64, usize)> = batcher
                        .active()
                        .iter()
                        .filter(|a| a.session.slo == SloClass::Batch && !a.prefill_complete())
                        .map(|a| (a.session.id, a.prefill_done))
                        .collect();
                    if !skipped.is_empty() {
                        preemptions += 1;
                        trace.preemptions.extend(skipped.iter().map(|&(id, cursor)| {
                            PreemptionRecord { step: prefill_steps, id, cursor }
                        }));
                    }
                    batcher.plan_chunks_where(budget, |a| a.session.slo == SloClass::Batch)
                } else {
                    batcher.plan_chunks(budget)
                };
                if !chunks.is_empty() {
                    prefill_tokens += chunks.iter().map(|c| c.tokens() as u64).sum::<u64>();
                    trace.chunks.extend(chunks.iter().copied());
                    for t in prefill_exec.chunk_charges(&chunks) {
                        prefill_sec += t;
                        step_sec += t;
                    }
                }
            }
            prefill_clock += step_sec;
            // Handoff: prefill-complete sessions leave the pool now.
            // The transfer charge is point-to-point on the ring link;
            // blocks already resident on the decode side (a shared
            // prefix a previous handoff moved) transfer nothing. The
            // transfer overlaps both pools' compute — it delays only
            // this session's decode admission.
            for s in batcher.take_prefilled() {
                if let Some(pool) = prefill_pool.as_mut() {
                    pool.release(s.id);
                }
                let total_bytes = cfg.session_kv_bytes(s.prefill);
                let (transferred, credited_b) = match decode_pool.as_mut() {
                    Some(pool) => {
                        let keys =
                            prompt_keys(s.id, s.prefill, s.shared_prefix, serve.kv_block_tokens);
                        let got = pool.acquire(s.id, &keys);
                        for &j in &got.inserted {
                            let (affine, total) = decode_exec.kv_block_affinity(j);
                            kv_affine_blocks += affine as u64;
                            kv_total_blocks += total as u64;
                        }
                        let t = got.inserted.len() as u64 * bb;
                        (t.min(total_bytes), total_bytes.saturating_sub(t.min(total_bytes)))
                    }
                    None => (total_bytes, 0),
                };
                let xfer = link.transfer_sec(transferred as f64);
                handoff_sec += xfer;
                let ready_sec = prefill_clock + xfer;
                trace.handoffs.push(HandoffRecord {
                    id: s.id,
                    slo: s.slo,
                    total_bytes,
                    transferred_bytes: transferred,
                    credited_bytes: credited_b,
                    sent_sec: prefill_clock,
                    ready_sec,
                    admitted_sec: None,
                });
                let record_idx = trace.handoffs.len() - 1;
                transit.push(Handoff { session: s, ready_sec, record_idx });
            }
            prefill_steps += 1;
            trace.audits.push(StepAudit {
                pool: PoolKind::Prefill,
                backlog: batcher.backlog_len(),
                prefill_active: batcher.active().len(),
                transit: transit.len(),
                decode_active: decode_active.len(),
                completed,
            });
            debug_assert_eq!(
                batcher.backlog_len()
                    + batcher.active().len()
                    + transit.len()
                    + decode_active.len()
                    + completed,
                total_sessions
            );
        } else {
            // ---- one decode-pool step ----
            if decode_steps >= serve.max_steps {
                truncated = true;
                break;
            }
            if decode_active.is_empty() {
                decode_clock = decode_clock.max(min_ready);
            }
            // Admit ready handoffs into free slots, earliest ready
            // first (ties by id — the order is total).
            while decode_active.len() < serve.max_active {
                let next = transit
                    .iter()
                    .enumerate()
                    .filter(|(_, h)| h.ready_sec <= decode_clock)
                    .min_by(|(_, a), (_, b)| {
                        a.ready_sec
                            .total_cmp(&b.ready_sec)
                            .then(a.session.id.cmp(&b.session.id))
                    })
                    .map(|(i, _)| i);
                match next {
                    Some(i) => {
                        let h = transit.remove(i);
                        trace.handoffs[h.record_idx].admitted_sec = Some(decode_clock);
                        decode_active.push(DecodeSession { session: h.session, generated: 0 });
                    }
                    None => break,
                }
            }
            // One iteration-level decode batch: group by bucketed KV
            // length, one split-KV launch per group (ascending bucket
            // order, exactly the colocated loop's grouping).
            let mut grouped: BTreeMap<usize, usize> = BTreeMap::new();
            for d in &decode_active {
                let kv = d.session.kv_len(d.generated, serve.kv_cap);
                *grouped.entry(serve.bucket_of(kv)).or_insert(0) += 1;
            }
            let groups: Vec<(usize, usize)> = grouped.into_iter().collect();
            let mut step_sec = 0.0f64;
            for t in decode_exec.decode_charges(&groups) {
                step_sec += t;
            }
            decode_clock += step_sec;
            for d in &mut decode_active {
                if d.generated == 0 {
                    let t = (decode_clock - d.session.arrival_sec) * 1e3;
                    ttft_ms.push(t);
                    class_ttft[cls(d.session.slo)].push(t);
                }
                d.generated += 1;
                tokens += 1;
                class_tokens[cls(d.session.slo)] += 1;
                tpot_ms.push(step_sec * 1e3);
                class_tpot[cls(d.session.slo)].push(step_sec * 1e3);
            }
            decode_active.retain(|d| {
                let keep = d.generated < d.session.decode_tokens;
                if !keep {
                    if let Some(pool) = decode_pool.as_mut() {
                        pool.release(d.session.id);
                    }
                    completed += 1;
                }
                keep
            });
            decode_steps += 1;
            trace.audits.push(StepAudit {
                pool: PoolKind::Decode,
                backlog: batcher.backlog_len(),
                prefill_active: batcher.active().len(),
                transit: transit.len(),
                decode_active: decode_active.len(),
                completed,
            });
        }
    }

    let sim_sec = prefill_clock.max(decode_clock);
    let (l2_hits, l2_misses) = decode_exec.decode_l2();
    let serve_stats = ServeStats {
        policy,
        sessions_completed: completed,
        tokens,
        steps: prefill_steps + decode_steps,
        sim_sec,
        tokens_per_sec: if sim_sec > 0.0 { tokens as f64 / sim_sec } else { 0.0 },
        tpot_p50_ms: pctl_or_nan(&tpot_ms, 0.50),
        tpot_p99_ms: pctl_or_nan(&tpot_ms, 0.99),
        ttft_p50_ms: pctl_or_nan(&ttft_ms, 0.50),
        ttft_p99_ms: pctl_or_nan(&ttft_ms, 0.99),
        prefill_sec,
        prefill_tokens,
        decode_l2_hit_pct: if l2_hits + l2_misses > 0 {
            100.0 * l2_hits as f64 / (l2_hits + l2_misses) as f64
        } else {
            0.0
        },
        advisor_consults: prefill_exec.consults() + decode_exec.consults(),
        distinct_geometries: prefill_exec.distinct_geometries()
            + decode_exec.distinct_geometries(),
        kv_shared_tokens,
        kv_xcd_affinity_pct: if kv_total_blocks > 0 {
            100.0 * kv_affine_blocks as f64 / kv_total_blocks as f64
        } else {
            0.0
        },
        truncated,
    };
    let extras = DisaggExtras {
        prefill_devices: cfg.prefill_devices,
        decode_devices: cfg.decode_devices,
        handoffs: trace.handoffs.len() as u64,
        handoff_total_bytes: trace.handoffs.iter().map(|h| h.total_bytes).sum(),
        handoff_transferred_bytes: trace.handoffs.iter().map(|h| h.transferred_bytes).sum(),
        handoff_credited_bytes: trace.handoffs.iter().map(|h| h.credited_bytes).sum(),
        handoff_sec,
        preemptions,
        prefill_steps,
        decode_steps,
        interactive: ClassStats::from_samples(&class_ttft[0], &class_tpot[0], class_tokens[0]),
        batch: ClassStats::from_samples(&class_ttft[1], &class_tpot[1], class_tokens[1]),
    };
    (DisaggStats { serve: serve_stats, extras: Some(extras) }, trace)
}

// ---------------------------------------------------------------------
// Sweep / report / CLI plumbing (mirrors the serve and cluster sweeps)
// ---------------------------------------------------------------------

/// One disaggregated sweep scenario.
#[derive(Debug, Clone)]
pub struct DisaggScenario {
    /// Row label in the disagg report / figure.
    pub label: String,
    /// The run configuration (once per applicable policy).
    pub cfg: DisaggConfig,
}

/// The disaggregated serving sweep: a mixed interactive+batch Llama-3
/// 70B trace served by a colocated baseline and by split pools on the
/// same device count — the equal-hardware twins the `disagg_serving`
/// bench compares. `quick` runs the 2-device pair; the full sweep adds
/// the 4-device pair and an 80%-shared handoff-credit scenario.
pub fn disagg_scenarios(quick: bool) -> Vec<DisaggScenario> {
    let serve = ServeConfig {
        arrival_per_sec: 120.0,
        sessions: 12,
        max_active: 8,
        max_steps: 2400,
        chunk_tokens: 1024,
        step_token_budget: 2048,
        prefill_lengths: vec![2048, 8192],
        decode_tokens: vec![32, 128],
        ..ServeConfig::default()
    };
    let base = DisaggConfig {
        serve,
        prefill_devices: 1,
        decode_devices: 1,
        interactive_pct: 30.0,
        ttft_slo_ms: 40.0,
        ..DisaggConfig::default()
    };
    let mut out = vec![
        DisaggScenario {
            label: "llama3-70b colocated x2 arr=120/s".into(),
            cfg: DisaggConfig { prefill_devices: 0, decode_devices: 2, ..base.clone() },
        },
        DisaggScenario {
            label: "llama3-70b disagg 1p+1d arr=120/s".into(),
            cfg: base.clone(),
        },
    ];
    if !quick {
        out.push(DisaggScenario {
            label: "llama3-70b colocated x4 arr=120/s".into(),
            cfg: DisaggConfig { prefill_devices: 0, decode_devices: 4, ..base.clone() },
        });
        out.push(DisaggScenario {
            label: "llama3-70b disagg 2p+2d arr=120/s".into(),
            cfg: DisaggConfig { prefill_devices: 2, decode_devices: 2, ..base.clone() },
        });
        out.push(DisaggScenario {
            label: "llama3-70b disagg 1p+1d 80%-shared arr=120/s".into(),
            cfg: DisaggConfig {
                serve: ServeConfig {
                    kv_block_tokens: 256,
                    prefix_share_pct: 80.0,
                    kv_capacity_mb: 1024,
                    ..base.serve.clone()
                },
                ..base
            },
        });
    }
    out
}

/// One disagg-report row: a scenario with per-policy stats.
#[derive(Debug, Clone)]
pub struct DisaggRow {
    /// Scenario label.
    pub label: String,
    /// One [`DisaggStats`] per applicable policy.
    pub stats: Vec<DisaggStats>,
}

/// The disaggregated serving report the `disagg` CLI subcommand emits.
#[derive(Debug, Clone)]
pub struct DisaggReport {
    /// Scenario rows in sweep order.
    pub rows: Vec<DisaggRow>,
}

/// Policies applicable to every pool of the deployment: the
/// intersection over pool shard-local geometries. A colocated config
/// reduces to the decode pool's set, which is exactly what the
/// historical `serve`/`cluster` row assembly uses (the golden pins
/// depend on identical policy lists).
pub fn disagg_applicable_policies(device: &Topology, cfg: &DisaggConfig) -> Vec<Policy> {
    let base = cfg.serve.base_geometry();
    let local_of = |tp: usize| {
        ShardPlan::new(&base, tp, ShardStrategy::Contiguous)
            .expect("validated: pool size divides h_k")
            .local_attn(&base)
    };
    let mut pols = advisor::applicable_policies(device, &local_of(cfg.decode_devices));
    if cfg.prefill_devices > 0 {
        let pre = advisor::applicable_policies(device, &local_of(cfg.prefill_devices));
        pols.retain(|p| pre.contains(p));
    }
    pols
}

/// Build one disagg-report row: the scenario served under every policy
/// applicable to all its pools. The ONE place row assembly lives — the
/// sweep ([`disagg_report`]) and the CLI's `--config` path both call
/// it.
pub fn disagg_row(
    driver: &SimDriver,
    device: &Topology,
    cfg: &DisaggConfig,
    label: String,
) -> DisaggRow {
    let stats = disagg_applicable_policies(device, cfg)
        .into_iter()
        .map(|p| serve_decode_disagg_with(driver, device, cfg, p))
        .collect();
    DisaggRow { label, stats }
}

/// The full disaggregated serving report: every sweep scenario under
/// every applicable policy through one driver (colocated twins share
/// cache entries with the historical sweeps where geometries coincide).
pub fn disagg_report(driver: &SimDriver, device: &Topology, quick: bool) -> DisaggReport {
    let rows = disagg_scenarios(quick)
        .into_iter()
        .map(|sc| disagg_row(driver, device, &sc.cfg, sc.label))
        .collect();
    DisaggReport { rows }
}

impl DisaggReport {
    /// Stats for (row label, policy), for assertions in tests/benches.
    pub fn stats(&self, label: &str, policy: Policy) -> Option<&DisaggStats> {
        self.rows
            .iter()
            .find(|r| r.label == label)?
            .stats
            .iter()
            .find(|s| s.serve.policy == policy)
    }

    /// Aligned-table rendering (one table per scenario row).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for row in &self.rows {
            let mut t = Table::new(&[
                "policy",
                "tokens/s",
                "int TTFT p99 (ms)",
                "bat TTFT p99 (ms)",
                "TTFT p99 (ms)",
                "TPOT p50 (ms)",
                "handoffs",
                "xfer MiB",
                "credit MiB",
                "preempt",
                "sessions",
            ]);
            for s in &row.stats {
                let (int_ttft, bat_ttft, handoffs, xfer, credit, preempt) = match &s.extras {
                    Some(e) => (
                        fmt_ms(e.interactive.ttft_p99_ms),
                        fmt_ms(e.batch.ttft_p99_ms),
                        e.handoffs.to_string(),
                        format!("{:.1}", e.handoff_transferred_bytes as f64 / (1024.0 * 1024.0)),
                        format!("{:.1}", e.handoff_credited_bytes as f64 / (1024.0 * 1024.0)),
                        e.preemptions.to_string(),
                    ),
                    None => ("-".into(), "-".into(), "-".into(), "-".into(), "-".into(), "-".into()),
                };
                t.row(vec![
                    s.serve.policy.label().into(),
                    format!("{:.0}", s.serve.tokens_per_sec),
                    int_ttft,
                    bat_ttft,
                    fmt_ms(s.serve.ttft_p99_ms),
                    fmt_ms(s.serve.tpot_p50_ms),
                    handoffs,
                    xfer,
                    credit,
                    preempt,
                    format!(
                        "{}{}",
                        s.serve.sessions_completed,
                        if s.serve.truncated { "*" } else { "" }
                    ),
                ]);
            }
            out.push_str(&format!("== disagg — {} ==\n{}", row.label, t.render()));
        }
        if self.rows.iter().any(|r| r.stats.iter().any(|s| s.serve.truncated)) {
            out.push_str("(* = step budget exhausted before the trace drained)\n");
        }
        out
    }

    /// JSON rendering for `disagg --json` (stable row/policy order).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "rows",
            Json::arr(self.rows.iter().map(|r| {
                Json::obj(vec![
                    ("label", Json::str(r.label.clone())),
                    ("policies", Json::arr(r.stats.iter().map(DisaggStats::to_json))),
                ])
            })),
        )])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets;

    fn fast_topo() -> Topology {
        Topology {
            name: "mi300x-mini".into(),
            cus_per_xcd: 8,
            l2_bytes_per_xcd: 1024 * 1024,
            hbm_bytes_per_sec: 5.3e12 / 4.75,
            ..presets::mi300x()
        }
    }

    fn tiny_serve() -> ServeConfig {
        ServeConfig {
            h_q: 16,
            h_k: 8,
            d_head: 64,
            kv_cap: 8192,
            kv_bucket: 2048,
            arrival_per_sec: 2000.0,
            prefill_lengths: vec![1024, 2048],
            decode_tokens: vec![4, 12],
            sessions: 6,
            max_active: 3,
            max_steps: 200,
            ..ServeConfig::default()
        }
    }

    fn tiny_disagg() -> DisaggConfig {
        DisaggConfig {
            serve: tiny_serve(),
            prefill_devices: 1,
            decode_devices: 1,
            interactive_pct: 50.0,
            ttft_slo_ms: 0.0,
            ..DisaggConfig::default()
        }
    }

    #[test]
    fn validate_rejects_degenerate_configs() {
        let ok = tiny_disagg();
        ok.validate().unwrap();
        let bad = DisaggConfig { decode_devices: 0, ..ok.clone() };
        assert!(bad.validate().is_err());
        let bad = DisaggConfig { prefill_devices: 3, ..ok.clone() };
        assert!(bad.validate().unwrap_err().contains("divide h_k"), "tp must divide h_k");
        let bad = DisaggConfig { link_gbs: 0.0, ..ok.clone() };
        assert!(bad.validate().is_err());
        let bad = DisaggConfig { interactive_pct: 140.0, ..ok.clone() };
        assert!(bad.validate().is_err());
        let bad = DisaggConfig { ttft_slo_ms: -1.0, ..ok };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn colocated_run_is_byte_identical_to_historical_serve() {
        let topo = fast_topo();
        let driver = SimDriver::new(2);
        let cfg = DisaggConfig {
            prefill_devices: 0,
            decode_devices: 1,
            interactive_pct: 0.0,
            ..tiny_disagg()
        };
        let d = serve_decode_disagg_with(&driver, &topo, &cfg, Policy::SwizzledHeadFirst);
        assert!(d.extras.is_none(), "colocated runs carry no extras");
        let s = serve_decode_with(&driver, &topo, &cfg.serve, Policy::SwizzledHeadFirst);
        assert_eq!(d.to_json().render(), s.to_json().render());
        // Even with SLO classes drawn, the colocated path is class-blind
        // and byte-identical (the class draw rides its own RNG stream).
        let classed = DisaggConfig { interactive_pct: 50.0, ..cfg };
        let dc = serve_decode_disagg_with(&driver, &topo, &classed, Policy::SwizzledHeadFirst);
        assert_eq!(dc.to_json().render(), s.to_json().render());
    }

    #[test]
    fn disagg_run_completes_and_conserves_sessions() {
        let topo = fast_topo();
        let driver = SimDriver::new(2);
        let cfg = tiny_disagg();
        let (stats, trace) =
            serve_decode_disagg_traced(&driver, &topo, &cfg, Policy::SwizzledHeadFirst);
        assert!(!stats.serve.truncated, "tiny trace drains");
        assert_eq!(stats.serve.sessions_completed, cfg.serve.sessions);
        let e = stats.extras.as_ref().expect("disagg extras present");
        assert_eq!(e.handoffs as usize, cfg.serve.sessions, "every session hands off once");
        assert!(e.handoff_total_bytes > 0 && e.handoff_sec > 0.0);
        // Pool disabled: every handoff byte moves over the link.
        assert_eq!(e.handoff_transferred_bytes, e.handoff_total_bytes);
        assert_eq!(e.handoff_credited_bytes, 0);
        // Tokens split per class and sum to the total.
        assert_eq!(e.interactive.tokens + e.batch.tokens, stats.serve.tokens);
        // Every decode admission respects its handoff's ready time.
        for h in &trace.handoffs {
            let adm = h.admitted_sec.expect("drained run admits every handoff");
            assert!(adm >= h.ready_sec - 1e-12, "session {} decoded before its KV arrived", h.id);
            assert!(h.ready_sec >= h.sent_sec);
        }
        // Conservation at every step: each session is in exactly one
        // place.
        for a in &trace.audits {
            assert_eq!(
                a.backlog + a.prefill_active + a.transit + a.decode_active + a.completed,
                cfg.serve.sessions
            );
        }
        // Prompt conservation: the chunk history covers every prompt
        // token exactly once (monolithic config: one chunk per session).
        let mut by_id: BTreeMap<u64, usize> = BTreeMap::new();
        for c in &trace.chunks {
            *by_id.entry(c.id).or_insert(0) += c.tokens();
        }
        for s in &trace.sessions {
            assert_eq!(by_id.get(&s.id).copied().unwrap_or(0), s.prefill, "session {}", s.id);
        }
    }

    #[test]
    fn disagg_is_deterministic_across_driver_threads() {
        let topo = fast_topo();
        let cfg = DisaggConfig {
            serve: ServeConfig { chunk_tokens: 256, step_token_budget: 512, ..tiny_serve() },
            ttft_slo_ms: 20.0,
            ..tiny_disagg()
        };
        let a =
            serve_decode_disagg_with(&SimDriver::new(1), &topo, &cfg, Policy::SwizzledHeadFirst);
        let b =
            serve_decode_disagg_with(&SimDriver::new(8), &topo, &cfg, Policy::SwizzledHeadFirst);
        assert_eq!(a.to_json().render(), b.to_json().render());
    }

    #[test]
    fn shared_prefixes_credit_handoff_bytes() {
        let topo = fast_topo();
        let driver = SimDriver::new(2);
        let cfg = DisaggConfig {
            serve: ServeConfig {
                kv_block_tokens: 256,
                prefix_share_pct: 100.0,
                kv_capacity_mb: 64,
                ..tiny_serve()
            },
            ..tiny_disagg()
        };
        let (stats, trace) =
            serve_decode_disagg_traced(&driver, &topo, &cfg, Policy::SwizzledHeadFirst);
        let e = stats.extras.as_ref().unwrap();
        assert!(e.handoff_credited_bytes > 0, "resident shared blocks transfer nothing");
        assert!(
            e.handoff_transferred_bytes + e.handoff_credited_bytes == e.handoff_total_bytes,
            "every byte is transferred or credited, never both"
        );
        // The first sharer moves the shared prefix; later sharers
        // credit it.
        let first = &trace.handoffs[0];
        assert_eq!(first.credited_bytes, 0, "first handoff finds nothing resident");
        assert!(trace.handoffs.iter().skip(1).any(|h| h.credited_bytes > 0));
    }

    #[test]
    fn report_renders_and_scenarios_validate() {
        for sc in disagg_scenarios(false) {
            sc.cfg.validate().unwrap_or_else(|e| panic!("{}: {e}", sc.label));
        }
        // A tiny two-row report end to end (colocated + disagg).
        let topo = fast_topo();
        let driver = SimDriver::new(2);
        let rows = vec![
            disagg_row(
                &driver,
                &topo,
                &DisaggConfig { prefill_devices: 0, ..tiny_disagg() },
                "colo".into(),
            ),
            disagg_row(&driver, &topo, &tiny_disagg(), "disagg".into()),
        ];
        let report = DisaggReport { rows };
        let text = report.render();
        assert!(text.contains("== disagg — colo =="), "{text}");
        assert!(text.contains("int TTFT p99"), "{text}");
        let json = report.to_json().render();
        assert!(json.contains("\"disagg\""), "disagg rows carry extras: {json}");
        assert!(report.stats("disagg", Policy::SwizzledHeadFirst).is_some());
    }
}
