//! The attention service: router + batcher + PJRT worker.
//!
//! Submissions enqueue immediately and return a [`Waiter`]; execution
//! happens on a dedicated worker thread because PJRT execution is
//! synchronous. Concurrent submissions therefore batch naturally. When a released batch
//! contains 2+ requests and the manifest has a batch-2 variant of the
//! bucket's artifact, requests are executed *stacked* through it —
//! dynamic batching that actually changes the executed computation, not
//! just the queueing.

use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::util::oneshot;

use crate::metrics::LatencyHistogram;
use crate::runtime::{inputs, Runtime};
use crate::workload::Request;

use super::batcher::{Batch, BatcherConfig, BatcherCore};
use super::router::Router;

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Directory holding `manifest.json` and the AOT artifacts.
    pub artifact_dir: std::path::PathBuf,
    /// Batching policy (max batch size, max wait).
    pub batcher: BatcherConfig,
}

/// One served response.
#[derive(Debug, Clone)]
pub struct Response {
    /// The request id this response answers.
    pub id: u64,
    /// Artifact the request executed through.
    pub artifact: String,
    /// abs-sum checksum of this request's output slice (verification).
    pub checksum: f64,
    /// Time spent queued before its batch released.
    pub queue_wait: Duration,
    /// PJRT execution time of the batch.
    pub exec_time: Duration,
    /// Requests co-executed in the same PJRT call.
    pub batch_size: usize,
}

/// Aggregate service counters and latency snapshots.
#[derive(Debug, Default, Clone)]
pub struct ServiceMetrics {
    /// Requests accepted.
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Batches executed stacked through a batch-2 artifact.
    pub stacked_executions: u64,
    /// Requests that failed.
    pub errors: u64,
    /// Queue-wait latency distribution.
    pub queue_wait: LatencyHistogramSnapshot,
    /// Execution latency distribution.
    pub exec: LatencyHistogramSnapshot,
}

/// Point-in-time summary of a latency histogram.
#[derive(Debug, Default, Clone)]
pub struct LatencyHistogramSnapshot {
    /// Samples recorded.
    pub count: u64,
    /// Mean latency in microseconds.
    pub mean_us: f64,
    /// 99th-percentile latency in microseconds.
    pub p99_us: u64,
    /// Maximum latency in microseconds.
    pub max_us: u64,
}

fn snapshot(h: &LatencyHistogram) -> LatencyHistogramSnapshot {
    LatencyHistogramSnapshot {
        count: h.count(),
        mean_us: h.mean_us(),
        p99_us: h.quantile_us(0.99),
        max_us: h.max_us(),
    }
}

#[derive(Default)]
struct MetricsInner {
    requests: u64,
    batches: u64,
    stacked: u64,
    errors: u64,
    queue_wait: LatencyHistogram,
    exec: LatencyHistogram,
}

struct Job {
    req: Request,
    artifact: String,
    reply: oneshot::Sender<anyhow::Result<Response>>,
}

/// Pending response handle.
pub struct Waiter {
    rx: oneshot::Receiver<anyhow::Result<Response>>,
}

impl Waiter {
    /// Block until the batch containing this request has executed.
    pub fn wait(self) -> anyhow::Result<Response> {
        self.rx
            .wait()
            .map_err(|_| anyhow::anyhow!("worker dropped reply"))?
    }
}

/// Handle to the running service.
pub struct AttentionService {
    tx: Option<std::sync::mpsc::Sender<Job>>,
    router: Router,
    metrics: Arc<Mutex<MetricsInner>>,
    worker: Option<JoinHandle<()>>,
}

impl AttentionService {
    /// Load artifacts, build the router, spawn the worker thread.
    ///
    /// PJRT handles are not `Send`, so the [`Runtime`] is constructed
    /// *inside* the worker thread; startup errors are reported back over
    /// a one-shot before any request is accepted.
    pub fn start(cfg: ServiceConfig) -> anyhow::Result<Self> {
        // The router only needs the manifest, which is plain data.
        let manifest = crate::runtime::Manifest::load(&cfg.artifact_dir)?;
        let router = Router::from_manifest(&manifest);
        anyhow::ensure!(router.num_buckets() > 0, "no batch-1 attention artifacts in manifest");

        let metrics = Arc::new(Mutex::new(MetricsInner::default()));
        let (tx, rx) = std::sync::mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = oneshot::channel::<Result<(), String>>();
        let worker_metrics = metrics.clone();
        let batcher_cfg = cfg.batcher;
        let artifact_dir = cfg.artifact_dir.clone();
        let worker = std::thread::Builder::new()
            .name("attn-worker".into())
            .spawn(move || {
                // Compile every attention artifact up front (serving never
                // compiles on the request path).
                let runtime = (|| -> anyhow::Result<Runtime> {
                    let mut rt = Runtime::open(&artifact_dir)?;
                    let names: Vec<String> = rt
                        .manifest()
                        .attention_artifacts()
                        .map(|a| a.name.clone())
                        .collect();
                    for n in &names {
                        rt.load(n)?;
                    }
                    Ok(rt)
                })();
                match runtime {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        worker_loop(rt, rx, batcher_cfg, worker_metrics);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                    }
                }
            })?;
        ready_rx
            .wait()
            .map_err(|_| anyhow::anyhow!("worker died during startup"))?
            .map_err(|e| anyhow::anyhow!("worker startup: {e}"))?;

        Ok(AttentionService { tx: Some(tx), router, metrics, worker: Some(worker) })
    }

    /// The service's context-length router.
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Submit a request. The job is enqueued *immediately* (so the
    /// batcher can group concurrent submissions); the returned [`Waiter`]
    /// resolves when its batch has executed.
    pub fn submit(&self, req: Request) -> anyhow::Result<Waiter> {
        let artifact = self
            .router
            .route(&req)
            .map_err(|e| anyhow::anyhow!("routing: {e}"))?
            .to_string();
        let (reply, rx) = oneshot::channel();
        self.tx
            .as_ref()
            .expect("service running")
            .send(Job { req, artifact, reply })
            .map_err(|_| anyhow::anyhow!("service worker stopped"))?;
        Ok(Waiter { rx })
    }

    /// Snapshot the service counters and latency histograms.
    pub fn metrics(&self) -> ServiceMetrics {
        let m = self.metrics.lock().unwrap();
        ServiceMetrics {
            requests: m.requests,
            batches: m.batches,
            stacked_executions: m.stacked,
            errors: m.errors,
            queue_wait: snapshot(&m.queue_wait),
            exec: snapshot(&m.exec),
        }
    }

    /// Graceful shutdown: drain queued work, join the worker.
    pub fn shutdown(mut self) -> ServiceMetrics {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.metrics()
    }
}

impl Drop for AttentionService {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    runtime: Runtime,
    rx: std::sync::mpsc::Receiver<Job>,
    batcher_cfg: BatcherConfig,
    metrics: Arc<Mutex<MetricsInner>>,
) {
    let mut batcher = BatcherCore::new(batcher_cfg);
    let mut replies: std::collections::HashMap<u64, oneshot::Sender<anyhow::Result<Response>>> =
        std::collections::HashMap::new();

    loop {
        let now = Instant::now();
        let job = match batcher.next_deadline() {
            Some(deadline) => {
                let timeout = deadline.saturating_duration_since(now);
                match rx.recv_timeout(timeout) {
                    Ok(j) => Some(j),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
            None => match rx.recv() {
                Ok(j) => Some(j),
                Err(_) => break,
            },
        };
        if let Some(job) = job {
            replies.insert(job.req.id, job.reply);
            if let Some(batch) = batcher.push(&job.artifact, job.req, Instant::now()) {
                execute_batch(&runtime, batch, &mut replies, &metrics);
            }
        }
        for batch in batcher.poll_expired(Instant::now()) {
            execute_batch(&runtime, batch, &mut replies, &metrics);
        }
    }
    // Shutdown: drain remaining queued requests.
    for batch in batcher.drain_all() {
        execute_batch(&runtime, batch, &mut replies, &metrics);
    }
}

/// Derive the three deterministic QKV input seeds of a request.
pub fn qkv_seeds(req_seed: u64) -> [u64; 3] {
    [req_seed, req_seed.wrapping_add(1_000_003), req_seed.wrapping_add(2_000_003)]
}

fn execute_batch(
    runtime: &Runtime,
    batch: Batch,
    replies: &mut std::collections::HashMap<u64, oneshot::Sender<anyhow::Result<Response>>>,
    metrics: &Arc<Mutex<MetricsInner>>,
) {
    let now = Instant::now();
    let meta = runtime
        .manifest()
        .get(&batch.artifact)
        .expect("routed artifact exists")
        .clone();
    let n = batch.requests.len();

    // Find a stacked (batch-2) variant with identical geometry.
    let stacked_name = meta.attn.as_ref().and_then(|a| {
        runtime
            .manifest()
            .attention_artifacts()
            .find(|c| {
                c.attn.as_ref().is_some_and(|ca| {
                    ca.batch == 2
                        && ca.n_ctx == a.n_ctx
                        && ca.h_q == a.h_q
                        && ca.h_k == a.h_k
                        && ca.d_head == a.d_head
                        && ca.causal == a.causal
                })
            })
            .filter(|c| runtime.is_loaded(&c.name))
            .map(|c| c.name.clone())
    });

    let mut idx = 0;
    while idx < n {
        let pair = stacked_name.is_some() && idx + 1 < n;
        let result = if pair {
            execute_stacked(
                runtime,
                stacked_name.as_deref().unwrap(),
                &batch.requests[idx].0,
                &batch.requests[idx + 1].0,
            )
        } else {
            execute_single(runtime, &batch.artifact, &batch.requests[idx].0).map(|(c, d)| (c, 0.0, d))
        };

        let consumed = if pair { 2 } else { 1 };
        match result {
            Ok((ck0, ck1, exec_d)) => {
                for (k, ck) in [(idx, ck0), (idx + 1, ck1)].into_iter().take(consumed) {
                    let (req, enq) = &batch.requests[k];
                    let resp = Response {
                        id: req.id,
                        artifact: if pair {
                            stacked_name.clone().unwrap()
                        } else {
                            batch.artifact.clone()
                        },
                        checksum: ck,
                        queue_wait: now.duration_since(*enq),
                        exec_time: exec_d,
                        batch_size: consumed,
                    };
                    let mut m = metrics.lock().unwrap();
                    m.requests += 1;
                    m.queue_wait.record(resp.queue_wait);
                    m.exec.record(exec_d);
                    if pair {
                        m.stacked += 1;
                    }
                    drop(m);
                    if let Some(tx) = replies.remove(&req.id) {
                        let _ = tx.send(Ok(resp));
                    }
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for k in idx..(idx + consumed).min(n) {
                    let (req, _) = &batch.requests[k];
                    metrics.lock().unwrap().errors += 1;
                    if let Some(tx) = replies.remove(&req.id) {
                        let _ = tx.send(Err(anyhow::anyhow!("{msg}")));
                    }
                }
            }
        }
        idx += consumed;
    }
    metrics.lock().unwrap().batches += 1;
}

fn request_qkv(runtime: &Runtime, artifact: &str, req: &Request) -> anyhow::Result<Vec<Vec<f32>>> {
    let meta = runtime
        .manifest()
        .get(artifact)
        .ok_or_else(|| anyhow::anyhow!("artifact '{artifact}' missing"))?;
    let seeds = qkv_seeds(req.seed);
    Ok(meta
        .inputs
        .iter()
        .zip(seeds)
        .map(|(spec, seed)| inputs::det_input(seed, spec.num_elements()))
        .collect())
}

fn execute_single(
    runtime: &Runtime,
    artifact: &str,
    req: &Request,
) -> anyhow::Result<(f64, Duration)> {
    let qkv = request_qkv(runtime, artifact, req)?;
    let r = runtime.execute(artifact, &qkv)?;
    let (abs_sum, _, _) = inputs::stats(&r.outputs[0]);
    Ok((abs_sum, r.elapsed))
}

/// Stack two requests' Q/K/V along the batch axis and run the batch-2
/// artifact; split the output checksums back per request.
fn execute_stacked(
    runtime: &Runtime,
    stacked_artifact: &str,
    a: &Request,
    b: &Request,
) -> anyhow::Result<(f64, f64, Duration)> {
    // The stacked artifact's inputs are (2, H, N, D); each request's
    // deterministic tensors are (1, H, N, D) halves.
    let meta = runtime
        .manifest()
        .get(stacked_artifact)
        .ok_or_else(|| anyhow::anyhow!("artifact '{stacked_artifact}' missing"))?;
    let sa = qkv_seeds(a.seed);
    let sb = qkv_seeds(b.seed);
    let mut stacked_inputs = Vec::with_capacity(3);
    for (i, spec) in meta.inputs.iter().enumerate() {
        let half = spec.num_elements() / 2;
        let mut buf = inputs::det_input(sa[i], half);
        buf.extend(inputs::det_input(sb[i], half));
        stacked_inputs.push(buf);
    }
    let r = runtime.execute(stacked_artifact, &stacked_inputs)?;
    let out = &r.outputs[0];
    let half = out.len() / 2;
    let (ck_a, _, _) = inputs::stats(&out[..half]);
    let (ck_b, _, _) = inputs::stats(&out[half..]);
    Ok((ck_a, ck_b, r.elapsed))
}
