//! The serving services, one per regime (docs/SERVING.md):
//!
//! * **Live prefill service** ([`AttentionService`]): router + batcher +
//!   PJRT worker. Submissions enqueue immediately and return a
//!   [`Waiter`]; execution happens on a dedicated worker thread because
//!   PJRT execution is synchronous. Concurrent submissions therefore
//!   batch naturally. When a released batch contains 2+ requests and the
//!   manifest has a batch-2 variant of the bucket's artifact, requests
//!   are executed *stacked* through it — dynamic batching that actually
//!   changes the executed computation, not just the queueing.
//! * **Simulated decode serving loop** ([`serve_decode`]): the
//!   iteration-level continuous-batching driver over the chiplet
//!   simulator. Sessions arrive on a seeded Poisson-ish schedule, the
//!   [`super::batcher::StepBatcher`] re-forms the active batch every
//!   decode step, each step's kernel launches are priced by
//!   [`crate::sim::SimReport`] tick costs obtained through the shared
//!   simulation driver, and the advisor re-picks the KV split count
//!   whenever a geometry is first seen (KV growth crossing a bucket
//!   boundary, or the batch changing size). This is how the paper's
//!   NUMA-aware mapping becomes the thing the service consults on every
//!   decode step rather than an offline figure.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::util::oneshot;

use crate::attn::AttnConfig;
use crate::cluster::{ClusterTopology, ShardPlan, ShardStrategy};
use crate::driver::{self, SimDriver};
use crate::mapping::Policy;
use crate::mem::{block_bytes, prompt_keys, KvPool};
use crate::metrics::{percentile, LatencyHistogram, Table};
use crate::runtime::{inputs, Runtime};
use crate::topology::Topology;
use crate::util::json::Json;
use crate::workload::sweeps::CLUSTER_TP;
use crate::workload::Request;
use crate::workload::SessionGenerator;
use crate::workload::{SessionSource, TraceReplay};

use super::advisor;
use super::batcher::{Batch, BatcherConfig, BatcherCore, PrefillChunk, StepBatcher};
use super::executor::{ClusterExecutor, SingleDeviceExecutor, StepExecutor};
use super::router::Router;

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Directory holding `manifest.json` and the AOT artifacts.
    pub artifact_dir: std::path::PathBuf,
    /// Batching policy (max batch size, max wait).
    pub batcher: BatcherConfig,
}

/// One served response.
#[derive(Debug, Clone)]
pub struct Response {
    /// The request id this response answers.
    pub id: u64,
    /// Artifact the request executed through.
    pub artifact: String,
    /// abs-sum checksum of this request's output slice (verification).
    pub checksum: f64,
    /// Time spent queued before its batch released.
    pub queue_wait: Duration,
    /// PJRT execution time of the batch.
    pub exec_time: Duration,
    /// Requests co-executed in the same PJRT call.
    pub batch_size: usize,
}

/// Aggregate service counters and latency snapshots.
#[derive(Debug, Default, Clone)]
pub struct ServiceMetrics {
    /// Requests accepted.
    pub requests: u64,
    /// Batches executed.
    pub batches: u64,
    /// Batches executed stacked through a batch-2 artifact.
    pub stacked_executions: u64,
    /// Requests that failed.
    pub errors: u64,
    /// Queue-wait latency distribution.
    pub queue_wait: LatencyHistogramSnapshot,
    /// Execution latency distribution.
    pub exec: LatencyHistogramSnapshot,
}

/// Point-in-time summary of a latency histogram.
#[derive(Debug, Default, Clone)]
pub struct LatencyHistogramSnapshot {
    /// Samples recorded.
    pub count: u64,
    /// Mean latency in microseconds.
    pub mean_us: f64,
    /// 99th-percentile latency in microseconds.
    pub p99_us: u64,
    /// Maximum latency in microseconds.
    pub max_us: u64,
}

fn snapshot(h: &LatencyHistogram) -> LatencyHistogramSnapshot {
    LatencyHistogramSnapshot {
        count: h.count(),
        mean_us: h.mean_us(),
        p99_us: h.quantile_us(0.99),
        max_us: h.max_us(),
    }
}

#[derive(Default)]
struct MetricsInner {
    requests: u64,
    batches: u64,
    stacked: u64,
    errors: u64,
    queue_wait: LatencyHistogram,
    exec: LatencyHistogram,
}

struct Job {
    req: Request,
    artifact: String,
    reply: oneshot::Sender<anyhow::Result<Response>>,
}

/// Pending response handle.
pub struct Waiter {
    rx: oneshot::Receiver<anyhow::Result<Response>>,
}

impl Waiter {
    /// Block until the batch containing this request has executed.
    pub fn wait(self) -> anyhow::Result<Response> {
        self.rx
            .wait()
            .map_err(|_| anyhow::anyhow!("worker dropped reply"))?
    }
}

/// Handle to the running service.
pub struct AttentionService {
    tx: Option<std::sync::mpsc::Sender<Job>>,
    router: Router,
    metrics: Arc<Mutex<MetricsInner>>,
    worker: Option<JoinHandle<()>>,
}

impl AttentionService {
    /// Load artifacts, build the router, spawn the worker thread.
    ///
    /// PJRT handles are not `Send`, so the [`Runtime`] is constructed
    /// *inside* the worker thread; startup errors are reported back over
    /// a one-shot before any request is accepted.
    pub fn start(cfg: ServiceConfig) -> anyhow::Result<Self> {
        // The router only needs the manifest, which is plain data.
        let manifest = crate::runtime::Manifest::load(&cfg.artifact_dir)?;
        let router = Router::from_manifest(&manifest);
        anyhow::ensure!(router.num_buckets() > 0, "no batch-1 attention artifacts in manifest");

        let metrics = Arc::new(Mutex::new(MetricsInner::default()));
        let (tx, rx) = std::sync::mpsc::channel::<Job>();
        let (ready_tx, ready_rx) = oneshot::channel::<Result<(), String>>();
        let worker_metrics = metrics.clone();
        let batcher_cfg = cfg.batcher;
        let artifact_dir = cfg.artifact_dir.clone();
        let worker = std::thread::Builder::new()
            .name("attn-worker".into())
            .spawn(move || {
                // Compile every attention artifact up front (serving never
                // compiles on the request path).
                let runtime = (|| -> anyhow::Result<Runtime> {
                    let mut rt = Runtime::open(&artifact_dir)?;
                    let names: Vec<String> = rt
                        .manifest()
                        .attention_artifacts()
                        .map(|a| a.name.clone())
                        .collect();
                    for n in &names {
                        rt.load(n)?;
                    }
                    Ok(rt)
                })();
                match runtime {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        worker_loop(rt, rx, batcher_cfg, worker_metrics);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                    }
                }
            })?;
        ready_rx
            .wait()
            .map_err(|_| anyhow::anyhow!("worker died during startup"))?
            .map_err(|e| anyhow::anyhow!("worker startup: {e}"))?;

        Ok(AttentionService { tx: Some(tx), router, metrics, worker: Some(worker) })
    }

    /// The service's context-length router.
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// Submit a request. The job is enqueued *immediately* (so the
    /// batcher can group concurrent submissions); the returned [`Waiter`]
    /// resolves when its batch has executed.
    pub fn submit(&self, req: Request) -> anyhow::Result<Waiter> {
        let artifact = self
            .router
            .route(&req)
            .map_err(|e| anyhow::anyhow!("routing: {e}"))?
            .to_string();
        let (reply, rx) = oneshot::channel();
        self.tx
            .as_ref()
            .expect("service running")
            .send(Job { req, artifact, reply })
            .map_err(|_| anyhow::anyhow!("service worker stopped"))?;
        Ok(Waiter { rx })
    }

    /// Snapshot the service counters and latency histograms.
    pub fn metrics(&self) -> ServiceMetrics {
        let m = self.metrics.lock().unwrap();
        ServiceMetrics {
            requests: m.requests,
            batches: m.batches,
            stacked_executions: m.stacked,
            errors: m.errors,
            queue_wait: snapshot(&m.queue_wait),
            exec: snapshot(&m.exec),
        }
    }

    /// Graceful shutdown: drain queued work, join the worker.
    pub fn shutdown(mut self) -> ServiceMetrics {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
        self.metrics()
    }
}

impl Drop for AttentionService {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    runtime: Runtime,
    rx: std::sync::mpsc::Receiver<Job>,
    batcher_cfg: BatcherConfig,
    metrics: Arc<Mutex<MetricsInner>>,
) {
    let mut batcher = BatcherCore::new(batcher_cfg);
    let mut replies: std::collections::HashMap<u64, oneshot::Sender<anyhow::Result<Response>>> =
        std::collections::HashMap::new();

    loop {
        let now = Instant::now();
        let job = match batcher.next_deadline() {
            Some(deadline) => {
                let timeout = deadline.saturating_duration_since(now);
                match rx.recv_timeout(timeout) {
                    Ok(j) => Some(j),
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
            None => match rx.recv() {
                Ok(j) => Some(j),
                Err(_) => break,
            },
        };
        if let Some(job) = job {
            replies.insert(job.req.id, job.reply);
            if let Some(batch) = batcher.push(&job.artifact, job.req, Instant::now()) {
                execute_batch(&runtime, batch, &mut replies, &metrics);
            }
        }
        for batch in batcher.poll_expired(Instant::now()) {
            execute_batch(&runtime, batch, &mut replies, &metrics);
        }
    }
    // Shutdown: drain remaining queued requests.
    for batch in batcher.drain_all() {
        execute_batch(&runtime, batch, &mut replies, &metrics);
    }
}

/// Derive the three deterministic QKV input seeds of a request.
pub fn qkv_seeds(req_seed: u64) -> [u64; 3] {
    [req_seed, req_seed.wrapping_add(1_000_003), req_seed.wrapping_add(2_000_003)]
}

fn execute_batch(
    runtime: &Runtime,
    batch: Batch,
    replies: &mut std::collections::HashMap<u64, oneshot::Sender<anyhow::Result<Response>>>,
    metrics: &Arc<Mutex<MetricsInner>>,
) {
    let now = Instant::now();
    let meta = runtime
        .manifest()
        .get(&batch.artifact)
        .expect("routed artifact exists")
        .clone();
    let n = batch.requests.len();

    // Find a stacked (batch-2) variant with identical geometry.
    let stacked_name = meta.attn.as_ref().and_then(|a| {
        runtime
            .manifest()
            .attention_artifacts()
            .find(|c| {
                c.attn.as_ref().is_some_and(|ca| {
                    ca.batch == 2
                        && ca.n_ctx == a.n_ctx
                        && ca.h_q == a.h_q
                        && ca.h_k == a.h_k
                        && ca.d_head == a.d_head
                        && ca.causal == a.causal
                })
            })
            .filter(|c| runtime.is_loaded(&c.name))
            .map(|c| c.name.clone())
    });

    let mut idx = 0;
    while idx < n {
        let pair = stacked_name.is_some() && idx + 1 < n;
        let result = if pair {
            execute_stacked(
                runtime,
                stacked_name.as_deref().unwrap(),
                &batch.requests[idx].0,
                &batch.requests[idx + 1].0,
            )
        } else {
            execute_single(runtime, &batch.artifact, &batch.requests[idx].0).map(|(c, d)| (c, 0.0, d))
        };

        let consumed = if pair { 2 } else { 1 };
        match result {
            Ok((ck0, ck1, exec_d)) => {
                for (k, ck) in [(idx, ck0), (idx + 1, ck1)].into_iter().take(consumed) {
                    let (req, enq) = &batch.requests[k];
                    let resp = Response {
                        id: req.id,
                        artifact: if pair {
                            stacked_name.clone().unwrap()
                        } else {
                            batch.artifact.clone()
                        },
                        checksum: ck,
                        queue_wait: now.duration_since(*enq),
                        exec_time: exec_d,
                        batch_size: consumed,
                    };
                    let mut m = metrics.lock().unwrap();
                    m.requests += 1;
                    m.queue_wait.record(resp.queue_wait);
                    m.exec.record(exec_d);
                    if pair {
                        m.stacked += 1;
                    }
                    drop(m);
                    if let Some(tx) = replies.remove(&req.id) {
                        let _ = tx.send(Ok(resp));
                    }
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for k in idx..(idx + consumed).min(n) {
                    let (req, _) = &batch.requests[k];
                    metrics.lock().unwrap().errors += 1;
                    if let Some(tx) = replies.remove(&req.id) {
                        let _ = tx.send(Err(anyhow::anyhow!("{msg}")));
                    }
                }
            }
        }
        idx += consumed;
    }
    metrics.lock().unwrap().batches += 1;
}

fn request_qkv(runtime: &Runtime, artifact: &str, req: &Request) -> anyhow::Result<Vec<Vec<f32>>> {
    let meta = runtime
        .manifest()
        .get(artifact)
        .ok_or_else(|| anyhow::anyhow!("artifact '{artifact}' missing"))?;
    let seeds = qkv_seeds(req.seed);
    Ok(meta
        .inputs
        .iter()
        .zip(seeds)
        .map(|(spec, seed)| inputs::det_input(seed, spec.num_elements()))
        .collect())
}

fn execute_single(
    runtime: &Runtime,
    artifact: &str,
    req: &Request,
) -> anyhow::Result<(f64, Duration)> {
    let qkv = request_qkv(runtime, artifact, req)?;
    let r = runtime.execute(artifact, &qkv)?;
    let (abs_sum, _, _) = inputs::stats(&r.outputs[0]);
    Ok((abs_sum, r.elapsed))
}

/// Stack two requests' Q/K/V along the batch axis and run the batch-2
/// artifact; split the output checksums back per request.
fn execute_stacked(
    runtime: &Runtime,
    stacked_artifact: &str,
    a: &Request,
    b: &Request,
) -> anyhow::Result<(f64, f64, Duration)> {
    // The stacked artifact's inputs are (2, H, N, D); each request's
    // deterministic tensors are (1, H, N, D) halves.
    let meta = runtime
        .manifest()
        .get(stacked_artifact)
        .ok_or_else(|| anyhow::anyhow!("artifact '{stacked_artifact}' missing"))?;
    let sa = qkv_seeds(a.seed);
    let sb = qkv_seeds(b.seed);
    let mut stacked_inputs = Vec::with_capacity(3);
    for (i, spec) in meta.inputs.iter().enumerate() {
        let half = spec.num_elements() / 2;
        let mut buf = inputs::det_input(sa[i], half);
        buf.extend(inputs::det_input(sb[i], half));
        stacked_inputs.push(buf);
    }
    let r = runtime.execute(stacked_artifact, &stacked_inputs)?;
    let out = &r.outputs[0];
    let half = out.len() / 2;
    let (ck_a, _, _) = inputs::stats(&out[..half]);
    let (ck_b, _, _) = inputs::stats(&out[half..]);
    Ok((ck_a, ck_b, r.elapsed))
}

// ---------------------------------------------------------------------
// The simulated continuous-batching decode serving loop (docs/SERVING.md)
// ---------------------------------------------------------------------

/// Configuration of one decode serving run: the model geometry being
/// served plus the traffic trace and loop knobs. Defaults model Llama-3
/// 70B (GQA-8) under a moderate arrival rate; `examples/serve.ini` and
/// the `[serve]` INI section ([`crate::config::SERVE_KEYS`]) override
/// these per deployment.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Query heads of the served model.
    pub h_q: usize,
    /// KV heads of the served model (GQA; `h_q` for MHA).
    pub h_k: usize,
    /// Head dimension of the served model.
    pub d_head: usize,
    /// Q row-block size of the served kernels (`[attention] block_m`).
    pub block_m: usize,
    /// K/V column-block size of the served kernels (`[attention]
    /// block_n` — also the granularity KV splits partition over).
    pub block_n: usize,
    /// Causal masking for the prefill kernels (decode is
    /// causal-insensitive; the query is always the last token).
    pub causal: bool,
    /// Bytes per element (2 = bf16/fp16, 4 = fp32).
    pub dtype_bytes: usize,
    /// KV-cache capacity in tokens (sessions clamp to this — the
    /// `[attention] n_ctx` key in serving INI files).
    pub kv_cap: usize,
    /// KV bucketing quantum: per-session KV lengths round up to the next
    /// multiple of this for kernel-launch grouping and advisor keying.
    pub kv_bucket: usize,
    /// Session arrival rate (sessions per simulated second).
    pub arrival_per_sec: f64,
    /// Prompt-length mix, sampled uniformly per session.
    pub prefill_lengths: Vec<usize>,
    /// Decode-budget mix (tokens to generate), sampled uniformly.
    pub decode_tokens: Vec<usize>,
    /// Sessions in the trace.
    pub sessions: usize,
    /// Max sessions decoding concurrently (the continuous batch cap).
    pub max_active: usize,
    /// Decode-step budget: the loop stops (and marks the run truncated)
    /// after this many steps even if sessions remain.
    pub max_steps: usize,
    /// Chunked-prefill chunk size in prompt tokens (docs/SERVING.md §6).
    /// `0` (the default) is the historical monolithic behavior: an
    /// admitted session's whole prompt is charged in its admission step.
    /// `> 0` admits sessions immediately and streams each prompt in
    /// chunks of up to this many tokens, composed into mixed
    /// prefill+decode steps under [`Self::step_token_budget`].
    pub chunk_tokens: usize,
    /// Mixed-step token budget (Sarathi-style): each step's decode
    /// tokens (one per decode-phase session) claim the budget first and
    /// the remainder streams prefill chunks. `0` = uncapped (every
    /// still-prefilling session streams one chunk per step). Only
    /// meaningful with [`Self::chunk_tokens`] `> 0`.
    pub step_token_budget: usize,
    /// Paged KV block size in prompt tokens (docs/KVCACHE.md). `0` (the
    /// default) disables the paged pool entirely; `> 0` with
    /// [`Self::prefix_share_pct`] `> 0` turns on cross-session prefix
    /// sharing: admissions whose leading blocks are already resident
    /// skip those prefill tokens.
    pub kv_block_tokens: usize,
    /// Percentage of sessions whose prompt opens with the canonical
    /// shared prefix (system prompt / few-shot preamble). `0` (the
    /// default) disables sharing; the serving loop is then
    /// byte-identical to the pre-pool behavior (the golden pins).
    pub prefix_share_pct: f64,
    /// Paged-pool HBM byte budget in MiB (`0` = unlimited). Under
    /// pressure, refcount-0 blocks evict LRU-first; blocks still leased
    /// by live sessions are never evicted.
    pub kv_capacity_mb: usize,
    /// Trace seed (arrivals and session mix draws).
    pub seed: u64,
    /// Replayed session trace (docs/SERVING.md §8). `None` (the default)
    /// runs the seeded [`SessionGenerator`] exactly as before — the
    /// golden pins depend on that path being untouched. `Some` replaces
    /// the generator's arrival process *and* session count: the loop
    /// consumes the trace's rows verbatim and [`Self::sessions`] /
    /// [`Self::arrival_per_sec`] / the mix knobs are ignored.
    pub trace: Option<TraceReplay>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            h_q: 64,
            h_k: 8,
            d_head: 128,
            block_m: 128,
            block_n: 64,
            causal: false,
            dtype_bytes: 2,
            kv_cap: 128 * 1024,
            kv_bucket: 4096,
            arrival_per_sec: 120.0,
            prefill_lengths: vec![2048, 8192],
            decode_tokens: vec![32, 128],
            sessions: 16,
            max_active: 8,
            max_steps: 1200,
            chunk_tokens: 0,
            step_token_budget: 0,
            kv_block_tokens: 0,
            prefix_share_pct: 0.0,
            kv_capacity_mb: 0,
            seed: 7,
            trace: None,
        }
    }
}

impl ServeConfig {
    /// Check the knobs are internally consistent (geometry validity,
    /// non-empty mixes, positive rates and budgets).
    pub fn validate(&self) -> Result<(), String> {
        self.base_geometry().validate()?;
        if self.kv_bucket == 0 || self.kv_cap == 0 {
            return Err("kv_bucket/kv_cap must be > 0".into());
        }
        if self.arrival_per_sec.is_nan() || self.arrival_per_sec <= 0.0 {
            return Err("arrival_per_sec must be > 0".into());
        }
        if self.prefill_lengths.is_empty() || self.decode_tokens.is_empty() {
            return Err("prefill_lengths/decode_tokens must be non-empty".into());
        }
        if self.prefill_lengths.contains(&0) || self.decode_tokens.contains(&0) {
            return Err("prefill_lengths/decode_tokens entries must be > 0".into());
        }
        if let Some(&p) = self.prefill_lengths.iter().find(|&&p| p > self.kv_cap) {
            return Err(format!(
                "prefill_lengths entry {p} exceeds the KV capacity ({}): a prompt cannot \
                 outgrow the cache it is served from — raise [attention] n_ctx or shorten \
                 the prompt mix",
                self.kv_cap
            ));
        }
        if self.sessions == 0 {
            return Err("sessions must be > 0".into());
        }
        if self.max_active == 0 || self.max_steps == 0 {
            return Err("max_active/max_steps must be > 0".into());
        }
        if self.step_token_budget > 0 && self.chunk_tokens == 0 {
            return Err(format!(
                "step_token_budget ({}) without chunk_tokens is contradictory: the budget \
                 only composes chunked-prefill steps — set [serve] chunk_tokens > 0 or drop \
                 step_token_budget",
                self.step_token_budget
            ));
        }
        if self.chunk_tokens > self.step_token_budget && self.step_token_budget > 0 {
            return Err(format!(
                "chunk_tokens ({}) must not exceed step_token_budget ({}): a prefill chunk \
                 must fit inside one mixed step — shrink chunk_tokens or raise the budget",
                self.chunk_tokens, self.step_token_budget
            ));
        }
        if self.step_token_budget > 0 && self.step_token_budget < self.max_active {
            return Err(format!(
                "step_token_budget ({}) is below max_active ({}): every decode-phase session \
                 emits one token per step and decode is never dropped, so the budget must \
                 cover max_active decode tokens — raise the budget or lower max_active",
                self.step_token_budget, self.max_active
            ));
        }
        if !(0.0..=100.0).contains(&self.prefix_share_pct) {
            return Err(format!(
                "prefix_share_pct ({}) must be in [0, 100]",
                self.prefix_share_pct
            ));
        }
        if let Some(trace) = &self.trace {
            if trace.is_empty() {
                return Err("trace must contain at least one session".into());
            }
            if let Some(s) = trace.sessions().iter().find(|s| s.prefill > self.kv_cap) {
                return Err(format!(
                    "trace session with prefill {} exceeds the KV capacity ({}): a prompt \
                     cannot outgrow the cache it is served from — raise [attention] n_ctx \
                     or regenerate the trace with shorter prompts",
                    s.prefill, self.kv_cap
                ));
            }
        }
        Ok(())
    }

    /// The geometry of one kernel launch: `batch` sessions at context
    /// `n_ctx`, with every `[attention]` knob (blocks, masking, dtype)
    /// carried through — only `batch` from an experiment file is
    /// replaced, by the live session count.
    pub fn geometry(&self, batch: usize, n_ctx: usize) -> AttnConfig {
        AttnConfig {
            block_m: self.block_m,
            block_n: self.block_n,
            causal: self.causal,
            dtype_bytes: self.dtype_bytes,
            ..AttnConfig::gqa(batch, self.h_q, self.h_k, n_ctx, self.d_head)
        }
    }

    /// The served model's geometry at full KV capacity and batch 1 —
    /// the shape policy applicability is decided on.
    pub fn base_geometry(&self) -> AttnConfig {
        self.geometry(1, self.kv_cap)
    }

    /// Round a KV length up to the bucket the loop launches kernels at,
    /// never past the KV capacity: a deployment cannot launch a longer
    /// context than its cache holds, so the top bucket is `kv_cap`
    /// itself even when the quantum does not divide it.
    pub fn bucket_of(&self, kv_len: usize) -> usize {
        (kv_len.max(1).div_ceil(self.kv_bucket) * self.kv_bucket).min(self.kv_cap.max(1))
    }

    /// A chunk's `(start, end)` prompt-prefix positions clamped to what
    /// the KV cache can hold (and to the simulator's one-token minimum
    /// context): pricing never launches a longer prefix than `kv_cap`,
    /// mirroring the monolithic path's prompt clamp. A chunk entirely
    /// beyond the capacity collapses to an empty span (zero charge).
    pub fn chunk_span(&self, c: &PrefillChunk) -> (usize, usize) {
        let end = c.end.clamp(1, self.kv_cap.max(1));
        (c.start.min(end), end)
    }

    /// True when the paged KV pool engages: both a block size and a
    /// share rate are configured. With either at zero the serving loop
    /// takes the exact pre-pool code path, which is what makes the
    /// sharing-disabled golden pins hold by construction.
    pub fn kv_pool_enabled(&self) -> bool {
        self.kv_block_tokens > 0 && self.prefix_share_pct > 0.0
    }

    /// The canonical shared-prefix span: the shortest prompt in the mix,
    /// rounded down to whole KV blocks (a partial tail block is keyed
    /// per-session and never hits across sessions, so crediting it would
    /// overstate sharing).
    pub fn shared_span(&self) -> usize {
        let min = self.prefill_lengths.iter().copied().min().unwrap_or(0);
        if self.kv_block_tokens == 0 {
            min
        } else {
            (min / self.kv_block_tokens) * self.kv_block_tokens
        }
    }

    /// The session stream one serving run consumes: the replayed trace
    /// when configured, else the seeded [`SessionGenerator`] built
    /// exactly as the loop always built it — same constructor, same
    /// sharing gate — so the generator path stays byte-identical to the
    /// historical behavior (the golden pins).
    pub(crate) fn session_source(&self) -> Box<dyn SessionSource> {
        match &self.trace {
            Some(t) => Box::new(t.clone()),
            None => {
                let mut gen = SessionGenerator::new(
                    self.seed,
                    self.arrival_per_sec,
                    self.prefill_lengths.clone(),
                    self.decode_tokens.clone(),
                );
                if self.prefix_share_pct > 0.0 {
                    // The shared-prefix draw rides a separate RNG stream,
                    // so the arrival/prompt/decode trace is identical
                    // with sharing on or off (the sharing-disabled golden
                    // pins depend on this).
                    gen = gen.with_prefix_sharing(self.prefix_share_pct, self.shared_span());
                }
                Box::new(gen)
            }
        }
    }

    /// Sessions one run consumes: the whole trace when replaying,
    /// [`Self::sessions`] when generating.
    pub(crate) fn session_budget(&self) -> usize {
        match &self.trace {
            Some(t) => t.len(),
            None => self.sessions,
        }
    }

    /// The paged pool for one serving run, or `None` when disabled.
    pub(crate) fn kv_pool(&self) -> Option<KvPool> {
        if !self.kv_pool_enabled() {
            return None;
        }
        Some(KvPool::new(
            block_bytes(self.kv_block_tokens, self.h_k, self.d_head, self.dtype_bytes),
            self.kv_capacity_mb as u64 * 1024 * 1024,
        ))
    }
}

/// A percentile that distinguishes "no samples" from "fast":
/// [`percentile`] of an empty slice returns `0.0` (a frozen contract its
/// unit tests pin), which a serving report would misrender as a perfect
/// `0.000 ms` — exactly what a fully degraded fault window produces.
/// This wrapper returns NaN for the empty case; the render/JSON layers
/// turn NaN into `n/a` / `null` ([`fmt_ms`], [`ms_json`]). Populated
/// samples pass through untouched, so every historical pin holds.
pub(crate) fn pctl_or_nan(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        f64::NAN
    } else {
        percentile(samples, q)
    }
}

/// Millisecond table cell: `n/a` for the empty-sample NaN sentinel,
/// else the historical `{:.3}` formatting byte-for-byte.
pub(crate) fn fmt_ms(v: f64) -> String {
    if v.is_nan() {
        "n/a".into()
    } else {
        format!("{v:.3}")
    }
}

/// Millisecond JSON value: `null` for the empty-sample NaN sentinel,
/// else the historical numeric rendering byte-for-byte.
pub(crate) fn ms_json(v: f64) -> Json {
    if v.is_nan() {
        Json::Null
    } else {
        Json::num(v)
    }
}

/// Outcome of one serving run (one scenario × one mapping policy): the
/// throughput and per-token latency a deployment configured with that
/// policy would observe, in simulated time.
#[derive(Debug, Clone)]
pub struct ServeStats {
    /// The mapping policy every kernel launch in the run used.
    pub policy: Policy,
    /// Sessions that finished their full decode budget.
    pub sessions_completed: usize,
    /// Decode tokens emitted across all sessions.
    pub tokens: u64,
    /// Decode steps executed.
    pub steps: usize,
    /// Simulated time at the end of the run (includes idle gaps spent
    /// waiting for arrivals).
    pub sim_sec: f64,
    /// Decode throughput: `tokens / sim_sec`.
    pub tokens_per_sec: f64,
    /// Median time-per-output-token over all emitted tokens (ms).
    pub tpot_p50_ms: f64,
    /// 99th-percentile time-per-output-token (ms).
    pub tpot_p99_ms: f64,
    /// Median time-to-first-token over all sessions that reached their
    /// first decode token: arrival → the end of the step emitting the
    /// session's first token, in ms (docs/SERVING.md §6).
    pub ttft_p50_ms: f64,
    /// 99th-percentile time-to-first-token (ms) — the head-of-line
    /// blocking metric chunked prefill targets.
    pub ttft_p99_ms: f64,
    /// Simulated time spent in prefill kernels (stalls decode — the
    /// continuous-batching TPOT tax; see docs/SERVING.md §4).
    pub prefill_sec: f64,
    /// Prompt tokens prefilled across the run (monolithic charges or
    /// chunk launches) — the conservation counter: a drained trace
    /// prefills every session's prompt exactly once, chunked or not
    /// (pinned by `tests/serving_invariants.rs`).
    pub prefill_tokens: u64,
    /// Aggregate L2 hit rate (%) across every decode launch the run
    /// priced — the serving-loop analogue of the `decode` figure's
    /// metric (summed over all shards for cluster runs).
    pub decode_l2_hit_pct: f64,
    /// Times the advisor was (re-)consulted — once per distinct
    /// (batch size, KV bucket) geometry the loop encountered.
    pub advisor_consults: usize,
    /// Distinct decode geometries the run launched.
    pub distinct_geometries: usize,
    /// Prompt tokens satisfied by resident shared KV blocks instead of
    /// prefill kernels (docs/KVCACHE.md). Zero when the paged pool is
    /// disabled. Conservation: `prefill_tokens + kv_shared_tokens` of a
    /// drained trace equals the trace's summed prompt lengths.
    pub kv_shared_tokens: u64,
    /// Percentage of inserted KV blocks that landed in the XCD their
    /// heads map to under this run's policy — head-first swizzles pin
    /// each KV head's group to one XCD (100%), NHF round-robins blocks
    /// across XCDs (~1/num_xcds). Zero when the pool is disabled.
    pub kv_xcd_affinity_pct: f64,
    /// True when the step budget ran out before the trace drained.
    pub truncated: bool,
}

impl ServeStats {
    /// JSON rendering (stable key order) for `serve --json` output and
    /// the byte-identical determinism tests.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("policy", Json::str(self.policy.name())),
            ("sessions_completed", Json::num(self.sessions_completed as f64)),
            ("tokens", Json::num(self.tokens as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("sim_sec", Json::num(self.sim_sec)),
            ("tokens_per_sec", Json::num(self.tokens_per_sec)),
            ("tpot_p50_ms", ms_json(self.tpot_p50_ms)),
            ("tpot_p99_ms", ms_json(self.tpot_p99_ms)),
            ("ttft_p50_ms", ms_json(self.ttft_p50_ms)),
            ("ttft_p99_ms", ms_json(self.ttft_p99_ms)),
            ("prefill_sec", Json::num(self.prefill_sec)),
            ("prefill_tokens", Json::num(self.prefill_tokens as f64)),
            ("decode_l2_hit_pct", Json::num(self.decode_l2_hit_pct)),
            ("advisor_consults", Json::num(self.advisor_consults as f64)),
            ("distinct_geometries", Json::num(self.distinct_geometries as f64)),
            ("kv_shared_tokens", Json::num(self.kv_shared_tokens as f64)),
            ("kv_xcd_affinity_pct", Json::num(self.kv_xcd_affinity_pct)),
            ("truncated", Json::Bool(self.truncated)),
        ])
    }
}

/// One serving-report row: a scenario label plus the per-policy stats
/// (in [`crate::mapping::ALL_POLICIES`] order, filtered to the policies
/// applicable to the scenario's geometry).
#[derive(Debug, Clone)]
pub struct ServeRow {
    /// Scenario label (arrival rate, batch cap, mix).
    pub label: String,
    /// One [`ServeStats`] per applicable policy.
    pub stats: Vec<ServeStats>,
}

/// The full serving report the `serve` CLI subcommand emits: one row per
/// sweep scenario, each comparing every applicable mapping policy.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Scenario rows in sweep order.
    pub rows: Vec<ServeRow>,
}

impl ServeReport {
    /// Aligned-table rendering (one table per scenario).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for row in &self.rows {
            let mut t = Table::new(&[
                "policy",
                "tokens/s",
                "TPOT p50 (ms)",
                "TPOT p99 (ms)",
                "TTFT p50 (ms)",
                "TTFT p99 (ms)",
                "dec L2 %",
                "kv aff %",
                "sessions",
                "tokens",
                "steps",
                "re-advised",
                "geoms",
            ]);
            for s in &row.stats {
                t.row(vec![
                    s.policy.label().into(),
                    format!("{:.0}", s.tokens_per_sec),
                    fmt_ms(s.tpot_p50_ms),
                    fmt_ms(s.tpot_p99_ms),
                    fmt_ms(s.ttft_p50_ms),
                    fmt_ms(s.ttft_p99_ms),
                    format!("{:.1}", s.decode_l2_hit_pct),
                    format!("{:.1}", s.kv_xcd_affinity_pct),
                    format!("{}{}", s.sessions_completed, if s.truncated { "*" } else { "" }),
                    s.tokens.to_string(),
                    s.steps.to_string(),
                    s.advisor_consults.to_string(),
                    s.distinct_geometries.to_string(),
                ]);
            }
            out.push_str(&format!("== serve — {} ==\n{}", row.label, t.render()));
        }
        if self.rows.iter().any(|r| r.stats.iter().any(|s| s.truncated)) {
            out.push_str("(* = step budget exhausted before the trace drained)\n");
        }
        out
    }

    /// JSON rendering for `serve --json` (stable row/policy order).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "rows",
            Json::arr(self.rows.iter().map(|r| {
                Json::obj(vec![
                    ("label", Json::str(r.label.clone())),
                    ("policies", Json::arr(r.stats.iter().map(ServeStats::to_json))),
                ])
            })),
        )])
    }

    /// Stats for (row label, policy), for assertions in tests/benches.
    pub fn stats(&self, label: &str, policy: Policy) -> Option<&ServeStats> {
        self.rows
            .iter()
            .find(|r| r.label == label)?
            .stats
            .iter()
            .find(|s| s.policy == policy)
    }
}

/// One serving sweep scenario: a label plus the loop configuration.
#[derive(Debug, Clone)]
pub struct ServeScenario {
    /// Row label in the serving report / `serve` figure.
    pub label: String,
    /// The loop configuration the row runs (once per policy).
    pub cfg: ServeConfig,
}

/// The serving sweep: Llama-3 70B (GQA-8) scenarios varying arrival rate,
/// continuous-batch cap, context mix, and prefill scheduling. `quick`
/// runs the three-scenario CI subset (including one chunked-prefill
/// row, so CI smokes the mixed-step composition); the full sweep adds a
/// wide-batch row and a monolithic/chunked long-context pair.
pub fn serve_scenarios(quick: bool) -> Vec<ServeScenario> {
    let base = ServeConfig::default();
    let mut out = vec![
        ServeScenario {
            label: "llama3-70b arr=60/s cap=4".into(),
            cfg: ServeConfig {
                arrival_per_sec: 60.0,
                max_active: 4,
                sessions: 10,
                ..base.clone()
            },
        },
        ServeScenario {
            label: "llama3-70b arr=120/s cap=8".into(),
            cfg: ServeConfig { arrival_per_sec: 120.0, max_active: 8, ..base.clone() },
        },
        ServeScenario {
            label: "llama3-70b chunked(1k/2k) arr=120/s cap=8".into(),
            cfg: ServeConfig {
                arrival_per_sec: 120.0,
                max_active: 8,
                chunk_tokens: 1024,
                step_token_budget: 2048,
                ..base.clone()
            },
        },
        // The prefix-sharing regime (docs/KVCACHE.md): 80% of sessions
        // open with the canonical shared prefix, so their leading blocks
        // are resident at admission and skip prefill entirely.
        ServeScenario {
            label: "llama3-70b 80%-shared arr=120/s cap=8".into(),
            cfg: ServeConfig {
                arrival_per_sec: 120.0,
                max_active: 8,
                kv_block_tokens: 256,
                prefix_share_pct: 80.0,
                kv_capacity_mb: 1024,
                ..base.clone()
            },
        },
    ];
    if !quick {
        out.push(ServeScenario {
            label: "llama3-70b arr=120/s cap=16".into(),
            cfg: ServeConfig {
                arrival_per_sec: 120.0,
                max_active: 16,
                sessions: 32,
                max_steps: 2400,
                ..base.clone()
            },
        });
        let long_ctx = ServeConfig {
            arrival_per_sec: 60.0,
            max_active: 8,
            sessions: 12,
            prefill_lengths: vec![16 * 1024, 64 * 1024],
            decode_tokens: vec![64, 256],
            max_steps: 2400,
            ..base
        };
        out.push(ServeScenario {
            label: "llama3-70b long-ctx arr=60/s cap=8".into(),
            cfg: long_ctx.clone(),
        });
        // The headline chunked regime: 64k prompts streamed in 2k
        // row-block chunks instead of freezing every decode stream.
        out.push(ServeScenario {
            label: "llama3-70b chunked(2k/4k) long-ctx arr=60/s cap=8".into(),
            cfg: ServeConfig {
                chunk_tokens: 2048,
                step_token_budget: 4096,
                max_steps: 4800,
                ..long_ctx
            },
        });
    }
    out
}

/// Run the continuous-batching decode serving loop for one policy,
/// through the process-wide shared driver ([`driver::global`]): repeated
/// geometries — within the run and across policy runs — are priced from
/// the memoized report cache, zero new engine runs.
pub fn serve_decode(topo: &Topology, cfg: &ServeConfig, policy: Policy) -> ServeStats {
    serve_decode_with(driver::global(), topo, cfg, policy)
}

/// [`serve_decode`] through an explicit driver (tests, CLI `--threads`).
///
/// The loop (docs/SERVING.md has the worked walk-through):
/// 1. admit arrived sessions up to the batch cap, charging each one's
///    prefill (a sampled forward-kernel report at its prompt length) —
///    or, with `chunk_tokens > 0`, composing a mixed step: decode tokens
///    claim the `step_token_budget` first and the remainder streams
///    prompt chunks (docs/SERVING.md §6);
/// 2. group the decode-phase sessions by bucketed KV length — each
///    group is one split-KV decode launch whose split count comes from
///    the advisor, re-consulted whenever the (batch, KV bucket) geometry
///    is new;
/// 3. advance simulated time by the step's summed `est_total_sec` and
///    emit one token per decode-phase session (each gets the step
///    duration as its TPOT sample; first tokens sample TTFT);
/// 4. retire finished sessions and loop until the trace drains or the
///    step budget runs out.
pub fn serve_decode_with(
    driver: &SimDriver,
    topo: &Topology,
    cfg: &ServeConfig,
    policy: Policy,
) -> ServeStats {
    cfg.validate().expect("valid serve config");
    assert!(
        advisor::applicable_policies(topo, &cfg.base_geometry()).contains(&policy),
        "policy {policy} is not applicable to h_q={} on {} XCDs",
        cfg.h_q,
        topo.num_xcds
    );
    let mut exec = SingleDeviceExecutor::new(driver, topo, cfg, policy);
    run_serve_loop(&mut exec, cfg)
}

/// [`serve_decode`] across a cluster: the same continuous-batching loop,
/// with every kernel launch fanned out over the shard plan's devices by a
/// [`ClusterExecutor`] — each device runs the shard-local geometry, the
/// step advances by the slowest device, and the interconnect all-gather
/// of the sharded outputs is charged on top (docs/CLUSTER.md). Uses the
/// process-wide shared driver like [`serve_decode`].
pub fn serve_decode_cluster(
    cluster: &ClusterTopology,
    plan: &ShardPlan,
    cfg: &ServeConfig,
    policy: Policy,
) -> ServeStats {
    serve_decode_cluster_with(driver::global(), cluster, plan, cfg, policy)
}

/// [`serve_decode_cluster`] through an explicit driver. At `tp = 1` the
/// output is byte-identical to [`serve_decode_with`] on the same device
/// (pinned by `tests/cluster_serving.rs`): a one-device cluster launches
/// the identical jobs and its all-gather charge is exactly zero.
pub fn serve_decode_cluster_with(
    driver: &SimDriver,
    cluster: &ClusterTopology,
    plan: &ShardPlan,
    cfg: &ServeConfig,
    policy: Policy,
) -> ServeStats {
    cfg.validate().expect("valid serve config");
    let local = plan.local_attn(&cfg.base_geometry());
    // Every device runs the shard-local geometry, so the policy must be
    // applicable on each one — a heterogeneous cluster with one
    // incompatible device is rejected here, not silently mispriced.
    for (i, device) in cluster.devices.iter().enumerate() {
        assert!(
            advisor::applicable_policies(device, &local).contains(&policy),
            "policy {policy} is not applicable to the shard-local h_q={} on device {i}'s {} XCDs",
            local.h_q,
            device.num_xcds
        );
    }
    let mut exec = ClusterExecutor::new(driver, cluster, plan, cfg, policy);
    run_serve_loop(&mut exec, cfg)
}

/// The executor-generic continuous-batching loop body shared by the
/// single-device and cluster serving paths: admission, step composition,
/// KV-bucket grouping, time advance, and retirement are identical in
/// both — only launch *pricing* differs, behind [`StepExecutor`].
/// Charges are accumulated one launch at a time in launch order, so an
/// executor cannot perturb the floating-point summation the determinism
/// tests pin. The stats are stamped with the executor's own policy, so a
/// run can never be labeled with a policy it didn't price.
///
/// With `chunk_tokens = 0` the step composition is the historical one:
/// each admission's whole prompt is charged before that step's decode
/// launches. With `chunk_tokens > 0` each step is a *mixed* step
/// (docs/SERVING.md §6): the decode-phase sessions' tokens claim the
/// `step_token_budget` first and the remainder streams prefill chunks,
/// so one long prompt never stalls the world.
fn run_serve_loop(exec: &mut dyn StepExecutor, cfg: &ServeConfig) -> ServeStats {
    let mut source = cfg.session_source();
    let sessions = source.take_sessions(cfg.session_budget());
    let mut batcher = StepBatcher::new(sessions, cfg.max_active, cfg.chunk_tokens);
    let mut pool = cfg.kv_pool();

    let mut now_sec = 0.0f64;
    let mut prefill_sec = 0.0f64;
    let mut prefill_tokens = 0u64;
    let mut kv_shared_tokens = 0u64;
    let mut kv_affine_blocks = 0u64;
    let mut kv_total_blocks = 0u64;
    let mut tokens = 0u64;
    let mut steps = 0usize;
    let mut tpot_ms: Vec<f64> = Vec::new();
    let mut ttft_ms: Vec<f64> = Vec::new();

    while steps < cfg.max_steps && !batcher.done() {
        if batcher.active().is_empty() {
            // Idle: jump simulated time forward to the next arrival.
            match batcher.next_arrival_sec() {
                Some(t) => now_sec = now_sec.max(t),
                None => break,
            }
        }
        let newly = batcher.admit(now_sec);
        // Paged-pool admission (docs/KVCACHE.md): each admission leases
        // its prompt's block chain. Blocks already resident (a shared
        // prefix another session inserted) are credited — those prompt
        // tokens never reach a prefill kernel. Freshly inserted blocks
        // score the NUMA placement stat: did the block land in the XCD
        // its heads map to under this run's policy?
        let mut credited: Vec<usize> = Vec::new();
        if let Some(pool) = pool.as_mut() {
            for s in &newly {
                let keys = prompt_keys(s.id, s.prefill, s.shared_prefix, cfg.kv_block_tokens);
                let got = pool.acquire(s.id, &keys);
                for &j in &got.inserted {
                    let (affine, total) = exec.kv_block_affinity(j);
                    kv_affine_blocks += affine as u64;
                    kv_total_blocks += total as u64;
                }
                let t = (got.credited_blocks * cfg.kv_block_tokens).min(s.prefill);
                kv_shared_tokens += t as u64;
                credited.push(t);
            }
        }
        let mut step_sec = 0.0f64;
        if cfg.chunk_tokens == 0 {
            // Monolithic prefill charge for this step's admissions:
            // prompts run as sampled forward kernels before decode
            // resumes, so co-scheduled admissions stretch every active
            // session's TPOT — the continuous-batching prefill tax.
            if pool.is_some() {
                // Pool path: price only each prompt's non-credited
                // suffix, as one (credited, prefill] chunk. A chunk
                // starting at 0 prices bit-identically to the monolithic
                // charge (pinned by the executor tests), so a fully
                // private prompt costs exactly what it always did; a
                // fully resident prompt skips prefill entirely.
                let chunks: Vec<PrefillChunk> = newly
                    .iter()
                    .zip(&credited)
                    .filter(|(s, &c)| c < s.prefill)
                    .map(|(s, &c)| PrefillChunk { id: s.id, start: c, end: s.prefill })
                    .collect();
                if !chunks.is_empty() {
                    prefill_tokens += chunks.iter().map(|c| c.tokens() as u64).sum::<u64>();
                    for t in exec.chunk_charges(&chunks) {
                        prefill_sec += t;
                        step_sec += t;
                    }
                }
            } else if !newly.is_empty() {
                let prompts: Vec<usize> = newly.iter().map(|s| s.prefill).collect();
                prefill_tokens += prompts.iter().map(|&p| p as u64).sum::<u64>();
                for t in exec.prefill_charges(&prompts) {
                    prefill_sec += t;
                    step_sec += t;
                }
            }
        } else {
            // Pool path: credit resident prefixes before planning, so
            // chunk streaming starts at each prompt's non-shared suffix.
            for (s, &c) in newly.iter().zip(&credited) {
                if c > 0 {
                    batcher.credit_prefix(s.id, c);
                }
            }
            // Mixed-step composition: decode tokens first, the budget's
            // remainder streams prompt chunks in admission order.
            let budget = if cfg.step_token_budget == 0 {
                usize::MAX
            } else {
                cfg.step_token_budget
            };
            let decoding = batcher.decoding();
            let chunks = batcher.plan_chunks(budget.saturating_sub(decoding));
            if !chunks.is_empty() {
                prefill_tokens += chunks.iter().map(|c| c.tokens() as u64).sum::<u64>();
                for t in exec.chunk_charges(&chunks) {
                    prefill_sec += t;
                    step_sec += t;
                }
            }
        }
        // Iteration-level batch: group the decode-phase sessions by
        // bucketed KV length; each group is one two-phase split-KV
        // decode launch. A session whose prefill completed this very
        // step decodes its first token in the same step — exactly the
        // monolithic path's admission semantics.
        let mut grouped: BTreeMap<usize, usize> = BTreeMap::new();
        for a in batcher.active().iter().filter(|a| a.prefill_complete()) {
            *grouped.entry(cfg.bucket_of(a.kv_len(cfg.kv_cap))).or_insert(0) += 1;
        }
        let groups: Vec<(usize, usize)> = grouped.into_iter().collect();
        for t in exec.decode_charges(&groups) {
            step_sec += t;
        }
        now_sec += step_sec;
        // TTFT: sessions emitting their first decode token this step
        // sample arrival → the step's end.
        for a in batcher.active() {
            if a.prefill_complete() && a.generated == 0 {
                ttft_ms.push((now_sec - a.session.arrival_sec) * 1e3);
            }
        }
        let emitted = batcher.advance_step();
        // Retired sessions drop their block leases; refcount-0 blocks
        // stay resident (warm for the next sharer) until evicted by
        // capacity pressure.
        for id in batcher.drain_retired() {
            if let Some(pool) = pool.as_mut() {
                pool.release(id);
            }
        }
        tokens += emitted as u64;
        tpot_ms.extend(std::iter::repeat(step_sec * 1e3).take(emitted));
        steps += 1;
    }

    let (l2_hits, l2_misses) = exec.decode_l2();
    ServeStats {
        policy: exec.policy(),
        sessions_completed: batcher.completed(),
        tokens,
        steps,
        sim_sec: now_sec,
        tokens_per_sec: if now_sec > 0.0 { tokens as f64 / now_sec } else { 0.0 },
        tpot_p50_ms: pctl_or_nan(&tpot_ms, 0.50),
        tpot_p99_ms: pctl_or_nan(&tpot_ms, 0.99),
        ttft_p50_ms: pctl_or_nan(&ttft_ms, 0.50),
        ttft_p99_ms: pctl_or_nan(&ttft_ms, 0.99),
        prefill_sec,
        prefill_tokens,
        decode_l2_hit_pct: if l2_hits + l2_misses > 0 {
            100.0 * l2_hits as f64 / (l2_hits + l2_misses) as f64
        } else {
            0.0
        },
        advisor_consults: exec.consults(),
        distinct_geometries: exec.distinct_geometries(),
        kv_shared_tokens,
        kv_xcd_affinity_pct: if kv_total_blocks > 0 {
            100.0 * kv_affine_blocks as f64 / kv_total_blocks as f64
        } else {
            0.0
        },
        truncated: !batcher.done(),
    }
}

/// Build one serving-report row: the scenario served under every policy
/// applicable to its geometry. The ONE place row assembly lives
/// (mirroring [`cluster_row`]) — the sweep ([`serve_report`]) and the
/// CLI's `--config` / chunking-override paths all call it, so they
/// cannot diverge.
pub fn serve_row(
    driver: &SimDriver,
    topo: &Topology,
    cfg: &ServeConfig,
    label: String,
) -> ServeRow {
    let stats = advisor::applicable_policies(topo, &cfg.base_geometry())
        .into_iter()
        .map(|p| serve_decode_with(driver, topo, cfg, p))
        .collect();
    ServeRow { label, stats }
}

/// The full serving report: every sweep scenario run under every
/// applicable mapping policy, through one driver — the report cache is
/// shared across policies, scenarios, and the advisor's projections, so
/// the hundreds of related geometries the sweep touches each simulate
/// exactly once per policy.
pub fn serve_report(driver: &SimDriver, topo: &Topology, quick: bool) -> ServeReport {
    let rows = serve_scenarios(quick)
        .into_iter()
        .map(|sc| serve_row(driver, topo, &sc.cfg, sc.label))
        .collect();
    ServeReport { rows }
}

// ---------------------------------------------------------------------
// Cluster serving: the tensor-parallel sweep (docs/CLUSTER.md)
// ---------------------------------------------------------------------

/// One cluster-sweep scenario: a serving configuration at one TP degree.
#[derive(Debug, Clone)]
pub struct ClusterScenario {
    /// Row label including the TP degree.
    pub label: String,
    /// Scenario label without the TP suffix (ties TP rows of one
    /// scenario together for scaling-efficiency reporting).
    pub base: String,
    /// The loop configuration the row runs (once per policy).
    pub cfg: ServeConfig,
    /// Tensor-parallel degree (devices in the cluster).
    pub tp: usize,
}

/// The cluster serving sweep: Llama-3 70B (GQA-8) scenarios crossed with
/// the TP axis ([`CLUSTER_TP`]). `quick` runs one scenario at the axis
/// endpoints (`tp ∈ {1, 8}` — enough for the TP-8 vs TP-1 scaling
/// check); the full sweep runs every degree and adds a long-context
/// scenario. Prompts skew long so the TP win (each device prefills
/// `H_Q/tp` heads) dominates the per-step all-gather tax.
pub fn cluster_scenarios(quick: bool) -> Vec<ClusterScenario> {
    let base = ServeConfig {
        prefill_lengths: vec![8192, 32768],
        decode_tokens: vec![32, 128],
        arrival_per_sec: 80.0,
        sessions: 10,
        max_active: 8,
        max_steps: 1600,
        ..ServeConfig::default()
    };
    // Quick mode runs the axis ENDPOINTS by construction, so extending
    // CLUSTER_TP automatically moves the quick sweep (and the TP-max vs
    // TP-min scaling checks built on it) to the new extremes.
    let endpoints = [CLUSTER_TP[0], *CLUSTER_TP.last().unwrap()];
    let tps: &[usize] = if quick { &endpoints } else { &CLUSTER_TP };
    let mut scenarios = vec![("llama3-70b arr=80/s cap=8".to_string(), base.clone())];
    if !quick {
        scenarios.push((
            "llama3-70b long-ctx arr=40/s cap=8".into(),
            ServeConfig {
                arrival_per_sec: 40.0,
                prefill_lengths: vec![16 * 1024, 64 * 1024],
                decode_tokens: vec![64, 256],
                max_steps: 3200,
                ..base
            },
        ));
    }
    let mut out = Vec::new();
    for (label, cfg) in scenarios {
        for &tp in tps {
            out.push(ClusterScenario {
                label: format!("{label} tp={tp}"),
                base: label.clone(),
                cfg: cfg.clone(),
                tp,
            });
        }
    }
    out
}

/// One cluster-report row: a (scenario, TP degree) pair with per-policy
/// serving stats.
#[derive(Debug, Clone)]
pub struct ClusterRow {
    /// Row label (scenario + TP degree).
    pub label: String,
    /// Scenario label without the TP suffix.
    pub base: String,
    /// Tensor-parallel degree of this row.
    pub tp: usize,
    /// One [`ServeStats`] per applicable policy.
    pub stats: Vec<ServeStats>,
}

/// The cluster serving report the `cluster` CLI subcommand emits: every
/// sweep scenario at every TP degree, each comparing the applicable
/// mapping policies, with scaling efficiency against the scenario's
/// `tp = 1` row.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    /// Rows in sweep order (scenario-major, TP ascending).
    pub rows: Vec<ClusterRow>,
}

impl ClusterReport {
    /// Stats for (row label, policy), for assertions in tests/benches.
    pub fn stats(&self, label: &str, policy: Policy) -> Option<&ServeStats> {
        self.rows
            .iter()
            .find(|r| r.label == label)?
            .stats
            .iter()
            .find(|s| s.policy == policy)
    }

    /// Scaling efficiency of a row's policy against the same scenario's
    /// `tp = 1` row: `tokens_per_sec / (tp × tokens_per_sec(tp=1))`.
    /// 1.0 = ideal linear scaling; `None` when the `tp = 1` row is
    /// missing or degenerate.
    pub fn efficiency(&self, row: &ClusterRow, policy: Policy) -> Option<f64> {
        let this = row.stats.iter().find(|s| s.policy == policy)?;
        let base = self
            .rows
            .iter()
            .find(|r| r.base == row.base && r.tp == 1)?
            .stats
            .iter()
            .find(|s| s.policy == policy)?;
        if base.tokens_per_sec <= 0.0 {
            return None;
        }
        Some(this.tokens_per_sec / (row.tp as f64 * base.tokens_per_sec))
    }

    /// Aligned-table rendering (one table per (scenario, TP) row).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for row in &self.rows {
            let mut t = Table::new(&[
                "policy",
                "tokens/s",
                "scale eff",
                "dec L2 %",
                "kv aff %",
                "TPOT p50 (ms)",
                "TTFT p99 (ms)",
                "sessions",
                "re-advised",
            ]);
            for s in &row.stats {
                let eff = self
                    .efficiency(row, s.policy)
                    .map(|e| format!("{e:.2}"))
                    .unwrap_or_else(|| "-".into());
                t.row(vec![
                    s.policy.label().into(),
                    format!("{:.0}", s.tokens_per_sec),
                    eff,
                    format!("{:.1}", s.decode_l2_hit_pct),
                    format!("{:.1}", s.kv_xcd_affinity_pct),
                    fmt_ms(s.tpot_p50_ms),
                    fmt_ms(s.ttft_p99_ms),
                    format!("{}{}", s.sessions_completed, if s.truncated { "*" } else { "" }),
                    s.advisor_consults.to_string(),
                ]);
            }
            out.push_str(&format!("== cluster — {} ==\n{}", row.label, t.render()));
        }
        if self.rows.iter().any(|r| r.stats.iter().any(|s| s.truncated)) {
            out.push_str("(* = step budget exhausted before the trace drained)\n");
        }
        out
    }

    /// JSON rendering for `cluster --json` (stable row/policy order).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "rows",
            Json::arr(self.rows.iter().map(|r| {
                Json::obj(vec![
                    ("label", Json::str(r.label.clone())),
                    ("tp", Json::num(r.tp as f64)),
                    (
                        "policies",
                        Json::arr(r.stats.iter().map(|s| {
                            let mut obj = match s.to_json() {
                                Json::Obj(pairs) => pairs,
                                _ => unreachable!("ServeStats::to_json returns an object"),
                            };
                            if let Some(e) = self.efficiency(r, s.policy) {
                                obj.push(("scaling_efficiency".into(), Json::num(e)));
                            }
                            Json::Obj(obj)
                        })),
                    ),
                ])
            })),
        )])
    }
}

/// Build one cluster-report row: the scenario served under every policy
/// applicable to the shard-local geometry. The ONE place row assembly
/// lives — the sweep ([`serve_cluster_report`]) and the CLI's
/// `cluster --config` path both call it, so they cannot diverge.
pub fn cluster_row(
    driver: &SimDriver,
    cluster: &ClusterTopology,
    plan: &ShardPlan,
    cfg: &ServeConfig,
    label: String,
    base: String,
) -> ClusterRow {
    let local = plan.local_attn(&cfg.base_geometry());
    let stats = advisor::applicable_policies(cluster.device(0), &local)
        .into_iter()
        .map(|p| serve_decode_cluster_with(driver, cluster, plan, cfg, p))
        .collect();
    ClusterRow { label, base, tp: plan.tp, stats }
}

/// The full cluster serving report: every sweep scenario at every TP
/// degree under every applicable policy, all priced through one driver —
/// identical shards of a homogeneous cluster collapse to single cache
/// entries, and the `tp = 1` rows share reports with the plain `serve`
/// sweep where geometries coincide.
pub fn serve_cluster_report(driver: &SimDriver, device: &Topology, quick: bool) -> ClusterReport {
    let rows = cluster_scenarios(quick)
        .into_iter()
        .map(|sc| {
            let cluster = ClusterTopology::node_of(device, sc.tp);
            let plan = ShardPlan::new(&sc.cfg.base_geometry(), sc.tp, ShardStrategy::Contiguous)
                .expect("sweep TP degrees divide the scenario's KV heads");
            cluster_row(driver, &cluster, &plan, &sc.cfg, sc.label, sc.base)
        })
        .collect();
    ClusterReport { rows }
}

#[cfg(test)]
mod serve_tests {
    use super::*;
    use crate::topology::presets;

    fn fast_topo() -> Topology {
        Topology {
            cus_per_xcd: 8,
            l2_bytes_per_xcd: 1024 * 1024,
            hbm_bytes_per_sec: 1.1e12,
            ..presets::mi300x()
        }
    }

    fn tiny_serve() -> ServeConfig {
        ServeConfig {
            h_q: 16,
            h_k: 8,
            d_head: 64,
            kv_cap: 8192,
            kv_bucket: 2048,
            arrival_per_sec: 2000.0,
            prefill_lengths: vec![1024, 2048],
            decode_tokens: vec![4, 12],
            sessions: 6,
            max_active: 3,
            max_steps: 200,
            seed: 9,
            ..ServeConfig::default()
        }
    }

    #[test]
    fn bucket_of_rounds_up_but_never_past_capacity() {
        let cfg = ServeConfig { kv_cap: 10000, kv_bucket: 4096, ..tiny_serve() };
        assert_eq!(cfg.bucket_of(1), 4096);
        assert_eq!(cfg.bucket_of(4096), 4096);
        assert_eq!(cfg.bucket_of(4097), 8192);
        // The top bucket is the capacity itself, not a rounding past it.
        assert_eq!(cfg.bucket_of(9000), 10000);
        assert_eq!(cfg.bucket_of(10000), 10000);
        // A quantum wider than the capacity still prices at capacity.
        let wide = ServeConfig { kv_cap: 2048, kv_bucket: 4096, ..tiny_serve() };
        assert_eq!(wide.bucket_of(100), 2048);
    }

    #[test]
    fn serve_smoke_completes_the_trace() {
        let driver = SimDriver::new(2);
        let topo = fast_topo();
        let cfg = tiny_serve();
        let s = serve_decode_with(&driver, &topo, &cfg, Policy::SwizzledHeadFirst);
        assert_eq!(s.sessions_completed, cfg.sessions);
        assert!(!s.truncated);
        // Token count equals the trace's summed decode budgets.
        let trace = SessionGenerator::new(
            cfg.seed,
            cfg.arrival_per_sec,
            cfg.prefill_lengths.clone(),
            cfg.decode_tokens.clone(),
        )
        .take(cfg.sessions);
        let want: u64 = trace.iter().map(|t| t.decode_tokens as u64).sum();
        assert_eq!(s.tokens, want);
        assert!(s.tokens_per_sec > 0.0);
        assert!(s.sim_sec > 0.0);
        assert!(s.prefill_sec > 0.0 && s.prefill_sec < s.sim_sec);
        assert!(s.tpot_p50_ms > 0.0 && s.tpot_p50_ms <= s.tpot_p99_ms);
        // Every distinct geometry consulted the advisor exactly once.
        assert!(s.advisor_consults >= 1);
        assert_eq!(s.advisor_consults, s.distinct_geometries);
        // At least max_active steps ran (the trace has more tokens than
        // any single batch can emit in one step).
        assert!(s.steps >= (want as usize) / cfg.max_active);
    }

    #[test]
    fn repeat_serve_run_is_engine_free() {
        // The whole point of pricing steps through the shared driver: a
        // second identical run re-plays every geometry from the report
        // cache — zero new engine runs — and reproduces the stats
        // byte-for-byte.
        let driver = SimDriver::new(2);
        let topo = fast_topo();
        let cfg = tiny_serve();
        let first = serve_decode_with(&driver, &topo, &cfg, Policy::NaiveHeadFirst);
        let misses = driver.cache().misses();
        let second = serve_decode_with(&driver, &topo, &cfg, Policy::NaiveHeadFirst);
        assert_eq!(driver.cache().misses(), misses, "zero new engine runs");
        assert_eq!(first.to_json().render(), second.to_json().render());
    }

    #[test]
    fn chunked_serve_conserves_tokens_and_improves_tails() {
        // The chunked smoke: identical trace, every prompt token
        // prefilled exactly once, and the mixed-step composition cuts
        // both the prefill wall-clock (row-block chunks price the
        // rectangle rows × prefix instead of the full square) and the
        // first-token tail.
        let driver = SimDriver::new(2);
        let topo = fast_topo();
        let mono_cfg = tiny_serve();
        let chunked_cfg =
            ServeConfig { chunk_tokens: 512, step_token_budget: 1024, ..tiny_serve() };
        chunked_cfg.validate().unwrap();
        let mono = serve_decode_with(&driver, &topo, &mono_cfg, Policy::SwizzledHeadFirst);
        let chunked = serve_decode_with(&driver, &topo, &chunked_cfg, Policy::SwizzledHeadFirst);
        assert!(!chunked.truncated && !mono.truncated);
        assert_eq!(chunked.tokens, mono.tokens, "same trace, same decode tokens");
        assert_eq!(chunked.sessions_completed, chunked_cfg.sessions);
        assert_eq!(
            chunked.prefill_tokens, mono.prefill_tokens,
            "chunking must conserve prompt tokens"
        );
        assert!(
            chunked.prefill_sec < mono.prefill_sec,
            "multi-chunk prompts must undercut monolithic prefill ({} >= {})",
            chunked.prefill_sec,
            mono.prefill_sec
        );
        assert!(
            chunked.ttft_p99_ms <= mono.ttft_p99_ms,
            "chunked TTFT p99 {} > monolithic {}",
            chunked.ttft_p99_ms,
            mono.ttft_p99_ms
        );
        assert!(chunked.ttft_p50_ms > 0.0 && chunked.ttft_p50_ms <= chunked.ttft_p99_ms);
        assert!(mono.ttft_p50_ms > 0.0 && mono.ttft_p50_ms <= mono.ttft_p99_ms);
    }

    #[test]
    fn serve_config_rejects_contradictory_chunking() {
        let budget_without_chunks =
            ServeConfig { step_token_budget: 2048, ..tiny_serve() };
        let err = budget_without_chunks.validate().unwrap_err();
        assert!(err.contains("chunk_tokens"), "{err}");
        let chunk_over_budget =
            ServeConfig { chunk_tokens: 4096, step_token_budget: 1024, ..tiny_serve() };
        let err = chunk_over_budget.validate().unwrap_err();
        assert!(err.contains("must not exceed"), "{err}");
        // A capped budget must cover max_active decode tokens (decode is
        // never dropped, so a smaller budget could never be honored).
        let starved = ServeConfig {
            chunk_tokens: 2,
            step_token_budget: 2,
            max_active: 8,
            ..tiny_serve()
        };
        let err = starved.validate().unwrap_err();
        assert!(err.contains("below max_active"), "{err}");
        // A prompt the KV cache cannot hold is rejected up front (it
        // would otherwise stream hundreds of beyond-capacity chunks).
        let over = ServeConfig { kv_cap: 1024, prefill_lengths: vec![512, 2048], ..tiny_serve() };
        let err = over.validate().unwrap_err();
        assert!(err.contains("exceeds the KV capacity"), "{err}");
        // Uncapped budget with chunking on is fine.
        ServeConfig { chunk_tokens: 512, ..tiny_serve() }.validate().unwrap();
    }

    #[test]
    fn chunk_span_clamps_to_capacity() {
        let cfg = ServeConfig { kv_cap: 4096, ..tiny_serve() };
        let span = |start, end| cfg.chunk_span(&PrefillChunk { id: 0, start, end });
        assert_eq!(span(0, 512), (0, 512));
        assert_eq!(span(3584, 4096), (3584, 4096));
        // Chunks straddling the capacity clamp their end...
        assert_eq!(span(3584, 5000), (3584, 4096));
        // ...and chunks entirely beyond it collapse to an empty span.
        assert_eq!(span(4096, 5000), (4096, 4096));
        assert_eq!(span(8000, 9000), (4096, 4096));
    }

    #[test]
    fn serve_kv_growth_crosses_buckets_and_readvises() {
        // Sessions start below one bucket boundary and decode across it,
        // so the loop must see (and advise) geometries in at least two
        // KV buckets.
        let driver = SimDriver::new(2);
        let topo = fast_topo();
        let cfg = ServeConfig {
            prefill_lengths: vec![2040], // 8 tokens below the 2048 boundary
            decode_tokens: vec![24],     // decodes well past it
            sessions: 3,
            max_active: 3,
            ..tiny_serve()
        };
        let s = serve_decode_with(&driver, &topo, &cfg, Policy::SwizzledHeadFirst);
        assert!(!s.truncated);
        assert!(
            s.distinct_geometries >= 2,
            "KV growth must cross a bucket boundary (saw {} geometries)",
            s.distinct_geometries
        );
    }

    #[test]
    fn shared_span_rounds_down_to_whole_blocks() {
        let cfg = ServeConfig {
            prefill_lengths: vec![1024, 2048],
            kv_block_tokens: 300,
            ..tiny_serve()
        };
        assert_eq!(cfg.shared_span(), 900, "3 whole 300-token blocks fit in 1024");
        let exact = ServeConfig { kv_block_tokens: 256, ..cfg.clone() };
        assert_eq!(exact.shared_span(), 1024);
        let off = ServeConfig { kv_block_tokens: 0, ..cfg };
        assert_eq!(off.shared_span(), 1024, "no block quantum, raw minimum");
    }

    #[test]
    fn sharing_disabled_knobs_are_byte_inert() {
        // Either gate at zero must take the exact pre-pool code path:
        // a block size without a share rate (and vice versa) reproduces
        // the baseline stats byte-for-byte. This is the unit-level form
        // of the golden equivalence pins in tests/serving_loop.rs.
        let driver = SimDriver::new(2);
        let topo = fast_topo();
        let base = serve_decode_with(&driver, &topo, &tiny_serve(), Policy::SwizzledHeadFirst);
        let blocks_only = ServeConfig { kv_block_tokens: 256, ..tiny_serve() };
        let share_only = ServeConfig { prefix_share_pct: 80.0, ..tiny_serve() };
        for cfg in [blocks_only, share_only] {
            assert!(!cfg.kv_pool_enabled());
            let s = serve_decode_with(&driver, &topo, &cfg, Policy::SwizzledHeadFirst);
            assert_eq!(s.to_json().render(), base.to_json().render());
        }
        assert_eq!(base.kv_shared_tokens, 0);
        assert_eq!(base.kv_xcd_affinity_pct, 0.0);
    }

    #[test]
    fn shared_prefix_serving_credits_tokens_and_cuts_prefill() {
        // 100%-shared twin of the baseline trace: every session opens
        // with the canonical 1024-token prefix, so after the first
        // insertion every admission's leading blocks are resident and
        // skip prefill. The trace itself is identical (separate RNG
        // stream), so decode-side stats are directly comparable.
        let driver = SimDriver::new(2);
        let topo = fast_topo();
        let mono = serve_decode_with(&driver, &topo, &tiny_serve(), Policy::SwizzledHeadFirst);
        let shared_cfg = ServeConfig {
            kv_block_tokens: 256,
            prefix_share_pct: 100.0,
            ..tiny_serve()
        };
        let shared = serve_decode_with(&driver, &topo, &shared_cfg, Policy::SwizzledHeadFirst);
        assert!(!shared.truncated && !mono.truncated);
        assert_eq!(shared.tokens, mono.tokens, "same trace, same decode tokens");
        assert!(shared.kv_shared_tokens > 0, "resident prefixes must credit tokens");
        assert_eq!(
            shared.prefill_tokens + shared.kv_shared_tokens,
            mono.prefill_tokens,
            "every prompt token is either prefilled or credited, never both"
        );
        assert!(
            shared.prefill_sec < mono.prefill_sec,
            "credited prefixes must cut prefill wall-clock ({} >= {})",
            shared.prefill_sec,
            mono.prefill_sec
        );
        assert!(
            shared.ttft_p99_ms <= mono.ttft_p99_ms,
            "shared TTFT p99 {} > baseline {}",
            shared.ttft_p99_ms,
            mono.ttft_p99_ms
        );
        // SHF pins each KV head's group to one XCD, so every inserted
        // block lands affine; NHF round-robins blocks across XCDs.
        assert_eq!(shared.kv_xcd_affinity_pct, 100.0);
        let nhf = serve_decode_with(&driver, &topo, &shared_cfg, Policy::NaiveHeadFirst);
        assert!(
            nhf.kv_xcd_affinity_pct < shared.kv_xcd_affinity_pct,
            "NHF affinity {} must trail SHF {}",
            nhf.kv_xcd_affinity_pct,
            shared.kv_xcd_affinity_pct
        );
    }

    #[test]
    fn chunked_shared_serving_conserves_prompt_tokens() {
        // Pool + chunked prefill: credited prefixes advance the chunk
        // cursor, so streaming starts at each prompt's private suffix
        // and the conservation identity still holds.
        let driver = SimDriver::new(2);
        let topo = fast_topo();
        let chunked = ServeConfig { chunk_tokens: 512, step_token_budget: 1024, ..tiny_serve() };
        let shared_cfg = ServeConfig {
            kv_block_tokens: 256,
            prefix_share_pct: 100.0,
            ..chunked.clone()
        };
        let base = serve_decode_with(&driver, &topo, &chunked, Policy::SwizzledHeadFirst);
        let shared = serve_decode_with(&driver, &topo, &shared_cfg, Policy::SwizzledHeadFirst);
        assert!(!shared.truncated && !base.truncated);
        assert_eq!(shared.tokens, base.tokens);
        assert!(shared.kv_shared_tokens > 0);
        assert_eq!(shared.prefill_tokens + shared.kv_shared_tokens, base.prefill_tokens);
        assert!(shared.prefill_sec < base.prefill_sec);
    }

    #[test]
    fn replayed_generator_trace_is_byte_identical() {
        // The trace-replay golden contract: render the generator's own
        // sessions to the `.trace` text format, parse it back, and serve
        // the replay — the stats must reproduce the generator run
        // byte-for-byte (Display round-trips f64 exactly, and the loop
        // consumes the same rows in the same order).
        let driver = SimDriver::new(2);
        let topo = fast_topo();
        let cfg = tiny_serve();
        let base = serve_decode_with(&driver, &topo, &cfg, Policy::SwizzledHeadFirst);
        let gen_sessions = SessionGenerator::new(
            cfg.seed,
            cfg.arrival_per_sec,
            cfg.prefill_lengths.clone(),
            cfg.decode_tokens.clone(),
        )
        .take(cfg.sessions);
        let replay = TraceReplay::new(gen_sessions);
        let reparsed = TraceReplay::parse(&replay.render()).unwrap();
        assert_eq!(replay, reparsed, "trace text must round-trip the sessions exactly");
        let replay_cfg = ServeConfig { trace: Some(reparsed), ..cfg };
        let replayed = serve_decode_with(&driver, &topo, &replay_cfg, Policy::SwizzledHeadFirst);
        assert_eq!(base.to_json().render(), replayed.to_json().render());
    }

    #[test]
    fn empty_sample_stats_render_na_and_null() {
        // A run where no session ever reaches its first token (exactly
        // what a fully degraded fault window produces) must say "n/a",
        // not a perfect 0.000 ms.
        assert!(pctl_or_nan(&[], 0.99).is_nan());
        assert_eq!(pctl_or_nan(&[2.0, 1.0], 0.50), percentile(&[2.0, 1.0], 0.50));
        assert_eq!(fmt_ms(f64::NAN), "n/a");
        assert_eq!(fmt_ms(1.25), "1.250");
        assert_eq!(ms_json(f64::NAN).render(), "null");
        assert_eq!(ms_json(1.25).render(), Json::num(1.25).render());
        let empty = ServeStats {
            policy: Policy::SwizzledHeadFirst,
            sessions_completed: 0,
            tokens: 0,
            steps: 0,
            sim_sec: 0.0,
            tokens_per_sec: 0.0,
            tpot_p50_ms: f64::NAN,
            tpot_p99_ms: f64::NAN,
            ttft_p50_ms: f64::NAN,
            ttft_p99_ms: f64::NAN,
            prefill_sec: 0.0,
            prefill_tokens: 0,
            decode_l2_hit_pct: 0.0,
            advisor_consults: 0,
            distinct_geometries: 0,
            kv_shared_tokens: 0,
            kv_xcd_affinity_pct: 0.0,
            truncated: true,
        };
        let json = empty.to_json().render();
        assert!(json.contains("\"ttft_p99_ms\": null"), "{json}");
        let report = ServeReport {
            rows: vec![ServeRow { label: "empty".into(), stats: vec![empty] }],
        };
        assert!(report.render().contains("n/a"));
    }

    #[test]
    fn serve_config_rejects_bad_traces() {
        let empty = ServeConfig { trace: Some(TraceReplay::new(Vec::new())), ..tiny_serve() };
        let err = empty.validate().unwrap_err();
        assert!(err.contains("at least one session"), "{err}");
        let long = TraceReplay::parse("0.5 100000 8\n").unwrap();
        let over = ServeConfig { trace: Some(long), ..tiny_serve() };
        let err = over.validate().unwrap_err();
        assert!(err.contains("exceeds the KV capacity"), "{err}");
    }

    #[test]
    fn cluster_scenarios_cover_the_tp_axis() {
        let quick = cluster_scenarios(true);
        assert_eq!(quick.len(), 2, "quick: one scenario at the axis endpoints");
        assert_eq!(quick[0].tp, 1);
        assert_eq!(quick[1].tp, 8);
        assert!(quick[1].label.ends_with("tp=8"), "{}", quick[1].label);
        assert_eq!(quick[0].base, quick[1].base, "same scenario across TP rows");
        let full = cluster_scenarios(false);
        assert_eq!(full.len(), 2 * CLUSTER_TP.len());
        for sc in &full {
            sc.cfg.validate().unwrap();
            assert!(CLUSTER_TP.contains(&sc.tp));
            // Every degree divides the KV heads: the plan always builds.
            ShardPlan::new(&sc.cfg.base_geometry(), sc.tp, ShardStrategy::Contiguous).unwrap();
        }
    }

    #[test]
    fn cluster_report_efficiency_and_render() {
        // A tiny two-TP cluster sweep on the scaled topology: efficiency
        // is 1.0 by definition on the tp=1 row and finite on tp=2.
        let driver = SimDriver::new(2);
        let device = fast_topo();
        let cfg = tiny_serve();
        let mut rows = Vec::new();
        for tp in [1usize, 2] {
            let cluster = ClusterTopology::node_of(&device, tp);
            let plan =
                ShardPlan::new(&cfg.base_geometry(), tp, ShardStrategy::Contiguous).unwrap();
            let stats = vec![serve_decode_cluster_with(
                &driver,
                &cluster,
                &plan,
                &cfg,
                Policy::SwizzledHeadFirst,
            )];
            rows.push(ClusterRow {
                label: format!("tiny tp={tp}"),
                base: "tiny".into(),
                tp,
                stats,
            });
        }
        let report = ClusterReport { rows };
        let tp1 = report.stats("tiny tp=1", Policy::SwizzledHeadFirst).unwrap();
        let tp2 = report.stats("tiny tp=2", Policy::SwizzledHeadFirst).unwrap();
        assert_eq!(tp1.tokens, tp2.tokens, "same trace at every TP degree");
        let e1 = report.efficiency(&report.rows[0], Policy::SwizzledHeadFirst).unwrap();
        assert!((e1 - 1.0).abs() < 1e-12, "tp=1 efficiency is 1.0 by definition, got {e1}");
        let e2 = report.efficiency(&report.rows[1], Policy::SwizzledHeadFirst).unwrap();
        assert!(e2 > 0.0 && e2.is_finite());
        let rendered = report.render();
        assert!(rendered.contains("scale eff"));
        assert!(rendered.contains("tp=2"));
        let json = report.to_json().render();
        assert!(json.contains("\"scaling_efficiency\""));
        assert!(json.contains("\"decode_l2_hit_pct\""));
    }

    #[test]
    fn serve_report_rows_cover_applicable_policies() {
        let driver = SimDriver::new(4);
        let topo = fast_topo();
        // Shrink the sweep's scenarios to the tiny geometry for speed:
        // exercise serve_report's plumbing, not the full llama sweep.
        let rows: Vec<ServeRow> = vec![ServeRow {
            label: "tiny".into(),
            stats: advisor::applicable_policies(&topo, &tiny_serve().base_geometry())
                .into_iter()
                .map(|p| serve_decode_with(&driver, &topo, &tiny_serve(), p))
                .collect(),
        }];
        let report = ServeReport { rows };
        assert_eq!(report.rows[0].stats.len(), 4, "16 heads / 8 XCDs: all four apply");
        let shf = report.stats("tiny", Policy::SwizzledHeadFirst).unwrap();
        let nhf = report.stats("tiny", Policy::NaiveHeadFirst).unwrap();
        assert!(
            shf.tokens_per_sec >= nhf.tokens_per_sec,
            "SHF {} < NHF {}",
            shf.tokens_per_sec,
            nhf.tokens_per_sec
        );
        let rendered = report.render();
        assert!(rendered.contains("tokens/s"));
        let json = report.to_json().render();
        assert!(json.contains("\"tokens_per_sec\""));
    }
}
