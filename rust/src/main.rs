//! `numa-attn` CLI: the leader entrypoint for simulations, figure
//! regeneration, artifact verification, and the serving loop.
//!
//! Subcommands:
//!   simulate  — run the chiplet simulator on one attention configuration
//!   decode    — run the two-phase split-KV decode pass (auto split count)
//!   figure    — regenerate a paper figure (12..16, decode, serve,
//!               serve_ttft, serve_share, cluster, gemm, all)
//!   explain   — print Table-1 style topology specs and mapping layouts
//!   verify    — check AOT artifacts against golden checksums
//!   serve     — run the continuous-batching decode serving loop,
//!               optionally with chunked prefill / mixed steps
//!               (docs/SERVING.md); `--live` runs the PJRT prefill demo
//!   cluster   — run the serving loop tensor-parallel across a cluster of
//!               devices (two-level NUMA; docs/CLUSTER.md)
//!   disagg    — run the serving loop disaggregated across prefill and
//!               decode pools with SLO classes (docs/DISAGG.md)
//!   tune      — search the composed mapping algebra for the best mapping
//!               per workload through the memoized driver (docs/TUNING.md)
//!
//! Run `numa-attn <subcommand> --help` for flags. The USAGE text below is
//! pinned against README.md and the parsed flag set by `usage_tests`.

use std::str::FromStr;
use std::sync::Arc;

use numa_attn::attn::AttnConfig;
use numa_attn::cluster::{ClusterTopology, ShardPlan, ShardStrategy};
use numa_attn::config::{self, ExperimentConfig};
use numa_attn::coordinator::{self, BatcherConfig, ServiceConfig};
use numa_attn::driver::{self, ReportCache, SimDriver, SimJob};
use numa_attn::figures;
use numa_attn::mapping::{Mapping, Policy, ALL_POLICIES};
use numa_attn::metrics::Table;
use numa_attn::sched::xcd_of_slot;
use numa_attn::sim::{self, SimConfig};
use numa_attn::topology::presets;
use numa_attn::util::args::Args;
use numa_attn::util::json::Json;
use numa_attn::workload::{RequestGenerator, TraceReplay};

const USAGE: &str = "\
numa-attn — NUMA-aware attention scheduling on chiplet GPUs

USAGE:
  numa-attn simulate [--config FILE | --topo T --heads H --n-ctx N ...]
  numa-attn decode [--topo T --batch Z --heads H --kv-heads HK --n-ctx N]
                   [--num-splits S] [--policy P] [--json]
  numa-attn figure <12|13|14|15|16|decode|serve|serve_ttft|serve_share|serve_burst|cluster|disagg|gemm|perf|tune|all> [--topo T] [--quick] [--json]
  numa-attn explain [--topo T] [--mapping POLICY|all] [--heads H] [--blocks B]
  numa-attn verify [--artifacts DIR]
  numa-attn serve [--quick] [--config FILE] [--topo T] [--trace FILE] [--json]
  numa-attn serve --live [--artifacts DIR] [--requests N] [--max-batch B]
                  [--max-wait-ms MS] [--seed S]
  numa-attn cluster [--quick] [--config FILE] [--topo T] [--tp N] [--json]
                    [--trace FILE] [--faults SPEC]
  numa-attn disagg [--quick] [--config FILE] [--topo T] [--trace FILE] [--json]
  numa-attn tune [--quick] [--config FILE] [--topo T] [--beam N] [--json]

driver flags (simulate, decode, figure, serve, cluster, disagg, tune):
  all simulations execute through the shared driver (src/driver): a worker
  pool plus a memoizing report cache keyed on (topology, attention, sim
  config). Results are bit-identical at any worker count.
  --threads N          simulation worker threads (default: all cores)
  --no-cache           disable report memoization (every job re-runs)
  cache/thread statistics are printed to stderr after the run

simulate flags:
  --topo NAME          topology preset (mi300x, unified, dual_die, quad_die)
  --policy P           nbf|sbf|nhf|shf or a composed spec such as
                       swz-head-saw-inherit (docs/TUNING.md; default: all four)
  --batch Z --heads H --kv-heads HK --n-ctx N --d-head D
  --causal             causal masking
  --backward           FA2 backward pass (dK/dV + dQ kernels)
  --generations G      steady-state sample size (0 = whole grid)
  --json               machine-readable output

decode flags:
  same geometry flags as simulate; the whole grid runs exactly.
  --num-splits S       KV splits per (batch, head); 0 (default) lets the
                       advisor pick the smallest power of two that fills
                       the device's workgroup slots (chosen value goes to
                       stderr; stdout stays row-stable)

serve flags (the continuous-batching decode loop; docs/SERVING.md):
  --quick              run the three-scenario CI sweep (default: full
                       sweep; both include a chunked-prefill scenario)
  --config FILE        serve ONE scenario from an experiment file's
                       [serve] section instead of the built-in sweep
  --chunk-tokens N     override chunked prefill: stream prompts in
                       N-token chunks, applied to every sweep scenario
                       or the --config scenario (0 = monolithic
                       prefill). Replaces a scenario's chunking policy
                       wholesale: its own step budget is discarded in
                       favor of --step-token-budget (or uncapped)
  --step-token-budget N  override the mixed-step token budget (decode
                       tokens first, prefill chunks with the remainder;
                       0 = uncapped; ignored where chunking is off)
  --kv-block-tokens N  override the paged KV pool block size in prompt
                       tokens (0 = pool off; docs/KVCACHE.md)
  --prefix-share-pct P override the percent of sessions opening with
                       the canonical shared prefix, in [0, 100] (the
                       pool engages only with --kv-block-tokens > 0)
  --kv-capacity-mb N   override the paged-pool HBM budget in MiB
                       (0 = unlimited; refcount-0 blocks evict LRU)
  --trace FILE         replay an explicit .trace arrival schedule instead
                       of the generated session stream (docs/SERVING.md
                       §8; an INI [trace] section can also name the file
                       or generate a bursty/diurnal trace)
  --live               run the live PJRT prefill demo instead (requires
                       artifacts; uses --artifacts/--requests/--max-batch/
                       --max-wait-ms/--seed)

cluster flags (the tensor-parallel serving sweep; docs/CLUSTER.md):
  --quick              one scenario at tp in {1, 8} (default: the full
                       tp in {1, 2, 4, 8} sweep over --topo devices)
  --config FILE        serve ONE scenario from an experiment file's
                       [cluster] + [serve] sections instead of the sweep
  --tp N               restrict the built-in sweep to one TP degree (the
                       tp=1 baseline rows are kept: they anchor the
                       scaling-efficiency column)
  --trace FILE         replay an explicit .trace arrival schedule in every
                       sweep row (or the --config scenario)
  --faults SPEC        inject device outages mid-serve and reprice every
                       rebalance (docs/SERVING.md §9): SPEC is a
                       comma-separated device:fail_sec:recover_sec list.
                       Runs the built-in fault sweep at the widest TP
                       degree; an INI [faults] section (explicit events,
                       or a seeded seed/count/horizon_sec plan) does the
                       same

disagg flags (the disaggregated prefill/decode sweep; docs/DISAGG.md):
  --quick              run the two-scenario CI sweep — colocated x2 vs
                       disagg 1p+1d (default: the full sweep, adding
                       wider pools and a prefix-sharing row)
  --config FILE        serve ONE deployment from an experiment file's
                       [disagg] + [serve] sections instead of the sweep
  --trace FILE         replay an explicit .trace arrival schedule in every
                       sweep row (or the --config deployment); trace rows
                       carry their own interactive/batch SLO classes

tune flags (the composed-mapping autotuner; docs/TUNING.md):
  --quick              search the two-row CI sweep (default: the full
                       decode/causal-forward/backward sweep)
  --config FILE        tune ONE workload from an experiment file's
                       [attention] + [sim] sections; the [tune] section
                       picks the search strategy (search, beam_width)
  --beam N             two-stage beam search keeping N legacy-plane
                       survivors (default 0 = exhaustive over the
                       pruned algebra; overrides the [tune] section)
";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if raw.is_empty() || raw[0] == "--help" || raw[0] == "-h" {
        print!("{USAGE}");
        return Ok(());
    }
    let args =
        Args::parse(&raw, &["causal", "backward", "quick", "json", "help", "no-cache", "live"])
            .map_err(|e| anyhow::anyhow!(e))?;
    if args.has("help") {
        print!("{USAGE}");
        return Ok(());
    }
    let cmd = args
        .positional()
        .first()
        .map(String::as_str)
        .unwrap_or("");
    match cmd {
        "simulate" => cmd_simulate(&args),
        "decode" => cmd_decode(&args),
        "figure" => cmd_figure(&args),
        "explain" => cmd_explain(&args),
        "verify" => cmd_verify(&args),
        "serve" => cmd_serve(&args),
        "cluster" => cmd_cluster(&args),
        "disagg" => cmd_disagg(&args),
        "tune" => cmd_tune(&args),
        other => anyhow::bail!(
            "unknown subcommand '{other}' (expected one of: {})\n{USAGE}",
            SUBCOMMANDS.join(", ")
        ),
    }
}

/// Every CLI subcommand. `usage_tests` pins this list against the USAGE
/// text, the dispatch match above, and README.md, so none of the three
/// can drift from the others.
const SUBCOMMANDS: [&str; 9] =
    ["simulate", "decode", "figure", "explain", "verify", "serve", "cluster", "disagg", "tune"];

fn topo_arg(args: &Args) -> anyhow::Result<numa_attn::topology::Topology> {
    let name: String = args.get_or("topo", "mi300x".to_string()).map_err(|e| anyhow::anyhow!(e))?;
    presets::by_name_or_err(&name).map_err(|e| anyhow::anyhow!(e))
}

/// Build the simulation driver from `--threads` / `--no-cache`.
fn driver_arg(args: &Args) -> anyhow::Result<SimDriver> {
    let threads: usize = args
        .get_or("threads", driver::default_threads())
        .map_err(|e| anyhow::anyhow!(e))?;
    anyhow::ensure!(threads > 0, "--threads must be > 0");
    let cache = if args.has("no-cache") {
        Arc::new(ReportCache::disabled())
    } else {
        Arc::new(ReportCache::new())
    };
    Ok(SimDriver::with_cache(threads, cache))
}

/// Filter to the policies applicable to this geometry (the advisor's
/// rule — swizzled assignment needs `heads % XCDs == 0`), printing a
/// note for each one skipped. Checked per policy rather than by
/// membership in the legacy list so composed specs pass through.
fn filter_applicable(
    policies: Vec<Policy>,
    topo: &numa_attn::topology::Topology,
    attn: &AttnConfig,
) -> Vec<Policy> {
    policies
        .into_iter()
        .filter(|p| {
            let ok = !p.requires_divisible_heads() || attn.h_q % topo.num_xcds == 0;
            if !ok {
                eprintln!(
                    "note: skipping {} (heads {} not divisible by XCDs {})",
                    p, attn.h_q, topo.num_xcds
                );
            }
            ok
        })
        .collect()
}

/// Load and parse a `.trace` replay schedule (docs/SERVING.md §8) named
/// by the serving subcommands' `--trace` flag or an INI `[trace] file`
/// key.
fn load_trace(path: &str) -> anyhow::Result<TraceReplay> {
    let text =
        std::fs::read_to_string(path).map_err(|e| anyhow::anyhow!("trace file {path}: {e}"))?;
    TraceReplay::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))
}

/// Cache/thread statistics on stderr (stdout stays row-for-row stable).
fn print_driver_stats(driver: &SimDriver) {
    let c = driver.cache().counters();
    eprintln!(
        "[driver] {} thread(s); cache {}: {} hit(s), {} miss(es), {} report(s) memoized",
        driver.threads(),
        if driver.cache().is_enabled() { "on" } else { "off" },
        c.hits,
        c.misses,
        c.entries,
    );
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let a = |e: String| anyhow::anyhow!(e);
    let driver = driver_arg(args)?;
    // Config-file mode: the experiment file fully determines everything.
    if let Some(path) = args.get::<String>("config").map_err(a)? {
        let text = std::fs::read_to_string(&path)?;
        let exp = ExperimentConfig::parse(&text).map_err(a)?;
        let topo = exp.topology().map_err(a)?;
        let attn = exp.attn().map_err(a)?;
        let kernel = exp.kernel().map_err(a)?;
        let applicable = coordinator::applicable_policies(&topo, &attn);
        let mut jobs = Vec::new();
        for p in exp.policies().map_err(a)? {
            if !applicable.contains(&p) {
                continue;
            }
            let sc = exp.sim(p).map_err(a)?;
            jobs.push(match kernel {
                config::ExpKernel::Backward => SimJob::backward(&topo, &attn, sc),
                config::ExpKernel::Decode(_) => SimJob::decode(&topo, &attn, sc),
                config::ExpKernel::Forward => SimJob::forward(&topo, &attn, sc),
            });
        }
        let reports = driver.run_all(jobs);
        print_reports(args, reports)?;
        print_driver_stats(&driver);
        return Ok(());
    }
    let (topo, attn, policies, backward, generations) =
        {
            let topo = topo_arg(args)?;
            let heads: usize = args.get_or("heads", 32).map_err(a)?;
            let attn = AttnConfig {
                causal: args.has("causal"),
                ..AttnConfig::gqa(
                    args.get_or("batch", 1).map_err(a)?,
                    heads,
                    args.get_or("kv-heads", heads).map_err(a)?,
                    args.get_or("n-ctx", 8192).map_err(a)?,
                    args.get_or("d-head", 128).map_err(a)?,
                )
            };
            attn.validate().map_err(a)?;
            let policies = match args.get::<String>("policy").map_err(a)? {
                Some(p) => vec![Policy::from_str(&p).map_err(a)?],
                None => ALL_POLICIES.to_vec(),
            };
            (topo, attn, policies, args.has("backward"), args.get_or("generations", 2).map_err(a)?)
        };

    let mut jobs = Vec::new();
    for p in filter_applicable(policies, &topo, &attn) {
        let mut sc = if backward { SimConfig::backward(p) } else { SimConfig::forward(p) };
        if generations > 0 {
            let sampled = SimConfig::sampled(p, &topo, generations);
            sc.max_wg_completions = sampled.max_wg_completions;
            sc.warmup_completions = sampled.warmup_completions;
        }
        jobs.push(if backward {
            SimJob::backward(&topo, &attn, sc)
        } else {
            SimJob::forward(&topo, &attn, sc)
        });
    }
    let reports = driver.run_all(jobs);
    print_reports(args, reports)?;
    print_driver_stats(&driver);
    Ok(())
}

/// Run the two-phase split-KV decode pass (flash-decode) on one
/// geometry: all four mapping policies unless `--policy` narrows it,
/// with the KV split count auto-picked by the advisor unless
/// `--num-splits` fixes it.
fn cmd_decode(args: &Args) -> anyhow::Result<()> {
    let a = |e: String| anyhow::anyhow!(e);
    let driver = driver_arg(args)?;
    let topo = topo_arg(args)?;
    let heads: usize = args.get_or("heads", 64).map_err(a)?;
    let attn = AttnConfig::gqa(
        args.get_or("batch", 1).map_err(a)?,
        heads,
        args.get_or("kv-heads", heads).map_err(a)?,
        args.get_or("n-ctx", 65536).map_err(a)?,
        args.get_or("d-head", 128).map_err(a)?,
    );
    attn.validate().map_err(a)?;
    let requested: usize = args.get_or("num-splits", 0).map_err(a)?;
    let num_splits = if requested == 0 {
        let s = coordinator::pick_num_splits(&topo, &attn);
        eprintln!(
            "[decode] auto num_splits = {s}: grid {} over {} WG slots",
            attn.batch * attn.h_q * s,
            topo.total_wg_slots()
        );
        s
    } else {
        let clamped = attn.clamp_num_splits(requested);
        if clamped != requested {
            eprintln!(
                "note: clamping --num-splits {requested} to {clamped} ({} KV column blocks)",
                attn.num_col_blocks()
            );
        }
        clamped
    };
    let policies = match args.get::<String>("policy").map_err(a)? {
        Some(p) => vec![Policy::from_str(&p).map_err(a)?],
        None => ALL_POLICIES.to_vec(),
    };
    let jobs: Vec<SimJob> = filter_applicable(policies, &topo, &attn)
        .into_iter()
        .map(|p| SimJob::decode(&topo, &attn, SimConfig::decode(p, num_splits)))
        .collect();
    let reports = driver.run_all(jobs);
    print_reports(args, reports)?;
    print_driver_stats(&driver);
    Ok(())
}

fn print_reports(args: &Args, reports: Vec<sim::SimReport>) -> anyhow::Result<()> {
    anyhow::ensure!(!reports.is_empty(), "no applicable policies");

    if args.has("json") {
        let arr = Json::arr(reports.iter().map(|r| r.to_json()));
        println!("{}", arr.render());
        return Ok(());
    }
    let best = reports.iter().map(|r| r.est_total_sec).fold(f64::INFINITY, f64::min);
    let mut table = Table::new(&["policy", "L2 hit %", "HBM GB", "est time (ms)", "TFLOP/s", "rel perf"]);
    for r in &reports {
        table.row(vec![
            r.policy.label().into(),
            format!("{:.1}", r.l2_hit_pct()),
            format!("{:.3}", r.hbm.bytes_read as f64 / 1e9),
            format!("{:.3}", r.est_total_sec * 1e3),
            format!("{:.1}", r.achieved_tflops),
            format!("{:.3}", best / r.est_total_sec),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

fn cmd_figure(args: &Args) -> anyhow::Result<()> {
    let topo = topo_arg(args)?;
    let quick = args.has("quick");
    let driver = driver_arg(args)?;
    let id = args
        .positional()
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    let figs: Vec<figures::FigureResult> = match id {
        "12" | "fig12" => vec![figures::fig12(&driver, &topo, quick)],
        "13" | "fig13" => vec![figures::fig13(&driver, &topo, quick)],
        "14" | "fig14" => vec![figures::fig14(&driver, &topo, quick)],
        "15" | "fig15" => vec![figures::fig15(&driver, &topo, quick)],
        "16" | "fig16" => vec![figures::fig16(&driver, &topo, quick)],
        "decode" => vec![figures::decode_fig(&driver, &topo, quick)],
        "serve" => {
            // All three panels project from ONE serving-report run.
            let (serve, serve_ttft, serve_share) = figures::serve_figs(&driver, &topo, quick);
            vec![serve, serve_ttft, serve_share]
        }
        "serve_ttft" => vec![figures::serve_ttft_fig(&driver, &topo, quick)],
        "serve_share" => vec![figures::serve_share_fig(&driver, &topo, quick)],
        "serve_burst" => vec![figures::serve_burst_fig(&driver, &topo, quick)],
        "cluster" => vec![figures::cluster_fig(&driver, &topo, quick)],
        "disagg" => vec![figures::disagg_fig(&driver, &topo, quick)],
        "gemm" => vec![figures::gemm_motivation(&topo)],
        "perf" => return cmd_figure_perf(args),
        "tune" => return cmd_figure_tune(args),
        "all" => figures::all(&driver, &topo, quick),
        other => anyhow::bail!("unknown figure '{other}'"),
    };
    for f in figs {
        if args.has("json") {
            println!("{}", f.to_json().render());
        } else {
            println!("{}", f.render());
        }
    }
    print_driver_stats(&driver);
    Ok(())
}

/// `figure perf`: render the pinned perf trajectory instead of running a
/// sweep. Reads the repo-root `BENCH_sim_hotpath.json` (bench-v1,
/// docs/PERF.md) from the working directory or its parent, so the
/// command works from both the repo root and `rust/`.
fn cmd_figure_perf(args: &Args) -> anyhow::Result<()> {
    let name = "BENCH_sim_hotpath.json";
    let path = [name.to_string(), format!("../{name}")]
        .iter()
        .map(std::path::PathBuf::from)
        .find(|p| p.is_file())
        .ok_or_else(|| {
            anyhow::anyhow!(
                "{name} not found in . or .. — regenerate it with \
                 `cargo bench --bench sim_hotpath` (docs/PERF.md)"
            )
        })?;
    let text = std::fs::read_to_string(&path)?;
    let doc = numa_attn::util::json::Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    if args.has("json") {
        println!("{}", doc.render());
    } else {
        println!("{}", figures::perf_panel(&doc).map_err(anyhow::Error::msg)?);
    }
    Ok(())
}

/// `figure tune`: the tuned-vs-SHF panel — run the default tuning sweep
/// (docs/TUNING.md) exhaustively and render each row's searched winner
/// against the paper's swizzled_head_first, the figure-style view of how
/// much the composed algebra buys beyond the four named policies.
fn cmd_figure_tune(args: &Args) -> anyhow::Result<()> {
    let driver = driver_arg(args)?;
    let topo = topo_arg(args)?;
    let rows = coordinator::tune_sweep(
        &driver,
        &topo,
        coordinator::SearchMode::Exhaustive,
        args.has("quick"),
    );
    if args.has("json") {
        let obj = Json::obj(vec![
            ("figure", Json::str("tune")),
            ("title", Json::str(format!("Tuned mapping vs swizzled_head_first ({})", topo.name))),
            ("rows", Json::arr(rows.iter().map(|r| r.to_json()))),
        ]);
        println!("{}", obj.render());
    } else {
        println!("== Figure tune: searched mapping vs swizzled_head_first ({}) ==", topo.name);
        println!("{}", render_tune_rows(&rows));
    }
    print_driver_stats(&driver);
    Ok(())
}

fn cmd_explain(args: &Args) -> anyhow::Result<()> {
    let topo = topo_arg(args)?;
    println!("== {} (Table 1) ==\n{}", topo.name, figures::table1(&topo));
    if let Some(m) = args.get::<String>("mapping").map_err(|e| anyhow::anyhow!(e))? {
        let heads: usize = args.get_or("heads", 8).map_err(|e| anyhow::anyhow!(e))?;
        let blocks: usize = args.get_or("blocks", 128).map_err(|e| anyhow::anyhow!(e))?;
        let pols = if m == "all" {
            ALL_POLICIES.to_vec()
        } else {
            vec![Policy::from_str(&m).map_err(|e| anyhow::anyhow!(e))?]
        };
        for p in pols {
            println!(
                "-- {} (heads={heads}, blocks={blocks}, XCDs={}) --",
                p.label(),
                topo.num_xcds
            );
            match Mapping::new(p, 1, heads, blocks, topo.num_xcds) {
                Ok(map) => {
                    let mut per_xcd: Vec<std::collections::BTreeSet<u32>> =
                        vec![Default::default(); topo.num_xcds];
                    for s in 0..map.grid_size() {
                        let w = map.decode(s);
                        per_xcd[xcd_of_slot(s, topo.dispatch_chunk, topo.num_xcds) as usize]
                            .insert(w.h);
                    }
                    for (x, hs) in per_xcd.iter().enumerate() {
                        let list: Vec<String> = hs.iter().map(|h| format!("HQ{h}")).collect();
                        println!("  XCD{x}: {}", list.join(","));
                    }
                }
                Err(e) => println!("  (not applicable: {e})"),
            }
        }
    }
    Ok(())
}

fn cmd_verify(args: &Args) -> anyhow::Result<()> {
    let dir: String = args.get_or("artifacts", "artifacts".to_string()).map_err(|e| anyhow::anyhow!(e))?;
    let mut rt = numa_attn::runtime::Runtime::open(&dir)?;
    rt.load_all()?;
    let names: Vec<String> = rt
        .manifest()
        .artifacts
        .iter()
        .filter(|a| a.golden.is_some())
        .map(|a| a.name.clone())
        .collect();
    println!("platform: {}", rt.platform());
    for n in names {
        let (got, want) = rt.verify(&n, 1e-3)?;
        println!("  {n}: abs_sum {got:.4} (golden {want:.4}) OK");
    }
    println!("all golden checks passed");
    Ok(())
}

/// The continuous-batching decode serving loop (docs/SERVING.md): run
/// the built-in scenario sweep — or one `[serve]` INI scenario — under
/// every applicable mapping policy, pricing every step through the
/// shared simulation driver, and emit the deterministic serving report
/// (tokens/s and TPOT p50/p99 per policy). `--live` instead runs the
/// historical PJRT prefill demo ([`cmd_serve_live`]).
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    if args.has("live") {
        return cmd_serve_live(args);
    }
    let a = |e: String| anyhow::anyhow!(e);
    let driver = driver_arg(args)?;
    // Chunked-prefill overrides, applied on top of the sweep scenarios
    // or the --config scenario, then re-validated (so an oversized
    // --chunk-tokens fails with the config section's message instead of
    // a panic inside the loop). `--chunk-tokens 0` means "serve
    // monolithically", so it also clears a scenario's own budget; a
    // budget override only lands where chunking is actually on (it is
    // meaningless for monolithic rows).
    let chunk: Option<usize> = args.get("chunk-tokens").map_err(a)?;
    let budget: Option<usize> = args.get("step-token-budget").map_err(a)?;
    // Paged-KV pool overrides (docs/KVCACHE.md): the same replace-then
    // -revalidate contract as the chunking flags, so an out-of-range
    // --prefix-share-pct fails with the config section's message.
    let kv_block: Option<usize> = args.get("kv-block-tokens").map_err(a)?;
    let kv_share: Option<f64> = args.get("prefix-share-pct").map_err(a)?;
    let kv_cap: Option<usize> = args.get("kv-capacity-mb").map_err(a)?;
    let kv_override = kv_block.is_some() || kv_share.is_some() || kv_cap.is_some();
    // Load replay (docs/SERVING.md §8): the --trace flag wins over an
    // INI `[trace] file` key; a generated `[trace]` section already
    // landed on the config via `serve_config()`.
    let trace_flag: Option<String> = args.get("trace").map_err(a)?;
    // `strict` (the single-scenario --config path) rejects a budget
    // override the scenario cannot honor, matching the INI parser's
    // contradiction error; the sweep path instead skips the budget on
    // its monolithic rows (documented: "ignored where chunking is off").
    let apply_overrides = |cfg: &mut coordinator::ServeConfig, strict: bool| -> anyhow::Result<()> {
        if let Some(c) = chunk {
            // Overriding the chunk size replaces the scenario's whole
            // chunking policy: its own budget is discarded (a larger
            // chunk must never trip over a budget the user never set)
            // and the budget override — or uncapped — takes its place.
            cfg.chunk_tokens = c;
            cfg.step_token_budget = 0;
        }
        if cfg.chunk_tokens == 0 {
            if strict && budget.unwrap_or(0) > 0 {
                anyhow::bail!(
                    "--step-token-budget ({}) without chunked prefill is contradictory: \
                     this scenario serves monolithically — add --chunk-tokens > 0 or drop \
                     the flag",
                    budget.unwrap_or(0)
                );
            }
            cfg.step_token_budget = 0;
        } else if let Some(b) = budget {
            cfg.step_token_budget = b;
        }
        if let Some(bt) = kv_block {
            cfg.kv_block_tokens = bt;
        }
        if let Some(p) = kv_share {
            cfg.prefix_share_pct = p;
        }
        if let Some(mb) = kv_cap {
            cfg.kv_capacity_mb = mb;
        }
        if chunk.is_some() || budget.is_some() || kv_override {
            cfg.validate().map_err(|e| anyhow::anyhow!(e))?;
        }
        Ok(())
    };
    // Overridden rows say so: the label carries the chunking policy the
    // stats were ACTUALLY produced with, not the scenario's original one.
    let override_label = |base: String, cfg: &coordinator::ServeConfig| -> String {
        let label = if chunk.is_none() && budget.is_none() {
            base
        } else if cfg.chunk_tokens == 0 {
            format!("{base} [override: monolithic]")
        } else {
            format!(
                "{base} [override: chunk={} budget={}]",
                cfg.chunk_tokens, cfg.step_token_budget
            )
        };
        if kv_override {
            format!(
                "{label} [override: kv block={} share={}% cap={}MiB]",
                cfg.kv_block_tokens, cfg.prefix_share_pct, cfg.kv_capacity_mb
            )
        } else {
            label
        }
    };
    let report = if let Some(path) = args.get::<String>("config").map_err(a)? {
        let text = std::fs::read_to_string(&path)?;
        let exp = ExperimentConfig::parse(&text).map_err(a)?;
        let topo = exp.topology().map_err(a)?;
        let mut cfg = exp.serve_config().map_err(a)?;
        apply_overrides(&mut cfg, true)?;
        if let Some(p) = trace_flag.as_deref().or(exp.trace_file()) {
            cfg.trace = Some(load_trace(p)?);
            cfg.validate().map_err(a)?;
        }
        let label = override_label(path, &cfg);
        coordinator::ServeReport { rows: vec![coordinator::serve_row(&driver, &topo, &cfg, label)] }
    } else if chunk.is_none() && budget.is_none() && !kv_override && trace_flag.is_none() {
        let topo = topo_arg(args)?;
        coordinator::serve_report(&driver, &topo, args.has("quick"))
    } else {
        let topo = topo_arg(args)?;
        let mut rows = Vec::new();
        for sc in coordinator::serve_scenarios(args.has("quick")) {
            let mut cfg = sc.cfg;
            apply_overrides(&mut cfg, false)?;
            if let Some(p) = trace_flag.as_deref() {
                cfg.trace = Some(load_trace(p)?);
                cfg.validate().map_err(a)?;
            }
            let label = override_label(sc.label, &cfg);
            let label =
                if trace_flag.is_some() { format!("{label} [trace]") } else { label };
            rows.push(coordinator::serve_row(&driver, &topo, &cfg, label));
        }
        coordinator::ServeReport { rows }
    };
    if args.has("json") {
        println!("{}", report.to_json().render());
    } else {
        print!("{}", report.render());
    }
    print_driver_stats(&driver);
    Ok(())
}

/// The tensor-parallel cluster serving sweep (docs/CLUSTER.md): run the
/// built-in Llama-3 70B scenarios across the TP axis — or one
/// `[cluster]` INI deployment — under every applicable mapping policy,
/// fanning each step's launches over the shard plan's devices and
/// charging the interconnect all-gather, and emit the deterministic
/// cluster report (tokens/s, scaling efficiency vs. ideal, decode L2 hit
/// rate per policy).
fn cmd_cluster(args: &Args) -> anyhow::Result<()> {
    let a = |e: String| anyhow::anyhow!(e);
    let driver = driver_arg(args)?;
    let trace_flag: Option<String> = args.get("trace").map_err(a)?;
    let config_path: Option<String> = args.get::<String>("config").map_err(a)?;
    let exp = match &config_path {
        Some(path) => {
            let text = std::fs::read_to_string(path)?;
            Some(ExperimentConfig::parse(&text).map_err(a)?)
        }
        None => None,
    };
    // Fault injection (docs/SERVING.md §9): the --faults flag (an
    // explicit device:fail_sec:recover_sec schedule) wins over the
    // file's [faults] section. A non-empty spec switches to the fault
    // report — the built-in scenario grid at the sweep's widest TP
    // degree, with the outages applied and every rebalance priced.
    let mut fault_spec = match &exp {
        Some(e) => e.fault_spec().map_err(a)?,
        None => coordinator::FaultSpec::default(),
    };
    if let Some(events) = args.get::<String>("faults").map_err(a)? {
        fault_spec = coordinator::FaultSpec { events, ..coordinator::FaultSpec::default() };
    }
    if !fault_spec.is_none() {
        anyhow::ensure!(
            trace_flag.is_none(),
            "--faults runs the built-in fault sweep and cannot replay a --trace schedule"
        );
        let topo = match &exp {
            Some(e) => {
                let name = e
                    .cluster
                    .as_ref()
                    .and_then(|c| c.topology.clone())
                    .unwrap_or_else(|| e.topology.clone());
                eprintln!(
                    "[faults] running the built-in fault sweep on '{name}' \
                     (the [cluster]/[serve] scenario keys do not apply)"
                );
                presets::by_name_or_err(&name).map_err(a)?
            }
            None => topo_arg(args)?,
        };
        let report =
            coordinator::fault_report(&driver, &topo, args.has("quick"), &fault_spec).map_err(a)?;
        if args.has("json") {
            println!("{}", report.to_json().render());
        } else {
            print!("{}", report.render());
        }
        print_driver_stats(&driver);
        return Ok(());
    }
    let report = if let (Some(exp), Some(path)) = (&exp, &config_path) {
        let cluster = exp.cluster_topology().map_err(a)?;
        let plan = exp.shard_plan().map_err(a)?;
        let mut cfg = exp.serve_config().map_err(a)?;
        if let Some(p) = trace_flag.as_deref().or(exp.trace_file()) {
            cfg.trace = Some(load_trace(p)?);
            cfg.validate().map_err(a)?;
        }
        let label = format!("{path} tp={}", plan.tp);
        let row = coordinator::cluster_row(&driver, &cluster, &plan, &cfg, label, path.clone());
        coordinator::ClusterReport { rows: vec![row] }
    } else {
        let topo = topo_arg(args)?;
        let mut report = if let Some(p) = trace_flag.as_deref() {
            // The built-in sweep with every scenario replaying the same
            // schedule: mirrors `serve_cluster_report` with the trace
            // installed on each scenario's config.
            let replay = load_trace(p)?;
            let rows = coordinator::cluster_scenarios(args.has("quick"))
                .into_iter()
                .map(|sc| {
                    let cluster = ClusterTopology::node_of(&topo, sc.tp);
                    let plan =
                        ShardPlan::new(&sc.cfg.base_geometry(), sc.tp, ShardStrategy::Contiguous)
                            .expect("sweep TP degrees divide the scenario's KV heads");
                    let cfg =
                        coordinator::ServeConfig { trace: Some(replay.clone()), ..sc.cfg };
                    cfg.validate().map_err(a)?;
                    Ok(coordinator::cluster_row(
                        &driver,
                        &cluster,
                        &plan,
                        &cfg,
                        format!("{} [trace]", sc.label),
                        sc.base,
                    ))
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            coordinator::ClusterReport { rows }
        } else {
            coordinator::serve_cluster_report(&driver, &topo, args.has("quick"))
        };
        if let Some(tp) = args.get::<usize>("tp").map_err(a)? {
            let degrees: Vec<usize> = report.rows.iter().map(|r| r.tp).collect();
            anyhow::ensure!(
                degrees.contains(&tp),
                "no sweep rows at tp={tp} (sweep degrees: {degrees:?})"
            );
            // Keep the tp=1 rows: they are the baseline the requested
            // degree's scaling efficiency is computed against.
            report.rows.retain(|r| r.tp == tp || r.tp == 1);
        }
        report
    };
    if args.has("json") {
        println!("{}", report.to_json().render());
    } else {
        print!("{}", report.render());
    }
    print_driver_stats(&driver);
    Ok(())
}

/// The disaggregated prefill/decode serving sweep (docs/DISAGG.md): run
/// the built-in colocated-vs-disaggregated scenarios — or one `[disagg]`
/// INI deployment — under every applicable mapping policy, pricing the
/// KV handoff against the pool interconnect and scheduling the SLO
/// classes, and emit the deterministic disagg report (tokens/s,
/// per-class TTFT/TPOT tails, handoff bytes, preemptions per policy).
fn cmd_disagg(args: &Args) -> anyhow::Result<()> {
    let a = |e: String| anyhow::anyhow!(e);
    let driver = driver_arg(args)?;
    let trace_flag: Option<String> = args.get("trace").map_err(a)?;
    let report = if let Some(path) = args.get::<String>("config").map_err(a)? {
        let text = std::fs::read_to_string(&path)?;
        let exp = ExperimentConfig::parse(&text).map_err(a)?;
        let topo = exp.topology().map_err(a)?;
        let mut cfg = exp.disagg_config().map_err(a)?;
        if let Some(p) = trace_flag.as_deref().or(exp.trace_file()) {
            cfg.serve.trace = Some(load_trace(p)?);
            cfg.validate().map_err(a)?;
        }
        let label = format!("{path} {}p+{}d", cfg.prefill_devices, cfg.decode_devices);
        let row = coordinator::disagg_row(&driver, &topo, &cfg, label);
        coordinator::DisaggReport { rows: vec![row] }
    } else if let Some(p) = trace_flag.as_deref() {
        // The built-in sweep with every deployment replaying the same
        // schedule; trace rows carry their own SLO classes, so the
        // scenarios' interactive_pct draw is bypassed.
        let topo = topo_arg(args)?;
        let replay = load_trace(p)?;
        let rows = coordinator::disagg_scenarios(args.has("quick"))
            .into_iter()
            .map(|sc| {
                let mut cfg = sc.cfg;
                cfg.serve.trace = Some(replay.clone());
                cfg.validate().map_err(a)?;
                Ok(coordinator::disagg_row(&driver, &topo, &cfg, format!("{} [trace]", sc.label)))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        coordinator::DisaggReport { rows }
    } else {
        let topo = topo_arg(args)?;
        coordinator::disagg_report(&driver, &topo, args.has("quick"))
    };
    if args.has("json") {
        println!("{}", report.to_json().render());
    } else {
        print!("{}", report.render());
    }
    print_driver_stats(&driver);
    Ok(())
}

/// The mapping autotuner (docs/TUNING.md): search the pruned composed
/// mapping algebra per workload — the built-in decode / causal-forward
/// sweep, or ONE workload from an experiment file's [attention] + [sim]
/// sections — through the memoized driver, and print the tuned mapping
/// against the SwizzledHeadFirst baseline. stdout is bit-identical at
/// any `--threads` count: candidates enumerate in canonical order and
/// ranking is a strict argmin (driver stats go to stderr).
fn cmd_tune(args: &Args) -> anyhow::Result<()> {
    let a = |e: String| anyhow::anyhow!(e);
    let driver = driver_arg(args)?;
    let beam: usize = args.get_or("beam", 0).map_err(a)?;
    let flag_mode = (beam > 0).then_some(coordinator::SearchMode::Beam { width: beam });
    let config_path = args.get::<String>("config").map_err(a)?;
    let rows: Vec<coordinator::TuneRow> = if let Some(path) = config_path {
        let text = std::fs::read_to_string(&path)?;
        let exp = ExperimentConfig::parse(&text).map_err(a)?;
        let topo = exp.topology().map_err(a)?;
        let cfg = exp.attn().map_err(a)?;
        let kernel = match exp.kernel().map_err(a)? {
            config::ExpKernel::Forward => coordinator::TuneKernel::Forward,
            config::ExpKernel::Backward => coordinator::TuneKernel::Backward,
            config::ExpKernel::Decode(s) => coordinator::TuneKernel::Decode { num_splits: s },
        };
        let cfg_mode = exp.tune_mode().map_err(a)?;
        let mode = flag_mode.or(cfg_mode).unwrap_or(coordinator::SearchMode::Exhaustive);
        let req = coordinator::TuneRequest { label: path, cfg, kernel };
        vec![coordinator::tune_with(&driver, &topo, &req, mode)]
    } else {
        let topo = topo_arg(args)?;
        let mode = flag_mode.unwrap_or(coordinator::SearchMode::Exhaustive);
        coordinator::tune_sweep(&driver, &topo, mode, args.has("quick"))
    };
    if args.has("json") {
        println!("{}", Json::arr(rows.iter().map(|r| r.to_json())).render());
    } else {
        println!("{}", render_tune_rows(&rows));
    }
    print_driver_stats(&driver);
    Ok(())
}

/// Shared table rendering for `tune` and `figure tune`.
fn render_tune_rows(rows: &[coordinator::TuneRow]) -> String {
    let mut t = Table::new(&[
        "config",
        "tuned mapping",
        "tuned ms",
        "baseline",
        "baseline ms",
        "speedup",
    ]);
    for r in rows {
        t.row(vec![
            r.label.clone(),
            r.best.name(),
            format!("{:.3}", r.best_sec * 1e3),
            r.baseline.name(),
            format!("{:.3}", r.baseline_sec * 1e3),
            format!("{:.3}x", r.speedup()),
        ]);
    }
    t.render()
}

/// The live PJRT prefill demo (`serve --live`): deterministic requests
/// through the router/batcher/worker service over AOT artifacts.
fn cmd_serve_live(args: &Args) -> anyhow::Result<()> {
    let a = |e: String| anyhow::anyhow!(e);
    let dir: String = args.get_or("artifacts", "artifacts".to_string()).map_err(a)?;
    let requests: usize = args.get_or("requests", 32).map_err(a)?;
    let cfg = ServiceConfig {
        artifact_dir: dir.into(),
        batcher: BatcherConfig {
            max_batch: args.get_or("max-batch", 4).map_err(a)?,
            max_wait: std::time::Duration::from_millis(args.get_or("max-wait-ms", 2).map_err(a)?),
        },
    };
    let service = coordinator::AttentionService::start(cfg)?;
    let lengths = service.router().bucket_lengths();
    println!("buckets: {lengths:?}");
    let mut gen = RequestGenerator::new(args.get_or("seed", 7).map_err(a)?, lengths);
    let reqs = gen.take(requests);
    let t0 = std::time::Instant::now();
    let waiters: Vec<_> = reqs
        .into_iter()
        .map(|r| service.submit(r))
        .collect::<anyhow::Result<Vec<_>>>()?;
    let mut ok = 0;
    for w in waiters {
        if w.wait().is_ok() {
            ok += 1;
        }
    }
    let elapsed = t0.elapsed();
    println!(
        "served {ok}/{requests} in {:.1} ms ({:.1} req/s)",
        elapsed.as_secs_f64() * 1e3,
        requests as f64 / elapsed.as_secs_f64()
    );
    let m = service.shutdown();
    println!(
        "batches: {} (stacked execs: {}), queue p99 {} us, exec mean {:.0} us",
        m.batches, m.stacked_executions, m.queue_wait.p99_us, m.exec.mean_us
    );
    Ok(())
}

/// USAGE-drift pins (the satellite contract of docs/SERVING.md's PR):
/// the USAGE text, the dispatch table, README.md, and the actually-parsed
/// flag set must all agree, `include_str!`-style, so the CLI docs cannot
/// silently rot the way free-floating usage strings do.
#[cfg(test)]
mod usage_tests {
    use super::{SUBCOMMANDS, USAGE};

    /// This file's own source — the ground truth for which subcommand
    /// and flag string literals the CLI actually dispatches on.
    const SRC: &str = include_str!("main.rs");
    const README: &str = include_str!("../../README.md");

    #[test]
    fn every_subcommand_is_in_usage_readme_and_dispatch() {
        for cmd in SUBCOMMANDS {
            assert!(
                USAGE.contains(&format!("numa-attn {cmd}")),
                "USAGE is missing the '{cmd}' subcommand"
            );
            assert!(
                README.contains(&format!("**`{cmd}`**")),
                "README.md Subcommands section is missing '{cmd}'"
            );
            // Match-arm shape ('"cmd" => '), not a bare quoted literal:
            // the SUBCOMMANDS const and this test live in the same file,
            // so a bare literal would match itself and never catch a
            // deleted dispatch arm.
            assert!(
                SRC.contains(&format!("\"{cmd}\" => ")),
                "dispatch match is missing the '{cmd}' arm"
            );
        }
    }

    /// Every `--flag` the USAGE text documents must appear as a parsed
    /// key somewhere in this file (an `args.get*("flag")` / bool-flag
    /// string literal). A flag documented but never parsed — or renamed
    /// in code but not in the docs — fails here.
    #[test]
    fn every_documented_flag_is_parsed() {
        let mut flags: Vec<String> = Vec::new();
        let mut rest = USAGE;
        while let Some(at) = rest.find("--") {
            rest = &rest[at + 2..];
            let name: String = rest
                .chars()
                .take_while(|c| c.is_ascii_lowercase() || *c == '-')
                .collect();
            if !name.is_empty() && !flags.contains(&name) {
                flags.push(name);
            }
        }
        assert!(flags.len() >= 20, "flag extraction looks broken: {flags:?}");
        for f in &flags {
            assert!(
                SRC.contains(&format!("\"{f}\"")),
                "USAGE documents --{f} but main.rs never parses it"
            );
        }
    }

    /// Every figure id the USAGE advertises must have a dispatch arm.
    #[test]
    fn every_documented_figure_id_is_dispatched() {
        let line = USAGE
            .lines()
            .find(|l| l.contains("figure <"))
            .expect("USAGE documents the figure id list");
        let ids = line.split_once('<').unwrap().1.split_once('>').unwrap().0;
        let ids: Vec<&str> = ids.split('|').collect();
        assert!(ids.contains(&"serve") && ids.contains(&"all"), "{ids:?}");
        for id in ids {
            // Match-arm shape only (see the dispatch-arm pin above): an
            // id must open an arm ('"id" =>') or an alternation
            // ('"id" |'), so quoting the id elsewhere cannot satisfy it.
            assert!(
                SRC.contains(&format!("\"{id}\" =>")) || SRC.contains(&format!("\"{id}\" |")),
                "USAGE advertises figure id '{id}' with no dispatch arm"
            );
        }
    }

    /// README's quickstart and the USAGE text must agree on the binary's
    /// driver flags (the shared `--threads` / `--no-cache` contract).
    #[test]
    fn readme_documents_the_driver_flags() {
        for flag in ["--threads", "--no-cache"] {
            assert!(USAGE.contains(flag), "USAGE lost {flag}");
            assert!(README.contains(flag), "README lost {flag}");
        }
        assert!(README.contains("docs/SERVING.md"), "README must link the serving handbook");
    }
}
