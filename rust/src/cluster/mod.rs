//! The two-level NUMA cluster layer (docs/CLUSTER.md): many chiplet GPUs
//! serving one attention workload with tensor-parallel head sharding.
//!
//! The paper's thesis — scheduling must follow the NUMA hierarchy — does
//! not stop at the XCDs inside one MI300X. A production attention
//! deployment spans a *second* NUMA level: several devices connected by
//! an interconnect that is two orders of magnitude slower than HBM, with
//! query heads partitioned across them (FlashAttention-2's head-parallel
//! work partitioning; AMMA's multi-chiplet serving design in PAPERS.md).
//! This module models that level:
//!
//! * [`ClusterTopology`] — N devices, each a full [`Topology`] (its own
//!   XCDs, L2s, HBM), plus a bytes/sec + latency interconnect model for
//!   the per-step all-gather of sharded attention outputs.
//! * [`ShardPlan`] — a GQA-aware tensor-parallel partition of the H_Q
//!   query heads across devices: KV heads are **never split** (every
//!   query head of a KV group lands on the KV head's device, so no KV
//!   cache entry is replicated or sliced across devices), and the plan is
//!   a bijection over heads (pinned by `tests/properties.rs`).
//!
//! Together they form a two-level NUMA tree: the plan decides which
//! *device* owns a head (level 1), then the paper's workgroup-mapping
//! policies decide which *XCD* of that device owns each of the head's
//! blocks (level 2) — Swizzled Head-first applies unchanged *within* each
//! shard's local head range. The serving loop fans each decode step's
//! kernel launches across the shards through
//! [`crate::coordinator::serve_decode_cluster`], advancing time by the
//! slowest device plus the interconnect charge.

use std::fmt;
use std::str::FromStr;

use crate::attn::AttnConfig;
use crate::topology::Topology;

/// Default per-device interconnect bandwidth: 128 GB/s, the effective
/// per-peer Infinity-Fabric/NVLink-class link rate of current 8-GPU
/// serving nodes (~40× slower than one MI300X's HBM).
pub const DEFAULT_LINK_BYTES_PER_SEC: f64 = 128e9;

/// Default interconnect hop latency: 1 µs (switch + serialization).
pub const DEFAULT_LINK_LATENCY_SEC: f64 = 1e-6;

/// What serving phase a device pool is specialized for in a
/// disaggregated deployment (docs/DISAGG.md). A colocated cluster (the
/// historical `serve`/`cluster` paths) has no pool kind at all —
/// [`ClusterTopology::pool`] is `None` there, and every byte of its
/// behavior is unchanged.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PoolKind {
    /// Prompt-processing pool: compute-bound monolithic or chunked
    /// prefill, no decode steps.
    Prefill,
    /// Token-generation pool: bandwidth-bound decode over growing KV
    /// caches, fed by KV handoffs from the prefill pool.
    Decode,
}

impl PoolKind {
    /// Stable lowercase identifier (JSON/logs).
    pub fn name(&self) -> &'static str {
        match self {
            PoolKind::Prefill => "prefill",
            PoolKind::Decode => "decode",
        }
    }
}

impl fmt::Display for PoolKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A cluster of chiplet GPUs: the second NUMA level above
/// [`Topology`]'s XCDs.
///
/// Equality and hashing compare the f64 interconnect fields by IEEE-754
/// bit pattern (like [`Topology`] itself), so a `ClusterTopology` can key
/// memoization tables the same way single-device topologies do.
#[derive(Debug, Clone)]
pub struct ClusterTopology {
    /// Human-readable name, e.g. `"mi300x x8"`.
    pub name: String,
    /// The member devices. Homogeneous in every preset, but the model
    /// carries one [`Topology`] per device so heterogeneous clusters
    /// price correctly (the step advances by the *slowest* device).
    pub devices: Vec<Topology>,
    /// Per-device interconnect bandwidth in bytes/second (the rate one
    /// device can send to its ring neighbor during an all-gather).
    pub link_bytes_per_sec: f64,
    /// Per-hop interconnect latency in seconds.
    pub link_latency_sec: f64,
    /// The serving phase this cluster is a pool for in a disaggregated
    /// deployment, or `None` for the historical colocated cluster
    /// (docs/DISAGG.md). Part of equality/hashing like every other
    /// field, so a tagged pool never aliases a colocated cluster in a
    /// memoization table.
    pub pool: Option<PoolKind>,
}

impl ClusterTopology {
    /// A homogeneous cluster: `n` copies of `device` joined by the given
    /// interconnect. All devices share the device's name (identical
    /// shards then share one memoized report in the driver's cache).
    pub fn homogeneous(
        device: &Topology,
        n: usize,
        link_bytes_per_sec: f64,
        link_latency_sec: f64,
    ) -> ClusterTopology {
        ClusterTopology {
            name: format!("{} x{n}", device.name),
            devices: vec![device.clone(); n],
            link_bytes_per_sec,
            link_latency_sec,
            pool: None,
        }
    }

    /// A homogeneous cluster with the default interconnect
    /// ([`DEFAULT_LINK_BYTES_PER_SEC`] / [`DEFAULT_LINK_LATENCY_SEC`]).
    pub fn node_of(device: &Topology, n: usize) -> ClusterTopology {
        Self::homogeneous(device, n, DEFAULT_LINK_BYTES_PER_SEC, DEFAULT_LINK_LATENCY_SEC)
    }

    /// A homogeneous pool of `n` devices specialized for one serving
    /// phase of a disaggregated deployment (docs/DISAGG.md). Identical
    /// to [`ClusterTopology::homogeneous`] except for the tag in the
    /// name and the [`PoolKind`] marker.
    pub fn pool_of(
        device: &Topology,
        n: usize,
        kind: PoolKind,
        link_bytes_per_sec: f64,
        link_latency_sec: f64,
    ) -> ClusterTopology {
        ClusterTopology {
            name: format!("{} {kind}-pool x{n}", device.name),
            pool: Some(kind),
            ..Self::homogeneous(device, n, link_bytes_per_sec, link_latency_sec)
        }
    }

    /// Number of member devices.
    pub fn num_devices(&self) -> usize {
        self.devices.len()
    }

    /// The `i`-th member device.
    pub fn device(&self, i: usize) -> &Topology {
        &self.devices[i]
    }

    /// Total workgroup slots across every device (the cluster-wide
    /// occupancy the tensor-parallel grid must fill).
    pub fn total_wg_slots(&self) -> usize {
        self.devices.iter().map(Topology::total_wg_slots).sum()
    }

    /// Check the cluster description for degenerate values: at least one
    /// device, every device valid, positive interconnect rates.
    pub fn validate(&self) -> Result<(), String> {
        if self.devices.is_empty() {
            return Err("cluster needs at least one device".into());
        }
        for (i, d) in self.devices.iter().enumerate() {
            d.validate().map_err(|e| format!("device {i}: {e}"))?;
        }
        if self.link_bytes_per_sec.is_nan() || self.link_bytes_per_sec <= 0.0 {
            return Err("link_bytes_per_sec must be > 0".into());
        }
        if self.link_latency_sec.is_nan() || self.link_latency_sec < 0.0 {
            return Err("link_latency_sec must be >= 0".into());
        }
        Ok(())
    }

    /// Time for a ring all-gather in which each device contributes
    /// `bytes_per_device` bytes: `(N-1)` hops, each moving one device's
    /// contribution over the link. Zero on a single-device cluster —
    /// which is what makes the `tp = 1` cluster serving path
    /// byte-identical to the single-device one (tests/cluster_serving.rs).
    pub fn all_gather_sec(&self, bytes_per_device: f64) -> f64 {
        let n = self.devices.len();
        if n <= 1 {
            return 0.0;
        }
        (n - 1) as f64 * (bytes_per_device / self.link_bytes_per_sec + self.link_latency_sec)
    }

    /// Time for a point-to-point transfer of `bytes` over one link hop —
    /// the KV-handoff charge of disaggregated serving (docs/DISAGG.md):
    /// a session's non-credited KV blocks move from the prefill pool to
    /// the decode pool over the same interconnect the all-gather uses.
    /// Exactly zero for zero bytes (a fully credited handoff pays no
    /// latency either — the blocks are already resident).
    pub fn transfer_sec(&self, bytes: f64) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        bytes / self.link_bytes_per_sec + self.link_latency_sec
    }
}

// Hash/Eq by bits, same convention as Topology/SimConfig: canonical
// memoization-key behavior for the two f64 interconnect fields.
impl PartialEq for ClusterTopology {
    fn eq(&self, other: &Self) -> bool {
        self.name == other.name
            && self.devices == other.devices
            && self.link_bytes_per_sec.to_bits() == other.link_bytes_per_sec.to_bits()
            && self.link_latency_sec.to_bits() == other.link_latency_sec.to_bits()
            && self.pool == other.pool
    }
}

impl Eq for ClusterTopology {}

impl std::hash::Hash for ClusterTopology {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.name.hash(state);
        self.devices.hash(state);
        self.link_bytes_per_sec.to_bits().hash(state);
        self.link_latency_sec.to_bits().hash(state);
        self.pool.hash(state);
    }
}

/// How a [`ShardPlan`] lays KV groups out across devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ShardStrategy {
    /// Device `d` owns the contiguous KV-head range
    /// `[d·H_K/tp, (d+1)·H_K/tp)` — the vLLM/Megatron default.
    Contiguous,
    /// Device `d` owns KV heads `{k : k mod tp == d}` — round-robin
    /// striding, useful when adjacent heads have correlated load.
    Strided,
}

impl ShardStrategy {
    /// Stable lowercase identifier (INI/CLI/JSON).
    pub fn name(&self) -> &'static str {
        match self {
            ShardStrategy::Contiguous => "contiguous",
            ShardStrategy::Strided => "strided",
        }
    }
}

impl fmt::Display for ShardStrategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for ShardStrategy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "contiguous" => Ok(ShardStrategy::Contiguous),
            "strided" => Ok(ShardStrategy::Strided),
            other => Err(format!(
                "unknown shard strategy '{other}' (expected contiguous or strided)"
            )),
        }
    }
}

/// A tensor-parallel partition of the query heads across `tp` devices.
///
/// The plan is GQA-aware: it assigns whole **KV heads** (hence whole GQA
/// groups of `h_q / h_k` query heads) to devices, so a KV cache entry is
/// owned by exactly one device — never split, never replicated. This
/// requires `tp` to divide `H_K`, which also makes every shard the same
/// size (`H_Q/tp` query heads, `H_K/tp` KV heads): the balanced partition
/// every production TP implementation uses.
///
/// Invariants (property-tested in `tests/properties.rs`):
/// * **bijection** — each of the `H_Q` query heads lands on exactly one
///   device;
/// * **group alignment** — the query heads of one KV group all land on
///   their KV head's device.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ShardPlan {
    /// Tensor-parallel degree (number of shards == number of devices).
    pub tp: usize,
    /// The layout strategy the plan was built with.
    pub strategy: ShardStrategy,
    /// Query heads of the sharded (global) geometry.
    pub h_q: usize,
    /// KV heads of the sharded (global) geometry.
    pub h_k: usize,
    /// KV head -> owning device (`h_k` entries).
    kv_owner: Vec<usize>,
}

impl ShardPlan {
    /// Build the plan for a geometry at the given TP degree. Fails when
    /// the geometry is invalid or `tp` does not divide `H_K` (splitting a
    /// KV head would shard its KV cache — exactly what the plan forbids).
    pub fn new(cfg: &AttnConfig, tp: usize, strategy: ShardStrategy) -> Result<ShardPlan, String> {
        cfg.validate()?;
        if tp == 0 {
            return Err("tp must be > 0".into());
        }
        if cfg.h_k % tp != 0 {
            return Err(format!(
                "tp ({tp}) must divide h_k ({}): KV heads are never split across devices",
                cfg.h_k
            ));
        }
        let kpd = cfg.h_k / tp; // KV heads per device
        let kv_owner = (0..cfg.h_k)
            .map(|k| match strategy {
                ShardStrategy::Contiguous => k / kpd,
                ShardStrategy::Strided => k % tp,
            })
            .collect();
        Ok(ShardPlan { tp, strategy, h_q: cfg.h_q, h_k: cfg.h_k, kv_owner })
    }

    /// GQA group size (query heads per KV head) of the global geometry.
    pub fn group(&self) -> usize {
        self.h_q / self.h_k
    }

    /// Device owning KV head `k` (and its whole KV-cache stream).
    pub fn device_of_kv_head(&self, k: usize) -> usize {
        self.kv_owner[k]
    }

    /// Device owning query head `h` — its KV group's device.
    pub fn device_of_query_head(&self, h: usize) -> usize {
        self.kv_owner[h / self.group()]
    }

    /// The global query-head ids resident on device `d`, ascending.
    pub fn query_heads(&self, d: usize) -> Vec<usize> {
        (0..self.h_q).filter(|&h| self.device_of_query_head(h) == d).collect()
    }

    /// Global KV head `k`'s index within its owning device's shard-local
    /// geometry — how many lower-numbered KV heads share its device.
    /// This is the head the paged KV pool scores XCD affinity against
    /// on a cluster (docs/KVCACHE.md): block placement is decided by
    /// where the *local* mapping puts the head, not its global id.
    pub fn kv_local_index(&self, k: usize) -> usize {
        let d = self.kv_owner[k];
        self.kv_owner[..k].iter().filter(|&&o| o == d).count()
    }

    /// The shard-local view of a global geometry: the same workload with
    /// `H_Q/tp` query heads and `H_K/tp` KV heads (blocks, masking, and
    /// dtype unchanged). Every shard of the balanced partition has this
    /// one shape, which is what lets a homogeneous cluster's per-shard
    /// reports collapse to a single memoized entry in the driver's cache.
    /// The paper's mapping policies then apply *within* this local head
    /// range — level 2 of the NUMA tree.
    pub fn local_attn(&self, cfg: &AttnConfig) -> AttnConfig {
        debug_assert_eq!((cfg.h_q, cfg.h_k), (self.h_q, self.h_k), "plan built for this geometry");
        AttnConfig { h_q: cfg.h_q / self.tp, h_k: cfg.h_k / self.tp, ..*cfg }
    }

    /// Bytes one device contributes to the per-step output all-gather for
    /// `tokens` query tokens: its `H_Q/tp` heads' output rows.
    pub fn output_bytes_per_device(&self, cfg: &AttnConfig, tokens: usize) -> f64 {
        (tokens * (self.h_q / self.tp) * cfg.d_head * cfg.dtype_bytes) as f64
    }
}

impl fmt::Display for ShardPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "tp={} {} ({}q+{}kv heads/device)",
            self.tp,
            self.strategy,
            self.h_q / self.tp,
            self.h_k / self.tp
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets;

    fn llama70b() -> AttnConfig {
        AttnConfig::gqa(1, 64, 8, 16384, 128)
    }

    #[test]
    fn homogeneous_cluster_shape_and_validation() {
        let c = ClusterTopology::node_of(&presets::mi300x(), 8);
        assert_eq!(c.num_devices(), 8);
        assert_eq!(c.total_wg_slots(), 8 * 304);
        assert_eq!(c.device(3).num_xcds, 8);
        c.validate().unwrap();
        let empty = ClusterTopology { devices: vec![], ..c.clone() };
        assert!(empty.validate().is_err());
        let bad_link = ClusterTopology { link_bytes_per_sec: 0.0, ..c.clone() };
        assert!(bad_link.validate().is_err());
        let mut bad_dev = c;
        bad_dev.devices[1].num_xcds = 0;
        let err = bad_dev.validate().unwrap_err();
        assert!(err.contains("device 1"), "{err}");
    }

    #[test]
    fn all_gather_is_free_on_one_device_and_ring_priced_beyond() {
        let one = ClusterTopology::node_of(&presets::mi300x(), 1);
        assert_eq!(one.all_gather_sec(1e9), 0.0);
        let eight = ClusterTopology::homogeneous(&presets::mi300x(), 8, 100e9, 1e-6);
        let t = eight.all_gather_sec(1e6); // 1 MB per device
        let want = 7.0 * (1e6 / 100e9 + 1e-6);
        assert!((t - want).abs() < 1e-15, "{t} vs {want}");
        // More devices move more data: all-gather grows with N.
        let four = ClusterTopology::homogeneous(&presets::mi300x(), 4, 100e9, 1e-6);
        assert!(four.all_gather_sec(1e6) < t);
    }

    #[test]
    fn pool_of_tags_and_transfer_prices_point_to_point() {
        let p = ClusterTopology::pool_of(&presets::mi300x(), 2, PoolKind::Prefill, 100e9, 1e-6);
        assert_eq!(p.pool, Some(PoolKind::Prefill));
        assert!(p.name.contains("prefill-pool x2"), "{}", p.name);
        p.validate().unwrap();
        // Point-to-point transfer: bytes/link + latency; exactly free at 0.
        assert_eq!(p.transfer_sec(0.0), 0.0);
        let t = p.transfer_sec(1e6);
        let want = 1e6 / 100e9 + 1e-6;
        assert!((t - want).abs() < 1e-15, "{t} vs {want}");
        // The pool tag participates in equality on its own: clearing it
        // (same name, same devices, same link) changes the key.
        let mut untagged = p.clone();
        untagged.pool = None;
        assert_ne!(p, untagged);
        // Colocated constructors stay untagged.
        assert_eq!(ClusterTopology::node_of(&presets::mi300x(), 2).pool, None);
    }

    #[test]
    fn cluster_hash_eq_by_bits() {
        use std::collections::hash_map::DefaultHasher;
        use std::hash::{Hash, Hasher};
        let hash_of = |c: &ClusterTopology| {
            let mut h = DefaultHasher::new();
            c.hash(&mut h);
            h.finish()
        };
        let a = ClusterTopology::node_of(&presets::mi300x(), 4);
        let b = ClusterTopology::node_of(&presets::mi300x(), 4);
        assert_eq!(a, b);
        assert_eq!(hash_of(&a), hash_of(&b));
        let mut c = ClusterTopology::node_of(&presets::mi300x(), 4);
        c.link_bytes_per_sec *= 2.0;
        assert_ne!(a, c);
        assert_ne!(hash_of(&a), hash_of(&c));
    }

    #[test]
    fn contiguous_plan_owns_contiguous_ranges() {
        let cfg = llama70b();
        let plan = ShardPlan::new(&cfg, 4, ShardStrategy::Contiguous).unwrap();
        assert_eq!(plan.group(), 8);
        // Device d owns KV heads [2d, 2d+2) -> query heads [16d, 16d+16).
        for d in 0..4 {
            let heads = plan.query_heads(d);
            assert_eq!(heads.len(), 16);
            assert_eq!(heads, (16 * d..16 * (d + 1)).collect::<Vec<_>>());
        }
        assert_eq!(plan.device_of_kv_head(0), 0);
        assert_eq!(plan.device_of_kv_head(7), 3);
        assert_eq!(plan.device_of_query_head(63), 3);
        // Contiguous: local indices count up within each device's pair.
        let local: Vec<usize> = (0..8).map(|k| plan.kv_local_index(k)).collect();
        assert_eq!(local, vec![0, 1, 0, 1, 0, 1, 0, 1]);
    }

    #[test]
    fn strided_kv_local_index_counts_per_device_rank() {
        let plan = ShardPlan::new(&llama70b(), 4, ShardStrategy::Strided).unwrap();
        // KV head k lives on device k % 4; its local rank is k / 4.
        for k in 0..8 {
            assert_eq!(plan.kv_local_index(k), k / 4, "kv head {k}");
        }
    }

    #[test]
    fn strided_plan_round_robins_kv_groups() {
        let cfg = llama70b();
        let plan = ShardPlan::new(&cfg, 4, ShardStrategy::Strided).unwrap();
        // KV head k -> device k % 4; its 8 query heads follow it.
        for k in 0..8 {
            assert_eq!(plan.device_of_kv_head(k), k % 4);
            for h in 8 * k..8 * (k + 1) {
                assert_eq!(plan.device_of_query_head(h), k % 4, "head {h}");
            }
        }
        // Still balanced: 16 query heads per device.
        for d in 0..4 {
            assert_eq!(plan.query_heads(d).len(), 16);
        }
    }

    #[test]
    fn local_attn_shrinks_heads_and_stays_valid() {
        let cfg = AttnConfig { causal: true, dtype_bytes: 2, ..llama70b() };
        for tp in [1usize, 2, 4, 8] {
            let plan = ShardPlan::new(&cfg, tp, ShardStrategy::Contiguous).unwrap();
            let local = plan.local_attn(&cfg);
            assert_eq!(local.h_q, 64 / tp);
            assert_eq!(local.h_k, 8 / tp);
            assert_eq!(local.group(), cfg.group(), "GQA ratio preserved");
            assert_eq!(local.n_ctx, cfg.n_ctx);
            assert!(local.causal);
            local.validate().unwrap();
        }
        // tp = 1 is the identity plan.
        let plan = ShardPlan::new(&cfg, 1, ShardStrategy::Contiguous).unwrap();
        assert_eq!(plan.local_attn(&cfg), cfg);
    }

    #[test]
    fn plan_rejects_kv_head_splits() {
        let cfg = llama70b(); // h_k = 8
        assert!(ShardPlan::new(&cfg, 3, ShardStrategy::Contiguous).is_err());
        assert!(ShardPlan::new(&cfg, 16, ShardStrategy::Contiguous).is_err());
        assert!(ShardPlan::new(&cfg, 0, ShardStrategy::Contiguous).is_err());
        let err = ShardPlan::new(&cfg, 5, ShardStrategy::Strided).unwrap_err();
        assert!(err.contains("never split"), "{err}");
    }

    #[test]
    fn output_bytes_match_sharded_rows() {
        let cfg = llama70b(); // d_head 128, bf16
        let plan = ShardPlan::new(&cfg, 8, ShardStrategy::Contiguous).unwrap();
        // 8 local heads x 128 x 2 bytes per token.
        assert_eq!(plan.output_bytes_per_device(&cfg, 1), (8 * 128 * 2) as f64);
        assert_eq!(plan.output_bytes_per_device(&cfg, 16), (16 * 8 * 128 * 2) as f64);
    }

    #[test]
    fn strategy_parsing_round_trips() {
        for s in [ShardStrategy::Contiguous, ShardStrategy::Strided] {
            assert_eq!(s.name().parse::<ShardStrategy>().unwrap(), s);
            assert_eq!(format!("{s}"), s.name());
        }
        assert!("diagonal".parse::<ShardStrategy>().is_err());
    }
}
