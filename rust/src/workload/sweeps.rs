//! Experiment sweep builders matching the paper's evaluation grids.

use crate::attn::AttnConfig;

use super::presets;

/// One point of a sweep, labeled for figure output.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    pub label: String,
    pub cfg: AttnConfig,
}

pub const TABLE2_N_CTX: [usize; 3] = [8 * 1024, 32 * 1024, 128 * 1024];
pub const TABLE2_BATCH: [usize; 4] = [1, 2, 4, 8];
pub const TABLE2_HEADS: [usize; 5] = [8, 16, 32, 64, 128];
pub const FIG13_N_CTX: [usize; 4] = [2 * 1024, 8 * 1024, 32 * 1024, 128 * 1024];

/// Paper Table 2: the MHA sensitivity grid (Figs. 12-13).
/// D_HEAD = 128, BLOCK = 128x64.
pub fn mha_sensitivity(
    n_ctxs: &[usize],
    batches: &[usize],
    heads: &[usize],
) -> Vec<SweepPoint> {
    let mut out = Vec::new();
    for &h in heads {
        for &n in n_ctxs {
            for &b in batches {
                out.push(SweepPoint {
                    label: format!("H={h} N={} B={b}", fmt_ctx(n)),
                    cfg: AttnConfig::mha(b, h, n, 128),
                });
            }
        }
    }
    out
}

/// Paper Fig. 14: GQA with fixed 8 KV heads, H_Q in {32, 64, 128}
/// (Llama-3 8B/70B/405B).
pub fn gqa_sensitivity(n_ctxs: &[usize], batches: &[usize]) -> Vec<SweepPoint> {
    let mut out = Vec::new();
    for preset in [presets::llama3_8b(), presets::llama3_70b(), presets::llama3_405b()] {
        for &n in n_ctxs {
            for &b in batches {
                out.push(SweepPoint {
                    label: format!("{} H_Q={} N={} B={b}", preset.name, preset.h_q, fmt_ctx(n)),
                    cfg: preset.attn(b, n),
                });
            }
        }
    }
    out
}

/// Paper Fig. 15: DeepSeek-V3 prefill (MHA, 128 heads, D=56).
pub fn deepseek_prefill(n_ctxs: &[usize], batches: &[usize]) -> Vec<SweepPoint> {
    let preset = presets::deepseek_v3();
    let mut out = Vec::new();
    for &n in n_ctxs {
        for &b in batches {
            out.push(SweepPoint {
                label: format!("N={} B={b}", fmt_ctx(n)),
                cfg: preset.attn(b, n),
            });
        }
    }
    out
}

/// Paper Fig. 16: backward pass, H_Q = 128 MHA, batch 1-2.
pub fn backward_sweep(n_ctxs: &[usize], batches: &[usize]) -> Vec<SweepPoint> {
    let mut out = Vec::new();
    for &n in n_ctxs {
        for &b in batches {
            out.push(SweepPoint {
                label: format!("N={} B={b}", fmt_ctx(n)),
                cfg: AttnConfig::mha(b, 128, n, 128),
            });
        }
    }
    out
}

/// "8K" / "128K" style context-length labels (paper axis format).
pub fn fmt_ctx(n: usize) -> String {
    if n % 1024 == 0 {
        format!("{}K", n / 1024)
    } else {
        format!("{n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_grid_size() {
        let pts = mha_sensitivity(&TABLE2_N_CTX, &TABLE2_BATCH, &TABLE2_HEADS);
        assert_eq!(pts.len(), 3 * 4 * 5);
        for p in &pts {
            p.cfg.validate().unwrap();
            assert_eq!(p.cfg.d_head, 128);
            assert_eq!(p.cfg.block_m, 128);
            assert_eq!(p.cfg.block_n, 64);
        }
    }

    #[test]
    fn gqa_all_have_8_kv_heads() {
        for p in gqa_sensitivity(&[8192], &[1, 8]) {
            assert_eq!(p.cfg.h_k, 8);
        }
    }

    #[test]
    fn deepseek_shape() {
        for p in deepseek_prefill(&[2048], &[1]) {
            assert_eq!(p.cfg.h_q, 128);
            assert_eq!(p.cfg.d_head, 56);
        }
    }

    #[test]
    fn ctx_labels() {
        assert_eq!(fmt_ctx(8192), "8K");
        assert_eq!(fmt_ctx(131072), "128K");
        assert_eq!(fmt_ctx(100), "100");
    }
}
