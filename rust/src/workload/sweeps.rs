//! Experiment sweep builders matching the paper's evaluation grids, plus
//! the serving-regime split-KV decode sweeps (batch × KV length × split
//! count) the `decode` figure plots and the tensor-parallel axis the
//! cluster sweeps cross them with (docs/CLUSTER.md).

use crate::attn::AttnConfig;
use crate::cluster::{ShardPlan, ShardStrategy};

use super::presets;

/// One point of a sweep, labeled for figure output.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Row label for figure output.
    pub label: String,
    /// The point's attention geometry.
    pub cfg: AttnConfig,
}

/// One point of a decode sweep: a geometry plus its KV split count.
#[derive(Debug, Clone)]
pub struct DecodePoint {
    /// Row label for figure output (model, batch, context, splits).
    pub label: String,
    /// Decode-shaped attention geometry (`n_ctx` = KV length served).
    pub cfg: AttnConfig,
    /// KV splits per (batch, head) — the split-KV grid's block dim.
    pub num_splits: usize,
}

/// Paper Table 2 context lengths (MHA sensitivity grid).
pub const TABLE2_N_CTX: [usize; 3] = [8 * 1024, 32 * 1024, 128 * 1024];
/// Paper Table 2 batch sizes.
pub const TABLE2_BATCH: [usize; 4] = [1, 2, 4, 8];
/// Paper Table 2 query-head counts.
pub const TABLE2_HEADS: [usize; 5] = [8, 16, 32, 64, 128];
/// Paper Fig. 13 context lengths (adds the 2K short-context corner).
pub const FIG13_N_CTX: [usize; 4] = [2 * 1024, 8 * 1024, 32 * 1024, 128 * 1024];
/// Decode-sweep KV lengths: serving-regime contexts (16K-256K).
pub const DECODE_N_CTX: [usize; 3] = [16 * 1024, 64 * 1024, 256 * 1024];
/// Decode-sweep batch sizes (concurrent requests being generated).
pub const DECODE_BATCH: [usize; 3] = [1, 4, 8];
/// Decode-sweep split counts. Deliberately NOT multiples of the MI300X
/// XCD count: when `num_splits % num_xcds == 0`, round-robin dispatch
/// incidentally co-locates each (kv head, split) stream even under the
/// naive head-first mapping, hiding the locality difference the sweep
/// measures (see docs/REFERENCE.md).
pub const DECODE_SPLITS: [usize; 2] = [2, 4];
/// Tensor-parallel degrees the cluster sweeps exercise. Every degree
/// divides the GQA-8 sweeps' 8 KV heads, so a GQA-aware
/// [`ShardPlan`] exists at each (KV heads are never split).
pub const CLUSTER_TP: [usize; 4] = [1, 2, 4, 8];

/// Paper Table 2: the MHA sensitivity grid (Figs. 12-13).
/// D_HEAD = 128, BLOCK = 128x64.
pub fn mha_sensitivity(
    n_ctxs: &[usize],
    batches: &[usize],
    heads: &[usize],
) -> Vec<SweepPoint> {
    let mut out = Vec::new();
    for &h in heads {
        for &n in n_ctxs {
            for &b in batches {
                out.push(SweepPoint {
                    label: format!("H={h} N={} B={b}", fmt_ctx(n)),
                    cfg: AttnConfig::mha(b, h, n, 128),
                });
            }
        }
    }
    out
}

/// Paper Fig. 14: GQA with fixed 8 KV heads, H_Q in {32, 64, 128}
/// (Llama-3 8B/70B/405B).
pub fn gqa_sensitivity(n_ctxs: &[usize], batches: &[usize]) -> Vec<SweepPoint> {
    let mut out = Vec::new();
    for preset in [presets::llama3_8b(), presets::llama3_70b(), presets::llama3_405b()] {
        for &n in n_ctxs {
            for &b in batches {
                out.push(SweepPoint {
                    label: format!("{} H_Q={} N={} B={b}", preset.name, preset.h_q, fmt_ctx(n)),
                    cfg: preset.attn(b, n),
                });
            }
        }
    }
    out
}

/// Paper Fig. 15: DeepSeek-V3 prefill (MHA, 128 heads, D=56).
pub fn deepseek_prefill(n_ctxs: &[usize], batches: &[usize]) -> Vec<SweepPoint> {
    let preset = presets::deepseek_v3();
    let mut out = Vec::new();
    for &n in n_ctxs {
        for &b in batches {
            out.push(SweepPoint {
                label: format!("N={} B={b}", fmt_ctx(n)),
                cfg: preset.attn(b, n),
            });
        }
    }
    out
}

/// Paper Fig. 16: backward pass, H_Q = 128 MHA, batch 1-2.
pub fn backward_sweep(n_ctxs: &[usize], batches: &[usize]) -> Vec<SweepPoint> {
    let mut out = Vec::new();
    for &n in n_ctxs {
        for &b in batches {
            out.push(SweepPoint {
                label: format!("N={} B={b}", fmt_ctx(n)),
                cfg: AttnConfig::mha(b, 128, n, 128),
            });
        }
    }
    out
}

/// Split-KV decode sweep over batch × KV length × split count for one
/// model preset (one query token per (batch, head)).
pub fn decode_sweep(
    preset: &presets::ModelPreset,
    n_ctxs: &[usize],
    batches: &[usize],
    splits: &[usize],
) -> Vec<DecodePoint> {
    let mut out = Vec::new();
    for &n in n_ctxs {
        for &b in batches {
            for &s in splits {
                out.push(DecodePoint {
                    label: format!("{} B={b} N={} S={s}", preset.name, fmt_ctx(n)),
                    cfg: preset.attn(b, n),
                    num_splits: s,
                });
            }
        }
    }
    out
}

/// The GQA-8 decode sweep (Llama-3 70B: H_Q=64, H_K=8) — the serving
/// shape the `decode` figure plots.
pub fn gqa8_decode_sweep(n_ctxs: &[usize], batches: &[usize], splits: &[usize]) -> Vec<DecodePoint> {
    decode_sweep(&presets::llama3_70b(), n_ctxs, batches, splits)
}

/// The GQA-8 decode sweep as ONE SHARD of a `tp`-way head-sharded
/// deployment sees it: every point's geometry reduced to its shard-local
/// view (`H_Q/tp` query heads, `H_K/tp` KV heads) through a contiguous
/// [`ShardPlan`]. `tp` must divide the sweep's 8 KV heads. This is the
/// grid the cluster benches replay per TP degree — the level-2 mapping
/// claims (SHF ≥ NHF L2 hit rate) must hold on the *local* head range.
pub fn sharded_gqa8_decode_sweep(
    tp: usize,
    n_ctxs: &[usize],
    batches: &[usize],
    splits: &[usize],
) -> Vec<DecodePoint> {
    gqa8_decode_sweep(n_ctxs, batches, splits)
        .into_iter()
        .map(|p| {
            let plan = ShardPlan::new(&p.cfg, tp, ShardStrategy::Contiguous)
                .expect("tp divides the GQA-8 sweep's KV heads");
            DecodePoint {
                label: format!("{} tp={tp}", p.label),
                cfg: plan.local_attn(&p.cfg),
                num_splits: p.num_splits,
            }
        })
        .collect()
}

/// MHA decode sweep (64 query heads, D=128) — the non-grouped control
/// row for the decode experiments.
pub fn mha_decode_sweep(n_ctxs: &[usize], batches: &[usize], splits: &[usize]) -> Vec<DecodePoint> {
    let preset = presets::ModelPreset {
        name: "mha-64".into(),
        h_q: 64,
        h_k: 64,
        d_head: 128,
        gqa: false,
    };
    decode_sweep(&preset, n_ctxs, batches, splits)
}

/// "8K" / "128K" style context-length labels (paper axis format).
pub fn fmt_ctx(n: usize) -> String {
    if n % 1024 == 0 {
        format!("{}K", n / 1024)
    } else {
        format!("{n}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_grid_size() {
        let pts = mha_sensitivity(&TABLE2_N_CTX, &TABLE2_BATCH, &TABLE2_HEADS);
        assert_eq!(pts.len(), 3 * 4 * 5);
        for p in &pts {
            p.cfg.validate().unwrap();
            assert_eq!(p.cfg.d_head, 128);
            assert_eq!(p.cfg.block_m, 128);
            assert_eq!(p.cfg.block_n, 64);
        }
    }

    #[test]
    fn gqa_all_have_8_kv_heads() {
        for p in gqa_sensitivity(&[8192], &[1, 8]) {
            assert_eq!(p.cfg.h_k, 8);
        }
    }

    #[test]
    fn deepseek_shape() {
        for p in deepseek_prefill(&[2048], &[1]) {
            assert_eq!(p.cfg.h_q, 128);
            assert_eq!(p.cfg.d_head, 56);
        }
    }

    #[test]
    fn ctx_labels() {
        assert_eq!(fmt_ctx(8192), "8K");
        assert_eq!(fmt_ctx(131072), "128K");
        assert_eq!(fmt_ctx(100), "100");
    }

    #[test]
    fn ctx_labels_non_power_of_two() {
        // Any multiple of 1024 gets the K suffix, even non-powers of two;
        // everything else renders verbatim. Pinned because sweep labels
        // are part of the figures' stable output.
        assert_eq!(fmt_ctx(3 * 1024), "3K");
        assert_eq!(fmt_ctx(48 * 1024), "48K");
        assert_eq!(fmt_ctx(1536), "1536");
        assert_eq!(fmt_ctx(1000), "1000");
        assert_eq!(fmt_ctx(1), "1");
        assert_eq!(fmt_ctx(1025), "1025");
    }

    #[test]
    fn gqa8_decode_sweep_shape() {
        let pts = gqa8_decode_sweep(&DECODE_N_CTX, &DECODE_BATCH, &DECODE_SPLITS);
        assert_eq!(pts.len(), 3 * 3 * 2);
        for p in &pts {
            p.cfg.validate().unwrap();
            assert_eq!(p.cfg.h_k, 8);
            assert_eq!(p.cfg.h_q, 64);
            assert!(p.num_splits > 0);
            // Splits never exceed the KV column blocks at these lengths.
            assert!(p.num_splits <= p.cfg.num_col_blocks());
        }
        let labels: std::collections::BTreeSet<_> = pts.iter().map(|p| p.label.clone()).collect();
        assert_eq!(labels.len(), pts.len(), "decode labels unique");
    }

    #[test]
    fn sharded_decode_sweep_reduces_heads_per_tp() {
        for tp in CLUSTER_TP {
            let pts = sharded_gqa8_decode_sweep(tp, &[16384], &[1, 8], &[2]);
            assert_eq!(pts.len(), 2);
            for p in &pts {
                p.cfg.validate().unwrap();
                assert_eq!(p.cfg.h_q, 64 / tp);
                assert_eq!(p.cfg.h_k, 8 / tp);
                assert_eq!(p.cfg.group(), 8, "GQA ratio survives sharding");
                assert!(p.label.ends_with(&format!("tp={tp}")), "{}", p.label);
            }
        }
        // tp = 1 is the unsharded sweep with a tp suffix.
        let base = gqa8_decode_sweep(&[16384], &[1], &[2]);
        let tp1 = sharded_gqa8_decode_sweep(1, &[16384], &[1], &[2]);
        assert_eq!(base[0].cfg, tp1[0].cfg);
    }

    #[test]
    fn mha_decode_sweep_shape() {
        for p in mha_decode_sweep(&[16384], &[1, 8], &[2]) {
            assert_eq!(p.cfg.h_q, p.cfg.h_k);
            assert_eq!(p.cfg.d_head, 128);
        }
    }
}
