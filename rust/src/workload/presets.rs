//! Model presets from the paper's Table 3 (plus the MHA sweep shapes).

use crate::attn::AttnConfig;

/// A named model attention configuration (paper Table 3).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelPreset {
    /// Preset name (Table 3 row).
    pub name: String,
    /// Query heads.
    pub h_q: usize,
    /// KV heads.
    pub h_k: usize,
    /// Head dimension.
    pub d_head: usize,
    /// True for grouped-query attention.
    pub gqa: bool,
}

impl ModelPreset {
    /// Attention config at a given batch size and context length.
    pub fn attn(&self, batch: usize, n_ctx: usize) -> AttnConfig {
        AttnConfig::gqa(batch, self.h_q, self.h_k, n_ctx, self.d_head)
    }
}

/// Llama-3 8B: GQA, H_Q=32, H_K=8, D=128.
pub fn llama3_8b() -> ModelPreset {
    ModelPreset { name: "llama3-8b".into(), h_q: 32, h_k: 8, d_head: 128, gqa: true }
}

/// Llama-3 70B: GQA, H_Q=64, H_K=8, D=128.
pub fn llama3_70b() -> ModelPreset {
    ModelPreset { name: "llama3-70b".into(), h_q: 64, h_k: 8, d_head: 128, gqa: true }
}

/// Llama-3 405B: GQA, H_Q=128, H_K=8, D=128.
pub fn llama3_405b() -> ModelPreset {
    ModelPreset { name: "llama3-405b".into(), h_q: 128, h_k: 8, d_head: 128, gqa: true }
}

/// DeepSeek-V3 prefill: MHA, H_Q=H_K=128, D=56 (paper Sec. 4.5).
pub fn deepseek_v3() -> ModelPreset {
    ModelPreset { name: "deepseek-v3".into(), h_q: 128, h_k: 128, d_head: 56, gqa: false }
}

/// Preset lookup by name.
pub fn by_name(name: &str) -> Option<ModelPreset> {
    match name {
        "llama3-8b" => Some(llama3_8b()),
        "llama3-70b" => Some(llama3_70b()),
        "llama3-405b" => Some(llama3_405b()),
        "deepseek-v3" => Some(deepseek_v3()),
        _ => None,
    }
}

/// Every model preset (Table 3).
pub fn all() -> Vec<ModelPreset> {
    vec![llama3_8b(), llama3_70b(), llama3_405b(), deepseek_v3()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_rows() {
        let l8 = llama3_8b();
        assert_eq!((l8.h_q, l8.h_k, l8.d_head), (32, 8, 128));
        let l70 = llama3_70b();
        assert_eq!((l70.h_q, l70.h_k, l70.d_head), (64, 8, 128));
        let l405 = llama3_405b();
        assert_eq!((l405.h_q, l405.h_k, l405.d_head), (128, 8, 128));
        let ds = deepseek_v3();
        assert_eq!((ds.h_q, ds.h_k, ds.d_head), (128, 128, 56));
        assert!(!ds.gqa);
    }

    #[test]
    fn attn_config_roundtrip() {
        let cfg = llama3_70b().attn(2, 8192);
        cfg.validate().unwrap();
        assert_eq!(cfg.group(), 8);
        assert_eq!(cfg.batch, 2);
    }

    #[test]
    fn lookup() {
        for p in all() {
            assert_eq!(by_name(&p.name).unwrap(), p);
        }
        assert!(by_name("gpt-5").is_none());
    }
}
