//! Load-replay traces for the serving loops (docs/SERVING.md §8):
//! a small line-based `.trace` text format holding an explicit arrival
//! schedule — one `arrival_sec prefill decode [shared] [slo]` row per
//! session — plus seeded generators for the two non-stationary shapes
//! the ROADMAP's scenario-pack item calls out (bursty square-wave and
//! diurnal sinusoid arrival rates, sampled by Poisson thinning).
//!
//! The [`SessionSource`] trait is the seam: [`SessionGenerator`] (the
//! historical stationary-Poisson stream) and [`TraceReplay`] (an
//! explicit schedule, parsed from a file or built by a
//! [`TraceSpec`]) are interchangeable everywhere the serving loops
//! consume sessions. Replay is exact: [`TraceReplay::render`] writes
//! `arrival_sec` with Rust's shortest-round-trip float formatting, so
//! parsing a rendered trace reproduces every `f64` bit-for-bit — the
//! "replayed generator trace ≡ generated trace" golden pin.

use crate::util::rng::SplitMix64;
use crate::workload::requests::{Session, SessionGenerator, SloClass};

/// Anything the serving loops can draw an arrival-ordered session
/// stream from. [`SessionGenerator`] draws sessions lazily from its
/// seeded streams; [`TraceReplay`] hands out a pre-built schedule.
pub trait SessionSource {
    /// The next `n` sessions, arrival-ordered. A finite source (a
    /// trace) returns fewer than `n` once exhausted.
    fn take_sessions(&mut self, n: usize) -> Vec<Session>;
}

impl SessionSource for SessionGenerator {
    fn take_sessions(&mut self, n: usize) -> Vec<Session> {
        SessionGenerator::take(self, n)
    }
}

/// An explicit session schedule replayed verbatim: the in-memory form
/// of a `.trace` file. Construction assigns ids in row order (0..n),
/// exactly like a generator would.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceReplay {
    sessions: Vec<Session>,
    cursor: usize,
}

impl TraceReplay {
    /// Wrap an arrival-ordered session list, re-assigning ids in row
    /// order so a trace's identity is its rows, not its provenance.
    pub fn new(mut sessions: Vec<Session>) -> Self {
        for (i, s) in sessions.iter_mut().enumerate() {
            s.id = i as u64;
        }
        TraceReplay { sessions, cursor: 0 }
    }

    /// The full schedule (row order).
    pub fn sessions(&self) -> &[Session] {
        &self.sessions
    }

    /// Number of sessions in the trace.
    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    /// True when the trace holds no sessions.
    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Parse the `.trace` text format: one whitespace-separated
    /// `arrival_sec prefill decode [shared] [slo]` row per line, `#`
    /// starting a comment, blank lines ignored. `shared` (leading
    /// prompt tokens on the canonical shared prefix) defaults to 0;
    /// `slo` is `interactive` or `batch` (default). Arrivals must be
    /// finite, non-negative, and non-decreasing; prefill and decode
    /// must be positive. Errors name the offending line.
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut sessions = Vec::new();
        let mut prev_arrival = 0.0f64;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let at = |msg: String| format!("trace line {}: {msg}", lineno + 1);
            let fields: Vec<&str> = line.split_whitespace().collect();
            if fields.len() < 3 || fields.len() > 5 {
                return Err(at(format!(
                    "expected 'arrival_sec prefill decode [shared] [slo]', got {} fields",
                    fields.len()
                )));
            }
            let arrival_sec: f64 = fields[0]
                .parse()
                .map_err(|_| at(format!("bad arrival_sec {:?}", fields[0])))?;
            if !arrival_sec.is_finite() || arrival_sec < 0.0 {
                return Err(at(format!("arrival_sec must be finite and >= 0, got {arrival_sec}")));
            }
            if arrival_sec < prev_arrival {
                return Err(at(format!(
                    "arrivals must be non-decreasing ({arrival_sec} < {prev_arrival})"
                )));
            }
            prev_arrival = arrival_sec;
            let uint = |what: &str, s: &str| -> Result<usize, String> {
                let v: usize = s.parse().map_err(|_| at(format!("bad {what} {s:?}")))?;
                Ok(v)
            };
            let prefill = uint("prefill", fields[1])?;
            let decode_tokens = uint("decode", fields[2])?;
            if prefill == 0 || decode_tokens == 0 {
                return Err(at("prefill and decode must be > 0".into()));
            }
            let shared_prefix = match fields.get(3) {
                Some(s) => uint("shared", s)?,
                None => 0,
            };
            if shared_prefix > prefill {
                return Err(at(format!(
                    "shared prefix {shared_prefix} exceeds prefill {prefill}"
                )));
            }
            let slo = match fields.get(4) {
                Some(&"interactive") => SloClass::Interactive,
                Some(&"batch") | None => SloClass::Batch,
                Some(other) => {
                    return Err(at(format!(
                        "bad slo class {other:?} (expected 'interactive' or 'batch')"
                    )))
                }
            };
            sessions.push(Session {
                id: sessions.len() as u64,
                arrival_sec,
                prefill,
                decode_tokens,
                shared_prefix,
                slo,
            });
        }
        Ok(TraceReplay { sessions, cursor: 0 })
    }

    /// Render the canonical `.trace` text of this schedule. Arrivals
    /// use Rust's shortest-round-trip `f64` formatting, so
    /// `parse(render(t))` reproduces `t`'s sessions bit-for-bit — the
    /// mechanism behind the replayed-≡-generated golden pin. Optional
    /// columns are emitted only when a later column needs them.
    pub fn render(&self) -> String {
        let mut out = String::from("# arrival_sec prefill decode [shared] [slo]\n");
        for s in &self.sessions {
            out.push_str(&format!("{} {} {}", s.arrival_sec, s.prefill, s.decode_tokens));
            let interactive = s.slo == SloClass::Interactive;
            if s.shared_prefix > 0 || interactive {
                out.push_str(&format!(" {}", s.shared_prefix));
            }
            if interactive {
                out.push_str(" interactive");
            }
            out.push('\n');
        }
        out
    }
}

impl SessionSource for TraceReplay {
    fn take_sessions(&mut self, n: usize) -> Vec<Session> {
        let end = (self.cursor + n).min(self.sessions.len());
        let out = self.sessions[self.cursor..end].to_vec();
        self.cursor = end;
        out
    }
}

/// Shape of a generated trace's arrival-rate curve.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceShape {
    /// Square wave: the rate sits at `peak_per_sec` for the leading
    /// `duty_pct`% of every period, at `base_per_sec` otherwise — the
    /// on/off burst regime.
    Bursty,
    /// Raised sinusoid: the rate sweeps smoothly from `base_per_sec`
    /// up to `peak_per_sec` and back once per period — the day/night
    /// load curve, compressed.
    Diurnal,
}

impl TraceShape {
    /// Stable lowercase identifier (`bursty` / `diurnal`), as written
    /// in `[trace] shape` and the CLI.
    pub fn name(&self) -> &'static str {
        match self {
            TraceShape::Bursty => "bursty",
            TraceShape::Diurnal => "diurnal",
        }
    }

    /// Parse the identifier form.
    pub fn from_name(s: &str) -> Result<Self, String> {
        match s {
            "bursty" => Ok(TraceShape::Bursty),
            "diurnal" => Ok(TraceShape::Diurnal),
            other => Err(format!("unknown trace shape {other:?} (bursty | diurnal)")),
        }
    }
}

/// A seeded non-stationary trace generator: everything needed to build
/// a [`TraceReplay`] with a bursty or diurnal arrival-rate curve.
/// Arrivals are sampled by Poisson thinning at `peak_per_sec` (draw
/// candidate gaps at the peak rate, accept each with probability
/// `rate(t) / peak`), so the schedule is exactly reproducible from the
/// seed. Prompt/decode/sharing/SLO draws follow the
/// [`SessionGenerator`] discipline: the shared-prefix and SLO draws
/// ride separate streams, so toggling them never perturbs arrivals.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    /// Arrival-rate curve shape.
    pub shape: TraceShape,
    /// Generator seed.
    pub seed: u64,
    /// Number of sessions to emit.
    pub sessions: usize,
    /// Off-burst / trough arrival rate (sessions per second).
    pub base_per_sec: f64,
    /// Burst / crest arrival rate (sessions per second).
    pub peak_per_sec: f64,
    /// Length of one rate cycle in seconds.
    pub period_sec: f64,
    /// [`TraceShape::Bursty`] only: the leading percentage of each
    /// period spent at the peak rate (ignored by `Diurnal`).
    pub duty_pct: f64,
    /// Prompt-length mix (uniformly sampled).
    pub prefill_lengths: Vec<usize>,
    /// Decode-budget mix (uniformly sampled).
    pub decode_tokens: Vec<usize>,
    /// Percentage of sessions starting on the canonical shared prefix.
    pub share_pct: f64,
    /// Shared-prefix span in tokens (clamped to the prompt).
    pub share_span: usize,
    /// Percentage of sessions in the interactive SLO class.
    pub interactive_pct: f64,
}

impl Default for TraceSpec {
    fn default() -> Self {
        TraceSpec {
            shape: TraceShape::Bursty,
            seed: 7,
            sessions: 16,
            base_per_sec: 40.0,
            peak_per_sec: 400.0,
            period_sec: 0.25,
            duty_pct: 25.0,
            prefill_lengths: vec![2048, 8192],
            decode_tokens: vec![32, 128],
            share_pct: 0.0,
            share_span: 0,
            interactive_pct: 0.0,
        }
    }
}

impl TraceSpec {
    /// Check every parameter, returning an actionable message instead
    /// of panicking on user-supplied INI/flag values.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.base_per_sec > 0.0) || !self.base_per_sec.is_finite() {
            return Err(format!("[trace] base_per_sec must be > 0, got {}", self.base_per_sec));
        }
        if !(self.peak_per_sec >= self.base_per_sec) || !self.peak_per_sec.is_finite() {
            return Err(format!(
                "[trace] peak_per_sec must be >= base_per_sec ({}), got {}",
                self.base_per_sec, self.peak_per_sec
            ));
        }
        if !(self.period_sec > 0.0) || !self.period_sec.is_finite() {
            return Err(format!("[trace] period_sec must be > 0, got {}", self.period_sec));
        }
        if !(0.0..=100.0).contains(&self.duty_pct) {
            return Err(format!("[trace] duty_pct must be in [0, 100], got {}", self.duty_pct));
        }
        if self.sessions == 0 {
            return Err("[trace] sessions must be > 0".into());
        }
        if self.prefill_lengths.is_empty() || self.prefill_lengths.contains(&0) {
            return Err("[trace] prefill mix must be non-empty with positive entries".into());
        }
        if self.decode_tokens.is_empty() || self.decode_tokens.contains(&0) {
            return Err("[trace] decode mix must be non-empty with positive entries".into());
        }
        if !(0.0..=100.0).contains(&self.share_pct) {
            return Err(format!("[trace] share_pct must be in [0, 100], got {}", self.share_pct));
        }
        if !(0.0..=100.0).contains(&self.interactive_pct) {
            return Err(format!(
                "[trace] interactive_pct must be in [0, 100], got {}",
                self.interactive_pct
            ));
        }
        Ok(())
    }

    /// The instantaneous arrival rate at trace time `t` seconds.
    pub fn rate_at(&self, t: f64) -> f64 {
        let phase = (t / self.period_sec).fract();
        match self.shape {
            TraceShape::Bursty => {
                if phase * 100.0 < self.duty_pct {
                    self.peak_per_sec
                } else {
                    self.base_per_sec
                }
            }
            TraceShape::Diurnal => {
                let swing = 0.5 * (1.0 - (2.0 * std::f64::consts::PI * phase).cos());
                self.base_per_sec + (self.peak_per_sec - self.base_per_sec) * swing
            }
        }
    }

    /// Generate the schedule. Panics only on an invalid spec — callers
    /// holding user input run [`Self::validate`] first.
    pub fn generate(&self) -> TraceReplay {
        self.validate().expect("valid trace spec");
        let mut rng = SplitMix64::new(self.seed);
        let mut share_rng = SplitMix64::new(self.seed ^ 0xA5A5_5A5A_D00D_F00D);
        let mut slo_rng = SplitMix64::new(self.seed ^ 0xA11C_E5ED_5105_C1A5);
        let mut clock = 0.0f64;
        let mut sessions = Vec::with_capacity(self.sessions);
        while sessions.len() < self.sessions {
            // Thinning: candidate arrivals at the peak rate, accepted
            // with probability rate(t)/peak. Both draws come from the
            // main stream so the arrival schedule is one frozen
            // function of the seed.
            let u = rng.next_f64();
            clock += -(1.0 - u).ln() / self.peak_per_sec;
            if rng.next_f64() * self.peak_per_sec >= self.rate_at(clock) {
                continue;
            }
            let prefill = *rng.choose(&self.prefill_lengths);
            let decode = *rng.choose(&self.decode_tokens);
            let shared_prefix =
                if self.share_pct > 0.0 && share_rng.next_f64() * 100.0 < self.share_pct {
                    self.share_span.min(prefill)
                } else {
                    0
                };
            let slo = if self.interactive_pct > 0.0
                && slo_rng.next_f64() * 100.0 < self.interactive_pct
            {
                SloClass::Interactive
            } else {
                SloClass::Batch
            };
            sessions.push(Session {
                id: sessions.len() as u64,
                arrival_sec: clock,
                prefill,
                decode_tokens: decode,
                shared_prefix,
                slo,
            });
        }
        TraceReplay { sessions, cursor: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> TraceSpec {
        TraceSpec {
            sessions: 64,
            share_pct: 50.0,
            share_span: 1024,
            interactive_pct: 25.0,
            ..TraceSpec::default()
        }
    }

    #[test]
    fn render_parse_round_trips_bit_for_bit() {
        // The golden-pin mechanism: shortest-round-trip f64 formatting
        // means a rendered trace parses back to the exact sessions.
        for shape in [TraceShape::Bursty, TraceShape::Diurnal] {
            let t = TraceSpec { shape, ..spec() }.generate();
            let back = TraceReplay::parse(&t.render()).unwrap();
            assert_eq!(t.sessions().len(), back.sessions().len());
            for (a, b) in t.sessions().iter().zip(back.sessions()) {
                assert_eq!(a.arrival_sec.to_bits(), b.arrival_sec.to_bits(), "{shape:?}");
                assert_eq!(a, b, "{shape:?}");
            }
        }
    }

    #[test]
    fn parse_handles_comments_defaults_and_errors() {
        let t = TraceReplay::parse(
            "# header\n\
             0.5 1024 16\n\
             0.75 2048 32 512   # inline comment\n\
             \n\
             1.0 4096 64 0 interactive\n",
        )
        .unwrap();
        assert_eq!(t.len(), 3);
        assert_eq!(t.sessions()[0].shared_prefix, 0);
        assert_eq!(t.sessions()[0].slo, SloClass::Batch);
        assert_eq!(t.sessions()[1].shared_prefix, 512);
        assert_eq!(t.sessions()[2].slo, SloClass::Interactive);
        assert_eq!(t.sessions().iter().map(|s| s.id).collect::<Vec<_>>(), vec![0, 1, 2]);

        for (bad, needle) in [
            ("1.0 1024", "got 2 fields"),
            ("x 1024 16", "bad arrival_sec"),
            ("-1 1024 16", ">= 0"),
            ("2.0 1024 16\n1.0 1024 16", "non-decreasing"),
            ("1.0 0 16", "must be > 0"),
            ("1.0 1024 16 2048", "exceeds prefill"),
            ("1.0 1024 16 0 gold", "bad slo class"),
            ("inf 1024 16", "finite"),
        ] {
            let err = TraceReplay::parse(bad).unwrap_err();
            assert!(err.contains(needle), "{bad:?} -> {err}");
            assert!(err.contains("trace line"), "{err}");
        }
    }

    #[test]
    fn generated_shapes_are_deterministic_and_bursty_clusters() {
        let a = spec().generate();
        let b = spec().generate();
        assert_eq!(a, b);
        for w in a.sessions().windows(2) {
            assert!(w[0].arrival_sec <= w[1].arrival_sec);
        }
        // The burst carries most arrivals: sessions landing in the
        // leading duty window of their period outnumber the rest even
        // though the window covers only 25% of each period.
        let s = spec();
        let in_burst = a
            .sessions()
            .iter()
            .filter(|x| (x.arrival_sec / s.period_sec).fract() * 100.0 < s.duty_pct)
            .count();
        assert!(in_burst * 2 > a.len(), "{in_burst}/{} arrivals in the 25% burst", a.len());
        // Optional draws behave like the generator's: spans clamp,
        // classes only appear when enabled.
        assert!(a.sessions().iter().all(|x| x.shared_prefix <= x.prefill));
        assert!(a.sessions().iter().any(|x| x.slo == SloClass::Interactive));
        let plain = TraceSpec { share_pct: 0.0, interactive_pct: 0.0, ..spec() }.generate();
        assert!(plain.sessions().iter().all(|x| x.shared_prefix == 0));
        assert!(plain.sessions().iter().all(|x| x.slo == SloClass::Batch));
        // Toggling the optional draws never perturbs the arrivals.
        for (p, q) in plain.sessions().iter().zip(a.sessions()) {
            assert_eq!(p.arrival_sec.to_bits(), q.arrival_sec.to_bits());
            assert_eq!((p.prefill, p.decode_tokens), (q.prefill, q.decode_tokens));
        }
    }

    #[test]
    fn diurnal_rate_sweeps_between_base_and_peak() {
        let s = TraceSpec { shape: TraceShape::Diurnal, ..spec() };
        assert!((s.rate_at(0.0) - s.base_per_sec).abs() < 1e-9);
        let crest = s.rate_at(s.period_sec / 2.0);
        assert!((crest - s.peak_per_sec).abs() < 1e-6 * s.peak_per_sec);
        for i in 0..100 {
            let r = s.rate_at(i as f64 * s.period_sec / 100.0);
            assert!(r >= s.base_per_sec - 1e-9 && r <= s.peak_per_sec + 1e-9);
        }
    }

    #[test]
    fn session_sources_are_interchangeable() {
        // The trait seam: a generator and a replay of its output hand
        // the loop identical sessions, in identical chunks.
        let mut g = SessionGenerator::new(11, 100.0, vec![1024, 4096], vec![16, 64]);
        let all = g.clone().take(10);
        let mut replay = TraceReplay::new(all.clone());
        let via_gen: Vec<Session> = SessionSource::take_sessions(&mut g, 10);
        let via_replay = replay.take_sessions(10);
        assert_eq!(via_gen, all);
        assert_eq!(via_replay, all);
        // A finite source drains: further takes are empty.
        assert!(replay.take_sessions(5).is_empty());
        // Partial takes chunk without loss.
        let mut r2 = TraceReplay::new(all.clone());
        let mut parts = r2.take_sessions(3);
        parts.extend(r2.take_sessions(100));
        assert_eq!(parts, all);
        assert!(!r2.is_empty());
        assert_eq!(r2.len(), 10);
    }

    #[test]
    fn shape_names_round_trip() {
        for shape in [TraceShape::Bursty, TraceShape::Diurnal] {
            assert_eq!(TraceShape::from_name(shape.name()).unwrap(), shape);
        }
        assert!(TraceShape::from_name("weekly").unwrap_err().contains("unknown trace shape"));
    }

    #[test]
    fn spec_validate_rejects_each_bad_field() {
        assert!(spec().validate().is_ok());
        let cases: Vec<(TraceSpec, &str)> = vec![
            (TraceSpec { base_per_sec: 0.0, ..spec() }, "base_per_sec"),
            (TraceSpec { peak_per_sec: 1.0, ..spec() }, "peak_per_sec"),
            (TraceSpec { period_sec: 0.0, ..spec() }, "period_sec"),
            (TraceSpec { duty_pct: 101.0, ..spec() }, "duty_pct"),
            (TraceSpec { sessions: 0, ..spec() }, "sessions"),
            (TraceSpec { prefill_lengths: vec![], ..spec() }, "prefill"),
            (TraceSpec { decode_tokens: vec![0], ..spec() }, "decode"),
            (TraceSpec { share_pct: -1.0, ..spec() }, "share_pct"),
            (TraceSpec { interactive_pct: 200.0, ..spec() }, "interactive_pct"),
        ];
        for (bad, needle) in cases {
            let err = bad.validate().unwrap_err();
            assert!(err.contains(needle), "{err}");
        }
    }
}
