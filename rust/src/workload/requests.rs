//! Serving request generation for the coordinator: deterministic,
//! seedable streams of prefill requests with mixed context lengths —
//! the workload of `examples/serve_attention.rs` and the coordinator
//! benches — plus the [`Session`] abstraction the continuous-batching
//! decode loop serves (docs/SERVING.md): a prompt to prefill followed by
//! a fixed number of decode steps, arriving on a Poisson-ish seeded
//! schedule ([`SessionGenerator`]).

use crate::util::rng::SplitMix64;

/// One attention prefill request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    /// Unique request id.
    pub id: u64,
    /// Context length of the prompt (tokens).
    pub n_ctx: usize,
    /// Deterministic input seed (see runtime::inputs).
    pub seed: u64,
}

/// Deterministic request generator (splitmix64-based).
#[derive(Debug, Clone)]
pub struct RequestGenerator {
    state: u64,
    next_id: u64,
    /// Allowed context lengths (requests are bucketed to these).
    pub lengths: Vec<usize>,
}

impl RequestGenerator {
    /// A deterministic generator over the given bucket lengths.
    pub fn new(seed: u64, lengths: Vec<usize>) -> Self {
        assert!(!lengths.is_empty());
        RequestGenerator { state: seed, next_id: 0, lengths }
    }

    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Generate the next request.
    pub fn next_request(&mut self) -> Request {
        let r = self.next_u64();
        let n_ctx = self.lengths[(r % self.lengths.len() as u64) as usize];
        let id = self.next_id;
        self.next_id += 1;
        Request { id, n_ctx, seed: r | 1 }
    }

    /// Generate `n` requests.
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }
}

/// Service-level-objective class of a serving session (docs/DISAGG.md):
/// how urgently its first token is needed. The disaggregated scheduler
/// admits `Interactive` sessions ahead of `Batch` ones and may preempt
/// batch prefill chunks to protect the interactive TTFT tail; the
/// historical colocated loop ignores the class entirely.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SloClass {
    /// Latency-sensitive (chat-style) traffic: tight TTFT objective.
    Interactive,
    /// Throughput-oriented (summarization/eval-style) traffic: no TTFT
    /// objective. The default class — a generator with SLO classes
    /// disabled emits only `Batch` sessions.
    Batch,
}

impl SloClass {
    /// Stable lowercase identifier (JSON/logs).
    pub fn name(&self) -> &'static str {
        match self {
            SloClass::Interactive => "interactive",
            SloClass::Batch => "batch",
        }
    }

    /// Admission priority rank: lower admits first.
    pub fn rank(&self) -> u8 {
        match self {
            SloClass::Interactive => 0,
            SloClass::Batch => 1,
        }
    }
}

/// One decode serving session: a prompt that is prefilled once, then
/// `decode_tokens` iteration-level decode steps over a KV cache that
/// grows by one token per step. Sessions are what the continuous-batching
/// loop ([`crate::coordinator::serve_decode`]) admits, batches, and
/// retires (docs/SERVING.md describes the full lifecycle).
#[derive(Debug, Clone, PartialEq)]
pub struct Session {
    /// Unique session id (monotonic per generator).
    pub id: u64,
    /// Simulated arrival time in seconds since the trace start.
    pub arrival_sec: f64,
    /// Prompt length in tokens (the prefill cost and the KV cache's
    /// starting length).
    pub prefill: usize,
    /// Decode tokens to generate before the session finishes.
    pub decode_tokens: usize,
    /// Leading prompt tokens drawn from the canonical shared prefix
    /// (system prompt / few-shot preamble). 0 = a fully private prompt.
    /// Only the paged KV pool reads this (docs/KVCACHE.md); the prefill
    /// and decode cost model sees `prefill` regardless.
    pub shared_prefix: usize,
    /// The session's SLO class. Only the disaggregated scheduler reads
    /// this (docs/DISAGG.md); [`SloClass::Batch`] everywhere the class
    /// draw is disabled.
    pub slo: SloClass,
}

impl Session {
    /// KV-cache length after `generated` decode steps, clamped to the
    /// serving deployment's KV capacity.
    pub fn kv_len(&self, generated: usize, kv_cap: usize) -> usize {
        (self.prefill + generated).max(1).min(kv_cap.max(1))
    }
}

/// Deterministic session-trace generator: Poisson-ish arrivals
/// (exponential inter-arrival times from a seeded [`SplitMix64`]) with
/// prompt lengths and decode budgets drawn uniformly from caller-supplied
/// mixes. Identical seeds and mixes produce identical traces, which is
/// what makes the serving report reproducible bit-for-bit.
#[derive(Debug, Clone)]
pub struct SessionGenerator {
    rng: SplitMix64,
    /// Separate stream for the shared-prefix draw, so switching prefix
    /// sharing on or off never perturbs the arrival/prompt/decode
    /// trace — the sharing-disabled golden pins and the shared-vs-
    /// private bench twins depend on the traces being identical.
    share_rng: SplitMix64,
    share_pct: f64,
    share_span: usize,
    /// Separate stream for the SLO-class draw, same discipline as
    /// `share_rng`: enabling SLO classes never perturbs the
    /// arrival/prompt/decode/sharing trace, which is what keeps the
    /// no-SLO disagg golden pins byte-identical to historical serving.
    slo_rng: SplitMix64,
    slo_pct: f64,
    next_id: u64,
    clock_sec: f64,
    arrival_per_sec: f64,
    /// Prompt-length mix (uniformly sampled).
    pub prefill_lengths: Vec<usize>,
    /// Decode-budget mix (uniformly sampled).
    pub decode_tokens: Vec<usize>,
}

impl SessionGenerator {
    /// A seeded generator with the given arrival rate (sessions per
    /// simulated second) and session mix. Both mixes must be non-empty
    /// and the arrival rate positive.
    pub fn new(
        seed: u64,
        arrival_per_sec: f64,
        prefill_lengths: Vec<usize>,
        decode_tokens: Vec<usize>,
    ) -> Self {
        assert!(arrival_per_sec > 0.0, "arrival rate must be > 0");
        assert!(!prefill_lengths.is_empty() && !decode_tokens.is_empty());
        SessionGenerator {
            rng: SplitMix64::new(seed),
            share_rng: SplitMix64::new(seed ^ 0xA5A5_5A5A_D00D_F00D),
            share_pct: 0.0,
            share_span: 0,
            slo_rng: SplitMix64::new(seed ^ 0xA11C_E5ED_5105_C1A5),
            slo_pct: 0.0,
            next_id: 0,
            clock_sec: 0.0,
            arrival_per_sec,
            prefill_lengths,
            decode_tokens,
        }
    }

    /// Enable prefix sharing: each generated session draws (from the
    /// dedicated stream) whether it starts with the canonical shared
    /// prefix of `span` tokens, with probability `pct` percent. The
    /// draw happens only when `pct > 0`, so a sharing-disabled
    /// generator emits the exact trace it always did.
    pub fn with_prefix_sharing(mut self, pct: f64, span: usize) -> Self {
        assert!((0.0..=100.0).contains(&pct), "share pct must be in [0, 100]");
        self.share_pct = pct;
        self.share_span = span;
        self
    }

    /// Enable SLO classes: each generated session draws (from the
    /// dedicated stream) whether it is [`SloClass::Interactive`], with
    /// probability `pct` percent; the rest are [`SloClass::Batch`]. The
    /// draw happens only when `pct > 0`, so a class-disabled generator
    /// emits the exact trace it always did (all-batch).
    pub fn with_slo_classes(mut self, pct: f64) -> Self {
        assert!((0.0..=100.0).contains(&pct), "interactive pct must be in [0, 100]");
        self.slo_pct = pct;
        self
    }

    /// Generate the next session. Arrival times are non-decreasing: each
    /// call advances the trace clock by an exponential inter-arrival gap
    /// with mean `1 / arrival_per_sec`.
    pub fn next_session(&mut self) -> Session {
        // Inverse-CDF sampling; 1 - u is in (0, 1] so ln() is finite.
        let u = self.rng.next_f64();
        self.clock_sec += -(1.0 - u).ln() / self.arrival_per_sec;
        let prefill = *self.rng.choose(&self.prefill_lengths);
        let decode = *self.rng.choose(&self.decode_tokens);
        let shared_prefix = if self.share_pct > 0.0
            && self.share_rng.next_f64() * 100.0 < self.share_pct
        {
            self.share_span.min(prefill)
        } else {
            0
        };
        let slo = if self.slo_pct > 0.0 && self.slo_rng.next_f64() * 100.0 < self.slo_pct {
            SloClass::Interactive
        } else {
            SloClass::Batch
        };
        let id = self.next_id;
        self.next_id += 1;
        Session {
            id,
            arrival_sec: self.clock_sec,
            prefill,
            decode_tokens: decode,
            shared_prefix,
            slo,
        }
    }

    /// Generate a trace of `n` sessions (arrival-ordered).
    pub fn take(&mut self, n: usize) -> Vec<Session> {
        (0..n).map(|_| self.next_session()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = RequestGenerator::new(7, vec![128, 256]);
        let mut b = RequestGenerator::new(7, vec![128, 256]);
        assert_eq!(a.take(10), b.take(10));
    }

    #[test]
    fn sessions_deterministic_and_arrival_ordered() {
        let mk = || SessionGenerator::new(11, 100.0, vec![1024, 4096], vec![16, 64]);
        let a = mk().take(50);
        let b = mk().take(50);
        assert_eq!(a, b);
        for (i, s) in a.iter().enumerate() {
            assert_eq!(s.id, i as u64);
            assert!(s.prefill == 1024 || s.prefill == 4096);
            assert!(s.decode_tokens == 16 || s.decode_tokens == 64);
        }
        for w in a.windows(2) {
            assert!(w[0].arrival_sec <= w[1].arrival_sec, "arrivals ordered");
        }
        // Both mix entries occur over 50 draws.
        assert!(a.iter().any(|s| s.prefill == 1024) && a.iter().any(|s| s.prefill == 4096));
        // Mean inter-arrival roughly matches 1/rate (loose band).
        let mean = a.last().unwrap().arrival_sec / 50.0;
        assert!((0.002..0.05).contains(&mean), "mean inter-arrival {mean}");
    }

    #[test]
    fn prefix_sharing_rides_a_separate_stream() {
        // Enabling sharing must not perturb the base trace: arrivals,
        // prompts, and decode budgets are identical with and without it
        // (the sharing-disabled golden pins depend on this).
        let base = SessionGenerator::new(11, 100.0, vec![1024, 4096], vec![16, 64]).take(200);
        let shared = SessionGenerator::new(11, 100.0, vec![1024, 4096], vec![16, 64])
            .with_prefix_sharing(80.0, 1024)
            .take(200);
        for (a, b) in base.iter().zip(&shared) {
            assert_eq!((a.id, a.prefill, a.decode_tokens), (b.id, b.prefill, b.decode_tokens));
            assert_eq!(a.arrival_sec.to_bits(), b.arrival_sec.to_bits());
            assert_eq!(a.shared_prefix, 0, "pct = 0 never marks a session shared");
            assert!(b.shared_prefix == 0 || b.shared_prefix == 1024);
        }
        // The share rate lands near the configured percentage, and the
        // span clamps to the prompt (never exceeds it).
        let hits = shared.iter().filter(|s| s.shared_prefix > 0).count();
        assert!((120..=200).contains(&hits), "~80% of 200 sessions share, got {hits}");
        assert!(shared.iter().all(|s| s.shared_prefix <= s.prefill));
        // 0% and 100% are exact.
        let all = SessionGenerator::new(5, 100.0, vec![512], vec![8])
            .with_prefix_sharing(100.0, 4096)
            .take(50);
        assert!(all.iter().all(|s| s.shared_prefix == 512), "span clamps to prompt");
    }

    #[test]
    fn slo_classes_ride_a_separate_stream() {
        // Enabling SLO classes must not perturb the base trace — or the
        // prefix-sharing draws, which ride their own stream. The no-SLO
        // disagg golden pins depend on this.
        let base = SessionGenerator::new(11, 100.0, vec![1024, 4096], vec![16, 64])
            .with_prefix_sharing(50.0, 1024)
            .take(200);
        let classed = SessionGenerator::new(11, 100.0, vec![1024, 4096], vec![16, 64])
            .with_prefix_sharing(50.0, 1024)
            .with_slo_classes(30.0)
            .take(200);
        for (a, b) in base.iter().zip(&classed) {
            assert_eq!((a.id, a.prefill, a.decode_tokens), (b.id, b.prefill, b.decode_tokens));
            assert_eq!(a.arrival_sec.to_bits(), b.arrival_sec.to_bits());
            assert_eq!(a.shared_prefix, b.shared_prefix, "share stream undisturbed");
            assert_eq!(a.slo, SloClass::Batch, "pct = 0 emits only batch sessions");
        }
        // The interactive rate lands near the configured percentage.
        let hits = classed.iter().filter(|s| s.slo == SloClass::Interactive).count();
        assert!((30..=95).contains(&hits), "~30% of 200 sessions interactive, got {hits}");
        // 100% is exact, and ranks order interactive first.
        let all = SessionGenerator::new(5, 100.0, vec![512], vec![8])
            .with_slo_classes(100.0)
            .take(50);
        assert!(all.iter().all(|s| s.slo == SloClass::Interactive));
        assert!(SloClass::Interactive.rank() < SloClass::Batch.rank());
        assert_eq!(SloClass::Interactive.name(), "interactive");
        assert_eq!(SloClass::Batch.name(), "batch");
    }

    #[test]
    fn session_trace_pin_seed_11() {
        // The trace-compat pin behind `SplitMix64::gen_range`'s frozen
        // modulo mapping: the exact sessions a historical seed draws.
        // If this fails, every serving/cluster/disagg golden built on a
        // generated trace silently re-rolled. Prompt/decode picks are
        // exact (integer stream); arrivals allow 1 ulp-scale slack for
        // the platform ln().
        let got = SessionGenerator::new(11, 100.0, vec![1024, 4096], vec![16, 64]).take(8);
        let want = [
            (0.0038015472479826563, 4096, 64),
            (0.010825728101193569, 1024, 16),
            (0.011885051326241498, 1024, 16),
            (0.04340270740578941, 1024, 64),
            (0.06767290728748605, 4096, 64),
            (0.07049107688060997, 1024, 16),
            (0.08316236607424983, 1024, 16),
            (0.09997350446954167, 1024, 64),
        ];
        for (s, (arrival, prefill, decode)) in got.iter().zip(want) {
            assert_eq!((s.prefill, s.decode_tokens), (prefill, decode), "session {}", s.id);
            assert!(
                (s.arrival_sec - arrival).abs() < 1e-12,
                "session {}: arrival {} != pinned {arrival}",
                s.id,
                s.arrival_sec
            );
            assert_eq!((s.shared_prefix, s.slo), (0, SloClass::Batch));
        }
    }

    #[test]
    fn session_kv_len_grows_then_caps() {
        let s = Session {
            id: 0,
            arrival_sec: 0.0,
            prefill: 1000,
            decode_tokens: 10,
            shared_prefix: 0,
            slo: SloClass::Batch,
        };
        assert_eq!(s.kv_len(0, 4096), 1000);
        assert_eq!(s.kv_len(5, 4096), 1005);
        assert_eq!(s.kv_len(5000, 4096), 4096); // clamped to capacity
    }

    #[test]
    fn ids_monotonic_lengths_bucketed() {
        let mut g = RequestGenerator::new(1, vec![128, 256]);
        let reqs = g.take(100);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.n_ctx == 128 || r.n_ctx == 256);
        }
        // Both buckets occur.
        assert!(reqs.iter().any(|r| r.n_ctx == 128));
        assert!(reqs.iter().any(|r| r.n_ctx == 256));
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = RequestGenerator::new(1, vec![128, 256]);
        let mut b = RequestGenerator::new(2, vec![128, 256]);
        assert_ne!(a.take(20), b.take(20));
    }
}
