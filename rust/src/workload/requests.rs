//! Serving request generation for the coordinator: deterministic,
//! seedable streams of prefill requests with mixed context lengths —
//! the workload of `examples/serve_attention.rs` and the coordinator
//! benches.

/// One attention prefill request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    /// Unique request id.
    pub id: u64,
    /// Context length of the prompt (tokens).
    pub n_ctx: usize,
    /// Deterministic input seed (see runtime::inputs).
    pub seed: u64,
}

/// Deterministic request generator (splitmix64-based).
#[derive(Debug, Clone)]
pub struct RequestGenerator {
    state: u64,
    next_id: u64,
    /// Allowed context lengths (requests are bucketed to these).
    pub lengths: Vec<usize>,
}

impl RequestGenerator {
    /// A deterministic generator over the given bucket lengths.
    pub fn new(seed: u64, lengths: Vec<usize>) -> Self {
        assert!(!lengths.is_empty());
        RequestGenerator { state: seed, next_id: 0, lengths }
    }

    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Generate the next request.
    pub fn next_request(&mut self) -> Request {
        let r = self.next_u64();
        let n_ctx = self.lengths[(r % self.lengths.len() as u64) as usize];
        let id = self.next_id;
        self.next_id += 1;
        Request { id, n_ctx, seed: r | 1 }
    }

    /// Generate `n` requests.
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = RequestGenerator::new(7, vec![128, 256]);
        let mut b = RequestGenerator::new(7, vec![128, 256]);
        assert_eq!(a.take(10), b.take(10));
    }

    #[test]
    fn ids_monotonic_lengths_bucketed() {
        let mut g = RequestGenerator::new(1, vec![128, 256]);
        let reqs = g.take(100);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
            assert!(r.n_ctx == 128 || r.n_ctx == 256);
        }
        // Both buckets occur.
        assert!(reqs.iter().any(|r| r.n_ctx == 128));
        assert!(reqs.iter().any(|r| r.n_ctx == 256));
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = RequestGenerator::new(1, vec![128, 256]);
        let mut b = RequestGenerator::new(2, vec![128, 256]);
        assert_ne!(a.take(20), b.take(20));
    }
}
