//! Workload presets and sweep builders: the paper's Tables 2-3 plus a
//! request generator for the serving coordinator.

pub mod presets;
pub mod requests;
pub mod sweeps;

pub use presets::ModelPreset;
pub use requests::{Request, RequestGenerator};
