//! Workload presets and sweep builders: the paper's Tables 2-3 plus the
//! serving request/session generators for the coordinator (one-shot
//! prefill [`Request`]s and continuous-batching decode [`Session`]s).

pub mod presets;
pub mod requests;
pub mod sweeps;
pub mod trace;

pub use presets::ModelPreset;
pub use requests::{Request, RequestGenerator, Session, SessionGenerator, SloClass};
pub use trace::{SessionSource, TraceReplay, TraceShape, TraceSpec};
