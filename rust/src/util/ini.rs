//! Experiment-config file format: INI-style `[section]` + `key = value`
//! (the toml crate is unavailable offline; this covers the subset the
//! project needs — scalars only, `#`/`;` comments, no nesting).

use std::collections::HashMap;

#[derive(Debug, Clone, Default, PartialEq)]
/// A parsed INI document: `(section, key) -> value` with quotes
/// stripped; the pre-section prelude is section `""`.
pub struct Ini {
    /// section -> key -> raw value string. Top-level keys live under "".
    sections: HashMap<String, HashMap<String, String>>,
}

impl Ini {
    /// Parse INI text (comments `#`/`;`, `[sections]`, `key = value`).
    pub fn parse(text: &str) -> Result<Ini, String> {
        let mut sections: HashMap<String, HashMap<String, String>> = HashMap::new();
        let mut current = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') || line.starts_with(';') {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                current = name.trim().to_string();
                sections.entry(current.clone()).or_default();
                continue;
            }
            let Some((k, v)) = line.split_once('=') else {
                return Err(format!("line {}: expected 'key = value'", lineno + 1));
            };
            let v = v.trim().trim_matches('"').to_string();
            sections
                .entry(current.clone())
                .or_default()
                .insert(k.trim().to_string(), v);
        }
        Ok(Ini { sections })
    }

    /// Raw string value lookup.
    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    /// Typed value lookup (`None` when the key is absent).
    pub fn get_parsed<T: std::str::FromStr>(
        &self,
        section: &str,
        key: &str,
    ) -> Result<Option<T>, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(section, key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| format!("[{section}] {key} = '{v}': {e}")),
        }
    }

    /// True when the section header appeared.
    pub fn has_section(&self, section: &str) -> bool {
        self.sections.contains_key(section)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment
topology = "mi300x"

[attention]
batch = 2
h_q = 64
causal = true

[sim]
policy = shf
"#;

    #[test]
    fn parse_sections() {
        let ini = Ini::parse(SAMPLE).unwrap();
        assert_eq!(ini.get("", "topology"), Some("mi300x"));
        assert_eq!(ini.get_parsed::<usize>("attention", "batch").unwrap(), Some(2));
        assert_eq!(ini.get_parsed::<bool>("attention", "causal").unwrap(), Some(true));
        assert_eq!(ini.get("sim", "policy"), Some("shf"));
        assert_eq!(ini.get("sim", "nope"), None);
        assert!(ini.has_section("attention"));
        assert!(!ini.has_section("other"));
    }

    #[test]
    fn bad_line_rejected() {
        assert!(Ini::parse("not a kv line").is_err());
    }

    #[test]
    fn bad_parse_reports_location() {
        let ini = Ini::parse("[a]\nx = abc").unwrap();
        let err = ini.get_parsed::<usize>("a", "x").unwrap_err();
        assert!(err.contains("[a] x"), "{err}");
    }
}
