//! SplitMix64: small, fast, deterministic RNG used for simulator jitter,
//! workload generation, and the hand-rolled property tests.

#[derive(Debug, Clone)]
/// SplitMix64: a tiny deterministic PRNG (test-case generation,
/// launch staggers).
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seeded generator.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        mix(self.state)
    }

    /// Uniform in [0, n) (n > 0).
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Pick an element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len() as u64) as usize]
    }
}

/// Stateless hash of a u64 (the jitter function).
#[inline]
pub fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58476D1CE4E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_and_f64_bounds() {
        let mut r = SplitMix64::new(1);
        for _ in 0..1000 {
            assert!(r.gen_range(7) < 7);
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn roughly_uniform() {
        let mut r = SplitMix64::new(3);
        let mut counts = [0u32; 8];
        for _ in 0..8000 {
            counts[r.gen_range(8) as usize] += 1;
        }
        for c in counts {
            assert!((700..1300).contains(&c), "{counts:?}");
        }
    }
}
