//! SplitMix64: small, fast, deterministic RNG used for simulator jitter,
//! workload generation, and the hand-rolled property tests.

#[derive(Debug, Clone)]
/// SplitMix64: a tiny deterministic PRNG (test-case generation,
/// launch staggers).
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seeded generator.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        mix(self.state)
    }

    /// Uniform-ish in [0, n) (n > 0).
    ///
    /// **Frozen trace-compat guarantee:** this is a plain
    /// `next_u64() % n`, which carries the classic modulo bias (values
    /// below `2^64 mod n` are marginally more likely). The bias is
    /// negligible for the small `n` the workload generators use, but it
    /// is *observable*: every historical [`crate::workload::Session`]
    /// trace — and through them every serving/cluster/disagg golden
    /// pin — was drawn through this exact mapping. Changing it would
    /// silently re-roll all of those traces, so the modulo form is
    /// frozen here on purpose. New consumers that want exact uniformity
    /// (e.g. fault-plan draws) should use [`Self::gen_range_unbiased`]
    /// instead.
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Exactly uniform in [0, n) (n > 0), via rejection sampling.
    ///
    /// Unlike the trace-frozen [`Self::gen_range`], this discards draws
    /// from the biased tail (`x >= 2^64 - (2^64 mod n)`) and re-rolls,
    /// so every value in [0, n) is equally likely. It may consume more
    /// than one `next_u64()` per call (still deterministic for a given
    /// seed and call sequence), so it must never replace `gen_range` on
    /// a pinned stream. Use it for new randomness (fault plans).
    pub fn gen_range_unbiased(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 2^64 mod n, computed without overflowing u64. Draws at or
        // above 2^64 - rem land in the short final partial cycle of
        // `% n` (the biased tail) and are re-rolled.
        let rem = (u64::MAX % n + 1) % n;
        if rem == 0 {
            return self.next_u64() % n;
        }
        loop {
            let x = self.next_u64();
            if x <= u64::MAX - rem {
                return x % n;
            }
        }
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Pick an element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len() as u64) as usize]
    }
}

/// Stateless hash of a u64 (the jitter function).
#[inline]
pub fn mix(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58476D1CE4E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn range_and_f64_bounds() {
        let mut r = SplitMix64::new(1);
        for _ in 0..1000 {
            assert!(r.gen_range(7) < 7);
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn gen_range_is_the_frozen_modulo_mapping() {
        // The trace-compat guarantee in the rustdoc: gen_range must stay
        // exactly `next_u64() % n`, because every historical Session
        // trace (and every golden pin built on one) was drawn through
        // it. If this test fails, traces silently re-rolled.
        let mut a = SplitMix64::new(99);
        let mut b = SplitMix64::new(99);
        for n in [1u64, 3, 7, 10, 1 << 20, u64::MAX] {
            assert_eq!(a.gen_range(n), b.next_u64() % n);
        }
    }

    #[test]
    fn gen_range_unbiased_bounds_and_uniformity() {
        let mut r = SplitMix64::new(17);
        for _ in 0..1000 {
            assert!(r.gen_range_unbiased(7) < 7);
            assert_eq!(r.gen_range_unbiased(1), 0);
        }
        let mut counts = [0u32; 5];
        for _ in 0..5000 {
            counts[r.gen_range_unbiased(5) as usize] += 1;
        }
        for c in counts {
            assert!((800..1200).contains(&c), "{counts:?}");
        }
        // Powers of two never reject (2^64 mod 2^k = 0).
        let mut p = SplitMix64::new(17);
        let mut q = SplitMix64::new(17);
        for _ in 0..100 {
            assert_eq!(p.gen_range_unbiased(8), q.next_u64() % 8);
        }
    }

    #[test]
    fn roughly_uniform() {
        let mut r = SplitMix64::new(3);
        let mut counts = [0u32; 8];
        for _ in 0..8000 {
            counts[r.gen_range(8) as usize] += 1;
        }
        for c in counts {
            assert!((700..1300).contains(&c), "{counts:?}");
        }
    }
}
