//! Dependency-free utility layer.
//!
//! The offline build environment vendors only the `xla` crate's closure,
//! so the conveniences a production crate would pull from the ecosystem
//! are implemented here, small and fully tested:
//!
//! * [`json`] — minimal JSON parser/writer (manifest.json, `--json` output)
//! * [`args`] — CLI flag parsing (replaces clap)
//! * [`rng`] — SplitMix64 deterministic RNG (sim jitter, property tests)
//! * [`mod@bench`] — micro-benchmark harness (replaces criterion)
//! * [`oneshot`] — one-shot channel (replaces tokio::sync::oneshot)
//! * [`fxhash`] — fast u64 hasher for the simulator's hot maps
//! * [`ini`] — key=value experiment-config format (replaces toml)

pub mod args;
pub mod bench;
pub mod fxhash;
pub mod ini;
pub mod json;
pub mod oneshot;
pub mod rng;
