//! Tiny CLI argument parser: `--key value`, `--key=value`, boolean
//! `--flag`, and positionals. The caller declares which flags are boolean
//! so `--causal --heads 8` parses unambiguously.

use std::collections::{HashMap, HashSet};
use std::str::FromStr;

#[derive(Debug, Clone)]
/// Parsed command line: positional args plus `--key value` /
/// `--flag` options.
pub struct Args {
    map: HashMap<String, String>,
    bools: HashSet<String>,
    pos: Vec<String>,
}

impl Args {
    /// Parse raw arguments (program name excluded). `bool_flags` lists
    /// the valueless flags.
    pub fn parse(raw: &[String], bool_flags: &[&str]) -> Result<Args, String> {
        let boolset: HashSet<&str> = bool_flags.iter().copied().collect();
        let mut map = HashMap::new();
        let mut bools = HashSet::new();
        let mut pos = Vec::new();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    map.insert(k.to_string(), v.to_string());
                } else if boolset.contains(name) {
                    bools.insert(name.to_string());
                } else {
                    i += 1;
                    let v = raw
                        .get(i)
                        .ok_or_else(|| format!("--{name} expects a value"))?;
                    map.insert(name.to_string(), v.clone());
                }
            } else {
                pos.push(a.clone());
            }
            i += 1;
        }
        Ok(Args { map, bools, pos })
    }

    /// Parse the process arguments.
    pub fn from_env(bool_flags: &[&str]) -> Result<Args, String> {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&raw, bool_flags)
    }

    /// True when the boolean flag was passed.
    pub fn has(&self, flag: &str) -> bool {
        self.bools.contains(flag)
    }

    /// Parse an optional `--key value` option.
    pub fn get<T: FromStr>(&self, key: &str) -> Result<Option<T>, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.map.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| format!("--{key} '{v}': {e}")),
        }
    }

    /// Parse `--key value` with a default.
    pub fn get_or<T: FromStr>(&self, key: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get(key)?.unwrap_or(default))
    }

    /// Parse a mandatory `--key value` option.
    pub fn require<T: FromStr>(&self, key: &str) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        self.get(key)?.ok_or_else(|| format!("missing required --{key}"))
    }

    /// The positional (non-option) arguments in order.
    pub fn positional(&self) -> &[String] {
        &self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn mixed_forms() {
        let a = Args::parse(
            &sv(&["figure", "--topo=mi300x", "--heads", "64", "--quick"]),
            &["quick"],
        )
        .unwrap();
        assert_eq!(a.positional(), &["figure".to_string()]);
        assert_eq!(a.get::<String>("topo").unwrap().unwrap(), "mi300x");
        assert_eq!(a.get::<usize>("heads").unwrap().unwrap(), 64);
        assert!(a.has("quick"));
        assert!(!a.has("json"));
    }

    #[test]
    fn defaults_and_required() {
        let a = Args::parse(&sv(&["--n", "3"]), &[]).unwrap();
        assert_eq!(a.get_or("n", 0usize).unwrap(), 3);
        assert_eq!(a.get_or("m", 7usize).unwrap(), 7);
        assert!(a.require::<usize>("missing").is_err());
    }

    #[test]
    fn bad_value_reports_flag() {
        let a = Args::parse(&sv(&["--n", "abc"]), &[]).unwrap();
        let err = a.get::<usize>("n").unwrap_err();
        assert!(err.contains("--n"), "{err}");
    }

    #[test]
    fn missing_value_rejected() {
        assert!(Args::parse(&sv(&["--n"]), &[]).is_err());
    }
}
