//! Micro-benchmark harness (criterion replacement): warmup, repeated
//! timed runs, mean/min/max reporting. Used by every `rust/benches/*.rs`
//! target (`harness = false`).

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
/// Timing summary of one benchmark case.
pub struct BenchResult {
    /// Case name.
    pub name: String,
    /// Iterations timed.
    pub iters: usize,
    /// Mean per-iteration time.
    pub mean: Duration,
    /// Fastest iteration.
    pub min: Duration,
    /// Slowest iteration.
    pub max: Duration,
}

impl BenchResult {
    /// One-line human-readable rendering.
    pub fn line(&self) -> String {
        format!(
            "{:<48} {:>10.3} ms/iter (min {:.3}, max {:.3}, n={})",
            self.name,
            self.mean.as_secs_f64() * 1e3,
            self.min.as_secs_f64() * 1e3,
            self.max.as_secs_f64() * 1e3,
            self.iters
        )
    }
}

/// Time `f` for up to `iters` iterations (after one warmup run), or stop
/// early once `budget` wall time is spent.
pub fn bench<F: FnMut()>(name: &str, iters: usize, budget: Duration, mut f: F) -> BenchResult {
    f(); // warmup
    let mut times = Vec::with_capacity(iters);
    let start = Instant::now();
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
        if start.elapsed() > budget {
            break;
        }
    }
    let total: Duration = times.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters: times.len(),
        mean: total / times.len() as u32,
        min: times.iter().min().copied().unwrap(),
        max: times.iter().max().copied().unwrap(),
    }
}

/// Collect results and print a closing summary (mirrors criterion's
/// console layout closely enough for `cargo bench` logs).
#[derive(Debug, Default)]
pub struct Harness {
    results: Vec<BenchResult>,
}

impl Harness {
    /// A named benchmark suite.
    pub fn new(title: &str) -> Self {
        println!("=== bench: {title} ===");
        Harness { results: Vec::new() }
    }

    /// Time `f` for `iters` iterations and record the result.
    pub fn run<F: FnMut()>(&mut self, name: &str, iters: usize, f: F) {
        let r = bench(name, iters, Duration::from_secs(20), f);
        println!("{}", r.line());
        self.results.push(r);
    }

    /// All recorded results.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 5, Duration::from_secs(1), || {
            std::hint::black_box((0..10_000u64).sum::<u64>());
        });
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.mean && r.mean <= r.max.max(r.mean));
    }

    #[test]
    fn budget_stops_early() {
        let r = bench("sleepy", 1000, Duration::from_millis(30), || {
            std::thread::sleep(Duration::from_millis(10));
        });
        assert!(r.iters < 1000);
    }
}
