//! Micro-benchmark harness (criterion replacement): warmup, repeated
//! timed runs, mean/min/max reporting, per-case metrics, and JSON
//! emission for the pinned perf trajectory (`BENCH_*.json` at the repo
//! root — format in docs/PERF.md, schema-checked by
//! `scripts/check_bench_json.py`). Used by every `rust/benches/*.rs`
//! target (`harness = false`).

use std::time::{Duration, Instant};

use crate::util::json::Json;

#[derive(Debug, Clone)]
/// Timing summary of one benchmark case.
pub struct BenchResult {
    /// Case name.
    pub name: String,
    /// Iterations timed.
    pub iters: usize,
    /// Mean per-iteration time.
    pub mean: Duration,
    /// Fastest iteration.
    pub min: Duration,
    /// Slowest iteration.
    pub max: Duration,
    /// Named derived metrics (e.g. `accesses_per_sec`,
    /// `speedup_vs_reference`), emitted under `"metrics"` in the JSON.
    pub metrics: Vec<(String, f64)>,
}

impl BenchResult {
    /// One-line human-readable rendering.
    pub fn line(&self) -> String {
        format!(
            "{:<48} {:>10.3} ms/iter (min {:.3}, max {:.3}, n={})",
            self.name,
            self.mean.as_secs_f64() * 1e3,
            self.min.as_secs_f64() * 1e3,
            self.max.as_secs_f64() * 1e3,
            self.iters
        )
    }

    /// Attach a named derived metric to this case.
    pub fn metric(&mut self, name: &str, value: f64) {
        self.metrics.push((name.to_string(), value));
    }

    /// JSON rendering of one case (the `cases[]` element of the
    /// `bench-v1` schema).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.as_str())),
            ("iters", Json::num(self.iters as f64)),
            ("mean_ms", Json::num(self.mean.as_secs_f64() * 1e3)),
            ("min_ms", Json::num(self.min.as_secs_f64() * 1e3)),
            ("max_ms", Json::num(self.max.as_secs_f64() * 1e3)),
            (
                "metrics",
                Json::Obj(
                    self.metrics.iter().map(|(k, v)| (k.clone(), Json::num(*v))).collect(),
                ),
            ),
        ])
    }
}

/// Time `f` for up to `iters` iterations (after one warmup run), or stop
/// early once `budget` wall time is spent.
pub fn bench<F: FnMut()>(name: &str, iters: usize, budget: Duration, mut f: F) -> BenchResult {
    f(); // warmup
    let mut times = Vec::with_capacity(iters);
    let start = Instant::now();
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
        if start.elapsed() > budget {
            break;
        }
    }
    let total: Duration = times.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters: times.len(),
        mean: total / times.len() as u32,
        min: times.iter().min().copied().unwrap(),
        max: times.iter().max().copied().unwrap(),
        metrics: Vec::new(),
    }
}

/// Collect results and print a closing summary (mirrors criterion's
/// console layout closely enough for `cargo bench` logs).
#[derive(Debug, Default)]
pub struct Harness {
    title: String,
    results: Vec<BenchResult>,
}

impl Harness {
    /// A named benchmark suite.
    pub fn new(title: &str) -> Self {
        println!("=== bench: {title} ===");
        Harness { title: title.to_string(), results: Vec::new() }
    }

    /// Time `f` for `iters` iterations and record the result.
    pub fn run<F: FnMut()>(&mut self, name: &str, iters: usize, f: F) {
        let r = bench(name, iters, Duration::from_secs(20), f);
        println!("{}", r.line());
        self.results.push(r);
    }

    /// Attach a named metric to the most recent case. Panics if no case
    /// has been run yet.
    pub fn metric(&mut self, name: &str, value: f64) {
        self.results.last_mut().expect("metric() before any run()").metric(name, value);
    }

    /// Attach a named metric to the case at `index` (in run order), for
    /// metrics computed only after later cases ran (e.g. a speedup whose
    /// reference timing comes from a subsequent case).
    pub fn metric_at(&mut self, index: usize, name: &str, value: f64) {
        self.results[index].metric(name, value);
    }

    /// All recorded results.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// The whole suite as a `bench-v1` JSON document (docs/PERF.md).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str("bench-v1")),
            ("suite", Json::str(self.title.as_str())),
            ("cases", Json::arr(self.results.iter().map(BenchResult::to_json))),
        ])
    }

    /// Write the suite JSON to `path` (the repo-root `BENCH_<suite>.json`
    /// convention — see docs/PERF.md for how trajectories are refreshed).
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().render() + "\n")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 5, Duration::from_secs(1), || {
            std::hint::black_box((0..10_000u64).sum::<u64>());
        });
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.mean && r.mean <= r.max.max(r.mean));
    }

    #[test]
    fn budget_stops_early() {
        let r = bench("sleepy", 1000, Duration::from_millis(30), || {
            std::thread::sleep(Duration::from_millis(10));
        });
        assert!(r.iters < 1000);
    }

    #[test]
    fn suite_json_matches_bench_v1_schema() {
        let mut h = Harness::new("unit");
        h.run("case_a", 2, || {
            std::hint::black_box(1 + 1);
        });
        h.metric("accesses_per_sec", 123.5);
        let j = h.to_json();
        assert_eq!(j.get("schema").unwrap().as_str(), Some("bench-v1"));
        assert_eq!(j.get("suite").unwrap().as_str(), Some("unit"));
        let cases = j.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(cases[0].get("name").unwrap().as_str(), Some("case_a"));
        assert_eq!(cases[0].get("iters").unwrap().as_usize(), Some(2));
        assert!(cases[0].get("mean_ms").unwrap().as_f64().is_some());
        let m = cases[0].get("metrics").unwrap();
        assert_eq!(m.get("accesses_per_sec").unwrap().as_f64(), Some(123.5));
        // The rendering must round-trip through the parser.
        assert!(Json::parse(&j.render()).is_ok());
    }
}
