//! Fast non-cryptographic hasher for the simulator's u64-keyed hot maps
//! (tile keys, MSHR file, waiter registry). std's default SipHash is
//! DoS-resistant but ~3x slower for these fixed-width keys; this is a
//! Fibonacci-multiply mixer in the fxhash/splitmix family.

use std::hash::{BuildHasherDefault, Hasher};

/// 64-bit multiply-xor hasher; state folds each written word.
#[derive(Default)]
pub struct MixHasher {
    state: u64,
}

const K: u64 = 0x9E3779B97F4A7C15;

impl Hasher for MixHasher {
    #[inline]
    fn finish(&self) -> u64 {
        // Final avalanche (splitmix64 tail).
        let mut x = self.state;
        x ^= x >> 30;
        x = x.wrapping_mul(0xBF58476D1CE4E5B9);
        x ^= x >> 27;
        x = x.wrapping_mul(0x94D049BB133111EB);
        x ^ (x >> 31)
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.write_u64(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.state = (self.state ^ i).wrapping_mul(K);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.write_u64(i as u64);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.write_u64(i as u64);
    }
}

/// BuildHasher for [`MixHasher`] (fingerprints, fast maps).
pub type MixBuildHasher = BuildHasherDefault<MixHasher>;

/// HashMap with the fast hasher.
pub type FastMap<K, V> = std::collections::HashMap<K, V, MixBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;
    use std::hash::{BuildHasher, Hash};

    fn hash_one<T: Hash>(v: T) -> u64 {
        MixBuildHasher::default().hash_one(v)
    }

    #[test]
    fn distinct_keys_distinct_hashes() {
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            assert!(seen.insert(hash_one(i)));
        }
        // tuple keys (the waiter registry shape)
        let a = hash_one((3u32, 7u64));
        let b = hash_one((7u32, 3u64));
        assert_ne!(a, b);
    }

    #[test]
    fn avalanche_on_low_bits() {
        // Tile keys differ in low bits; high bits of the hash must vary
        // (HashMap uses the high bits for bucket selection with capacity
        // masks on low bits — check both halves move).
        let h1 = hash_one(1u64);
        let h2 = hash_one(2u64);
        assert_ne!(h1 >> 32, h2 >> 32);
        assert_ne!(h1 & 0xFFFF_FFFF, h2 & 0xFFFF_FFFF);
    }

    #[test]
    fn fastmap_works() {
        let mut m: FastMap<u64, u32> = FastMap::default();
        for i in 0..1000 {
            m.insert(i, (i * 2) as u32);
        }
        assert_eq!(m.get(&500), Some(&1000));
        assert_eq!(m.len(), 1000);
    }
}
