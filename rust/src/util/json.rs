//! Minimal JSON: a recursive-descent parser and a writer. Covers the full
//! JSON grammar minus exotic numbers (parsed as f64). Object key order is
//! preserved.

use std::fmt::Write as _;

/// A JSON value tree.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, key order preserved.
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ----- accessors ---------------------------------------------------

    /// Object field lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The value as a non-negative integer, if whole.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    /// The value as a usize, if a whole non-negative number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    /// The boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The element slice, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    // ----- construction helpers ----------------------------------------

    /// Build an object from `(key, value)` pairs.
    pub fn obj(kvs: Vec<(&str, Json)>) -> Json {
        Json::Obj(kvs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build an array from an iterator of values.
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Build a number.
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    /// Build a string.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ----- parse --------------------------------------------------------

    /// Parse a complete JSON document (rejects trailing data).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // ----- write --------------------------------------------------------

    /// Serialize to compact JSON text (deterministic: key order is
    /// preserved, whole numbers render without a fraction).
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{s}': {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else {
                return Err("unterminated string".into());
            };
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        return Err("bad escape".into());
                    };
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence starting at c.
                    let len = utf8_len(c);
                    let start = self.i - 1;
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err("truncated utf8".into());
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "bad utf8 in string")?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            kvs.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "c"}, null], "d": false}"#).unwrap();
        assert_eq!(v.get("d"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].get("b").unwrap().as_str(), Some("c"));
        assert_eq!(arr[2], Json::Null);
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = Json::parse(r#""a\n\t\"\\ A é""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\ A é"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"x","vals":[1,2.5,-3],"ok":true,"n":null,"s":"a\"b"}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.render()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn integer_rendering_is_exact() {
        assert_eq!(Json::Num(1048576.0).render(), "1048576");
        assert_eq!(Json::Num(0.5).render(), "0.5");
    }

    #[test]
    fn as_u64_guards() {
        assert_eq!(Json::Num(3.0).as_u64(), Some(3));
        assert_eq!(Json::Num(3.5).as_u64(), None);
        assert_eq!(Json::Num(-3.0).as_u64(), None);
    }

    #[test]
    fn real_manifest_subset_parses() {
        let src = r#"{
          "format": "hlo-text-v1",
          "artifacts": [
            {"name": "a", "inputs": [{"shape": [1,8,256,64], "dtype": "float32"}],
             "golden": {"abs_sum": 1234.5678}}
          ]
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("format").unwrap().as_str(), Some("hlo-text-v1"));
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        let shape: Vec<usize> = a.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![1, 8, 256, 64]);
    }
}
