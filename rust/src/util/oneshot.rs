//! One-shot channel (tokio is unavailable offline): a thin typed wrapper
//! over `std::sync::mpsc::sync_channel(1)` with consume-on-send.

use std::sync::mpsc;
use std::time::Duration;

/// Sending half: consumes itself on send.
pub struct Sender<T>(mpsc::SyncSender<T>);
/// Receiving half: blocks until the value (or disconnect) arrives.
pub struct Receiver<T>(mpsc::Receiver<T>);

#[derive(Debug, PartialEq, Eq)]
/// The sender was dropped without sending.
pub struct RecvError;

/// A rendezvous channel for exactly one value.
pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
    let (tx, rx) = mpsc::sync_channel(1);
    (Sender(tx), Receiver(rx))
}

impl<T> Sender<T> {
    /// Send the single value; returns it back if the receiver is gone.
    pub fn send(self, value: T) -> Result<(), T> {
        self.0.try_send(value).map_err(|e| match e {
            mpsc::TrySendError::Full(v) | mpsc::TrySendError::Disconnected(v) => v,
        })
    }
}

impl<T> Receiver<T> {
    /// Block until the value arrives (or the sender is dropped).
    pub fn wait(self) -> Result<T, RecvError> {
        self.0.recv().map_err(|_| RecvError)
    }

    /// Wait up to `timeout` for the value.
    pub fn wait_timeout(&self, timeout: Duration) -> Result<T, RecvError> {
        self.0.recv_timeout(timeout).map_err(|_| RecvError)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn send_then_wait() {
        let (tx, rx) = channel();
        tx.send(42).unwrap();
        assert_eq!(rx.wait(), Ok(42));
    }

    #[test]
    fn dropped_sender_errors() {
        let (tx, rx) = channel::<u32>();
        drop(tx);
        assert_eq!(rx.wait(), Err(RecvError));
    }

    #[test]
    fn cross_thread() {
        let (tx, rx) = channel();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(5));
            tx.send("done").unwrap();
        });
        assert_eq!(rx.wait(), Ok("done"));
    }

    #[test]
    fn dropped_receiver_returns_value() {
        let (tx, rx) = channel();
        drop(rx);
        assert_eq!(tx.send(7), Err(7));
    }
}
