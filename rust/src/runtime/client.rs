//! The PJRT client wrapper: compile-once, execute-many.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::Context;

use super::manifest::{ArtifactMeta, Manifest};

/// Output of one artifact execution.
#[derive(Debug, Clone)]
pub struct ExecutionResult {
    /// One flat f32 buffer per declared output.
    pub outputs: Vec<Vec<f32>>,
    /// Device execution time (compile excluded).
    pub elapsed: Duration,
}

struct Loaded {
    exe: xla::PjRtLoadedExecutable,
    meta: ArtifactMeta,
}

/// Compiled-artifact registry over a PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    loaded: HashMap<String, Loaded>,
    manifest: Manifest,
    dir: PathBuf,
}

impl Runtime {
    /// Create a runtime over `artifact_dir` without compiling anything.
    pub fn open(artifact_dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, loaded: HashMap::new(), manifest, dir })
    }

    /// The parsed artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The PJRT platform name (or the stub's placeholder).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// True when the artifact has been loaded/compiled.
    pub fn is_loaded(&self, name: &str) -> bool {
        self.loaded.contains_key(name)
    }

    /// Names of all loaded artifacts, sorted.
    pub fn loaded_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.loaded.keys().cloned().collect();
        v.sort();
        v
    }

    /// Load + compile one artifact by name (idempotent).
    pub fn load(&mut self, name: &str) -> anyhow::Result<()> {
        if self.loaded.contains_key(name) {
            return Ok(());
        }
        let meta = self
            .manifest
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?
            .clone();
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing HLO text {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling '{name}': {e:?}"))?;
        self.loaded.insert(name.to_string(), Loaded { exe, meta });
        Ok(())
    }

    /// Load + compile every artifact in the manifest.
    pub fn load_all(&mut self) -> anyhow::Result<()> {
        let names: Vec<String> = self.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
        for n in names {
            self.load(&n)?;
        }
        Ok(())
    }

    /// Execute a loaded artifact on flat f32 input buffers (shapes from
    /// the manifest). Returns flat f32 outputs.
    pub fn execute(&self, name: &str, inputs: &[Vec<f32>]) -> anyhow::Result<ExecutionResult> {
        let loaded = self
            .loaded
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))?;
        let meta = &loaded.meta;
        anyhow::ensure!(
            inputs.len() == meta.inputs.len(),
            "'{name}' expects {} inputs, got {}",
            meta.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, spec) in inputs.iter().zip(&meta.inputs) {
            anyhow::ensure!(
                buf.len() == spec.num_elements(),
                "input size {} != spec {:?}",
                buf.len(),
                spec.shape
            );
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(buf)
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("reshape input: {e:?}"))?;
            literals.push(lit);
        }

        let start = Instant::now();
        let result = loaded
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing '{name}': {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result: {e:?}"))?;
        let elapsed = start.elapsed();

        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("decomposing tuple: {e:?}"))?;
        anyhow::ensure!(
            parts.len() == meta.outputs.len(),
            "'{name}' returned {} outputs, manifest says {}",
            parts.len(),
            meta.outputs.len()
        );
        let mut outputs = Vec::with_capacity(parts.len());
        for p in parts {
            outputs.push(
                p.to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("reading f32 output: {e:?}"))?,
            );
        }
        Ok(ExecutionResult { outputs, elapsed })
    }

    /// Execute an artifact on its manifest-declared deterministic inputs
    /// (the golden path used by `verify`).
    pub fn execute_with_det_inputs(&self, name: &str) -> anyhow::Result<ExecutionResult> {
        let meta = &self
            .loaded
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))?
            .meta;
        let inputs: Vec<Vec<f32>> = meta
            .input_seeds
            .iter()
            .zip(&meta.inputs)
            .map(|(&seed, spec)| super::inputs::det_input(seed, spec.num_elements()))
            .collect();
        self.execute(name, &inputs)
    }

    /// Execute with deterministic inputs and check against the manifest's
    /// golden statistics. Returns (abs_sum_measured, abs_sum_expected).
    pub fn verify(&self, name: &str, tol: f64) -> anyhow::Result<(f64, f64)> {
        let meta = self
            .loaded
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))?
            .meta
            .clone();
        let golden = meta
            .golden
            .as_ref()
            .with_context(|| format!("artifact '{name}' has no golden stats"))?;
        let result = self.execute_with_det_inputs(name)?;
        let (abs_sum, _, _) = super::inputs::stats(&result.outputs[0]);
        let rel = (abs_sum - golden.abs_sum).abs() / golden.abs_sum.max(1e-9);
        anyhow::ensure!(
            rel < tol,
            "'{name}' golden mismatch: measured {abs_sum:.4}, expected {:.4} (rel {rel:.2e})",
            golden.abs_sum
        );
        Ok((abs_sum, golden.abs_sum))
    }
}
