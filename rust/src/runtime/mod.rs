//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only place the `xla` crate is touched. Python never runs
//! on the request path: `make artifacts` is a build step, after which the
//! Rust binary is self-contained.
//!
//! Interchange is HLO *text* (not serialized protos): jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod inputs;
pub mod manifest;

// The real PJRT client needs the `xla` bindings crate (native libs, no
// offline build); the default build substitutes a stub with the same API
// whose execute paths error. See rust/src/runtime/client_stub.rs.
#[cfg(feature = "pjrt")]
mod client;
#[cfg(not(feature = "pjrt"))]
#[path = "client_stub.rs"]
mod client;

pub use client::{ExecutionResult, Runtime};
pub use manifest::{ArtifactMeta, Manifest, TensorSpec};
