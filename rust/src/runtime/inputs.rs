//! Deterministic input generation — bit-for-bit mirror of
//! `python/compile/aot.py::det_input` (Knuth multiplicative hash of
//! seed + index mapped to [-0.5, 0.5)), so the Rust serving path can
//! regenerate exactly the tensors whose golden output statistics the
//! Python oracle recorded in the manifest.

const HASH_MULT: u64 = 2654435761;

/// Deterministic pseudo-random f32 tensor of `len` elements.
pub fn det_input(seed: u64, len: usize) -> Vec<f32> {
    (0..len as u64)
        .map(|i| det_value(seed, i))
        .collect()
}

/// Single element of the deterministic stream.
#[inline]
pub fn det_value(seed: u64, index: u64) -> f32 {
    let h = (index.wrapping_add(seed)).wrapping_mul(HASH_MULT) & 0xFFFF_FFFF;
    (h as f64 / 4294967296.0 - 0.5) as f32
}

/// Summary statistics matching the manifest's golden block.
pub fn stats(values: &[f32]) -> (f64, f64, f64) {
    let abs_sum: f64 = values.iter().map(|v| v.abs() as f64).sum();
    let mean: f64 = values.iter().map(|&v| v as f64).sum::<f64>() / values.len().max(1) as f64;
    let l2: f64 = values.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt();
    (abs_sum, mean, l2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_values_match_python() {
        // python/tests/test_aot.py::test_det_input_golden pins the same
        // four values for seed=1.
        let v = det_input(1, 4);
        let expected: Vec<f32> = (0..4u64)
            .map(|i| (((1 + i) * 2654435761 % (1u64 << 32)) as f64 / 4294967296.0 - 0.5) as f32)
            .collect();
        assert_eq!(v, expected);
    }

    #[test]
    fn range_and_determinism() {
        let a = det_input(7, 1000);
        let b = det_input(7, 1000);
        assert_eq!(a, b);
        assert!(a.iter().all(|&x| (-0.5..0.5).contains(&x)));
        let c = det_input(8, 1000);
        assert_ne!(a, c);
    }

    #[test]
    fn stats_sane() {
        let v = det_input(3, 10_000);
        let (abs_sum, mean, l2) = stats(&v);
        assert!(abs_sum > 0.0);
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!(l2 > 0.0);
    }
}
