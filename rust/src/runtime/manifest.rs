//! `artifacts/manifest.json` schema (written by python/compile/aot.py),
//! parsed with the in-tree JSON parser (`util::json`).

use std::path::Path;

use anyhow::{anyhow, Context};

use crate::util::json::Json;

/// The parsed `artifacts/manifest.json`: the AOT artifact catalogue.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Schema version tag (must be `"hlo-text-v1"`).
    pub format: String,
    /// Every artifact the manifest describes, in file order.
    pub artifacts: Vec<ArtifactMeta>,
}

/// Shape + dtype of one artifact input or output tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
    /// Element type name as emitted by the compiler (e.g. `"float32"`).
    pub dtype: String,
}

impl TensorSpec {
    /// Total element count (product of the shape).
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Attention geometry of an `attn_fwd` artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct AttnMeta {
    /// Batch size Z.
    pub batch: usize,
    /// Query heads.
    pub h_q: usize,
    /// KV heads.
    pub h_k: usize,
    /// Context length.
    pub n_ctx: usize,
    /// Head dimension.
    pub d_head: usize,
    /// Causal masking.
    pub causal: bool,
    /// Q row-block size the kernel was compiled with.
    pub block_m: usize,
    /// K/V column-block size the kernel was compiled with.
    pub block_n: usize,
    /// Mapping policy name baked into the kernel grid.
    pub policy: String,
    /// XCD count the swizzle was compiled for.
    pub num_xcd: usize,
}

/// Golden output statistics computed by the Python oracle on the
/// deterministic inputs (`input_seeds` + runtime::inputs::det_input).
#[derive(Debug, Clone, PartialEq)]
pub struct Golden {
    /// Sum of absolute output values.
    pub abs_sum: f64,
    /// Mean output value.
    pub mean: f64,
    /// L2 norm of the output.
    pub l2: f64,
}

/// One AOT-compiled artifact: file location, I/O contract, and the
/// attention/golden metadata when applicable.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Unique artifact name (the serving router's key).
    pub name: String,
    /// Artifact kind tag (e.g. `"attn_fwd"`).
    pub kind: String,
    /// HLO text file name, relative to the artifact directory.
    pub file: String,
    /// Input tensor specs, in argument order.
    pub inputs: Vec<TensorSpec>,
    /// Deterministic-input seeds, one per input.
    pub input_seeds: Vec<u64>,
    /// Output tensor specs.
    pub outputs: Vec<TensorSpec>,
    /// Attention geometry, for `attn_fwd` artifacts.
    pub attn: Option<AttnMeta>,
    /// Golden statistics, when the oracle produced them.
    pub golden: Option<Golden>,
}

fn spec_from(j: &Json) -> anyhow::Result<TensorSpec> {
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .context("tensor spec missing shape")?
        .iter()
        .map(|d| d.as_usize().context("bad shape dim"))
        .collect::<anyhow::Result<Vec<usize>>>()?;
    let dtype = j
        .get("dtype")
        .and_then(Json::as_str)
        .context("tensor spec missing dtype")?
        .to_string();
    Ok(TensorSpec { shape, dtype })
}

fn req_usize(j: &Json, key: &str) -> anyhow::Result<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .with_context(|| format!("missing/invalid '{key}'"))
}

fn artifact_from(j: &Json) -> anyhow::Result<ArtifactMeta> {
    let name = j
        .get("name")
        .and_then(Json::as_str)
        .context("artifact missing name")?
        .to_string();
    let parse = |key: &str| -> anyhow::Result<Vec<TensorSpec>> {
        j.get(key)
            .and_then(Json::as_arr)
            .with_context(|| format!("'{name}' missing {key}"))?
            .iter()
            .map(spec_from)
            .collect()
    };
    let attn = match j.get("attn") {
        None | Some(Json::Null) => None,
        Some(a) => Some(AttnMeta {
            batch: req_usize(a, "batch")?,
            h_q: req_usize(a, "h_q")?,
            h_k: req_usize(a, "h_k")?,
            n_ctx: req_usize(a, "n_ctx")?,
            d_head: req_usize(a, "d_head")?,
            causal: a.get("causal").and_then(Json::as_bool).unwrap_or(false),
            block_m: req_usize(a, "block_m")?,
            block_n: req_usize(a, "block_n")?,
            policy: a
                .get("policy")
                .and_then(Json::as_str)
                .unwrap_or("swizzled_head_first")
                .to_string(),
            num_xcd: req_usize(a, "num_xcd")?,
        }),
    };
    let golden = match j.get("golden") {
        None | Some(Json::Null) => None,
        Some(g) => Some(Golden {
            abs_sum: g.get("abs_sum").and_then(Json::as_f64).context("golden.abs_sum")?,
            mean: g.get("mean").and_then(Json::as_f64).context("golden.mean")?,
            l2: g.get("l2").and_then(Json::as_f64).context("golden.l2")?,
        }),
    };
    Ok(ArtifactMeta {
        kind: j
            .get("kind")
            .and_then(Json::as_str)
            .context("artifact missing kind")?
            .to_string(),
        file: j
            .get("file")
            .and_then(Json::as_str)
            .context("artifact missing file")?
            .to_string(),
        inputs: parse("inputs")?,
        input_seeds: j
            .get("input_seeds")
            .and_then(Json::as_arr)
            .context("missing input_seeds")?
            .iter()
            .map(|s| s.as_u64().context("bad seed"))
            .collect::<anyhow::Result<Vec<u64>>>()?,
        outputs: parse("outputs")?,
        attn,
        golden,
        name,
    })
}

impl Manifest {
    /// Parse a manifest from JSON text, validating the format tag and
    /// every artifact's required fields.
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest JSON: {e}"))?;
        let format = j
            .get("format")
            .and_then(Json::as_str)
            .context("manifest missing format")?
            .to_string();
        anyhow::ensure!(format == "hlo-text-v1", "unsupported artifact format '{format}'");
        let artifacts = j
            .get("artifacts")
            .and_then(Json::as_arr)
            .context("manifest missing artifacts")?
            .iter()
            .map(artifact_from)
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Manifest { format, artifacts })
    }

    /// Load and parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    /// Look an artifact up by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// attn_fwd artifacts, the serving catalogue.
    pub fn attention_artifacts(&self) -> impl Iterator<Item = &ArtifactMeta> {
        self.artifacts.iter().filter(|a| a.kind == "attn_fwd")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text-v1",
      "artifacts": [{
        "name": "attn_mha_z1_h8_n256_d64",
        "kind": "attn_fwd",
        "file": "attn_mha_z1_h8_n256_d64.hlo.txt",
        "inputs": [{"shape": [1,8,256,64], "dtype": "float32"}],
        "input_seeds": [1],
        "outputs": [{"shape": [1,8,256,64], "dtype": "float32"}],
        "attn": {"batch":1,"h_q":8,"h_k":8,"n_ctx":256,"d_head":64,
                 "causal":false,"block_m":64,"block_n":64,
                 "policy":"swizzled_head_first","num_xcd":8},
        "golden": {"abs_sum": 123.4, "mean": 0.01, "l2": 5.0}
      }]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.get("attn_mha_z1_h8_n256_d64").unwrap();
        assert_eq!(a.inputs[0].num_elements(), 8 * 256 * 64);
        assert_eq!(a.attn.as_ref().unwrap().n_ctx, 256);
        assert!((a.golden.as_ref().unwrap().abs_sum - 123.4).abs() < 1e-9);
        assert!(m.get("nope").is_none());
        assert_eq!(m.attention_artifacts().count(), 1);
    }

    #[test]
    fn rejects_wrong_format() {
        let bad = SAMPLE.replace("hlo-text-v1", "hlo-v2");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        let bad = SAMPLE.replace("\"kind\": \"attn_fwd\",", "");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn real_manifest_parses_if_built() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.attention_artifacts().count() >= 2);
            for a in &m.artifacts {
                assert!(dir.join(&a.file).exists(), "{} missing", a.file);
                assert_eq!(a.input_seeds.len(), a.inputs.len());
            }
        }
    }
}
