//! Stub PJRT client used when the crate is built without the `pjrt`
//! feature (the default in offline environments, where the `xla` bindings
//! crate and its native xla_extension libraries are unavailable).
//!
//! The stub keeps every *metadata* operation working — manifests load and
//! validate, artifacts "load" (existence-checked against the manifest) —
//! so the router/batcher/coordinator layers stay fully testable. Only the
//! actual HLO *execution* entry points return a clear error directing the
//! user to rebuild with `--features pjrt`. The integration tests skip
//! themselves when `artifacts/` is absent, so `cargo test` passes either
//! way.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::Context;

use super::manifest::{ArtifactMeta, Manifest};

/// Output of one artifact execution (never produced by the stub).
#[derive(Debug, Clone)]
pub struct ExecutionResult {
    /// One flat f32 buffer per declared output.
    pub outputs: Vec<Vec<f32>>,
    /// Device execution time (compile excluded).
    pub elapsed: Duration,
}

/// Compiled-artifact registry without a PJRT client behind it.
pub struct Runtime {
    loaded: HashMap<String, ArtifactMeta>,
    manifest: Manifest,
    #[allow(dead_code)]
    dir: PathBuf,
}

fn unavailable(what: &str) -> anyhow::Error {
    anyhow::anyhow!(
        "{what} requires the PJRT runtime, but this binary was built without it \
         (rebuild with `cargo build --features pjrt` and the xla bindings crate)"
    )
}

impl Runtime {
    /// Create a runtime over `artifact_dir` without compiling anything.
    pub fn open(artifact_dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        Ok(Runtime { loaded: HashMap::new(), manifest, dir })
    }

    /// The parsed artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The PJRT platform name (or the stub's placeholder).
    pub fn platform(&self) -> String {
        "stub (built without the `pjrt` feature)".to_string()
    }

    /// True when the artifact has been loaded/compiled.
    pub fn is_loaded(&self, name: &str) -> bool {
        self.loaded.contains_key(name)
    }

    /// Names of all loaded artifacts, sorted.
    pub fn loaded_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.loaded.keys().cloned().collect();
        v.sort();
        v
    }

    /// Register one artifact by name (idempotent). Metadata only: the
    /// stub validates the manifest entry but compiles nothing.
    pub fn load(&mut self, name: &str) -> anyhow::Result<()> {
        if self.loaded.contains_key(name) {
            return Ok(());
        }
        let meta = self
            .manifest
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))?
            .clone();
        self.loaded.insert(name.to_string(), meta);
        Ok(())
    }

    /// Register every artifact in the manifest.
    pub fn load_all(&mut self) -> anyhow::Result<()> {
        let names: Vec<String> = self.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
        for n in names {
            self.load(&n)?;
        }
        Ok(())
    }

    /// Execution is unavailable without PJRT.
    pub fn execute(&self, name: &str, _inputs: &[Vec<f32>]) -> anyhow::Result<ExecutionResult> {
        self.loaded
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))?;
        Err(unavailable("executing an artifact"))
    }

    /// Execution is unavailable without PJRT.
    pub fn execute_with_det_inputs(&self, name: &str) -> anyhow::Result<ExecutionResult> {
        self.loaded
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))?;
        Err(unavailable("executing an artifact"))
    }

    /// Golden verification is unavailable without PJRT.
    pub fn verify(&self, name: &str, _tol: f64) -> anyhow::Result<(f64, f64)> {
        self.loaded
            .get(name)
            .with_context(|| format!("artifact '{name}' not loaded"))?;
        Err(unavailable("golden verification"))
    }
}
