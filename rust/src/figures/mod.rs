//! Paper figure/table regeneration (DESIGN.md §5 experiment index).
//!
//! Each `figN` function declares the corresponding sweep as a *flat job
//! list* — one [`SimJob`] per (sweep point × policy) — and submits the
//! whole list to the shared [`SimDriver`], which fans it out across
//! worker threads through the memoizing report cache. Results come back
//! in submission order, so the rendered rows are byte-identical to the
//! historical serial loops at any `--threads` count. The benches
//! (`rust/benches/figN_*.rs`) and the CLI (`numa-attn figure N`) both
//! call these with their own driver.

use crate::attn::KernelKind;
use crate::driver::{SimDriver, SimJob};
use crate::mapping::{Policy, ALL_POLICIES};
use crate::metrics::Table;
use crate::roofline;
use crate::sim::{gemm, SimConfig, SimReport};
use crate::topology::Topology;
use crate::workload::sweeps::{self, DecodePoint, SweepPoint};

/// One x-axis point: metric value per policy.
#[derive(Debug, Clone)]
pub struct FigureRow {
    /// The x-axis label (sweep-point identity).
    pub label: String,
    /// Metric value per policy, in [`ALL_POLICIES`] order.
    pub values: Vec<(Policy, f64)>,
}

/// A regenerated figure: rows of (config, per-policy metric).
#[derive(Debug, Clone)]
pub struct FigureResult {
    /// Stable figure id (`fig12` … `decode`, `gemm`).
    pub id: String,
    /// Human-readable title.
    pub title: String,
    /// What the numbers mean (y-axis).
    pub metric: String,
    /// One row per sweep point, in sweep order.
    pub rows: Vec<FigureRow>,
}

impl FigureResult {
    /// Render as the aligned text table the CLI prints.
    pub fn render(&self) -> String {
        let labels: Vec<String> = self
            .rows
            .first()
            .map(|r| r.values.iter().map(|(p, _)| p.label()).collect())
            .unwrap_or_default();
        let mut headers: Vec<&str> = vec!["config"];
        headers.extend(labels.iter().map(String::as_str));
        let mut t = Table::new(&headers);
        for row in &self.rows {
            let mut cells = vec![row.label.clone()];
            cells.extend(row.values.iter().map(|(_, v)| format!("{v:.3}")));
            t.row(cells);
        }
        format!("== {} — {} ==\nmetric: {}\n{}", self.id, self.title, self.metric, t.render())
    }

    /// JSON rendering for `--json` CLI output.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("id", Json::str(self.id.clone())),
            ("title", Json::str(self.title.clone())),
            ("metric", Json::str(self.metric.clone())),
            (
                "rows",
                Json::arr(self.rows.iter().map(|r| {
                    Json::obj(vec![
                        ("label", Json::str(r.label.clone())),
                        (
                            "values",
                            Json::Obj(
                                r.values
                                    .iter()
                                    .map(|(p, v)| (p.name().to_string(), Json::num(*v)))
                                    .collect(),
                            ),
                        ),
                    ])
                })),
            ),
        ])
    }

    /// Value for (row label, policy), for assertions in tests/benches.
    pub fn value(&self, label: &str, policy: Policy) -> Option<f64> {
        self.rows
            .iter()
            .find(|r| r.label == label)?
            .values
            .iter()
            .find(|(p, _)| *p == policy)
            .map(|(_, v)| *v)
    }
}

/// How many steady-state occupancy generations the sampled runs measure.
const GENERATIONS: usize = 2;

/// The sampled forward-kernel job for one (point, policy).
fn forward_job(topo: &Topology, pt: &SweepPoint, policy: Policy) -> SimJob {
    SimJob::forward(topo, &pt.cfg, SimConfig::sampled(policy, topo, GENERATIONS))
}

/// The sampled backward-pass job for one (point, policy) — Fig. 16.
fn backward_job(topo: &Topology, pt: &SweepPoint, policy: Policy) -> SimJob {
    let sampled = SimConfig::sampled(policy, topo, GENERATIONS);
    let sim = SimConfig {
        max_wg_completions: sampled.max_wg_completions,
        warmup_completions: sampled.warmup_completions,
        ..SimConfig::backward(policy)
    };
    SimJob::backward(topo, &pt.cfg, sim)
}

/// Flat job list for a sweep: every point × every policy, point-major
/// (so chunking results by `ALL_POLICIES.len()` recovers the rows).
/// Generic over the point type so the prefill and decode sweeps share
/// the one place this invariant lives.
fn sweep_jobs<P>(
    topo: &Topology,
    points: &[P],
    job: impl Fn(&Topology, &P, Policy) -> SimJob,
) -> Vec<SimJob> {
    let mut jobs = Vec::with_capacity(points.len() * ALL_POLICIES.len());
    for pt in points {
        for &p in &ALL_POLICIES {
            jobs.push(job(topo, pt, p));
        }
    }
    jobs
}

/// Run all four policies on one sweep point; forward kernel.
pub fn run_point(driver: &SimDriver, topo: &Topology, pt: &SweepPoint) -> Vec<(Policy, SimReport)> {
    let jobs: Vec<SimJob> = ALL_POLICIES.iter().map(|&p| forward_job(topo, pt, p)).collect();
    ALL_POLICIES.iter().copied().zip(driver.run_all(jobs)).collect()
}

/// Reduce a point's four reports to one figure row.
fn row_from(pt: &SweepPoint, reports: &[SimReport], value: impl Fn(&SimReport) -> f64) -> FigureRow {
    FigureRow {
        label: pt.label.clone(),
        values: ALL_POLICIES.iter().copied().zip(reports.iter().map(&value)).collect(),
    }
}

/// Per-policy performance relative to `baseline`, one row per point.
fn perf_rows_vs(
    driver: &SimDriver,
    topo: &Topology,
    points: &[SweepPoint],
    baseline: Policy,
    job: impl Fn(&Topology, &SweepPoint, Policy) -> SimJob,
) -> Vec<FigureRow> {
    let reports = driver.run_all(sweep_jobs(topo, points, job));
    let base_idx = ALL_POLICIES.iter().position(|&p| p == baseline).unwrap();
    points
        .iter()
        .zip(reports.chunks(ALL_POLICIES.len()))
        .map(|(pt, chunk)| {
            let base_sec = chunk[base_idx].est_total_sec;
            row_from(pt, chunk, |r| base_sec / r.est_total_sec)
        })
        .collect()
}

fn perf_rows(driver: &SimDriver, topo: &Topology, points: &[SweepPoint]) -> Vec<FigureRow> {
    perf_rows_vs(driver, topo, points, Policy::SwizzledHeadFirst, forward_job)
}

fn hit_rate_rows(driver: &SimDriver, topo: &Topology, points: &[SweepPoint]) -> Vec<FigureRow> {
    let reports = driver.run_all(sweep_jobs(topo, points, forward_job));
    points
        .iter()
        .zip(reports.chunks(ALL_POLICIES.len()))
        .map(|(pt, chunk)| row_from(pt, chunk, |r| r.l2_hit_pct()))
        .collect()
}

/// The exact-run decode job for one (decode point, policy) — phase 1
/// (split-KV) plus phase 2 (reduction) merged by the driver's
/// [`crate::sim::simulate_decode`] path.
fn decode_job(topo: &Topology, pt: &DecodePoint, policy: Policy) -> SimJob {
    SimJob::decode(topo, &pt.cfg, SimConfig::decode(policy, pt.num_splits))
}

/// Sweep subsetting for quick runs (CI) vs full paper grids.
fn mha_points(quick: bool) -> Vec<SweepPoint> {
    if quick {
        sweeps::mha_sensitivity(&[8192, 131072], &[1, 8], &[8, 128])
    } else {
        sweeps::mha_sensitivity(
            &sweeps::TABLE2_N_CTX,
            &sweeps::TABLE2_BATCH,
            &sweeps::TABLE2_HEADS,
        )
    }
}

/// Fig. 12: MHA performance relative to Swizzled Head-first across batch
/// sizes and sequence lengths.
pub fn fig12(driver: &SimDriver, topo: &Topology, quick: bool) -> FigureResult {
    FigureResult {
        id: "fig12".into(),
        title: "MHA performance relative to Swizzled Head-first".into(),
        metric: "normalized performance (SHF = 1.0)".into(),
        rows: perf_rows(driver, topo, &mha_points(quick)),
    }
}

/// Fig. 13: aggregate L2 cache hit rates for the MHA sweep.
pub fn fig13(driver: &SimDriver, topo: &Topology, quick: bool) -> FigureResult {
    let points = if quick {
        sweeps::mha_sensitivity(&[2048, 131072], &[1, 8], &[8, 128])
    } else {
        sweeps::mha_sensitivity(
            &sweeps::FIG13_N_CTX,
            &sweeps::TABLE2_BATCH,
            &sweeps::TABLE2_HEADS,
        )
    };
    FigureResult {
        id: "fig13".into(),
        title: "MHA aggregate L2 cache hit rates".into(),
        metric: "L2 hit rate (%)".into(),
        rows: hit_rate_rows(driver, topo, &points),
    }
}

/// Fig. 14: GQA (8 KV heads, Llama-3 family) performance relative to SHF.
pub fn fig14(driver: &SimDriver, topo: &Topology, quick: bool) -> FigureResult {
    let points = if quick {
        sweeps::gqa_sensitivity(&[8192, 131072], &[1, 8])
    } else {
        sweeps::gqa_sensitivity(&sweeps::TABLE2_N_CTX, &sweeps::TABLE2_BATCH)
    };
    FigureResult {
        id: "fig14".into(),
        title: "GQA performance relative to Swizzled Head-first".into(),
        metric: "normalized performance (SHF = 1.0)".into(),
        rows: perf_rows(driver, topo, &points),
    }
}

/// Fig. 15: DeepSeek-V3 prefill (MHA, 128 heads, D=56) relative to SHF.
pub fn fig15(driver: &SimDriver, topo: &Topology, quick: bool) -> FigureResult {
    let points = if quick {
        sweeps::deepseek_prefill(&[2048, 131072], &[1, 8])
    } else {
        sweeps::deepseek_prefill(&sweeps::FIG13_N_CTX, &sweeps::TABLE2_BATCH)
    };
    FigureResult {
        id: "fig15".into(),
        title: "DeepSeek-V3 prefill performance relative to SHF".into(),
        metric: "normalized performance (SHF = 1.0)".into(),
        rows: perf_rows(driver, topo, &points),
    }
}

/// Fig. 16: FA2 backward speedup vs Naive Block-first (H_Q = 128).
pub fn fig16(driver: &SimDriver, topo: &Topology, quick: bool) -> FigureResult {
    let points = if quick {
        sweeps::backward_sweep(&[8192, 131072], &[1])
    } else {
        sweeps::backward_sweep(&[8192, 32768, 131072], &[1, 2])
    };
    FigureResult {
        id: "fig16".into(),
        title: "FA2 backward speedup vs Naive Block-first (H_Q=128)".into(),
        metric: "speedup over Naive Block-first".into(),
        rows: perf_rows_vs(driver, topo, &points, Policy::NaiveBlockFirst, backward_job),
    }
}

/// Decode figure (beyond the paper: the serving regime AMMA/FA2 split-KV
/// target): aggregate L2 hit rates of the two-phase flash-decode pass on
/// the GQA-8 sweep. Split counts are chosen so the KV split dimension
/// does NOT divide evenly into the XCD round-robin (see
/// [`sweeps::DECODE_SPLITS`]) — the regime where the mapping policy, not
/// dispatch luck, decides whether a (kv head, split) stream is replicated
/// across L2 domains.
pub fn decode_fig(driver: &SimDriver, topo: &Topology, quick: bool) -> FigureResult {
    let points = if quick {
        sweeps::gqa8_decode_sweep(&[16 * 1024, 64 * 1024], &[1, 8], &sweeps::DECODE_SPLITS)
    } else {
        sweeps::gqa8_decode_sweep(
            &sweeps::DECODE_N_CTX,
            &sweeps::DECODE_BATCH,
            &sweeps::DECODE_SPLITS,
        )
    };
    let reports = driver.run_all(sweep_jobs(topo, &points, decode_job));
    let rows = points
        .iter()
        .zip(reports.chunks(ALL_POLICIES.len()))
        .map(|(pt, chunk)| FigureRow {
            label: pt.label.clone(),
            values: ALL_POLICIES
                .iter()
                .copied()
                .zip(chunk.iter().map(|r| r.l2_hit_pct()))
                .collect(),
        })
        .collect();
    FigureResult {
        id: "decode".into(),
        title: "Split-KV decode aggregate L2 hit rates (GQA-8)".into(),
        metric: "L2 hit rate (%), both phases merged".into(),
        rows,
    }
}

/// Serving figure (beyond the paper, DESIGN.md §10): decode throughput
/// of the continuous-batching serving loop per mapping policy, one row
/// per sweep scenario ([`crate::coordinator::serve_scenarios`]). The
/// loop prices every step from simulator reports, so this figure is the
/// end-to-end answer to "what does the paper's mapping buy a serving
/// deployment": SwizzledHeadFirst's tokens/s is >= NaiveHeadFirst's on
/// every row (asserted by `tests/serving_loop.rs` and the `serve_loop`
/// bench). The richer per-policy report (TPOT percentiles, advisor
/// consult counts) is `numa-attn serve`.
pub fn serve_fig(driver: &SimDriver, topo: &Topology, quick: bool) -> FigureResult {
    serve_figs(driver, topo, quick).0
}

/// Both serving panels — throughput and the TTFT p99 tail — projected
/// from ONE serving-report run: the panels are pure projections of the
/// same [`crate::coordinator::ServeStats`] rows, so `figure serve` and
/// `figure all` call this instead of running the sweep's serving loops
/// once per panel. The TTFT panel (lower is better) is where the
/// chunked sweep rows earn their keep: streaming prompts in row-block
/// chunks keeps the first-token tail flat where monolithic prefill
/// freezes every admission wave behind the longest prompt
/// (docs/SERVING.md §6).
pub fn serve_figs(
    driver: &SimDriver,
    topo: &Topology,
    quick: bool,
) -> (FigureResult, FigureResult, FigureResult) {
    let report = crate::coordinator::serve_report(driver, topo, quick);
    let rows_by = |value: fn(&crate::coordinator::ServeStats) -> f64| -> Vec<FigureRow> {
        report
            .rows
            .iter()
            .map(|row| FigureRow {
                label: row.label.clone(),
                values: row.stats.iter().map(|s| (s.policy, value(s))).collect(),
            })
            .collect()
    };
    (
        FigureResult {
            id: "serve".into(),
            title: "Continuous-batching decode serving throughput (Llama-3 70B GQA-8)".into(),
            metric: "decode tokens/s over simulated time".into(),
            rows: rows_by(|s| s.tokens_per_sec),
        },
        FigureResult {
            id: "serve_ttft".into(),
            title: "Continuous-batching TTFT p99 (Llama-3 70B GQA-8)".into(),
            metric: "TTFT p99 (ms, arrival -> first decode token; lower is better)".into(),
            rows: rows_by(|s| s.ttft_p99_ms),
        },
        FigureResult {
            id: "serve_share".into(),
            title: "Paged KV pool XCD affinity of inserted blocks (Llama-3 70B GQA-8)".into(),
            metric: "kv_xcd_affinity_pct (%, home-XCD-resident KV blocks; pool rows only)".into(),
            rows: rows_by(|s| s.kv_xcd_affinity_pct),
        },
    )
}

/// The TTFT panel alone (the `figure serve_ttft` id) — see
/// [`serve_figs`].
pub fn serve_ttft_fig(driver: &SimDriver, topo: &Topology, quick: bool) -> FigureResult {
    serve_figs(driver, topo, quick).1
}

/// The paged-KV NUMA-placement panel alone (the `figure serve_share`
/// id, docs/KVCACHE.md §5): per-policy `kv_xcd_affinity_pct` — the
/// share of freshly inserted KV blocks that land on the XCD their KV
/// head's decode stream is pinned to under that mapping. Rows without
/// the pool enabled (no `kv_block_tokens`/`prefix_share_pct`) report 0;
/// on the pool row the head-first swizzle keeps every block home
/// (100%) while the naive layout scatters blocks round-robin
/// (~1/num_xcds) — the serving-side restatement of the paper's NUMA
/// thesis.
pub fn serve_share_fig(driver: &SimDriver, topo: &Topology, quick: bool) -> FigureResult {
    serve_figs(driver, topo, quick).2
}

/// Cluster figure (docs/CLUSTER.md): decode throughput of the
/// tensor-parallel cluster serving sweep, one row per (scenario, TP
/// degree) over clusters of `topo` devices. The two-level claim this
/// figure carries: Swizzled Head-first's tokens/s (and decode L2 hit
/// rate, via [`crate::coordinator::ClusterReport`]) is >= Naive
/// Head-first's on every (tp, policy) row — the level-2 mapping win
/// survives head sharding — and TP-8 outruns TP-1 (asserted by
/// `tests/cluster_serving.rs` and the `cluster_scaling` bench). The
/// richer report (scaling efficiency vs. ideal, TPOT) is
/// `numa-attn cluster`.
pub fn cluster_fig(driver: &SimDriver, topo: &Topology, quick: bool) -> FigureResult {
    let report = crate::coordinator::serve_cluster_report(driver, topo, quick);
    FigureResult {
        id: "cluster".into(),
        title: "Tensor-parallel cluster decode serving throughput (Llama-3 70B GQA-8)".into(),
        metric: "decode tokens/s over simulated time".into(),
        rows: report
            .rows
            .iter()
            .map(|row| FigureRow {
                label: row.label.clone(),
                values: row.stats.iter().map(|s| (s.policy, s.tokens_per_sec)).collect(),
            })
            .collect(),
    }
}

/// Disaggregated-serving figure (docs/DISAGG.md): interactive TTFT p99
/// of the prefill/decode-disaggregated serving sweep, one row per
/// scenario ([`crate::coordinator::disagg_scenarios`]) over pools of
/// `topo` devices. This is the panel the disaggregation claim lives in:
/// the disagg rows' interactive tail beats the colocated rows' because
/// a dedicated prefill pool keeps long prompts out of the decode
/// steps' way (asserted by the `disagg_serving` bench). Colocated rows
/// run the historical single-pool loop with no SLO classes, so they
/// report the overall TTFT p99 — the apples-to-apples baseline tail.
/// The richer report (per-class TPOT, handoff bytes, preemptions) is
/// `numa-attn disagg`.
pub fn disagg_fig(driver: &SimDriver, topo: &Topology, quick: bool) -> FigureResult {
    let report = crate::coordinator::disagg_report(driver, topo, quick);
    FigureResult {
        id: "disagg".into(),
        title: "Disaggregated prefill/decode interactive TTFT p99 (Llama-3 70B GQA-8)".into(),
        metric: "interactive TTFT p99 (ms; overall p99 on colocated rows; lower is better)".into(),
        rows: report
            .rows
            .iter()
            .map(|row| FigureRow {
                label: row.label.clone(),
                values: row
                    .stats
                    .iter()
                    .map(|s| {
                        let v = match &s.extras {
                            Some(e) => e.interactive.ttft_p99_ms,
                            None => s.serve.ttft_p99_ms,
                        };
                        (s.serve.policy, v)
                    })
                    .collect(),
            })
            .collect(),
    }
}

/// Bursty-serving fault panel (docs/SERVING.md §9): the widest-TP
/// cluster scenarios re-served under one engineered mid-run outage —
/// device 1 down across the middle ~30% of a clean serve — reported as
/// per-window busy-time decode throughput (full width before the
/// failure, rebalanced width during the outage, full width again after
/// recovery) plus the whole-run TTFT p99 tail and the recovery ratio
/// (last full-width window's rate over the first). The outage is timed
/// off the *fastest* policy's clean run, so the degraded interval lands
/// inside every policy's serve and all three windows contain decode
/// steps — every value is finite by construction (NaN would not render
/// as JSON). Arbitrary plans, lease/requeue counters and the full
/// scenario grid live in `numa-attn cluster --faults`.
pub fn serve_burst_fig(driver: &SimDriver, topo: &Topology, quick: bool) -> FigureResult {
    use crate::cluster::{ShardPlan, ShardStrategy};
    use crate::coordinator::{self as coord, FaultEvent, FaultPlan};
    let tp = *sweeps::CLUSTER_TP.last().expect("cluster sweep has TP degrees");
    let mut rows = Vec::new();
    for sc in coord::cluster_scenarios(quick).into_iter().filter(|sc| sc.tp == tp) {
        // Headroom over the sweep's step budget so neither the clean
        // timing runs nor the (longer) degraded re-serves ever truncate
        // mid-outage — truncation would leave the recovery window empty.
        let cfg = coord::ServeConfig { max_steps: sc.cfg.max_steps * 4, ..sc.cfg.clone() };
        let base = cfg.base_geometry();
        // Policies the rebalance can keep serving at every valid width
        // (the same rule `cluster --faults` applies).
        let policies: Vec<Policy> = coord::applicable_policies(topo, &base)
            .into_iter()
            .filter(|p| {
                (1..=tp).filter(|w| base.h_k % w == 0).all(|w| {
                    let sp = ShardPlan::new(&base, w, ShardStrategy::Contiguous)
                        .expect("w divides h_k by construction");
                    coord::applicable_policies(topo, &sp.local_attn(&base)).contains(p)
                })
            })
            .collect();
        let horizon = policies
            .iter()
            .map(|&p| {
                coord::serve_decode_faulty_with(driver, topo, tp, &cfg, p, &FaultPlan::default())
                    .serve
                    .sim_sec
            })
            .fold(f64::INFINITY, f64::min);
        let plan = FaultPlan {
            events: vec![FaultEvent {
                device: 1,
                fail_sec: 0.35 * horizon,
                recover_sec: 0.65 * horizon,
            }],
        };
        let runs: Vec<(Policy, coord::FaultyServeStats)> = policies
            .iter()
            .map(|&p| (p, coord::serve_decode_faulty_with(driver, topo, tp, &cfg, p, &plan)))
            .collect();
        let extras = |s: &coord::FaultyServeStats| -> coord::FaultExtras {
            s.faults.clone().expect("the plan scheduled an outage")
        };
        let degraded_width = extras(&runs[0].1)
            .windows
            .iter()
            .find(|w| w.width < tp)
            .map_or(0, |w| w.width);
        let window_row = |tag: String, value: &dyn Fn(&coord::FaultExtras) -> f64| FigureRow {
            label: format!("{} {tag}", sc.label),
            values: runs.iter().map(|(p, s)| (*p, value(&extras(s)))).collect(),
        };
        rows.push(window_row(format!("tokens/s w0 full (tp={tp})"), &|f| {
            f.windows.first().expect("pre-failure window").tokens_per_sec
        }));
        rows.push(window_row(format!("tokens/s w1 degraded (tp={degraded_width})"), &|f| {
            f.degraded_tokens_per_sec
        }));
        rows.push(window_row(format!("tokens/s w2 recovered (tp={tp})"), &|f| {
            f.windows
                .iter()
                .rev()
                .find(|w| w.width == tp && w.busy_sec > 0.0)
                .expect("the post-recovery window serves")
                .tokens_per_sec
        }));
        rows.push(FigureRow {
            label: format!("{} ttft p99 (ms)", sc.label),
            values: runs.iter().map(|(p, s)| (*p, s.serve.ttft_p99_ms)).collect(),
        });
        rows.push(window_row("recovery ratio (w2/w0)".into(), &|f| f.recovery_ratio));
    }
    FigureResult {
        id: "serve_burst".into(),
        title: "Cluster serving through a mid-run device outage (Llama-3 70B GQA-8)".into(),
        metric: "per-row: busy-time decode tokens/s (w0/w1/w2), TTFT p99 ms, recovery ratio".into(),
        rows,
    }
}

/// Regenerate every figure (the `numa-attn figure all` path) through one
/// driver: the whole set is still submitted figure-by-figure, but each
/// figure's grid fans out across the pool and repeated (point, policy)
/// jobs between figures (e.g. Fig. 12's grid overlapping Fig. 13's) are
/// served from the report cache.
pub fn all(driver: &SimDriver, topo: &Topology, quick: bool) -> Vec<FigureResult> {
    let mut figs = vec![
        fig12(driver, topo, quick),
        fig13(driver, topo, quick),
        fig14(driver, topo, quick),
        fig15(driver, topo, quick),
        fig16(driver, topo, quick),
        decode_fig(driver, topo, quick),
    ];
    let (serve, serve_ttft, serve_share) = serve_figs(driver, topo, quick);
    figs.push(serve);
    figs.push(serve_ttft);
    figs.push(serve_share);
    figs.push(cluster_fig(driver, topo, quick));
    figs.push(serve_burst_fig(driver, topo, quick));
    figs.push(disagg_fig(driver, topo, quick));
    figs.push(gemm_motivation(topo));
    figs
}

/// Sec. 1 motivating claim: GEMM L2 hit rate 43% -> 92% with the chiplet
/// swizzle.
pub fn gemm_motivation(topo: &Topology) -> FigureResult {
    let cfg = gemm::GemmConfig::default();
    let naive = gemm::simulate_gemm(topo, &cfg, false);
    let swizzled = gemm::simulate_gemm(topo, &cfg, true);
    FigureResult {
        id: "gemm".into(),
        title: "GEMM workgroup swizzling (Sec. 1 motivation)".into(),
        metric: "L2 hit rate (%)".into(),
        rows: vec![
            FigureRow {
                label: "GEMM 4096x65536x4096 bf16".into(),
                values: vec![
                    (Policy::NaiveBlockFirst, 100.0 * naive.l2.hit_rate()),
                    (Policy::SwizzledBlockFirst, 100.0 * swizzled.l2.hit_rate()),
                ],
            },
        ],
    }
}

/// Render the pinned perf trajectory (a `bench-v1` document, normally
/// the repo-root `BENCH_sim_hotpath.json` — format in docs/PERF.md) as
/// the aligned text panel behind `numa-attn figure perf`: one row per
/// bench case with its timings plus derived metrics (engine accesses/s,
/// event-vs-reference speedup).
pub fn perf_panel(doc: &crate::util::json::Json) -> Result<String, String> {
    use crate::util::json::Json;
    if doc.get("schema").and_then(Json::as_str) != Some("bench-v1") {
        return Err("not a bench-v1 document (see docs/PERF.md)".into());
    }
    let suite = doc.get("suite").and_then(Json::as_str).unwrap_or("?");
    let cases = doc
        .get("cases")
        .and_then(Json::as_arr)
        .ok_or("bench-v1 document has no 'cases' array")?;
    let mut t = Table::new(&["case", "iters", "mean ms", "min ms", "max ms", "metrics"]);
    for case in cases {
        let num = |k: &str| case.get(k).and_then(Json::as_f64);
        let ms = |k: &str| num(k).map(|v| format!("{v:.3}")).unwrap_or_else(|| "?".into());
        let metrics = match case.get("metrics") {
            Some(Json::Obj(kvs)) => kvs
                .iter()
                .filter_map(|(k, v)| {
                    let v = v.as_f64()?;
                    Some(match k.as_str() {
                        "accesses_per_sec" => format!("{:.1}M accesses/s", v / 1e6),
                        k if k.starts_with("speedup") => format!("{k}={v:.1}x"),
                        k => format!("{k}={v:.3}"),
                    })
                })
                .collect::<Vec<_>>()
                .join(", "),
            _ => String::new(),
        };
        t.row(vec![
            case.get("name").and_then(Json::as_str).unwrap_or("?").to_string(),
            num("iters").map(|v| format!("{v:.0}")).unwrap_or_else(|| "?".into()),
            ms("mean_ms"),
            ms("min_ms"),
            ms("max_ms"),
            metrics,
        ]);
    }
    Ok(format!(
        "== perf — {suite} trajectory (bench-v1, docs/PERF.md) ==\n\
         refresh: cargo bench --bench {suite}\n{}",
        t.render()
    ))
}

/// Table 1 as a rendered string (`numa-attn explain --topo`).
pub fn table1(topo: &Topology) -> String {
    let mut t = Table::new(&["component", "specification"]);
    t.row(vec!["Number of XCDs".into(), topo.num_xcds.to_string()]);
    t.row(vec![
        "Compute Units per XCD".into(),
        format!("{} ({} total)", topo.cus_per_xcd, topo.total_cus()),
    ]);
    t.row(vec![
        "L2 Cache per XCD".into(),
        format!(
            "{} MB ({} MB total)",
            topo.l2_bytes_per_xcd / (1024 * 1024),
            topo.total_l2_bytes() / (1024 * 1024)
        ),
    ]);
    t.row(vec![
        "HBM Bandwidth".into(),
        format!("{:.1} TB/s", topo.hbm_bytes_per_sec / 1e12),
    ]);
    t.row(vec![
        "Peak bf16".into(),
        format!("{:.0} TFLOP/s", topo.device_flops_per_sec() / 1e12),
    ]);
    t.row(vec![
        "Balance point".into(),
        format!("{:.0} FLOP/byte", topo.balance_flops_per_byte()),
    ]);
    t.render()
}

/// Roofline summary rows for a config (used by `explain` and perf docs).
pub fn roofline_summary(topo: &Topology, pt: &SweepPoint) -> String {
    let r = roofline::attention_roofline(topo, &pt.cfg, KernelKind::Forward);
    let k = roofline::kernel_estimate(&pt.cfg);
    format!(
        "{}: {:.1} GFLOP, intensity {:.0} flop/B ({}), ideal {:.3} ms | \
         kernel: VMEM {:.1} KiB, MXU util {:.0}%",
        pt.label,
        r.total_flops / 1e9,
        r.intensity,
        if r.compute_bound { "compute-bound" } else { "memory-bound" },
        r.ideal_sec * 1e3,
        k.vmem_bytes as f64 / 1024.0,
        100.0 * k.mxu_utilization,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets;

    fn fast_topo() -> Topology {
        // Scaled-down MI300X (keeps ratios; 8x fewer CUs, 8x smaller L2
        // and bandwidth) so unit tests run fast.
        Topology {
            name: "mi300x-mini".into(),
            cus_per_xcd: 8,
            l2_bytes_per_xcd: 1024 * 1024,
            hbm_bytes_per_sec: 5.3e12 / 4.75,
            ..presets::mi300x()
        }
    }

    #[test]
    fn fig12_shape_shf_wins_at_scale() {
        let topo = fast_topo();
        let driver = SimDriver::new(4);
        let f = fig12(&driver, &topo, true);
        assert_eq!(f.rows.len(), 2 * 2 * 2);
        // Every (point × policy) run went through the driver's cache.
        assert_eq!(driver.cache().misses() as usize, 2 * 2 * 2 * ALL_POLICIES.len());
        // At the extreme point, block-first must lose noticeably.
        let label = "H=128 N=128K B=8";
        let nbf = f.value(label, Policy::NaiveBlockFirst).unwrap();
        let shf = f.value(label, Policy::SwizzledHeadFirst).unwrap();
        assert!((shf - 1.0).abs() < 1e-9, "baseline normalization");
        assert!(nbf < 0.9, "NBF should degrade at extreme config, got {nbf}");
        // At the small point, all policies are close (paper: similar).
        let small = "H=8 N=8K B=1";
        let nbf_small = f.value(small, Policy::NaiveBlockFirst).unwrap();
        assert!(nbf_small > 0.8, "small configs similar, got {nbf_small}");
    }

    #[test]
    fn perf_panel_renders_bench_v1_and_rejects_other_schemas() {
        let doc = crate::util::json::Json::parse(
            r#"{"schema":"bench-v1","suite":"sim_hotpath","cases":[
                {"name":"engine: X","iters":5,"mean_ms":12.5,"min_ms":12.0,"max_ms":13.0,
                 "metrics":{"accesses_per_sec":24100000,"speedup_vs_reference":46.6}}]}"#,
        )
        .unwrap();
        let panel = perf_panel(&doc).unwrap();
        assert!(panel.contains("sim_hotpath trajectory"), "{panel}");
        assert!(panel.contains("engine: X"), "{panel}");
        assert!(panel.contains("24.1M accesses/s"), "{panel}");
        assert!(panel.contains("speedup_vs_reference=46.6x"), "{panel}");
        assert!(panel.contains("12.500"), "{panel}");

        let bad = crate::util::json::Json::parse(r#"{"schema":"bench-v2","cases":[]}"#).unwrap();
        assert!(perf_panel(&bad).is_err());
    }

    #[test]
    fn parallel_rows_match_serial_rows() {
        // The acceptance invariant: >1 worker produces row-for-row
        // identical figure output to a single worker (the full-figure
        // version of this is tests/driver_determinism.rs).
        let topo = fast_topo();
        let points = sweeps::mha_sensitivity(&[2048, 8192], &[1], &[8]);
        let serial = perf_rows(&SimDriver::new(1), &topo, &points);
        let parallel = perf_rows(&SimDriver::new(8), &topo, &points);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.label, b.label);
            for ((pa, va), (pb, vb)) in a.values.iter().zip(&b.values) {
                assert_eq!(pa, pb);
                assert_eq!(va.to_bits(), vb.to_bits(), "{} {pa:?}", a.label);
            }
        }
    }

    #[test]
    fn run_point_reports_all_policies_in_order() {
        let topo = fast_topo();
        let driver = SimDriver::new(2);
        let pt = &sweeps::mha_sensitivity(&[8192], &[1], &[8])[0];
        let reports = run_point(&driver, &topo, pt);
        assert_eq!(reports.len(), ALL_POLICIES.len());
        for ((p, r), want) in reports.iter().zip(ALL_POLICIES) {
            assert_eq!(*p, want);
            assert_eq!(r.policy, want);
        }
    }

    #[test]
    fn decode_fig_shf_at_least_nhf_and_thread_invariant() {
        // The decode acceptance claims: (a) SwizzledHeadFirst's L2 hit
        // rate is >= NaiveHeadFirst's on every GQA-8 decode row (NHF
        // replicates each (kv head, split) stream across XCDs), and
        // (b) the figure is byte-identical at 1 and 8 worker threads.
        // Runs on the real MI300X topology: decode grids are small, and
        // the 38-slot XCDs are what make the locality effect well-posed.
        let topo = presets::mi300x();
        let serial = decode_fig(&SimDriver::new(1), &topo, true);
        assert_eq!(serial.rows.len(), 2 * 2 * 2);
        for row in &serial.rows {
            let shf = serial.value(&row.label, Policy::SwizzledHeadFirst).unwrap();
            let nhf = serial.value(&row.label, Policy::NaiveHeadFirst).unwrap();
            assert!(shf >= nhf, "{}: SHF {shf:.2}% < NHF {nhf:.2}%", row.label);
        }
        let parallel = decode_fig(&SimDriver::new(8), &topo, true);
        assert_eq!(serial.to_json().render(), parallel.to_json().render());
    }

    #[test]
    fn serve_burst_fig_windows_are_finite_and_degraded_loses() {
        let topo = fast_topo();
        let driver = SimDriver::new(2);
        let f = serve_burst_fig(&driver, &topo, true);
        // One widest-TP scenario in quick mode, five panel rows.
        assert_eq!(f.rows.len(), 5, "{:?}", f.rows.iter().map(|r| &r.label).collect::<Vec<_>>());
        for row in &f.rows {
            for (p, v) in &row.values {
                assert!(v.is_finite(), "{} {p:?} = {v} must render as JSON", row.label);
            }
        }
        let label_of = |needle: &str| {
            f.rows
                .iter()
                .find(|r| r.label.contains(needle))
                .unwrap_or_else(|| panic!("row containing {needle:?}"))
                .label
                .clone()
        };
        let full = label_of("w0 full");
        let degraded = label_of("w1 degraded");
        let ratio = label_of("recovery ratio");
        for (p, _) in &f.rows[0].values {
            let w0 = f.value(&full, *p).unwrap();
            let w1 = f.value(&degraded, *p).unwrap();
            assert!(w1 < w0, "{p:?}: degraded {w1} should fall below healthy {w0}");
            let r = f.value(&ratio, *p).unwrap();
            assert!(r > 0.5, "{p:?}: recovery should restore most of the rate, got {r}");
        }
        // The panel must render as parseable JSON (no NaN leakage).
        crate::util::json::Json::parse(&f.to_json().render()).unwrap();
    }

    #[test]
    fn gemm_motivation_shape() {
        let f = gemm_motivation(&presets::mi300x());
        let naive = f.rows[0].values[0].1;
        let swz = f.rows[0].values[1].1;
        assert!(swz > naive + 20.0);
        assert!(swz > 80.0);
    }

    #[test]
    fn table1_renders() {
        let s = table1(&presets::mi300x());
        assert!(s.contains("8"));
        assert!(s.contains("38 (304 total)"));
        assert!(s.contains("4 MB (32 MB total)"));
        assert!(s.contains("5.3 TB/s"));
    }
}
