//! Per-workgroup tile access streams for the FA2 forward and backward
//! kernels — what the simulator replays through the memory hierarchy.
//!
//! A workgroup's life is a *prologue* (operands resident for its whole
//! duration: the Q row block for the forward kernel, the K/V column block
//! for dK/dV, the single-token query vector for decode), followed by a
//! sequence of *steps*, each reading the next tile(s) of the streamed
//! tensors and performing one tile of compute, and an output write at the
//! end. [`WgCursor`] yields these steps lazily so no trace is ever
//! materialized. The flash-decode kernels stream a KV *split* (phase 1)
//! or the phase-1 partial results (phase 2 reduction).

use super::tile::{self, Tensor};
use super::{AttnConfig, KernelKind, WorkItem};

/// One tile read: key + size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Read {
    /// Tile key ([`tile::key`]).
    pub key: u64,
    /// Tile size in bytes.
    pub bytes: u32,
}

/// One execution step of a workgroup: up to 4 tile reads then `flops` of
/// compute.
#[derive(Debug, Clone, Copy)]
pub struct Step {
    reads: [Read; 4],
    num_reads: u8,
    /// FLOPs of this step's compute (0 for the prologue).
    pub flops: f64,
}

impl Step {
    /// The tile reads this step performs.
    pub fn reads(&self) -> &[Read] {
        &self.reads[..self.num_reads as usize]
    }

    fn new(reads: &[Read], flops: f64) -> Self {
        let mut arr = [Read { key: 0, bytes: 0 }; 4];
        arr[..reads.len()].copy_from_slice(reads);
        Step { reads: arr, num_reads: reads.len() as u8, flops }
    }
}

/// Lazy generator of a workgroup's access stream.
#[derive(Debug, Clone)]
pub struct WgCursor {
    cfg: AttnConfig,
    kernel: KernelKind,
    item: WorkItem,
    /// Next step index; 0 = prologue.
    pos: u32,
    /// One past the last stream index (exclusive).
    end: u32,
    /// First stream index (causal dK/dV skips masked row blocks).
    start: u32,
}

impl WgCursor {
    /// Cursor over workgroup `item`'s access stream for `kernel`.
    pub fn new(cfg: &AttnConfig, kernel: KernelKind, item: WorkItem) -> Self {
        let (start, end) = stream_bounds(cfg, kernel, item);
        WgCursor { cfg: *cfg, kernel, item, pos: 0, start, end }
    }

    /// The workgroup's identity.
    pub fn item(&self) -> WorkItem {
        self.item
    }

    /// The kernel this workgroup belongs to.
    pub fn kernel(&self) -> KernelKind {
        self.kernel
    }

    /// Steps remaining, including the prologue if not yet consumed.
    pub fn remaining_steps(&self) -> u32 {
        if self.pos == 0 {
            1 + (self.end - self.start)
        } else {
            self.end - (self.start + self.pos - 1)
        }
    }

    /// Total stream steps (excluding prologue) this WG performs.
    pub fn stream_len(&self) -> u32 {
        self.end - self.start
    }

    /// Bytes this workgroup writes back to HBM when it retires.
    pub fn write_bytes(&self) -> u64 {
        match self.kernel {
            // O block (+ lse vector).
            KernelKind::Forward => self.cfg.q_block_bytes() + self.cfg.vec_block_bytes(),
            // dK + dV column tiles.
            KernelKind::BwdDkDv => 2 * self.cfg.kv_tile_bytes(),
            // dQ block.
            KernelKind::BwdDq => self.cfg.q_block_bytes(),
            // Partial (O, lse) of one split.
            KernelKind::DecodeSplitKv { .. } => self.cfg.decode_partial_bytes(),
            // Final output row of one (batch, head).
            KernelKind::DecodeReduce { .. } => self.cfg.q_vec_bytes(),
        }
    }

    /// Produce the next step, or `None` when the workgroup retires.
    pub fn next_step(&mut self) -> Option<Step> {
        let s = self.step_for_pos(self.pos);
        if s.is_some() {
            self.pos += 1;
        }
        s
    }

    /// Look `ahead` steps past the next one without advancing — used by
    /// the simulator's prefetch (double-buffering) model.
    pub fn peek(&self, ahead: u32) -> Option<Step> {
        self.step_for_pos(self.pos + ahead)
    }

    fn step_for_pos(&self, pos: u32) -> Option<Step> {
        let cfg = &self.cfg;
        let WorkItem { z, h, b } = self.item;
        let kv = cfg.kv_head(h as usize) as u32;
        if pos == 0 {
            let step = match self.kernel {
                KernelKind::Forward => Step::new(
                    &[Read { key: tile::key(Tensor::Q, z, h, b), bytes: cfg.q_block_bytes() as u32 }],
                    0.0,
                ),
                KernelKind::BwdDkDv => Step::new(
                    &[
                        Read { key: tile::key(Tensor::K, z, kv, b), bytes: cfg.kv_tile_bytes() as u32 },
                        Read { key: tile::key(Tensor::V, z, kv, b), bytes: cfg.kv_tile_bytes() as u32 },
                    ],
                    0.0,
                ),
                KernelKind::BwdDq => Step::new(
                    &[
                        Read { key: tile::key(Tensor::Q, z, h, b), bytes: cfg.q_block_bytes() as u32 },
                        Read { key: tile::key(Tensor::DO, z, h, b), bytes: cfg.q_block_bytes() as u32 },
                        Read { key: tile::key(Tensor::Lse, z, h, b), bytes: cfg.vec_block_bytes() as u32 },
                        Read { key: tile::key(Tensor::Delta, z, h, b), bytes: cfg.vec_block_bytes() as u32 },
                    ],
                    0.0,
                ),
                // Every split of a head reads the SAME single-token query
                // vector (tile index 0): splits that co-locate share it.
                KernelKind::DecodeSplitKv { .. } => Step::new(
                    &[Read { key: tile::key(Tensor::Q, z, h, 0), bytes: cfg.q_vec_bytes() as u32 }],
                    0.0,
                ),
                // The reduction has no resident operands: it only streams
                // the phase-1 partials.
                KernelKind::DecodeReduce { .. } => Step::new(&[], 0.0),
            };
            return Some(step);
        }
        let idx = self.start + pos - 1;
        if idx >= self.end {
            return None;
        }
        let step = match self.kernel {
            KernelKind::Forward => Step::new(
                &[
                    Read { key: tile::key(Tensor::K, z, kv, idx), bytes: cfg.kv_tile_bytes() as u32 },
                    Read { key: tile::key(Tensor::V, z, kv, idx), bytes: cfg.kv_tile_bytes() as u32 },
                ],
                cfg.fwd_step_flops(),
            ),
            KernelKind::BwdDkDv => Step::new(
                &[
                    Read { key: tile::key(Tensor::Q, z, h, idx), bytes: cfg.q_block_bytes() as u32 },
                    Read { key: tile::key(Tensor::DO, z, h, idx), bytes: cfg.q_block_bytes() as u32 },
                    Read { key: tile::key(Tensor::Lse, z, h, idx), bytes: cfg.vec_block_bytes() as u32 },
                    Read { key: tile::key(Tensor::Delta, z, h, idx), bytes: cfg.vec_block_bytes() as u32 },
                ],
                cfg.dkdv_step_flops(),
            ),
            KernelKind::BwdDq => Step::new(
                &[
                    Read { key: tile::key(Tensor::K, z, kv, idx), bytes: cfg.kv_tile_bytes() as u32 },
                    Read { key: tile::key(Tensor::V, z, kv, idx), bytes: cfg.kv_tile_bytes() as u32 },
                ],
                cfg.dq_step_flops(),
            ),
            // Same K/V column tiles as the forward kernel, restricted to
            // this split's [start, end) slice by `stream_bounds`.
            KernelKind::DecodeSplitKv { .. } => Step::new(
                &[
                    Read { key: tile::key(Tensor::K, z, kv, idx), bytes: cfg.kv_tile_bytes() as u32 },
                    Read { key: tile::key(Tensor::V, z, kv, idx), bytes: cfg.kv_tile_bytes() as u32 },
                ],
                cfg.decode_step_flops(),
            ),
            // Stream the phase-1 partials of this (batch, head), one
            // split per step.
            KernelKind::DecodeReduce { .. } => Step::new(
                &[
                    Read {
                        key: tile::key(Tensor::PartialO, z, h, idx),
                        bytes: (cfg.decode_partial_bytes() - 8) as u32,
                    },
                    Read { key: tile::key(Tensor::PartialLse, z, h, idx), bytes: 8 },
                ],
                cfg.reduce_step_flops(),
            ),
        };
        Some(step)
    }
}

/// [start, end) indices of the streamed dimension for one workgroup,
/// honoring the causal mask exactly like the Pallas kernels
/// (python/compile/kernels/fa2.py, fa2_bwd.py).
fn stream_bounds(cfg: &AttnConfig, kernel: KernelKind, item: WorkItem) -> (u32, u32) {
    let b = item.b as usize;
    match kernel {
        KernelKind::Forward | KernelKind::BwdDq => {
            let n_kv = cfg.num_col_blocks();
            let hi = if cfg.causal {
                (((b + 1) * cfg.block_m).div_ceil(cfg.block_n)).min(n_kv)
            } else {
                n_kv
            };
            (0, hi as u32)
        }
        KernelKind::BwdDkDv => {
            let n_rows = cfg.num_row_blocks();
            let lo = if cfg.causal { (b * cfg.block_n) / cfg.block_m } else { 0 };
            (lo as u32, n_rows as u32)
        }
        // Decode generates the NEXT token: the query is the last position
        // and attends to the whole context, so the causal mask never
        // truncates a split's slice.
        KernelKind::DecodeSplitKv { num_splits } => {
            let (lo, hi) = cfg.split_bounds(b, num_splits);
            (lo as u32, hi as u32)
        }
        KernelKind::DecodeReduce { num_splits } => (0, num_splits as u32),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attn::tile::decode;

    fn cfg() -> AttnConfig {
        AttnConfig::mha(2, 4, 1024, 64) // 8 row blocks, 16 col blocks
    }

    fn drain(cur: &mut WgCursor) -> Vec<Step> {
        let mut v = Vec::new();
        while let Some(s) = cur.next_step() {
            v.push(s);
        }
        v
    }

    #[test]
    fn forward_stream_shape() {
        let c = cfg();
        let item = WorkItem { z: 1, h: 2, b: 3 };
        let mut cur = WgCursor::new(&c, KernelKind::Forward, item);
        assert_eq!(cur.stream_len(), 16);
        let steps = drain(&mut cur);
        assert_eq!(steps.len(), 17); // prologue + 16 K/V steps
        // Prologue reads this WG's own Q block.
        let (t, z, h, i) = decode(steps[0].reads()[0].key);
        assert_eq!((t, z, h, i), (Tensor::Q as u8, 1, 2, 3));
        // Step j reads K and V tile j of the right head.
        for (j, s) in steps[1..].iter().enumerate() {
            assert_eq!(s.reads().len(), 2);
            let (tk, _, hk, ik) = decode(s.reads()[0].key);
            let (tv, _, hv, iv) = decode(s.reads()[1].key);
            assert_eq!(tk, Tensor::K as u8);
            assert_eq!(tv, Tensor::V as u8);
            assert_eq!((ik as usize, iv as usize), (j, j));
            assert_eq!((hk, hv), (2, 2)); // MHA: kv head == q head
            assert!(s.flops > 0.0);
        }
    }

    #[test]
    fn gqa_reads_shared_kv_head() {
        let c = AttnConfig::gqa(1, 8, 2, 512, 64);
        let mut cur = WgCursor::new(&c, KernelKind::Forward, WorkItem { z: 0, h: 5, b: 0 });
        let steps = drain(&mut cur);
        let (_, _, h_kv, _) = decode(steps[1].reads()[0].key);
        assert_eq!(h_kv, 1); // head 5, group 4 -> kv head 1
    }

    #[test]
    fn causal_forward_truncates_stream() {
        let mut c = cfg();
        c.causal = true;
        // block_m=128, block_n=64: row block b sees 2(b+1) K/V tiles.
        for b in 0..8u32 {
            let cur = WgCursor::new(&c, KernelKind::Forward, WorkItem { z: 0, h: 0, b });
            assert_eq!(cur.stream_len(), 2 * (b + 1));
        }
    }

    #[test]
    fn causal_dkdv_skips_masked_rows() {
        let mut c = cfg();
        c.causal = true;
        // column block jb starts at row block (jb*64)/128.
        let cur = WgCursor::new(&c, KernelKind::BwdDkDv, WorkItem { z: 0, h: 0, b: 6 });
        assert_eq!(cur.stream_len(), 8 - 3);
    }

    #[test]
    fn dkdv_stream_reads_q_do_lse_delta() {
        let c = cfg();
        let mut cur = WgCursor::new(&c, KernelKind::BwdDkDv, WorkItem { z: 0, h: 1, b: 2 });
        let steps = drain(&mut cur);
        assert_eq!(steps.len(), 1 + 8);
        // Prologue holds this WG's K/V column tiles.
        assert_eq!(steps[0].reads().len(), 2);
        let (t0, _, _, i0) = decode(steps[0].reads()[0].key);
        assert_eq!((t0, i0), (Tensor::K as u8, 2));
        // Each step reads 4 tensors of row block i.
        let kinds: Vec<u8> = steps[1].reads().iter().map(|r| decode(r.key).0).collect();
        assert_eq!(kinds, vec![Tensor::Q as u8, Tensor::DO as u8, Tensor::Lse as u8, Tensor::Delta as u8]);
    }

    #[test]
    fn write_bytes() {
        let c = cfg();
        let fwd = WgCursor::new(&c, KernelKind::Forward, WorkItem { z: 0, h: 0, b: 0 });
        assert_eq!(fwd.write_bytes(), c.q_block_bytes() + c.vec_block_bytes());
        let dkdv = WgCursor::new(&c, KernelKind::BwdDkDv, WorkItem { z: 0, h: 0, b: 0 });
        assert_eq!(dkdv.write_bytes(), 2 * c.kv_tile_bytes());
    }

    #[test]
    fn remaining_steps_counts_down() {
        let c = cfg();
        let mut cur = WgCursor::new(&c, KernelKind::Forward, WorkItem { z: 0, h: 0, b: 0 });
        let total = cur.remaining_steps();
        assert_eq!(total, 17);
        cur.next_step();
        assert_eq!(cur.remaining_steps(), 16);
        drain(&mut cur);
        assert_eq!(cur.remaining_steps(), 0);
    }

    #[test]
    fn decode_split_stream_shape() {
        let c = cfg(); // 16 col blocks
        let kernel = KernelKind::DecodeSplitKv { num_splits: 4 };
        let mut cur = WgCursor::new(&c, kernel, WorkItem { z: 1, h: 2, b: 3 });
        assert_eq!(cur.stream_len(), 4); // 16 col blocks / 4 splits
        assert_eq!(cur.write_bytes(), c.decode_partial_bytes());
        let steps = drain(&mut cur);
        assert_eq!(steps.len(), 1 + 4);
        // Prologue reads the single-token query vector (tile 0).
        let (t, z, h, i) = decode(steps[0].reads()[0].key);
        assert_eq!((t, z, h, i), (Tensor::Q as u8, 1, 2, 0));
        assert_eq!(steps[0].reads()[0].bytes, c.q_vec_bytes() as u32);
        // Split 3 of 4 covers column blocks 12..16.
        for (j, s) in steps[1..].iter().enumerate() {
            assert_eq!(s.reads().len(), 2);
            let (tk, _, hk, ik) = decode(s.reads()[0].key);
            let (tv, _, _, iv) = decode(s.reads()[1].key);
            assert_eq!((tk, tv), (Tensor::K as u8, Tensor::V as u8));
            assert_eq!((ik as usize, iv as usize), (12 + j, 12 + j));
            assert_eq!(hk, 2); // MHA: kv head == q head
            assert!(s.flops > 0.0);
        }
    }

    #[test]
    fn decode_splits_partition_kv_stream() {
        // Across all splits, each K/V column tile of a head is read by
        // exactly one split-KV workgroup (splits are disjoint and cover).
        let c = cfg();
        let kernel = KernelKind::DecodeSplitKv { num_splits: 3 }; // 16 % 3 != 0
        let mut seen = Vec::new();
        for b in 0..3u32 {
            let mut cur = WgCursor::new(&c, kernel, WorkItem { z: 0, h: 1, b });
            cur.next_step(); // skip prologue
            while let Some(s) = cur.next_step() {
                let (_, _, _, idx) = decode(s.reads()[0].key);
                seen.push(idx as usize);
            }
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..c.num_col_blocks()).collect::<Vec<_>>());
    }

    #[test]
    fn decode_gqa_splits_share_group_kv() {
        // Two query heads of the same GQA group read identical K/V tile
        // keys for the same split — the decode locality the mapping
        // policies compete on.
        let c = AttnConfig::gqa(1, 8, 2, 1024, 64);
        let kernel = KernelKind::DecodeSplitKv { num_splits: 4 };
        let keys = |h: u32| {
            let mut cur = WgCursor::new(&c, kernel, WorkItem { z: 0, h, b: 2 });
            cur.next_step();
            drain(&mut cur).iter().flat_map(|s| s.reads().iter().map(|r| r.key)).collect::<Vec<_>>()
        };
        assert_eq!(keys(4), keys(7)); // heads 4..7 share kv head 1
        assert_ne!(keys(0), keys(4)); // different groups share nothing
    }

    #[test]
    fn decode_reduce_streams_partials() {
        let c = cfg();
        let kernel = KernelKind::DecodeReduce { num_splits: 5 };
        let mut cur = WgCursor::new(&c, kernel, WorkItem { z: 1, h: 3, b: 0 });
        assert_eq!(cur.stream_len(), 5);
        assert_eq!(cur.write_bytes(), c.q_vec_bytes());
        let steps = drain(&mut cur);
        assert_eq!(steps.len(), 1 + 5);
        assert_eq!(steps[0].reads().len(), 0); // no resident operands
        for (j, s) in steps[1..].iter().enumerate() {
            let (to, z, h, i) = decode(s.reads()[0].key);
            let (tl, _, _, il) = decode(s.reads()[1].key);
            assert_eq!((to, tl), (Tensor::PartialO as u8, Tensor::PartialLse as u8));
            assert_eq!((z, h), (1, 3));
            assert_eq!((i as usize, il as usize), (j, j));
        }
        // Total partial bytes streamed == what phase 1 wrote.
        let read: u64 = steps.iter().flat_map(|s| s.reads().iter().map(|r| r.bytes as u64)).sum();
        assert_eq!(read, 5 * c.decode_partial_bytes());
    }

    #[test]
    fn causal_does_not_truncate_decode() {
        let mut c = cfg();
        c.causal = true;
        let kernel = KernelKind::DecodeSplitKv { num_splits: 2 };
        for b in 0..2u32 {
            let cur = WgCursor::new(&c, kernel, WorkItem { z: 0, h: 0, b });
            assert_eq!(cur.stream_len(), 8); // 16 col blocks / 2, mask-free
        }
    }

    #[test]
    fn two_wgs_same_head_share_kv_keys() {
        // The spatial-locality fact the whole paper rests on (Fig. 4):
        // row blocks of one head read IDENTICAL K/V tile keys.
        let c = cfg();
        let mut a = WgCursor::new(&c, KernelKind::Forward, WorkItem { z: 0, h: 1, b: 0 });
        let mut bq = WgCursor::new(&c, KernelKind::Forward, WorkItem { z: 0, h: 1, b: 5 });
        a.next_step();
        bq.next_step(); // skip prologues (different Q blocks)
        let ka: Vec<u64> = drain(&mut a).iter().flat_map(|s| s.reads().iter().map(|r| r.key)).collect();
        let kb: Vec<u64> = drain(&mut bq).iter().flat_map(|s| s.reads().iter().map(|r| r.key)).collect();
        assert_eq!(ka, kb);
        // ... and different heads share NOTHING.
        let mut other = WgCursor::new(&c, KernelKind::Forward, WorkItem { z: 0, h: 2, b: 0 });
        other.next_step();
        let ko: Vec<u64> = drain(&mut other).iter().flat_map(|s| s.reads().iter().map(|r| r.key)).collect();
        assert!(ka.iter().all(|k| !ko.contains(k)));
    }
}
