//! FlashAttention2 grid model: the computational structure the paper's
//! mapping policies schedule (Figs. 4-6).
//!
//! * [`AttnConfig`] — the workload hyper-parameters (Z, H_Q, H_K, N_CTX,
//!   D_HEAD, BLOCK_M/N, causal, dtype).
//! * [`WorkItem`] — one workgroup's identity: (batch, head, block) —
//!   where "block" is a KV split for the flash-decode kernels.
//! * [`tile`] — tile-key encoding for the cache simulator.
//! * [`trace`] — per-workgroup tile access streams for the forward and
//!   backward kernels ([`trace::WgCursor`]).
//! * [`acc`] — Attention Compute Cluster derivation: the set of workgroups
//!   sharing the same K/V (MHA: one per head; GQA: one per KV group).

pub mod acc;
pub mod tile;
pub mod trace;

/// Which kernel's grid is being scheduled.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// FA2 forward: one WG per Q row block, streaming K/V (Fig. 4).
    Forward,
    /// FA2 backward dK/dV: one WG per K/V column block, streaming
    /// Q/dO/lse/delta.
    BwdDkDv,
    /// FA2 backward dQ: one WG per Q row block, streaming K/V.
    BwdDq,
    /// Flash-decode phase 1: one WG per (batch, head, KV split), each
    /// streaming its contiguous slice of the head's K/V and writing a
    /// partial (O, lse) result. The decode grid has one query token per
    /// (batch, head) — too small to fill eight XCDs unless the KV
    /// dimension is split, which is exactly what this kernel does
    /// (FlashAttention-2's split-KV work partitioning; see
    /// docs/REFERENCE.md and DESIGN.md §9).
    DecodeSplitKv {
        /// Number of KV splits per (batch, head) — the grid's block
        /// dimension. Mapping policies treat splits exactly like blocks.
        num_splits: usize,
    },
    /// Flash-decode phase 2: one WG per (batch, head), reading the
    /// `num_splits` partial (O, lse) results of phase 1 and reducing
    /// them into the final output row.
    DecodeReduce {
        /// Splits produced by the matching [`KernelKind::DecodeSplitKv`]
        /// launch (the reduction's stream length).
        num_splits: usize,
    },
}

impl KernelKind {
    /// Stable lowercase identifier (JSON output, CLI messages).
    pub fn name(&self) -> &'static str {
        match self {
            KernelKind::Forward => "forward",
            KernelKind::BwdDkDv => "bwd_dkdv",
            KernelKind::BwdDq => "bwd_dq",
            KernelKind::DecodeSplitKv { .. } => "decode_split_kv",
            KernelKind::DecodeReduce { .. } => "decode_reduce",
        }
    }

    /// KV splits for the decode kernels, `None` for prefill/backward.
    pub fn num_splits(&self) -> Option<usize> {
        match self {
            KernelKind::DecodeSplitKv { num_splits } | KernelKind::DecodeReduce { num_splits } => {
                Some(*num_splits)
            }
            _ => None,
        }
    }
}

/// Attention workload hyper-parameters (paper Table 2 / Table 3 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct AttnConfig {
    /// Batch size Z.
    pub batch: usize,
    /// Query heads H_Q.
    pub h_q: usize,
    /// Key/value heads H_K (== h_q for MHA; h_q % h_k == 0 for GQA).
    pub h_k: usize,
    /// Context length N_CTX.
    pub n_ctx: usize,
    /// Head dimension D_HEAD.
    pub d_head: usize,
    /// Q row-block size (paper: 128).
    pub block_m: usize,
    /// K/V column-block size (paper: 64).
    pub block_n: usize,
    /// Causal masking (halves the average K/V stream length).
    pub causal: bool,
    /// Bytes per element (2 = bf16/fp16, 4 = fp32).
    pub dtype_bytes: usize,
}

impl AttnConfig {
    /// MHA config with the paper's default blocks (Table 2).
    pub fn mha(batch: usize, heads: usize, n_ctx: usize, d_head: usize) -> Self {
        AttnConfig {
            batch,
            h_q: heads,
            h_k: heads,
            n_ctx,
            d_head,
            block_m: 128,
            block_n: 64,
            causal: false,
            dtype_bytes: 2,
        }
    }

    /// GQA config (Table 3 Llama rows: H_K = 8).
    pub fn gqa(batch: usize, h_q: usize, h_k: usize, n_ctx: usize, d_head: usize) -> Self {
        AttnConfig { h_q, h_k, ..Self::mha(batch, h_q, n_ctx, d_head) }
    }

    /// Check the geometry's internal consistency (GQA divisibility,
    /// positive sizes, supported dtype width).
    pub fn validate(&self) -> Result<(), String> {
        if self.batch == 0 || self.h_q == 0 || self.h_k == 0 {
            return Err("batch/h_q/h_k must be > 0".into());
        }
        if self.h_q % self.h_k != 0 {
            return Err(format!("h_k ({}) must divide h_q ({})", self.h_k, self.h_q));
        }
        if self.n_ctx == 0 || self.d_head == 0 {
            return Err("n_ctx/d_head must be > 0".into());
        }
        if self.block_m == 0 || self.block_n == 0 {
            return Err("block sizes must be > 0".into());
        }
        if self.dtype_bytes != 2 && self.dtype_bytes != 4 {
            return Err("dtype_bytes must be 2 or 4".into());
        }
        Ok(())
    }

    /// GQA group size (query heads per KV head).
    pub fn group(&self) -> usize {
        self.h_q / self.h_k
    }

    /// KV head serving query head `h`.
    pub fn kv_head(&self, h: usize) -> usize {
        h / self.group()
    }

    /// Q row blocks per head.
    pub fn num_row_blocks(&self) -> usize {
        self.n_ctx.div_ceil(self.block_m)
    }

    /// K/V column blocks per head.
    pub fn num_col_blocks(&self) -> usize {
        self.n_ctx.div_ceil(self.block_n)
    }

    /// Number of blocks in the dimension a kernel parallelizes over.
    pub fn blocks_for(&self, kernel: KernelKind) -> usize {
        match kernel {
            KernelKind::Forward | KernelKind::BwdDq => self.num_row_blocks(),
            KernelKind::BwdDkDv => self.num_col_blocks(),
            KernelKind::DecodeSplitKv { num_splits } => num_splits,
            KernelKind::DecodeReduce { .. } => 1,
        }
    }

    /// Clamp a requested KV split count to the valid range: at least 1,
    /// at most one KV column block per split (beyond that, extra splits
    /// stream nothing and only multiply partial-result traffic). The
    /// single definition of the bound the CLI, the advisor, and the
    /// experiment-file parser all share.
    pub fn clamp_num_splits(&self, requested: usize) -> usize {
        requested.clamp(1, self.num_col_blocks().max(1))
    }

    /// [start, end) K/V column-block range of decode split `split` out of
    /// `num_splits` — the balanced partition FlashAttention-2 uses (every
    /// column block covered exactly once; sizes differ by at most one).
    pub fn split_bounds(&self, split: usize, num_splits: usize) -> (usize, usize) {
        debug_assert!(num_splits > 0 && split < num_splits);
        let nb = self.num_col_blocks();
        (split * nb / num_splits, (split + 1) * nb / num_splits)
    }

    /// Total workgroups in a kernel's grid
    /// (`batch * h_q * blocks`, the paper's Fig. 11 grid lambda).
    pub fn grid_size(&self, kernel: KernelKind) -> usize {
        self.batch * self.h_q * self.blocks_for(kernel)
    }

    /// Head dimension padded to the MFMA K-granule (64): kernels lay
    /// K/V/Q tiles out padded so the matrix cores can consume them
    /// directly, so D_HEAD=56 moves 64-wide tiles (paper Sec. 4.5's
    /// "lower arithmetic intensity": more bytes per useful FLOP).
    pub fn padded_d_head(&self) -> usize {
        self.d_head.div_ceil(64) * 64
    }

    /// Bytes of one Q row block (also dO/O block), MFMA-padded.
    pub fn q_block_bytes(&self) -> u64 {
        (self.block_m * self.padded_d_head() * self.dtype_bytes) as u64
    }

    /// Bytes of one K (or V) column tile, MFMA-padded.
    pub fn kv_tile_bytes(&self) -> u64 {
        (self.block_n * self.padded_d_head() * self.dtype_bytes) as u64
    }

    /// Bytes of one lse/delta row-block vector (float32).
    pub fn vec_block_bytes(&self) -> u64 {
        (self.block_m * 4) as u64
    }

    /// Bytes of one decode query vector (a single token's Q row,
    /// MFMA-padded like the block operands).
    pub fn q_vec_bytes(&self) -> u64 {
        (self.padded_d_head() * self.dtype_bytes) as u64
    }

    /// Bytes of one decode partial result: an fp32 accumulator row plus
    /// the split's (max, sum-of-exp) softmax state — what each phase-1
    /// split-KV workgroup writes and the phase-2 reduction reads.
    pub fn decode_partial_bytes(&self) -> u64 {
        (self.padded_d_head() * 4 + 8) as u64
    }

    /// Bytes of the full K + V tensors of ONE head — the ACC working set
    /// whose fit (or not) in a 4 MB XCD L2 drives the paper's Fig. 13.
    pub fn kv_bytes_per_head(&self) -> u64 {
        2 * (self.n_ctx * self.d_head * self.dtype_bytes) as u64
    }

    /// FLOPs of one forward K/V tile step for one WG:
    /// S = Q·K^T (2·m·n·d) plus O += P·V (2·m·n·d).
    pub fn fwd_step_flops(&self) -> f64 {
        4.0 * (self.block_m * self.block_n * self.d_head) as f64
    }

    /// FLOPs of one dK/dV tile step (4 GEMMs: S, dV, dP, dK).
    pub fn dkdv_step_flops(&self) -> f64 {
        8.0 * (self.block_m * self.block_n * self.d_head) as f64
    }

    /// FLOPs of one dQ tile step (3 GEMMs: S, dP, dQ).
    pub fn dq_step_flops(&self) -> f64 {
        6.0 * (self.block_m * self.block_n * self.d_head) as f64
    }

    /// FLOPs of one decode split-KV step: the forward tile step with a
    /// single query row (m = 1) — s = q·K^T plus o += p·V.
    pub fn decode_step_flops(&self) -> f64 {
        4.0 * (self.block_n * self.d_head) as f64
    }

    /// FLOPs of one decode-reduce step: rescale one partial accumulator
    /// row and fold it into the running (max, sum) softmax state
    /// (~4 vector ops per element).
    pub fn reduce_step_flops(&self) -> f64 {
        (4 * self.padded_d_head()) as f64
    }

    /// FLOPs of one stream step of `kernel` — the quantity one simulator
    /// tick is normalized to ([`crate::sim`]).
    pub fn step_flops_for(&self, kernel: KernelKind) -> f64 {
        match kernel {
            KernelKind::Forward => self.fwd_step_flops(),
            KernelKind::BwdDkDv => self.dkdv_step_flops(),
            KernelKind::BwdDq => self.dq_step_flops(),
            KernelKind::DecodeSplitKv { .. } => self.decode_step_flops(),
            KernelKind::DecodeReduce { .. } => self.reduce_step_flops(),
        }
    }

    /// Total forward FLOPs (non-causal: 4·Z·H·N²·D; causal: half).
    pub fn total_fwd_flops(&self) -> f64 {
        let full = 4.0
            * (self.batch * self.h_q) as f64
            * (self.n_ctx as f64)
            * (self.n_ctx as f64)
            * self.d_head as f64;
        if self.causal {
            full / 2.0
        } else {
            full
        }
    }

    /// Arithmetic intensity of the forward pass assuming *ideal* caching
    /// (each tensor read once from HBM): FLOPs / HBM bytes.
    pub fn ideal_intensity(&self) -> f64 {
        let q_bytes = (self.batch * self.h_q * self.n_ctx * self.d_head * self.dtype_bytes) as f64;
        let kv_bytes = 2.0 * (self.batch * self.h_k * self.n_ctx * self.d_head * self.dtype_bytes) as f64;
        let o_bytes = q_bytes;
        self.total_fwd_flops() / (q_bytes + kv_bytes + o_bytes)
    }

    /// Matrix-core efficiency of the inner GEMMs for this head dimension.
    ///
    /// The MFMA/MXU contracts over K in fixed granules; a head dimension
    /// that is not a granule multiple pads the contraction (D_HEAD = 56
    /// runs at 56/64 of peak), and a small D also raises the relative
    /// cost of the softmax vector work (~a few vector ops per m*n score
    /// element vs 2*D MACs). This is the paper's Sec. 4.5 observation
    /// ("the smaller head dimension reduces overall arithmetic
    /// intensity, thereby lowering absolute performance") made concrete.
    pub fn compute_efficiency_factor(&self) -> f64 {
        const K_GRANULE: f64 = 64.0;
        const SOFTMAX_VOPS_PER_SCORE: f64 = 6.0; // exp, max, mul, adds
        let d = self.d_head as f64;
        let mfma = d / (d / K_GRANULE).ceil() / K_GRANULE;
        let softmax_overhead = SOFTMAX_VOPS_PER_SCORE / (4.0 * d);
        mfma / (1.0 + softmax_overhead)
    }
}

/// One workgroup's logical work assignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WorkItem {
    /// Batch index.
    pub z: u32,
    /// Query head index.
    pub h: u32,
    /// Block index: row block for Forward/BwdDq, column block for
    /// BwdDkDv, KV split for DecodeSplitKv (always 0 for DecodeReduce).
    pub b: u32,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_config() {
        let c = AttnConfig::mha(8, 128, 128 * 1024, 128);
        c.validate().unwrap();
        assert_eq!(c.num_row_blocks(), 1024);
        assert_eq!(c.num_col_blocks(), 2048);
        assert_eq!(c.grid_size(KernelKind::Forward), 8 * 128 * 1024);
        assert_eq!(c.group(), 1);
    }

    #[test]
    fn gqa_llama70b() {
        // Table 3: Llama-3 70B = GQA H_Q=64 H_K=8 D=128.
        let c = AttnConfig::gqa(1, 64, 8, 8192, 128);
        c.validate().unwrap();
        assert_eq!(c.group(), 8);
        assert_eq!(c.kv_head(0), 0);
        assert_eq!(c.kv_head(7), 0);
        assert_eq!(c.kv_head(8), 1);
        assert_eq!(c.kv_head(63), 7);
    }

    #[test]
    fn tile_byte_sizes() {
        let c = AttnConfig::mha(1, 8, 8192, 128);
        assert_eq!(c.q_block_bytes(), 128 * 128 * 2);
        assert_eq!(c.kv_tile_bytes(), 64 * 128 * 2);
        // One head's K+V at 128K fp16 D=128 = 64 MiB >> 4 MiB L2.
        let big = AttnConfig::mha(1, 8, 128 * 1024, 128);
        assert_eq!(big.kv_bytes_per_head(), 64 * 1024 * 1024);
    }

    #[test]
    fn validation() {
        assert!(AttnConfig::mha(0, 8, 1024, 64).validate().is_err());
        assert!(AttnConfig::gqa(1, 6, 4, 1024, 64).validate().is_err());
        let mut c = AttnConfig::mha(1, 8, 1024, 64);
        c.dtype_bytes = 3;
        assert!(c.validate().is_err());
    }

    #[test]
    fn flops_accounting() {
        let c = AttnConfig::mha(1, 1, 1024, 128);
        // steps per WG (non-causal) = n/block_n = 16;
        // WGs = n/block_m = 8; total = fwd_step_flops * 16 * 8
        let total = c.fwd_step_flops() * 16.0 * 8.0;
        assert!((total - c.total_fwd_flops()).abs() / total < 1e-12);
    }

    #[test]
    fn causal_halves_flops() {
        let mut c = AttnConfig::mha(1, 8, 4096, 128);
        let full = c.total_fwd_flops();
        c.causal = true;
        assert!((c.total_fwd_flops() - full / 2.0).abs() < 1.0);
    }

    #[test]
    fn deepseek_low_compute_efficiency() {
        // D_HEAD=56 pads the MFMA K granule and raises relative softmax
        // cost vs D=128 (paper Sec. 4.5).
        let ds = AttnConfig::mha(1, 128, 8192, 56);
        let std = AttnConfig::mha(1, 128, 8192, 128);
        assert!(ds.compute_efficiency_factor() < std.compute_efficiency_factor());
        assert!(ds.compute_efficiency_factor() < 0.9);
        assert!(std.compute_efficiency_factor() > 0.95);
    }

    #[test]
    fn bwd_grids() {
        let c = AttnConfig::mha(2, 16, 8192, 128);
        assert_eq!(c.grid_size(KernelKind::BwdDq), 2 * 16 * 64);
        assert_eq!(c.grid_size(KernelKind::BwdDkDv), 2 * 16 * 128);
    }

    #[test]
    fn decode_grids() {
        // Decode grid = batch * heads * splits; reduce grid = batch * heads.
        let c = AttnConfig::gqa(4, 64, 8, 65536, 128);
        assert_eq!(c.grid_size(KernelKind::DecodeSplitKv { num_splits: 8 }), 4 * 64 * 8);
        assert_eq!(c.grid_size(KernelKind::DecodeReduce { num_splits: 8 }), 4 * 64);
        assert_eq!(KernelKind::DecodeSplitKv { num_splits: 8 }.num_splits(), Some(8));
        assert_eq!(KernelKind::Forward.num_splits(), None);
        assert_eq!(KernelKind::DecodeSplitKv { num_splits: 8 }.name(), "decode_split_kv");
    }

    #[test]
    fn split_bounds_partition_col_blocks() {
        // Balanced partition: covers every column block exactly once,
        // sizes differ by at most one, including non-divisible counts.
        for (n_ctx, splits) in [(65536, 8), (4096, 4), (4096, 3), (1024, 16), (128, 4)] {
            let c = AttnConfig::mha(1, 8, n_ctx, 128);
            let nb = c.num_col_blocks();
            let mut covered = 0;
            let mut sizes = Vec::new();
            for s in 0..splits {
                let (lo, hi) = c.split_bounds(s, splits);
                assert_eq!(lo, covered, "split {s} of {splits} at N={n_ctx}");
                assert!(hi >= lo);
                sizes.push(hi - lo);
                covered = hi;
            }
            assert_eq!(covered, nb);
            let min = sizes.iter().min().unwrap();
            let max = sizes.iter().max().unwrap();
            assert!(max - min <= 1, "unbalanced split sizes {sizes:?}");
        }
    }

    #[test]
    fn decode_byte_and_flop_accounting() {
        let c = AttnConfig::mha(1, 8, 8192, 128);
        assert_eq!(c.q_vec_bytes(), 128 * 2);
        assert_eq!(c.decode_partial_bytes(), 128 * 4 + 8);
        // m = 1 forward tile step.
        assert!((c.decode_step_flops() - 4.0 * 64.0 * 128.0).abs() < 1e-9);
        assert!(c.reduce_step_flops() > 0.0);
        assert_eq!(
            c.step_flops_for(KernelKind::DecodeSplitKv { num_splits: 4 }),
            c.decode_step_flops()
        );
        assert_eq!(c.step_flops_for(KernelKind::Forward), c.fwd_step_flops());
    }
}
