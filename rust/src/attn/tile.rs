//! Tile-key encoding: a unique `u64` per cacheable tile of every tensor
//! in the attention workload, used as the cache/HBM key space.
//!
//! Layout (low to high): tile index (28 bits) | head (14) | batch (10) |
//! tensor kind (4). Bounds checked in debug builds; the paper's largest
//! config (B=8, H=128, N_CTX=128K, BLOCK_N=64 → 2048 tiles) uses a tiny
//! fraction of each field.

/// Which tensor a tile belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Tensor {
    /// Query row blocks (one token vector in decode).
    Q = 0,
    /// Key column tiles.
    K = 1,
    /// Value column tiles.
    V = 2,
    /// Output row blocks.
    O = 3,
    /// Backward: upstream gradient dO row blocks.
    DO = 4,
    /// Log-sum-exp row vectors.
    Lse = 5,
    /// Backward: the precomputed rowsum(dO * O) vectors.
    Delta = 6,
    /// GEMM operand A (for the GEMM motivation figure).
    GemmA = 7,
    /// GEMM operand B.
    GemmB = 8,
    /// Flash-decode phase-1 partial output row, indexed by KV split.
    PartialO = 9,
    /// Flash-decode phase-1 partial (max, sum-of-exp) softmax state,
    /// indexed by KV split.
    PartialLse = 10,
}

const TILE_BITS: u32 = 28;
const HEAD_BITS: u32 = 14;
const BATCH_BITS: u32 = 10;

/// Encode a tile key.
#[inline]
pub fn key(tensor: Tensor, z: u32, head: u32, tile: u32) -> u64 {
    debug_assert!(tile < (1 << TILE_BITS));
    debug_assert!(head < (1 << HEAD_BITS));
    debug_assert!(z < (1 << BATCH_BITS));
    ((tensor as u64) << (TILE_BITS + HEAD_BITS + BATCH_BITS))
        | ((z as u64) << (TILE_BITS + HEAD_BITS))
        | ((head as u64) << TILE_BITS)
        | tile as u64
}

/// Decode a tile key (diagnostics/tests).
pub fn decode(k: u64) -> (u8, u32, u32, u32) {
    let tile = (k & ((1 << TILE_BITS) - 1)) as u32;
    let head = ((k >> TILE_BITS) & ((1 << HEAD_BITS) - 1)) as u32;
    let z = ((k >> (TILE_BITS + HEAD_BITS)) & ((1 << BATCH_BITS) - 1)) as u32;
    let tensor = (k >> (TILE_BITS + HEAD_BITS + BATCH_BITS)) as u8;
    (tensor, z, head, tile)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        for (t, z, h, i) in [
            (Tensor::Q, 0u32, 0u32, 0u32),
            (Tensor::K, 7, 127, 2047),
            (Tensor::V, 1, 1, 1),
            (Tensor::Delta, 1023, 16383, (1 << 28) - 1),
            (Tensor::PartialO, 3, 63, 255),
            (Tensor::PartialLse, 3, 63, 255),
        ] {
            let k = key(t, z, h, i);
            assert_eq!(decode(k), (t as u8, z, h, i));
        }
    }

    #[test]
    fn distinct_tensors_distinct_keys() {
        let a = key(Tensor::K, 0, 0, 5);
        let b = key(Tensor::V, 0, 0, 5);
        let c = key(Tensor::Q, 0, 0, 5);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_ne!(b, c);
    }

    #[test]
    fn distinct_heads_distinct_keys() {
        assert_ne!(key(Tensor::K, 0, 1, 0), key(Tensor::K, 0, 2, 0));
        assert_ne!(key(Tensor::K, 1, 1, 0), key(Tensor::K, 2, 1, 0));
    }
}
