//! Attention Compute Clusters (paper Fig. 6).
//!
//! An ACC is the set of workgroups that share the same K/V tensors:
//! one per (batch, head) in MHA, one per (batch, KV group) in GQA.
//! Co-locating an ACC on a single XCD is the paper's key optimization
//! insight; these helpers derive ACC identities and measure how a mapping
//! policy distributes ACCs over XCDs (used by tests and `numa-attn
//! explain`).

use std::collections::{BTreeMap, BTreeSet};

use super::{AttnConfig, WorkItem};

/// Identity of an attention compute cluster: (batch, kv_head).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AccId {
    /// Batch index.
    pub z: u32,
    /// KV head (group) index.
    pub kv_head: u32,
}

/// ACC of a workgroup: determined by the K/V tensors it streams. The
/// block index never matters — on the flash-decode grid, where `b` is a
/// KV split, all splits of a head stream (slices of) the same K/V pair
/// and so belong to the same ACC.
pub fn acc_of(cfg: &AttnConfig, item: WorkItem) -> AccId {
    AccId { z: item.z, kv_head: cfg.kv_head(item.h as usize) as u32 }
}

/// Total ACCs in the workload: batch × H_K groups.
pub fn num_accs(cfg: &AttnConfig) -> usize {
    cfg.batch * cfg.h_k
}

/// Workgroups per ACC (grid cells sharing one K/V tensor pair).
pub fn wgs_per_acc(cfg: &AttnConfig, blocks: usize) -> usize {
    cfg.group() * blocks
}

/// Summary of how a WG->XCD assignment treats ACCs.
#[derive(Debug, Clone, PartialEq)]
pub struct AccSpread {
    /// For each ACC: how many distinct XCDs its workgroups land on.
    /// 1 everywhere == perfect co-location (the paper's goal).
    pub xcds_per_acc: BTreeMap<AccId, usize>,
    /// For each XCD: how many distinct ACCs it services over the whole
    /// grid. High values mean the XCD's L2 is timeshared by many K/V
    /// streams (the block-first pathology).
    pub accs_per_xcd: Vec<usize>,
}

impl AccSpread {
    /// Compute the spread of an assignment `(item, xcd)` pairs.
    pub fn measure(
        cfg: &AttnConfig,
        num_xcds: usize,
        assignment: impl Iterator<Item = (WorkItem, u32)>,
    ) -> Self {
        let mut per_acc: BTreeMap<AccId, BTreeSet<u32>> = BTreeMap::new();
        let mut per_xcd: Vec<BTreeSet<AccId>> = vec![BTreeSet::new(); num_xcds];
        for (item, xcd) in assignment {
            let acc = acc_of(cfg, item);
            per_acc.entry(acc).or_default().insert(xcd);
            per_xcd[xcd as usize].insert(acc);
        }
        AccSpread {
            xcds_per_acc: per_acc.into_iter().map(|(k, v)| (k, v.len())).collect(),
            accs_per_xcd: per_xcd.into_iter().map(|s| s.len()).collect(),
        }
    }

    /// True iff every ACC is confined to exactly one XCD.
    pub fn perfectly_colocated(&self) -> bool {
        self.xcds_per_acc.values().all(|&n| n == 1)
    }

    /// Maximum number of distinct ACCs any XCD services.
    pub fn max_accs_per_xcd(&self) -> usize {
        self.accs_per_xcd.iter().copied().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mha_one_acc_per_head() {
        let cfg = AttnConfig::mha(2, 8, 1024, 64);
        assert_eq!(num_accs(&cfg), 16);
        let a = acc_of(&cfg, WorkItem { z: 1, h: 3, b: 0 });
        assert_eq!(a, AccId { z: 1, kv_head: 3 });
    }

    #[test]
    fn gqa_groups_share_acc() {
        let cfg = AttnConfig::gqa(1, 8, 2, 1024, 64);
        assert_eq!(num_accs(&cfg), 2);
        let a0 = acc_of(&cfg, WorkItem { z: 0, h: 0, b: 0 });
        let a3 = acc_of(&cfg, WorkItem { z: 0, h: 3, b: 9 });
        let a4 = acc_of(&cfg, WorkItem { z: 0, h: 4, b: 0 });
        assert_eq!(a0, a3);
        assert_ne!(a0, a4);
        assert_eq!(wgs_per_acc(&cfg, 16), 4 * 16);
    }

    #[test]
    fn decode_splits_of_one_head_share_an_acc() {
        // Flash-decode grid: b is the KV split index; every split of a
        // (batch, head) — and every group-mate's splits under GQA —
        // derive the same ACC, because they stream the same K/V tensors.
        let cfg = AttnConfig::gqa(2, 8, 2, 4096, 64);
        let a = acc_of(&cfg, WorkItem { z: 1, h: 2, b: 0 });
        let b = acc_of(&cfg, WorkItem { z: 1, h: 2, b: 7 }); // other split
        let c = acc_of(&cfg, WorkItem { z: 1, h: 3, b: 5 }); // group-mate
        assert_eq!(a, b);
        assert_eq!(a, c);
        assert_ne!(a, acc_of(&cfg, WorkItem { z: 0, h: 2, b: 0 }));
        // Workgroups per ACC on a decode grid = group size * splits.
        assert_eq!(wgs_per_acc(&cfg, 8), 4 * 8);
    }

    #[test]
    fn spread_detects_colocation() {
        let cfg = AttnConfig::mha(1, 4, 512, 64);
        // Perfect: head h -> XCD h.
        let good = (0..4u32).flat_map(|h| {
            (0..4u32).map(move |b| (WorkItem { z: 0, h, b }, h))
        });
        let s = AccSpread::measure(&cfg, 4, good);
        assert!(s.perfectly_colocated());
        assert_eq!(s.max_accs_per_xcd(), 1);
        // Bad: block b -> XCD b (stripes every head).
        let bad = (0..4u32).flat_map(|h| {
            (0..4u32).map(move |b| (WorkItem { z: 0, h, b }, b))
        });
        let s = AccSpread::measure(&cfg, 4, bad);
        assert!(!s.perfectly_colocated());
        assert_eq!(s.max_accs_per_xcd(), 4);
    }
}
