//! Analytic roofline and kernel-resource models.
//!
//! Two uses:
//! * sanity-bounding the simulator (a policy can never beat the
//!   compute/bandwidth roofline), and
//! * the L1 performance estimate the Pallas kernel cannot give us on CPU
//!   (interpret mode): VMEM footprint and MXU utilization from the
//!   BlockSpec tile shapes (DESIGN.md §Perf).

use crate::attn::{AttnConfig, KernelKind};
use crate::topology::Topology;

/// Roofline estimate for one kernel launch.
#[derive(Debug, Clone, Copy)]
pub struct Roofline {
    /// Total FLOPs of the launch.
    pub total_flops: f64,
    /// HBM bytes with perfect per-device caching (each tensor once).
    pub ideal_bytes: f64,
    /// HBM bytes if every XCD streams its own copy of shared tensors
    /// (the replication worst case, e.g. Naive Head-first).
    pub replicated_bytes: f64,
    /// Time at peak compute throughput.
    pub compute_sec: f64,
    /// Time at peak HBM bandwidth with ideal caching.
    pub ideal_memory_sec: f64,
    /// min attainable time = max(compute, ideal memory).
    pub ideal_sec: f64,
    /// Arithmetic intensity in FLOP/byte.
    pub intensity: f64,
    /// True when intensity exceeds the machine balance point.
    pub compute_bound: bool,
}

/// Roofline for an attention kernel on a topology.
pub fn attention_roofline(topo: &Topology, cfg: &AttnConfig, kernel: KernelKind) -> Roofline {
    let steps = crate::sim::avg_stream_len(cfg, kernel);
    let step_flops = cfg.step_flops_for(kernel);
    let grid = cfg.grid_size(kernel);
    let total_flops = grid as f64 * step_flops * steps;

    let elt = cfg.dtype_bytes as f64;
    let q = (cfg.batch * cfg.h_q * cfg.n_ctx * cfg.d_head) as f64 * elt;
    let kv = 2.0 * (cfg.batch * cfg.h_k * cfg.n_ctx * cfg.d_head) as f64 * elt;
    let o = q;
    let q_vec = (cfg.batch * cfg.h_q) as f64 * cfg.q_vec_bytes() as f64;
    let ideal_bytes = match kernel {
        KernelKind::Forward => q + kv + o,
        // Decode phase 1: one query token per (batch, head); the KV
        // stream dominates, plus the partial results written out.
        KernelKind::DecodeSplitKv { num_splits } => {
            let partials =
                (cfg.batch * cfg.h_q * num_splits) as f64 * cfg.decode_partial_bytes() as f64;
            kv + q_vec + partials
        }
        // Decode phase 2 never touches K/V: it re-reads the phase-1
        // partials and writes the final output rows.
        KernelKind::DecodeReduce { num_splits } => {
            let partials =
                (cfg.batch * cfg.h_q * num_splits) as f64 * cfg.decode_partial_bytes() as f64;
            partials + q_vec
        }
        // backward reads q, k, v, o(do), lse, delta and writes dq/dk/dv
        KernelKind::BwdDkDv | KernelKind::BwdDq => 3.0 * q + 2.0 * kv,
    };
    // Replication worst case: every XCD streams its own copy of the
    // shared K/V. The decode reduction has no shared tensors at all —
    // each partial is read by exactly one WG — so it cannot replicate.
    let replicated_bytes = match kernel {
        KernelKind::DecodeReduce { .. } => ideal_bytes,
        _ => ideal_bytes + (topo.num_xcds as f64 - 1.0) * kv.min(ideal_bytes),
    };

    let compute_sec = total_flops / topo.device_flops_per_sec();
    let ideal_memory_sec = ideal_bytes / topo.hbm_bytes_per_sec;
    let intensity = total_flops / ideal_bytes;
    Roofline {
        total_flops,
        ideal_bytes,
        replicated_bytes,
        compute_sec,
        ideal_memory_sec,
        ideal_sec: compute_sec.max(ideal_memory_sec),
        intensity,
        compute_bound: intensity > topo.balance_flops_per_byte(),
    }
}

/// Pallas-kernel VMEM/MXU estimate from the BlockSpec tile shapes — the
/// L1 performance deliverable for a CPU-only environment (DESIGN.md
/// §Hardware-Adaptation). Mirrors python/compile/kernels/fa2.py.
#[derive(Debug, Clone, Copy)]
pub struct KernelEstimate {
    /// Bytes resident in VMEM per grid step: Q block + K/V tiles (double
    /// buffered) + accumulator + softmax state.
    pub vmem_bytes: u64,
    /// Fraction of the 128x128 MXU each dot's operand tiles fill.
    pub mxu_utilization: f64,
    /// FLOPs per grid step.
    pub step_flops: f64,
}

/// Estimate the Pallas kernel's VMEM footprint and MXU utilization
/// from the BlockSpec tile shapes.
pub fn kernel_estimate(cfg: &AttnConfig) -> KernelEstimate {
    let elt = cfg.dtype_bytes as u64;
    let (m, n, d) = (cfg.block_m as u64, cfg.block_n as u64, cfg.d_head as u64);
    // Q tile + 2x double-buffered K and V tiles + f32 accumulator
    // (m x d) + m/l vectors (f32) + S/P scratch (m x n f32).
    let vmem = m * d * elt + 2 * 2 * (n * d * elt) + m * d * 4 + 2 * m * 4 + m * n * 4;
    // MXU on TPU-like hardware multiplies 128x128 tiles; a dot of
    // (m x d) @ (d x n) utilizes min(m,128)/128 * min(n,128)/128 ...
    // averaged over the two dots (S = Q K^T over d, O = P V over n).
    let u = |rows: u64, cols: u64| -> f64 {
        (rows.min(128) as f64 / 128.0) * (cols.min(128) as f64 / 128.0)
    };
    let mxu = 0.5 * (u(m, n) + u(m, d));
    KernelEstimate {
        vmem_bytes: vmem,
        mxu_utilization: mxu,
        step_flops: cfg.fwd_step_flops(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets;

    #[test]
    fn fwd_roofline_matches_hand_math() {
        let topo = presets::mi300x();
        let cfg = AttnConfig::mha(1, 8, 8192, 128);
        let r = attention_roofline(&topo, &cfg, KernelKind::Forward);
        // 4 Z H N^2 D
        let expected = 4.0 * 8.0 * 8192.0f64 * 8192.0 * 128.0;
        assert!((r.total_flops - expected).abs() / expected < 1e-9);
        assert!(r.compute_bound); // D=128 fp16 attention is compute bound
    }

    #[test]
    fn deepseek_d56_lower_absolute_performance() {
        // Paper Sec. 4.5: D_HEAD = 56 lowers absolute performance across
        // all methods — modeled as reduced matrix-core efficiency.
        let d128 = AttnConfig::mha(1, 128, 8192, 128);
        let d56 = AttnConfig::mha(1, 128, 8192, 56);
        assert!(d56.compute_efficiency_factor() < d128.compute_efficiency_factor());
    }

    #[test]
    fn decode_is_memory_bound() {
        // Split-KV decode reads the whole KV stream to produce a single
        // token per (batch, head): intensity is ~2 FLOPs per KV element,
        // far below the MI300X balance point.
        let topo = presets::mi300x();
        let cfg = AttnConfig::gqa(1, 64, 8, 65536, 128);
        let r = attention_roofline(&topo, &cfg, KernelKind::DecodeSplitKv { num_splits: 8 });
        assert!(!r.compute_bound, "decode must be memory-bound");
        assert!(r.intensity < topo.balance_flops_per_byte() / 10.0, "intensity {}", r.intensity);
        assert!(r.total_flops > 0.0 && r.ideal_bytes > 0.0);
        // The reduction only moves partials + output rows — orders of
        // magnitude below phase 1's KV stream.
        let red = attention_roofline(&topo, &cfg, KernelKind::DecodeReduce { num_splits: 8 });
        assert!(red.ideal_bytes < r.ideal_bytes / 100.0, "{} vs {}", red.ideal_bytes, r.ideal_bytes);
        // Per-WG-private partials cannot be replicated across XCDs.
        assert_eq!(red.replicated_bytes, red.ideal_bytes);
        assert!(r.replicated_bytes > r.ideal_bytes);
    }

    #[test]
    fn replication_inflates_bytes() {
        let topo = presets::mi300x();
        let cfg = AttnConfig::mha(1, 8, 8192, 128);
        let r = attention_roofline(&topo, &cfg, KernelKind::Forward);
        assert!(r.replicated_bytes > 2.0 * r.ideal_bytes);
    }

    #[test]
    fn kernel_estimate_fits_vmem() {
        // The paper's tile config must fit a TPU-like 16 MiB VMEM easily.
        let cfg = AttnConfig::mha(1, 8, 8192, 128);
        let e = kernel_estimate(&cfg);
        assert!(e.vmem_bytes < 16 * 1024 * 1024);
        assert!(e.vmem_bytes > 0);
        // 128x64 blocks with D=128: S-dot uses a half-full MXU in n.
        assert!((e.mxu_utilization - 0.75).abs() < 1e-9);
    }

    #[test]
    fn simulator_never_beats_roofline() {
        use crate::mapping::Policy;
        use crate::sim::{simulate, SimConfig};
        let mut topo = presets::mi300x();
        topo.cus_per_xcd = 8; // keep test fast
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 8, 2048, 128) };
        let r = attention_roofline(&topo, &cfg, KernelKind::Forward);
        let s = simulate(&topo, &cfg, &SimConfig::forward(Policy::SwizzledHeadFirst));
        // Efficiency < 1.0 of peak is enforced, so sim time > roofline.
        assert!(s.est_total_sec >= r.compute_sec * 0.99, "{} vs {}", s.est_total_sec, r.compute_sec);
    }
}
