//! Size-aware LRU cache over u64 keys, built on a slab + intrusive
//! doubly-linked list (no per-access allocation on the hot path).

use super::CacheStats;
use crate::util::fxhash::FastMap;

const NIL: u32 = u32::MAX;

#[derive(Debug, Clone)]
struct Node {
    key: u64,
    bytes: u32,
    prev: u32,
    next: u32,
}

/// LRU cache with byte-capacity eviction.
///
/// `access` is the hot-path entry point: it records a hit or a miss and,
/// on miss, inserts the key (evicting LRU entries until the new entry
/// fits). `probe`/`fill` split that into the two phases the simulator
/// needs when a miss must first travel through the HBM queue.
#[derive(Debug, Clone)]
pub struct LruCache {
    capacity_bytes: u64,
    used_bytes: u64,
    map: FastMap<u64, u32>,
    slab: Vec<Node>,
    free: Vec<u32>,
    head: u32, // most recently used
    tail: u32, // least recently used
    stats: CacheStats,
    /// Analytic fast-path flag: the caller has proven the working set
    /// fits, so eviction can never occur and recency order is
    /// unobservable — hits skip the LRU `touch`. See `set_no_evict`.
    no_evict: bool,
}

impl LruCache {
    /// An empty cache bounded to `capacity_bytes`.
    pub fn new(capacity_bytes: u64) -> Self {
        assert!(capacity_bytes > 0, "cache capacity must be > 0");
        LruCache {
            capacity_bytes,
            used_bytes: 0,
            map: FastMap::default(),
            slab: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            stats: CacheStats::default(),
            no_evict: false,
        }
    }

    /// Enable (or disable) the analytic no-evict fast path. Correct ONLY
    /// when the caller has proven the total bytes ever inserted fit in
    /// `capacity_bytes` (e.g. the engine's per-XCD working-set bound):
    /// then `evict_lru` is unreachable and the recency list is never
    /// consulted, so skipping the MRU promotion on hits changes no
    /// observable statistic. Entries stay fully linked (insertion order),
    /// so `invalidate`/`clear`/`keys_mru_to_lru` remain valid — but the
    /// latter reports insertion order, not recency, while enabled.
    pub fn set_no_evict(&mut self, on: bool) {
        self.no_evict = on;
    }

    /// The configured capacity.
    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Bytes currently resident.
    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    /// Tiles currently resident.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when nothing is resident.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Accumulated hit/miss statistics.
    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    /// Mutable statistics access: the simulator uses this to account
    /// demand accesses that merge into an in-flight fill (MSHR hits-on-
    /// miss are recorded as misses there, not via `probe`).
    pub fn stats_mut(&mut self) -> &mut CacheStats {
        &mut self.stats
    }

    /// Zero the statistics (warmup boundary) without evicting data.
    pub fn reset_stats(&mut self) {
        self.stats = CacheStats::default();
    }

    /// Evict everything (statistics are preserved).
    pub fn clear(&mut self) {
        self.map.clear();
        self.slab.clear();
        self.free.clear();
        self.head = NIL;
        self.tail = NIL;
        self.used_bytes = 0;
    }

    /// Record an access: hit -> promote to MRU; miss -> insert (evicting).
    /// Returns `true` on hit. Single map probe per phase: the miss path
    /// skips `fill`'s redundant presence re-check (the lookup just
    /// failed), so a miss costs one `get` + one `insert` instead of the
    /// former probe/probe/insert triple.
    pub fn access(&mut self, key: u64, bytes: u32) -> bool {
        if let Some(&idx) = self.map.get(&key) {
            self.stats.hits += 1;
            self.stats.hit_bytes += bytes as u64;
            if !self.no_evict {
                self.touch(idx);
            }
            true
        } else {
            self.stats.misses += 1;
            self.stats.miss_bytes += bytes as u64;
            self.insert_absent(key, bytes);
            false
        }
    }

    /// Hit check + stat recording WITHOUT filling on miss. The simulator
    /// uses this when a miss is sent to the HBM queue and `fill` happens
    /// only once the data arrives.
    pub fn probe(&mut self, key: u64, bytes: u32) -> bool {
        if let Some(&idx) = self.map.get(&key) {
            self.stats.hits += 1;
            self.stats.hit_bytes += bytes as u64;
            if !self.no_evict {
                self.touch(idx);
            }
            true
        } else {
            self.stats.misses += 1;
            self.stats.miss_bytes += bytes as u64;
            false
        }
    }

    /// Peek without recording statistics (used by MSHR-merged waiters so a
    /// single demand miss isn't double-counted).
    pub fn contains(&self, key: u64) -> bool {
        self.map.contains_key(&key)
    }

    /// Record a hit + LRU touch if present; record NOTHING if absent
    /// (the engine attributes the miss after consulting the MSHR file).
    pub fn try_hit(&mut self, key: u64, bytes: u32) -> bool {
        if let Some(&idx) = self.map.get(&key) {
            self.stats.hits += 1;
            self.stats.hit_bytes += bytes as u64;
            if !self.no_evict {
                self.touch(idx);
            }
            true
        } else {
            false
        }
    }

    /// Record a hit that was serviced by an in-flight fill issued by a
    /// DIFFERENT workgroup (MSHR sharing: no new HBM traffic).
    pub fn record_shared_hit(&mut self, bytes: u32) {
        self.stats.hits += 1;
        self.stats.hit_bytes += bytes as u64;
    }

    /// Record a demand miss (data absent and not covered by another
    /// workgroup's fetch).
    pub fn record_miss(&mut self, bytes: u32) {
        self.stats.misses += 1;
        self.stats.miss_bytes += bytes as u64;
    }

    /// Insert `key` (e.g. when its HBM fill arrives), evicting LRU entries
    /// until it fits. No stats are recorded — the miss was already counted
    /// by `probe`.
    pub fn fill(&mut self, key: u64, bytes: u32) {
        if let Some(&idx) = self.map.get(&key) {
            if !self.no_evict {
                self.touch(idx);
            }
            return;
        }
        self.insert_absent(key, bytes);
    }

    /// Insert a key the caller has just verified absent (one failed map
    /// lookup ago, with no intervening mutation). Evicts until it fits;
    /// the entry is linked MRU-first even in no-evict mode so the list
    /// invariants hold.
    fn insert_absent(&mut self, key: u64, bytes: u32) {
        debug_assert!(!self.map.contains_key(&key));
        let bytes64 = bytes as u64;
        if bytes64 > self.capacity_bytes {
            // Entry larger than the whole cache: streams straight through.
            return;
        }
        while self.used_bytes + bytes64 > self.capacity_bytes {
            self.evict_lru();
        }
        let idx = self.alloc_node(key, bytes);
        self.push_front(idx);
        self.map.insert(key, idx);
        self.used_bytes += bytes64;
    }

    /// Invalidate a key if present (failure-injection / flush tests).
    pub fn invalidate(&mut self, key: u64) -> bool {
        if let Some(idx) = self.map.remove(&key) {
            self.unlink(idx);
            self.used_bytes -= self.slab[idx as usize].bytes as u64;
            self.free.push(idx);
            true
        } else {
            false
        }
    }

    fn alloc_node(&mut self, key: u64, bytes: u32) -> u32 {
        if let Some(idx) = self.free.pop() {
            let n = &mut self.slab[idx as usize];
            n.key = key;
            n.bytes = bytes;
            n.prev = NIL;
            n.next = NIL;
            idx
        } else {
            self.slab.push(Node { key, bytes, prev: NIL, next: NIL });
            (self.slab.len() - 1) as u32
        }
    }

    fn evict_lru(&mut self) {
        debug_assert!(!self.no_evict, "eviction under no_evict: working-set bound lied");
        let idx = self.tail;
        debug_assert_ne!(idx, NIL, "evict on empty cache");
        let (key, bytes) = {
            let n = &self.slab[idx as usize];
            (n.key, n.bytes)
        };
        self.unlink(idx);
        self.map.remove(&key);
        self.used_bytes -= bytes as u64;
        self.free.push(idx);
        self.stats.evictions += 1;
    }

    fn touch(&mut self, idx: u32) {
        if self.head == idx {
            return;
        }
        self.unlink(idx);
        self.push_front(idx);
    }

    fn unlink(&mut self, idx: u32) {
        let (prev, next) = {
            let n = &self.slab[idx as usize];
            (n.prev, n.next)
        };
        if prev != NIL {
            self.slab[prev as usize].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.slab[next as usize].prev = prev;
        } else {
            self.tail = prev;
        }
        let n = &mut self.slab[idx as usize];
        n.prev = NIL;
        n.next = NIL;
    }

    fn push_front(&mut self, idx: u32) {
        let old_head = self.head;
        {
            let n = &mut self.slab[idx as usize];
            n.prev = NIL;
            n.next = old_head;
        }
        if old_head != NIL {
            self.slab[old_head as usize].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    /// Keys from MRU to LRU (test/debug helper).
    pub fn keys_mru_to_lru(&self) -> Vec<u64> {
        let mut out = Vec::with_capacity(self.map.len());
        let mut cur = self.head;
        while cur != NIL {
            out.push(self.slab[cur as usize].key);
            cur = self.slab[cur as usize].next;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_hit_miss() {
        let mut c = LruCache::new(1000);
        assert!(!c.access(1, 100));
        assert!(c.access(1, 100));
        assert_eq!(c.stats().hits, 1);
        assert_eq!(c.stats().misses, 1);
        assert_eq!(c.used_bytes(), 100);
    }

    #[test]
    fn evicts_lru_order() {
        let mut c = LruCache::new(300);
        c.access(1, 100);
        c.access(2, 100);
        c.access(3, 100);
        assert_eq!(c.keys_mru_to_lru(), vec![3, 2, 1]);
        // Touch 1 so 2 becomes LRU.
        assert!(c.access(1, 100));
        // Insert 4 -> evicts 2.
        c.access(4, 100);
        assert!(c.contains(1));
        assert!(!c.contains(2));
        assert!(c.contains(3));
        assert!(c.contains(4));
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn size_aware_eviction_evicts_multiple() {
        let mut c = LruCache::new(300);
        c.access(1, 100);
        c.access(2, 100);
        c.access(3, 100);
        // 250-byte entry: evicting 1 and 2 leaves 100+250 > 300, so 3
        // must go too (strict capacity).
        c.access(4, 250);
        assert!(!c.contains(1));
        assert!(!c.contains(2));
        assert!(!c.contains(3));
        assert!(c.contains(4));
        assert_eq!(c.used_bytes(), 250);
        assert_eq!(c.stats().evictions, 3);
    }

    #[test]
    fn oversized_entry_streams_through() {
        let mut c = LruCache::new(100);
        assert!(!c.access(1, 200));
        assert!(!c.contains(1));
        assert_eq!(c.used_bytes(), 0);
        // Existing entries untouched.
        c.access(2, 50);
        c.access(1, 200);
        assert!(c.contains(2));
    }

    #[test]
    fn probe_then_fill() {
        let mut c = LruCache::new(100);
        assert!(!c.probe(7, 10));
        assert!(!c.contains(7)); // probe does not fill
        c.fill(7, 10);
        assert!(c.probe(7, 10));
        assert_eq!(c.stats().hits, 1);
        assert_eq!(c.stats().misses, 1);
    }

    #[test]
    fn fill_idempotent() {
        let mut c = LruCache::new(100);
        c.fill(1, 40);
        c.fill(1, 40);
        assert_eq!(c.used_bytes(), 40);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn invalidate() {
        let mut c = LruCache::new(100);
        c.fill(1, 40);
        assert!(c.invalidate(1));
        assert!(!c.contains(1));
        assert_eq!(c.used_bytes(), 0);
        assert!(!c.invalidate(1));
    }

    #[test]
    fn clear_resets_contents_not_stats() {
        let mut c = LruCache::new(100);
        c.access(1, 10);
        c.access(1, 10);
        c.clear();
        assert!(c.is_empty());
        assert_eq!(c.used_bytes(), 0);
        assert_eq!(c.stats().hits, 1);
        c.reset_stats();
        assert_eq!(c.stats().hits, 0);
    }

    #[test]
    fn slab_reuse_after_eviction() {
        let mut c = LruCache::new(200);
        for k in 0..1000u64 {
            c.access(k, 100);
        }
        // Only 2 entries fit; slab should not have grown to 1000 nodes.
        assert_eq!(c.len(), 2);
        assert!(c.slab.len() <= 4, "slab grew to {}", c.slab.len());
    }

    #[test]
    fn working_set_within_capacity_all_hits_after_warmup() {
        let mut c = LruCache::new(1024);
        let keys: Vec<u64> = (0..8).collect();
        for &k in &keys {
            c.access(k, 128);
        }
        c.reset_stats();
        for _ in 0..10 {
            for &k in &keys {
                assert!(c.access(k, 128));
            }
        }
        assert_eq!(c.stats().misses, 0);
        assert!((c.stats().hit_rate() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn no_evict_mode_preserves_stats_and_contents() {
        // Within-capacity workload: stats must be identical with the
        // fast path on, since recency order is unobservable.
        let mut fast = LruCache::new(1024);
        fast.set_no_evict(true);
        let mut slow = LruCache::new(1024);
        for round in 0..3 {
            for k in 0..8u64 {
                assert_eq!(fast.access(k, 128), round > 0);
                slow.access(k, 128);
            }
        }
        assert_eq!(fast.stats().hits, slow.stats().hits);
        assert_eq!(fast.stats().misses, slow.stats().misses);
        assert_eq!(fast.stats().hit_bytes, slow.stats().hit_bytes);
        assert_eq!(fast.stats().evictions, 0);
        assert_eq!(fast.used_bytes(), slow.used_bytes());
        // List stays fully linked: invalidate works, order is insertion.
        assert_eq!(fast.keys_mru_to_lru(), vec![7, 6, 5, 4, 3, 2, 1, 0]);
        assert!(fast.invalidate(3));
        assert_eq!(fast.len(), 7);
        assert_eq!(fast.used_bytes(), 7 * 128);
    }

    #[test]
    fn no_evict_fill_and_probe_paths() {
        let mut c = LruCache::new(1024);
        c.set_no_evict(true);
        assert!(!c.probe(1, 100));
        c.fill(1, 100);
        c.fill(1, 100); // present: no touch, no duplicate
        assert!(c.try_hit(1, 100));
        assert!(c.probe(1, 100));
        assert_eq!(c.len(), 1);
        assert_eq!(c.stats().hits, 2);
        assert_eq!(c.stats().misses, 1);
    }

    #[test]
    fn working_set_exceeding_capacity_thrashes_under_lru_scan() {
        // Classic LRU pathology: cyclic scan of N+1 entries in N-entry
        // cache misses every time — the block-first collapse mechanism.
        let mut c = LruCache::new(800); // 8 entries of 100
        for _ in 0..5 {
            for k in 0..9u64 {
                c.access(k, 100);
            }
        }
        let s = c.stats();
        assert_eq!(s.hits, 0, "cyclic scan must never hit: {s:?}");
    }
}
