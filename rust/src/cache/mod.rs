//! Cache models for the chiplet memory hierarchy.
//!
//! The simulator models each XCD's private L2 as a size-aware LRU over
//! *tiles* (the natural access granularity of FA2: one BLOCK_N × D slice
//! of K or V, one BLOCK_M × D block of Q, ...). Tile granularity keeps the
//! hot loop ~2 orders of magnitude cheaper than line granularity while
//! preserving the quantity the paper measures — the hit *rate* of the
//! request stream — because FA2 either reuses a whole tile or none of it.
//! Byte-weighted statistics are tracked alongside request counts.

mod lru;

pub use lru::LruCache;

/// Hit/miss statistics for one cache.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    /// Requests served from the cache.
    pub hits: u64,
    /// Requests that had to fetch.
    pub misses: u64,
    /// Tiles evicted to make room.
    pub evictions: u64,
    /// Bytes served from the cache.
    pub hit_bytes: u64,
    /// Bytes fetched on misses.
    pub miss_bytes: u64,
}

impl CacheStats {
    /// Request hit rate in [0, 1] — the metric of paper Fig. 13
    /// (ROCProfiler's aggregated L2 hit rate).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }

    /// Byte-weighted hit rate in [0, 1].
    pub fn byte_hit_rate(&self) -> f64 {
        let total = self.hit_bytes + self.miss_bytes;
        if total == 0 {
            return 0.0;
        }
        self.hit_bytes as f64 / total as f64
    }

    /// Total requests (hits + misses).
    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }

    /// Merge another cache's statistics into this one (device aggregate).
    pub fn merge(&mut self, other: &CacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.evictions += other.evictions;
        self.hit_bytes += other.hit_bytes;
        self.miss_bytes += other.miss_bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_hit_rate() {
        let s = CacheStats { hits: 90, misses: 10, ..Default::default() };
        assert!((s.hit_rate() - 0.9).abs() < 1e-12);
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
    }

    #[test]
    fn stats_merge() {
        let mut a = CacheStats { hits: 1, misses: 2, evictions: 3, hit_bytes: 4, miss_bytes: 5 };
        let b = CacheStats { hits: 10, misses: 20, evictions: 30, hit_bytes: 40, miss_bytes: 50 };
        a.merge(&b);
        assert_eq!(a.hits, 11);
        assert_eq!(a.misses, 22);
        assert_eq!(a.evictions, 33);
        assert_eq!(a.hit_bytes, 44);
        assert_eq!(a.miss_bytes, 55);
    }

    #[test]
    fn byte_weighted_rate_differs_from_request_rate() {
        let s = CacheStats { hits: 1, misses: 1, hit_bytes: 100, miss_bytes: 300, ..Default::default() };
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
        assert!((s.byte_hit_rate() - 0.25).abs() < 1e-12);
    }
}
