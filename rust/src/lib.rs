//! # numa-attn
//!
//! Reproduction of *"Optimizing Attention on GPUs by Exploiting GPU
//! Architectural NUMA Effects"* (CS.AR 2025): NUMA-aware workgroup
//! scheduling for FlashAttention2 on chiplet GPUs, evaluated on a
//! trace-driven chiplet-GPU memory-hierarchy simulator (we have no MI300X;
//! see `DESIGN.md` for the substitution argument).
//!
//! The crate is organized bottom-up:
//!
//! * [`topology`] — chiplet GPU architecture models (MI300X preset etc.)
//! * [`cluster`] — the second NUMA level: clusters of devices with
//!   tensor-parallel head sharding ([`cluster::ClusterTopology`],
//!   [`cluster::ShardPlan`]; docs/CLUSTER.md)
//! * [`cache`] — set-associative/LRU cache models with hit/miss statistics
//! * [`mem`] — HBM bandwidth/queue model shared across XCDs
//! * [`attn`] — FlashAttention2 grid model: workgroups and their tile
//!   access streams (forward and backward), MHA/GQA, ACC derivation
//! * [`mapping`] — the four workgroup-mapping policies of the paper
//!   (Naive/Swizzled × Block-first/Head-first) plus ablation variants
//! * [`sched`] — the hardware dispatcher model (chunked round-robin)
//! * [`sim`] — the simulation engine: replays tile access streams through
//!   per-XCD L2s + HBM and reports hit rates / cycles / normalized perf
//! * [`driver`] — the shared simulation driver: a hashable [`driver::SimJob`]
//!   spec, a std-thread worker pool, and a memoizing report cache — the
//!   ONE execution path figures, the advisor, the CLI (`--threads N`,
//!   `--no-cache`), and the benches all run simulations through
//! * [`roofline`] — analytic FLOPs/bytes and kernel VMEM/MXU estimates
//! * [`workload`] — model presets (Llama-3, DeepSeek-V3) and paper sweeps
//! * [`figures`] — one generator per paper table/figure (Figs. 12-16 ...)
//! * [`runtime`] — PJRT CPU runtime executing AOT-compiled HLO artifacts
//! * [`coordinator`] — the serving layer: router, batcher, workers, the
//!   mapping/split-count advisor, and the continuous-batching decode
//!   serving loop ([`coordinator::serve_decode`], docs/SERVING.md)
//! * [`metrics`] — counters/histograms and report formatting

// Doc rot fails CI: every public item must carry a doc comment
// (`cargo doc --no-deps` runs with RUSTDOCFLAGS="-D warnings").
#![warn(missing_docs)]

pub mod attn;
pub mod cache;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod driver;
pub mod figures;
pub mod mapping;
pub mod mem;
pub mod metrics;
pub mod roofline;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod topology;
pub mod util;
pub mod workload;

pub use attn::AttnConfig;
pub use cluster::{ClusterTopology, PoolKind, ShardPlan, ShardStrategy};
pub use driver::{ReportCache, SimDriver, SimJob, SimPass};
pub use mapping::Policy;
pub use sim::{SimConfig, SimReport};
pub use topology::Topology;
