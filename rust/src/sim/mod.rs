//! The chiplet-GPU simulator: replays FA2 workgroup tile streams through
//! per-XCD L2 caches and a shared HBM bandwidth queue, under a chosen
//! workgroup-mapping policy, and reports the metrics of the paper's
//! evaluation — aggregate L2 hit rate (Fig. 13) and relative performance
//! (Figs. 12/14/15/16). Beyond the paper's prefill/backward grids it also
//! simulates the serving-side flash-decode pass ([`simulate_decode`]):
//! the split-KV kernel plus its partial-result reduction, merged into one
//! report (DESIGN.md §9).
//!
//! ## Fidelity model (DESIGN.md §7)
//!
//! * One simulator *tick* = the time one CU spends computing one stream
//!   step (one K/V tile of FA2 forward). All rates are normalized to it.
//! * Workgroups occupy CU slots per XCD; freed slots immediately receive
//!   the next workgroup in hardware dispatch order (chunked round-robin
//!   over *policy-remapped* slots — exactly the paper's mechanism).
//! * Each step's tile reads probe the XCD's private L2 (size-aware LRU).
//!   Misses enqueue HBM fetches; fetches for the same (XCD, tile) merge
//!   (MSHR); fetches from different XCDs do NOT merge — that is the NUMA
//!   replication traffic.
//! * A workgroup prefetches `prefetch_depth` steps ahead (the kernel's
//!   double buffering), so latency is hidden while bandwidth keeps up.
//! * A small deterministic per-step jitter models wavefront-scheduling
//!   noise; drift between workgroups sharing a stream is then bounded by
//!   the L2 *window* (capacity / live streams), which is what makes many
//!   concurrent ACC streams per XCD collapse — the paper's block-first
//!   pathology.
//! * Performance is reported as steady-state throughput over a sampled
//!   window (whole grid if small), extrapolated to the full grid.
//!
//! Two execution strategies exist: the event-driven engine behind
//! [`simulate`] (the default — skips dead ticks and takes an analytic
//! no-evict cache path when provably safe) and the reference per-tick
//! scan behind [`simulate_reference`]. Their reports are bit-identical;
//! `tests/engine_equivalence.rs` enforces it (DESIGN.md §13).

mod engine;
pub mod gemm;

pub use engine::Engine;

use crate::attn::{AttnConfig, KernelKind};
use crate::cache::CacheStats;
use crate::mapping::Policy;
use crate::mem::HbmStats;
use crate::topology::Topology;

/// Simulation parameters (knobs beyond topology/workload).
///
/// Equality and hashing compare the f64 knobs by IEEE-754 bit pattern
/// (manual impls below) so a `SimConfig` can be part of the driver's
/// memoization key ([`crate::driver::SimJob`]).
#[derive(Debug, Clone, Copy)]
pub struct SimConfig {
    /// Which kernel grid is simulated.
    pub kernel: KernelKind,
    /// Workgroup-mapping policy under test.
    pub policy: Policy,
    /// Stop after this many workgroup completions (0 = run whole grid).
    /// Sampled runs extrapolate steady-state throughput to the grid.
    pub max_wg_completions: usize,
    /// Completions before statistics reset (cold-start exclusion for
    /// sampled runs). Ignored when the whole grid is simulated.
    pub warmup_completions: usize,
    /// Hard tick limit (safety net; sets `truncated` in the report).
    pub max_ticks: u64,
    /// Fraction of peak CU FLOPs actually achieved by the kernel's
    /// inner GEMMs (MXU/MFMA efficiency).
    pub compute_efficiency: f64,
    /// Extra per-step scalar-op overhead multiplier (1.0 = none).
    /// The FA2 backward's softmax-recompute/scalar work (paper Sec. 4.6)
    /// uses > 1.
    pub compute_overhead: f64,
    /// Steps of double-buffered prefetch issued ahead of the demand
    /// stream (0 = no prefetch).
    pub prefetch_depth: u32,
    /// 1-in-N chance a step takes +1 tick (deterministic hash jitter
    /// modeling wavefront scheduling noise). 0 disables jitter.
    /// NOTE: per-step jitter random-walks workgroup phases apart without
    /// bound, which is unphysical (real wavefront noise is elastic); the
    /// default is 0 and `launch_stagger` models phase spread instead.
    pub jitter_denom: u64,
    /// Workgroup launch-stagger CAP: a new WG starts up to
    /// min(8 + stream/64, this) ticks after its slot frees
    /// (hash-deterministic; spread grows with kernel duration). This bounded phase
    /// spread is what separates policies: it stays inside the per-stream
    /// L2 window when an XCD serves ONE ACC (head-first swizzled) and
    /// exceeds it when the L2 is split across many ACC streams
    /// (block-first) — the paper's Fig. 13 mechanism.
    pub launch_stagger: u64,
    /// RNG seed for the jitter hash.
    pub seed: u64,
}

impl SimConfig {
    /// Forward-kernel defaults (exact run of the whole grid).
    pub fn forward(policy: Policy) -> Self {
        SimConfig {
            kernel: KernelKind::Forward,
            policy,
            max_wg_completions: 0,
            warmup_completions: 0,
            max_ticks: 50_000_000,
            compute_efficiency: 0.85,
            compute_overhead: 1.0,
            prefetch_depth: 2,
            jitter_denom: 0,
            launch_stagger: 40,
            seed: 0x5eed,
        }
    }

    /// Sampled steady-state run: simulate ~`generations` full occupancy
    /// generations after one generation of warmup.
    pub fn sampled(policy: Policy, topo: &Topology, generations: usize) -> Self {
        let slots = topo.total_wg_slots();
        SimConfig {
            max_wg_completions: slots * (generations + 1),
            warmup_completions: slots,
            ..Self::forward(policy)
        }
    }

    /// Backward-pass defaults (dK/dV first; see [`simulate_backward`]).
    pub fn backward(policy: Policy) -> Self {
        SimConfig {
            kernel: KernelKind::BwdDkDv,
            // Paper Sec. 4.6: additional scalar operations constrain the
            // backward pass; it is less memory-bound than the forward,
            // which is why the Fig. 16 speedups are modest (~1.10x).
            compute_overhead: 1.45,
            ..Self::forward(policy)
        }
    }

    /// Split-KV decode phase-1 config ([`KernelKind::DecodeSplitKv`]).
    /// Decode grids are small (batch × heads × splits), so the whole grid
    /// runs exactly — no steady-state sampling.
    pub fn decode(policy: Policy, num_splits: usize) -> Self {
        assert!(num_splits > 0, "decode requires num_splits >= 1");
        SimConfig {
            kernel: KernelKind::DecodeSplitKv { num_splits },
            ..Self::forward(policy)
        }
    }
}

// Hash/Eq by bits (f64 knobs via `to_bits()`): two configs are the same
// cache key iff every knob is bit-identical — the deterministic engine
// then guarantees bit-identical reports, which is what lets the driver's
// report cache substitute a memoized result for a fresh run.
impl PartialEq for SimConfig {
    fn eq(&self, other: &Self) -> bool {
        self.kernel == other.kernel
            && self.policy == other.policy
            && self.max_wg_completions == other.max_wg_completions
            && self.warmup_completions == other.warmup_completions
            && self.max_ticks == other.max_ticks
            && self.compute_efficiency.to_bits() == other.compute_efficiency.to_bits()
            && self.compute_overhead.to_bits() == other.compute_overhead.to_bits()
            && self.prefetch_depth == other.prefetch_depth
            && self.jitter_denom == other.jitter_denom
            && self.launch_stagger == other.launch_stagger
            && self.seed == other.seed
    }
}

impl Eq for SimConfig {}

impl std::hash::Hash for SimConfig {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.kernel.hash(state);
        self.policy.hash(state);
        self.max_wg_completions.hash(state);
        self.warmup_completions.hash(state);
        self.max_ticks.hash(state);
        self.compute_efficiency.to_bits().hash(state);
        self.compute_overhead.to_bits().hash(state);
        self.prefetch_depth.hash(state);
        self.jitter_denom.hash(state);
        self.launch_stagger.hash(state);
        self.seed.hash(state);
    }
}

/// Engine-internal pressure counters surfaced for observability.
///
/// The per-WG `issued`/`pending`/`blocked` rings in the engine are
/// fixed-size; historically a full ring dropped keys *silently*, which
/// made ring pressure invisible (and, for the `blocked` ring, would
/// manifest only as an inexplicable `max_ticks` truncation). Every drop
/// is now counted here. All counters are zero for every supported
/// kernel (≤ 4 reads per step, prefetch window ≤ 8 keys); a nonzero
/// value means a future kernel outgrew the rings and they must be
/// resized.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineDebugStats {
    /// Keys dropped from the `issued` ring (prefetch bookkeeping lost:
    /// the consume step re-counts the access as un-prefetched).
    pub issued_ring_overflows: u64,
    /// Keys dropped from the `pending` ring (an in-flight fill is no
    /// longer tracked; its arrival is treated as already-consumed).
    pub pending_ring_overflows: u64,
    /// Keys dropped from the `blocked` ring while `outstanding` was
    /// still bumped — the historical semantics, which can deadlock the
    /// WG until `max_ticks`. Nonzero here demands a ring resize.
    pub blocked_ring_overflows: u64,
}

impl EngineDebugStats {
    /// Total dropped keys across all three rings.
    pub fn total(&self) -> u64 {
        self.issued_ring_overflows + self.pending_ring_overflows + self.blocked_ring_overflows
    }

    /// Accumulate another engine run's counters (multi-kernel merges).
    pub fn merge(&mut self, other: &EngineDebugStats) {
        self.issued_ring_overflows += other.issued_ring_overflows;
        self.pending_ring_overflows += other.pending_ring_overflows;
        self.blocked_ring_overflows += other.blocked_ring_overflows;
    }
}

/// Simulation outcome: the quantities the paper's figures plot.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Policy the run was mapped with.
    pub policy: Policy,
    /// Kernel simulated (the first phase's kernel for merged two-phase
    /// reports: BwdDkDv for backward, DecodeSplitKv for decode).
    pub kernel: KernelKind,
    /// Total workgroups in the grid (both phases for merged reports).
    pub grid_size: usize,
    /// Workgroups actually simulated (== grid_size for exact runs).
    pub simulated_wgs: usize,
    /// Ticks in the measured (post-warmup) window.
    pub ticks: u64,
    /// Wall-clock seconds represented by one tick.
    pub sec_per_tick: f64,
    /// Aggregate L2 statistics across all XCDs (paper Fig. 13 metric).
    pub l2: CacheStats,
    /// Per-XCD L2 hit/miss statistics. Kept as full counts (not just
    /// rates) so multi-kernel runs (`simulate_backward`) can merge
    /// per-XCD statistics exactly.
    pub l2_stats_per_xcd: Vec<CacheStats>,
    /// Per-XCD L2 hit rates (derived from `l2_stats_per_xcd`).
    pub l2_hit_rate_per_xcd: Vec<f64>,
    /// HBM traffic statistics.
    pub hbm: HbmStats,
    /// Workgroup completions per tick in the measured window.
    pub throughput_wgs_per_tick: f64,
    /// Estimated ticks for the full grid at steady-state throughput.
    pub est_total_ticks: f64,
    /// Estimated seconds for the full grid.
    pub est_total_sec: f64,
    /// Achieved TFLOP/s over the estimated run.
    pub achieved_tflops: f64,
    /// True if the run hit `max_ticks` before its completion target.
    pub truncated: bool,
    /// Engine ring-pressure counters (zero in every supported config).
    pub debug: EngineDebugStats,
}

impl SimReport {
    /// Aggregate L2 hit rate in percent (the Fig. 13 y-axis).
    pub fn l2_hit_pct(&self) -> f64 {
        100.0 * self.l2.hit_rate()
    }

    /// JSON rendering for `--json` CLI output.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("policy", Json::str(self.policy.name())),
            ("kernel", Json::str(self.kernel.name())),
            (
                "num_splits",
                Json::num(self.kernel.num_splits().unwrap_or(0) as f64),
            ),
            ("grid_size", Json::num(self.grid_size as f64)),
            ("simulated_wgs", Json::num(self.simulated_wgs as f64)),
            ("ticks", Json::num(self.ticks as f64)),
            ("sec_per_tick", Json::num(self.sec_per_tick)),
            ("l2_hit_pct", Json::num(self.l2_hit_pct())),
            ("l2_hits", Json::num(self.l2.hits as f64)),
            ("l2_misses", Json::num(self.l2.misses as f64)),
            (
                "l2_hit_rate_per_xcd",
                Json::arr(self.l2_hit_rate_per_xcd.iter().map(|&r| Json::num(r))),
            ),
            ("hbm_bytes_read", Json::num(self.hbm.bytes_read as f64)),
            ("hbm_bytes_written", Json::num(self.hbm.bytes_written as f64)),
            ("hbm_mshr_merges", Json::num(self.hbm.mshr_merges as f64)),
            ("est_total_sec", Json::num(self.est_total_sec)),
            ("achieved_tflops", Json::num(self.achieved_tflops)),
            ("truncated", Json::Bool(self.truncated)),
            ("ring_overflows", Json::num(self.debug.total() as f64)),
        ])
    }

    /// Performance of this run relative to `baseline` (the Fig. 12/14/15
    /// y-axis when baseline = Swizzled Head-first, Fig. 16 when baseline
    /// = Naive Block-first).
    pub fn perf_relative_to(&self, baseline: &SimReport) -> f64 {
        baseline.est_total_sec / self.est_total_sec
    }
}

/// Run one simulation (event-driven engine; bit-identical to
/// [`simulate_reference`], pinned by `tests/engine_equivalence.rs`).
pub fn simulate(topo: &Topology, attn: &AttnConfig, sim: &SimConfig) -> SimReport {
    Engine::new(topo.clone(), *attn, *sim).run()
}

/// Run one simulation on the reference per-tick-scan engine — the
/// behavioral oracle for the event-driven path (DESIGN.md §13). Orders
/// of magnitude slower in stall-heavy regimes; use [`simulate`] for
/// everything except differential testing and benchmarking.
pub fn simulate_reference(topo: &Topology, attn: &AttnConfig, sim: &SimConfig) -> SimReport {
    Engine::new_reference(topo.clone(), *attn, *sim).run()
}

/// Run the FA2 backward pass: both kernels (dK/dV then dQ) sequentially,
/// combining traffic/hit statistics and summing time — matching how the
/// AITER backward launches (paper Sec. 4.6).
pub fn simulate_backward(topo: &Topology, attn: &AttnConfig, sim: &SimConfig) -> SimReport {
    let dkdv = Engine::new(
        topo.clone(),
        *attn,
        SimConfig { kernel: KernelKind::BwdDkDv, ..*sim },
    )
    .run();
    let dq = Engine::new(
        topo.clone(),
        *attn,
        SimConfig { kernel: KernelKind::BwdDq, ..*sim },
    )
    .run();
    merge_two_phase(attn, dkdv, dq)
}

/// Reference-engine variant of [`simulate_backward`] (differential
/// testing only — see [`simulate_reference`]).
pub fn simulate_backward_reference(
    topo: &Topology,
    attn: &AttnConfig,
    sim: &SimConfig,
) -> SimReport {
    let dkdv = Engine::new_reference(
        topo.clone(),
        *attn,
        SimConfig { kernel: KernelKind::BwdDkDv, ..*sim },
    )
    .run();
    let dq = Engine::new_reference(
        topo.clone(),
        *attn,
        SimConfig { kernel: KernelKind::BwdDq, ..*sim },
    )
    .run();
    merge_two_phase(attn, dkdv, dq)
}

/// Run the flash-decode pass: the split-KV kernel (one WG per
/// (batch, head, split)) followed by the partial-result reduction (one WG
/// per (batch, head)), launched back-to-back like the backward kernels.
/// The merged report carries both phases' traffic and per-XCD statistics;
/// `sim.kernel` must be [`KernelKind::DecodeSplitKv`] (see
/// [`SimConfig::decode`]). The merged `est_total_sec` is also the tick
/// cost the decode serving loop charges for one iteration-level batch
/// step ([`crate::coordinator::serve_decode`], DESIGN.md §10).
pub fn simulate_decode(topo: &Topology, attn: &AttnConfig, sim: &SimConfig) -> SimReport {
    let KernelKind::DecodeSplitKv { num_splits } = sim.kernel else {
        panic!("simulate_decode requires a DecodeSplitKv sim config");
    };
    let split = Engine::new(topo.clone(), *attn, *sim).run();
    let reduce = Engine::new(
        topo.clone(),
        *attn,
        SimConfig { kernel: KernelKind::DecodeReduce { num_splits }, ..*sim },
    )
    .run();
    merge_two_phase(attn, split, reduce)
}

/// Reference-engine variant of [`simulate_decode`] (differential
/// testing only — see [`simulate_reference`]).
pub fn simulate_decode_reference(
    topo: &Topology,
    attn: &AttnConfig,
    sim: &SimConfig,
) -> SimReport {
    let KernelKind::DecodeSplitKv { num_splits } = sim.kernel else {
        panic!("simulate_decode requires a DecodeSplitKv sim config");
    };
    let split = Engine::new_reference(topo.clone(), *attn, *sim).run();
    let reduce = Engine::new_reference(
        topo.clone(),
        *attn,
        SimConfig { kernel: KernelKind::DecodeReduce { num_splits }, ..*sim },
    )
    .run();
    merge_two_phase(attn, split, reduce)
}

/// Merge two sequentially-launched kernel phases into one report: traffic
/// and per-XCD hit statistics are summed, times add, and throughput is
/// total completions over total window ticks. The merged report keeps the
/// FIRST phase's kernel/`sec_per_tick` as its identity.
fn merge_two_phase(attn: &AttnConfig, first: SimReport, second: SimReport) -> SimReport {
    let mut l2 = first.l2;
    l2.merge(&second.l2);
    // Merge per-XCD statistics from BOTH kernels (the second kernel sees
    // the same XCDs; dropping it understated per-XCD traffic) and derive
    // the combined per-XCD hit rates from the merged counts.
    let l2_stats_per_xcd: Vec<CacheStats> = first
        .l2_stats_per_xcd
        .iter()
        .zip(&second.l2_stats_per_xcd)
        .map(|(a, b)| {
            let mut s = *a;
            s.merge(b);
            s
        })
        .collect();
    let l2_hit_rate_per_xcd: Vec<f64> = l2_stats_per_xcd.iter().map(|s| s.hit_rate()).collect();
    let mut hbm = first.hbm;
    hbm.bytes_read += second.hbm.bytes_read;
    hbm.requests += second.hbm.requests;
    hbm.mshr_merges += second.hbm.mshr_merges;
    hbm.busy_ticks += second.hbm.busy_ticks;
    hbm.queue_depth_sum += second.hbm.queue_depth_sum;
    hbm.bytes_written += second.hbm.bytes_written;

    // The phases normalize their ticks to different step FLOPs (a decode
    // reduce tick is ~64x shorter than a split-KV tick), so raw tick
    // counts are not commensurate: convert the second phase's window
    // onto the FIRST phase's tick scale before summing. Merged ticks ×
    // sec_per_tick then equals the combined window time, and the merged
    // throughput is total completions over that combined window.
    let scale = second.sec_per_tick / first.sec_per_tick;
    let ticks = first.ticks + (second.ticks as f64 * scale).round() as u64;
    let window_completions = first.throughput_wgs_per_tick * first.ticks as f64
        + second.throughput_wgs_per_tick * second.ticks as f64;
    let throughput_wgs_per_tick = if ticks > 0 { window_completions / ticks as f64 } else { 0.0 };

    let mut debug = first.debug;
    debug.merge(&second.debug);

    let est_total_sec = first.est_total_sec + second.est_total_sec;
    let total_flops = attn.grid_size(first.kernel) as f64
        * attn.step_flops_for(first.kernel)
        * avg_stream_len(attn, first.kernel)
        + attn.grid_size(second.kernel) as f64
            * attn.step_flops_for(second.kernel)
            * avg_stream_len(attn, second.kernel);
    SimReport {
        policy: first.policy,
        kernel: first.kernel,
        grid_size: first.grid_size + second.grid_size,
        simulated_wgs: first.simulated_wgs + second.simulated_wgs,
        ticks,
        sec_per_tick: first.sec_per_tick,
        l2,
        l2_stats_per_xcd,
        l2_hit_rate_per_xcd,
        hbm,
        throughput_wgs_per_tick,
        est_total_ticks: first.est_total_ticks + second.est_total_ticks * scale,
        est_total_sec,
        achieved_tflops: total_flops / est_total_sec / 1e12,
        truncated: first.truncated || second.truncated,
        debug,
    }
}

/// Merge the per-device reports of one cluster-wide kernel launch
/// executed in *parallel* (one report per device, in device order): the
/// dual of `merge_two_phase`'s sequential composition. Wall time is the
/// slowest device (`max` of `est_total_sec` — the cluster step advances
/// by its critical path), traffic and cache statistics are summed, the
/// per-XCD statistics concatenate device-major (a cluster of 2× 8-XCD
/// devices reports 16 per-XCD entries), and throughput is total
/// completions over the critical-path window. The merged report keeps the
/// FIRST report's policy/kernel/`sec_per_tick` identity; tick counts from
/// other devices are rescaled onto that tick length like
/// `merge_two_phase` does. Panics on an empty slice.
pub fn merge_parallel(reports: &[SimReport]) -> SimReport {
    let first = reports.first().expect("merge_parallel needs >= 1 report");
    let mut l2 = CacheStats::default();
    let mut l2_stats_per_xcd: Vec<CacheStats> = Vec::new();
    let mut hbm = HbmStats::default();
    let mut window_ticks_max = 0u64;
    let mut window_completions = 0.0f64;
    let mut est_total_sec = 0.0f64;
    let mut est_total_ticks = 0.0f64;
    let mut grid_size = 0usize;
    let mut simulated_wgs = 0usize;
    let mut flop_sec_sum = 0.0f64; // sum of (TFLOP/s x seconds) = TFLOPs
    let mut truncated = false;
    let mut debug = EngineDebugStats::default();
    for r in reports {
        debug.merge(&r.debug);
        l2.merge(&r.l2);
        l2_stats_per_xcd.extend_from_slice(&r.l2_stats_per_xcd);
        hbm.bytes_read += r.hbm.bytes_read;
        hbm.bytes_written += r.hbm.bytes_written;
        hbm.requests += r.hbm.requests;
        hbm.mshr_merges += r.hbm.mshr_merges;
        hbm.busy_ticks += r.hbm.busy_ticks;
        hbm.queue_depth_sum += r.hbm.queue_depth_sum;
        let scale = r.sec_per_tick / first.sec_per_tick;
        window_ticks_max = window_ticks_max.max((r.ticks as f64 * scale).round() as u64);
        window_completions += r.throughput_wgs_per_tick * r.ticks as f64;
        est_total_sec = est_total_sec.max(r.est_total_sec);
        est_total_ticks = est_total_ticks.max(r.est_total_ticks * scale);
        grid_size += r.grid_size;
        simulated_wgs += r.simulated_wgs;
        flop_sec_sum += r.achieved_tflops * r.est_total_sec;
        truncated |= r.truncated;
    }
    let l2_hit_rate_per_xcd = l2_stats_per_xcd.iter().map(CacheStats::hit_rate).collect();
    SimReport {
        policy: first.policy,
        kernel: first.kernel,
        grid_size,
        simulated_wgs,
        ticks: window_ticks_max,
        sec_per_tick: first.sec_per_tick,
        l2,
        l2_stats_per_xcd,
        l2_hit_rate_per_xcd,
        hbm,
        throughput_wgs_per_tick: if window_ticks_max > 0 {
            window_completions / window_ticks_max as f64
        } else {
            0.0
        },
        est_total_ticks,
        est_total_sec,
        achieved_tflops: if est_total_sec > 0.0 { flop_sec_sum / est_total_sec } else { 0.0 },
        truncated,
        debug,
    }
}

/// Mean stream length over a kernel's workgroups (causal-aware).
pub(crate) fn avg_stream_len(cfg: &AttnConfig, kernel: KernelKind) -> f64 {
    match kernel {
        // Decode is causal-insensitive: the query is the last token, so
        // every split streams its full slice (exact mean — the balanced
        // partition sums to num_col_blocks).
        KernelKind::DecodeSplitKv { num_splits } => {
            return cfg.num_col_blocks() as f64 / num_splits as f64;
        }
        KernelKind::DecodeReduce { num_splits } => return num_splits as f64,
        _ => {}
    }
    if !cfg.causal {
        return match kernel {
            KernelKind::Forward | KernelKind::BwdDq => cfg.num_col_blocks() as f64,
            KernelKind::BwdDkDv => cfg.num_row_blocks() as f64,
            KernelKind::DecodeSplitKv { .. } | KernelKind::DecodeReduce { .. } => unreachable!(),
        };
    }
    // Causal: average over blocks (exact, mirrors trace::stream_bounds).
    let blocks = cfg.blocks_for(kernel);
    let total: usize = (0..blocks)
        .map(|b| {
            let cur = crate::attn::trace::WgCursor::new(
                cfg,
                kernel,
                crate::attn::WorkItem { z: 0, h: 0, b: b as u32 },
            );
            cur.stream_len() as usize
        })
        .sum();
    total as f64 / blocks as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets;

    fn small_cfg() -> AttnConfig {
        AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 8, 4096, 128) }
    }

    fn tiny_topo() -> Topology {
        Topology {
            name: "tiny".into(),
            num_xcds: 4,
            cus_per_xcd: 4,
            l2_bytes_per_xcd: 512 * 1024,
            ..presets::mi300x()
        }
    }

    #[test]
    fn exact_run_completes_whole_grid() {
        let topo = tiny_topo();
        let cfg = small_cfg();
        let sim = SimConfig::forward(Policy::SwizzledHeadFirst);
        let r = simulate(&topo, &cfg, &sim);
        assert_eq!(r.simulated_wgs, cfg.grid_size(KernelKind::Forward));
        assert!(!r.truncated);
        assert!(r.ticks > 0);
        assert!(r.est_total_sec > 0.0);
        assert!(r.l2.accesses() > 0);
    }

    #[test]
    fn shf_beats_naive_block_first_on_many_heads() {
        // The headline claim: with heads >> XCDs and streams >> L2,
        // swizzled head-first must win on both hit rate and time.
        let topo = presets::mi300x();
        let cfg = AttnConfig::mha(1, 64, 32768, 128);
        let sampled = |p| SimConfig::sampled(p, &topo, 2);
        let shf = simulate(&topo, &cfg, &sampled(Policy::SwizzledHeadFirst));
        let nbf = simulate(&topo, &cfg, &sampled(Policy::NaiveBlockFirst));
        assert!(
            shf.l2.hit_rate() > nbf.l2.hit_rate() + 0.3,
            "SHF {:.3} vs NBF {:.3}",
            shf.l2.hit_rate(),
            nbf.l2.hit_rate()
        );
        assert!(
            shf.est_total_sec < nbf.est_total_sec * 0.95,
            "SHF {:.6} vs NBF {:.6}",
            shf.est_total_sec,
            nbf.est_total_sec
        );
    }

    #[test]
    fn shf_sustains_high_hit_rate() {
        let topo = presets::mi300x();
        let cfg = AttnConfig::mha(1, 64, 16384, 128);
        let sim = SimConfig::sampled(Policy::SwizzledHeadFirst, &topo, 2);
        let r = simulate(&topo, &cfg, &sim);
        assert!(r.l2_hit_pct() > 80.0, "hit rate {:.1}%", r.l2_hit_pct());
    }

    #[test]
    fn replication_traffic_nhf_vs_shf() {
        // Naive Head-first replicates each head's K/V into every XCD.
        // The replication tax is visible when a head's K/V fits in one
        // L2 (short context): SHF fetches it once, NHF once PER XCD.
        // (At very long contexts both policies re-stream per occupancy
        // generation and total traffic converges — see EXPERIMENTS.md.)
        let topo = tiny_topo();
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 8, 1024, 64) };
        let shf = simulate(&topo, &cfg, &SimConfig::forward(Policy::SwizzledHeadFirst));
        let nhf = simulate(&topo, &cfg, &SimConfig::forward(Policy::NaiveHeadFirst));
        assert!(
            nhf.hbm.bytes_read as f64 > 1.5 * shf.hbm.bytes_read as f64,
            "NHF {} vs SHF {}",
            nhf.hbm.bytes_read,
            shf.hbm.bytes_read
        );
    }

    #[test]
    fn backward_combines_both_kernels() {
        let topo = tiny_topo();
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 8, 2048, 64) };
        let sim = SimConfig::backward(Policy::SwizzledHeadFirst);
        let r = simulate_backward(&topo, &cfg, &sim);
        let dkdv_wgs = cfg.grid_size(KernelKind::BwdDkDv);
        let dq_wgs = cfg.grid_size(KernelKind::BwdDq);
        assert_eq!(r.simulated_wgs, dkdv_wgs + dq_wgs);
        assert!(r.achieved_tflops > 0.0);
        // The merged report must carry a real combined throughput, not
        // the historical hard-coded 0.0.
        assert!(r.throughput_wgs_per_tick > 0.0);
        // Exact run, no warmup window: throughput == completions/ticks.
        let expected = r.simulated_wgs as f64 / r.ticks as f64;
        assert!((r.throughput_wgs_per_tick - expected).abs() < 1e-12);
    }

    #[test]
    fn backward_merges_per_xcd_stats_from_both_kernels() {
        let topo = tiny_topo();
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 8, 2048, 64) };
        let sim = SimConfig::backward(Policy::SwizzledHeadFirst);
        let dkdv = simulate(&topo, &cfg, &SimConfig { kernel: KernelKind::BwdDkDv, ..sim });
        let dq = simulate(&topo, &cfg, &SimConfig { kernel: KernelKind::BwdDq, ..sim });
        let r = simulate_backward(&topo, &cfg, &sim);
        assert_eq!(r.l2_stats_per_xcd.len(), topo.num_xcds);
        for (x, merged) in r.l2_stats_per_xcd.iter().enumerate() {
            let mut want = dkdv.l2_stats_per_xcd[x];
            want.merge(&dq.l2_stats_per_xcd[x]);
            assert_eq!(*merged, want, "XCD{x} merged stats");
            assert!((r.l2_hit_rate_per_xcd[x] - want.hit_rate()).abs() < 1e-12);
        }
        // The dQ kernel streams K/V again: its accesses must be visible
        // in the merged per-XCD counts (i.e., not dropped).
        let merged_accesses: u64 = r.l2_stats_per_xcd.iter().map(|s| s.accesses()).sum();
        let dkdv_accesses: u64 = dkdv.l2_stats_per_xcd.iter().map(|s| s.accesses()).sum();
        assert!(merged_accesses > dkdv_accesses);
    }

    #[test]
    fn decode_combines_both_phases() {
        let topo = tiny_topo();
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 8, 2048, 64) };
        let sim = SimConfig::decode(Policy::SwizzledHeadFirst, 4);
        let r = simulate_decode(&topo, &cfg, &sim);
        let split_wgs = cfg.grid_size(KernelKind::DecodeSplitKv { num_splits: 4 });
        let reduce_wgs = cfg.grid_size(KernelKind::DecodeReduce { num_splits: 4 });
        assert_eq!(r.simulated_wgs, split_wgs + reduce_wgs);
        assert_eq!(r.grid_size, split_wgs + reduce_wgs);
        assert!(matches!(r.kernel, KernelKind::DecodeSplitKv { num_splits: 4 }));
        assert!(r.achieved_tflops > 0.0);
        assert!(r.throughput_wgs_per_tick > 0.0);
        // Exact run, no warmup window: throughput == completions/ticks.
        let expected = r.simulated_wgs as f64 / r.ticks as f64;
        assert!((r.throughput_wgs_per_tick - expected).abs() < 1e-12);
    }

    #[test]
    fn decode_merges_per_xcd_stats_from_both_phases() {
        let topo = tiny_topo();
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 8, 2048, 64) };
        let sim = SimConfig::decode(Policy::SwizzledHeadFirst, 4);
        let split = simulate(&topo, &cfg, &sim);
        let reduce = simulate(
            &topo,
            &cfg,
            &SimConfig { kernel: KernelKind::DecodeReduce { num_splits: 4 }, ..sim },
        );
        let r = simulate_decode(&topo, &cfg, &sim);
        assert_eq!(r.l2_stats_per_xcd.len(), topo.num_xcds);
        for (x, merged) in r.l2_stats_per_xcd.iter().enumerate() {
            let mut want = split.l2_stats_per_xcd[x];
            want.merge(&reduce.l2_stats_per_xcd[x]);
            assert_eq!(*merged, want, "XCD{x} merged stats");
            assert!((r.l2_hit_rate_per_xcd[x] - want.hit_rate()).abs() < 1e-12);
        }
        // The reduction streams the partials phase 1 wrote: its accesses
        // must be visible in the merged counts and its reads in HBM.
        let merged_accesses: u64 = r.l2_stats_per_xcd.iter().map(|s| s.accesses()).sum();
        let split_accesses: u64 = split.l2_stats_per_xcd.iter().map(|s| s.accesses()).sum();
        assert!(merged_accesses > split_accesses);
        assert_eq!(r.hbm.bytes_read, split.hbm.bytes_read + reduce.hbm.bytes_read);
        assert_eq!(r.est_total_sec, split.est_total_sec + reduce.est_total_sec);
    }

    #[test]
    fn decode_shf_beats_nhf_on_gqa8() {
        // The decode locality claim (docs/REFERENCE.md): with GQA-8 on 8
        // XCDs and a split count that is not a multiple of the XCD count,
        // Naive Head-first replicates every (kv head, split) stream onto
        // several XCDs while Swizzled Head-first keeps each on exactly
        // one — so SHF's aggregate L2 hit rate must be at least NHF's.
        let topo = presets::mi300x();
        let cfg = AttnConfig::gqa(1, 64, 8, 16384, 128);
        let shf = simulate_decode(&topo, &cfg, &SimConfig::decode(Policy::SwizzledHeadFirst, 2));
        let nhf = simulate_decode(&topo, &cfg, &SimConfig::decode(Policy::NaiveHeadFirst, 2));
        assert!(
            shf.l2.hit_rate() >= nhf.l2.hit_rate(),
            "SHF {:.3} vs NHF {:.3}",
            shf.l2.hit_rate(),
            nhf.l2.hit_rate()
        );
        // The replication is also visible as raw HBM read traffic.
        assert!(
            shf.hbm.bytes_read < nhf.hbm.bytes_read,
            "SHF {} vs NHF {}",
            shf.hbm.bytes_read,
            nhf.hbm.bytes_read
        );
    }

    #[test]
    fn merge_parallel_single_report_is_identity_on_cost() {
        // The tp = 1 cluster path leans on this: merging one device's
        // report must preserve its cost fields exactly (bit-for-bit for
        // est_total_sec, which is what the serving loop charges).
        let topo = tiny_topo();
        let cfg = small_cfg();
        let r = simulate(&topo, &cfg, &SimConfig::forward(Policy::SwizzledHeadFirst));
        let m = merge_parallel(std::slice::from_ref(&r));
        assert_eq!(m.est_total_sec.to_bits(), r.est_total_sec.to_bits());
        assert_eq!(m.ticks, r.ticks);
        assert_eq!(m.grid_size, r.grid_size);
        assert_eq!(m.hbm.bytes_read, r.hbm.bytes_read);
        assert_eq!(m.l2, r.l2);
        assert_eq!(m.l2_stats_per_xcd, r.l2_stats_per_xcd);
    }

    #[test]
    fn merge_parallel_sums_traffic_and_takes_critical_path() {
        let topo = tiny_topo();
        let cfg = small_cfg();
        let r = simulate(&topo, &cfg, &SimConfig::forward(Policy::SwizzledHeadFirst));
        // Two identical devices in parallel: same wall time, double
        // traffic, per-XCD stats concatenated device-major.
        let m = merge_parallel(&[r.clone(), r.clone()]);
        assert_eq!(m.est_total_sec.to_bits(), r.est_total_sec.to_bits());
        assert_eq!(m.ticks, r.ticks, "parallel devices do not add time");
        assert_eq!(m.grid_size, 2 * r.grid_size);
        assert_eq!(m.simulated_wgs, 2 * r.simulated_wgs);
        assert_eq!(m.hbm.bytes_read, 2 * r.hbm.bytes_read);
        assert_eq!(m.l2.accesses(), 2 * r.l2.accesses());
        assert_eq!(m.l2_stats_per_xcd.len(), 2 * topo.num_xcds);
        assert!((m.l2.hit_rate() - r.l2.hit_rate()).abs() < 1e-12);
        // Twice the completions in the same window: double throughput.
        assert!((m.throughput_wgs_per_tick - 2.0 * r.throughput_wgs_per_tick).abs() < 1e-9);
        // A slower straggler device stretches the merged wall time.
        let slow = simulate(&topo, &cfg, &SimConfig::forward(Policy::NaiveBlockFirst));
        let (fast, slow) = if r.est_total_sec < slow.est_total_sec {
            (r.clone(), slow)
        } else {
            (slow, r.clone())
        };
        let m = merge_parallel(&[fast.clone(), slow.clone()]);
        assert_eq!(m.est_total_sec.to_bits(), slow.est_total_sec.to_bits());
        assert_eq!(m.policy, fast.policy, "identity comes from the first report");
    }

    #[test]
    fn sim_config_hash_eq_by_bits() {
        use std::collections::hash_map::DefaultHasher;
        use std::hash::{Hash, Hasher};
        let hash_of = |c: &SimConfig| {
            let mut h = DefaultHasher::new();
            c.hash(&mut h);
            h.finish()
        };
        let a = SimConfig::forward(Policy::SwizzledHeadFirst);
        let b = SimConfig::forward(Policy::SwizzledHeadFirst);
        assert_eq!(a, b);
        assert_eq!(hash_of(&a), hash_of(&b));
        let c = SimConfig { compute_efficiency: 0.9, ..a };
        assert_ne!(a, c);
        assert_ne!(hash_of(&a), hash_of(&c));
        let d = SimConfig::forward(Policy::NaiveBlockFirst);
        assert_ne!(a, d);
    }

    #[test]
    fn causal_avg_stream_len() {
        let mut cfg = AttnConfig::mha(1, 1, 1024, 64); // 8 row, 16 col blocks
        assert_eq!(avg_stream_len(&cfg, KernelKind::Forward), 16.0);
        cfg.causal = true;
        // Row block b streams 2(b+1) tiles, avg over b=0..8 = 9.
        assert_eq!(avg_stream_len(&cfg, KernelKind::Forward), 9.0);
    }

    #[test]
    fn sampled_run_extrapolates() {
        let topo = presets::mi300x();
        let cfg = AttnConfig::mha(4, 64, 32768, 128);
        let sim = SimConfig::sampled(Policy::SwizzledHeadFirst, &topo, 2);
        let r = simulate(&topo, &cfg, &sim);
        assert!(r.simulated_wgs < cfg.grid_size(KernelKind::Forward));
        assert!(r.est_total_ticks > r.ticks as f64);
        assert!(!r.truncated);
    }

    #[test]
    fn unified_topology_is_policy_insensitive() {
        // On a single-die GPU (Fig. 1a) all policies see one shared L2:
        // mapping must make little difference (< 10% in est time).
        let topo = presets::unified_single_die();
        let mut topo = topo;
        topo.cus_per_xcd = 16; // keep the test fast
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 16, 4096, 128) };
        let shf = simulate(&topo, &cfg, &SimConfig::forward(Policy::SwizzledHeadFirst));
        let nbf = simulate(&topo, &cfg, &SimConfig::forward(Policy::NaiveBlockFirst));
        let ratio = nbf.est_total_sec / shf.est_total_sec;
        assert!((0.8..1.25).contains(&ratio), "ratio {ratio}");
    }
}
