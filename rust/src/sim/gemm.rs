//! GEMM workgroup-swizzling microbenchmark — reproduces the motivating
//! claim of paper Sec. 1: spatially-aware mapping lifted GEMM L2 hit
//! rates from 43% to 92% on MI300X (AMD Tensile data).
//!
//! A tiled GEMM C = A·B assigns one C tile per workgroup; WG (i, j)
//! streams row panel A(i, :) and column panel B(:, j) over the K loop.
//!
//! * **Naive**: row-major tile order + round-robin dispatch. With a wide
//!   C (tiles_n >= one wave), every XCD's in-flight WGs sit in the same
//!   tile row with strided columns: the A panel is shared but every B
//!   tile is private -> hit rate collapses toward ~50%.
//! * **Swizzled**: Tensile/Triton-style *grouped* ordering (GROUP_M tile
//!   rows traversed column-fastest) combined with the Fig.-3 chiplet
//!   swizzle, giving each XCD a compact 2D block of C tiles whose A rows
//!   AND B columns are both shared.

use crate::attn::tile::{key, Tensor};
use crate::cache::{CacheStats, LruCache};
use crate::mapping::chiplet_swizzle;
use crate::topology::Topology;

/// GEMM geometry (dimensions in *tiles*; each tile read is `tile_bytes`).
#[derive(Debug, Clone, Copy)]
pub struct GemmConfig {
    /// C tile grid rows (M / BLOCK_M).
    pub tiles_m: usize,
    /// C tile grid cols (N / BLOCK_N).
    pub tiles_n: usize,
    /// K-loop length in tiles.
    pub tiles_k: usize,
    /// Bytes of one A/B tile.
    pub tile_bytes: u32,
    /// Grouped-ordering row-group size (Triton GROUP_SIZE_M; Tensile
    /// WorkGroupMapping). Used by the swizzled variant.
    pub group_m: usize,
}

impl Default for GemmConfig {
    fn default() -> Self {
        // 4096x65536x4096 bf16 with 128x128x128 tiles: a wide skinny
        // GEMM like an LLM's LM-head / MLP, where the naive mapping's
        // locality loss is most visible.
        GemmConfig { tiles_m: 32, tiles_n: 512, tiles_k: 32, tile_bytes: 32 * 1024, group_m: 8 }
    }
}

/// Result of one GEMM replay.
#[derive(Debug, Clone)]
pub struct GemmReport {
    /// Aggregate L2 statistics across XCDs.
    pub l2: CacheStats,
    /// Total bytes fetched from HBM.
    pub hbm_bytes: u64,
}

/// Map a logical *ordering index* to a C tile (i, j).
fn tile_of(cfg: &GemmConfig, idx: usize, grouped: bool) -> (usize, usize) {
    if !grouped {
        return (idx / cfg.tiles_n, idx % cfg.tiles_n);
    }
    // Triton grouped ordering: walk GROUP_M rows column-fastest.
    let group_rows = cfg.group_m.min(cfg.tiles_m);
    let per_group = group_rows * cfg.tiles_n;
    let g = idx / per_group;
    let r = idx % per_group;
    let first_row = g * group_rows;
    let rows_here = group_rows.min(cfg.tiles_m - first_row);
    (first_row + r % rows_here, r / rows_here)
}

/// Replay the GEMM tile traffic on `topo`'s L2s, in occupancy-sized
/// waves (no timing — the motivating claim is about hit rates).
pub fn simulate_gemm(topo: &Topology, cfg: &GemmConfig, swizzled: bool) -> GemmReport {
    let grid = cfg.tiles_m * cfg.tiles_n;
    let num_xcds = topo.num_xcds;
    let mut caches: Vec<LruCache> =
        (0..num_xcds).map(|_| LruCache::new(topo.l2_bytes_per_xcd)).collect();
    let mut hbm_bytes = 0u64;
    let slots = topo.wg_slots_per_xcd();

    // Dispatch slot s -> XCD s % num_xcds. The logical tile that slot
    // executes: naive = row-major order at index s; swizzled = grouped
    // order at the chiplet-swizzled index.
    let mut next_slot = 0usize;
    while next_slot < grid {
        let wave_end = (next_slot + slots * num_xcds).min(grid);
        // K-loop outer: wave members advance in lockstep like real
        // wavefront execution, touching A(i,k) and B(j,k) per step.
        for k in 0..cfg.tiles_k {
            for s in next_slot..wave_end {
                let xcd = s % num_xcds;
                let logical = if swizzled { chiplet_swizzle(s, grid, num_xcds) } else { s };
                let (i, j) = tile_of(cfg, logical, swizzled);
                let a = key(Tensor::GemmA, 0, i as u32, k as u32);
                let b = key(Tensor::GemmB, 0, j as u32, k as u32);
                for t in [a, b] {
                    if !caches[xcd].access(t, cfg.tile_bytes) {
                        hbm_bytes += cfg.tile_bytes as u64;
                    }
                }
            }
        }
        next_slot = wave_end;
    }

    let mut l2 = CacheStats::default();
    for c in &caches {
        l2.merge(c.stats());
    }
    GemmReport { l2, hbm_bytes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::presets;

    #[test]
    fn grouped_order_covers_grid() {
        let cfg = GemmConfig { tiles_m: 12, tiles_n: 7, tiles_k: 1, group_m: 8, tile_bytes: 1024 };
        let mut seen: Vec<(usize, usize)> =
            (0..cfg.tiles_m * cfg.tiles_n).map(|i| tile_of(&cfg, i, true)).collect();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), cfg.tiles_m * cfg.tiles_n);
    }

    #[test]
    fn swizzle_dramatically_improves_gemm_hit_rate() {
        let topo = presets::mi300x();
        let cfg = GemmConfig::default();
        let naive = simulate_gemm(&topo, &cfg, false);
        let swizzled = simulate_gemm(&topo, &cfg, true);
        let (hn, hs) = (naive.l2.hit_rate(), swizzled.l2.hit_rate());
        // Paper Sec. 1: 43% -> 92%. Shape check: big jump, high absolute.
        assert!(hs > hn + 0.2, "naive {hn:.2} swizzled {hs:.2}");
        assert!(hs > 0.8, "swizzled {hs:.2}");
        assert!(hn < 0.6, "naive {hn:.2}");
    }

    #[test]
    fn traffic_drops_with_swizzle() {
        let topo = presets::mi300x();
        let cfg = GemmConfig::default();
        let naive = simulate_gemm(&topo, &cfg, false);
        let swizzled = simulate_gemm(&topo, &cfg, true);
        assert!(swizzled.hbm_bytes < naive.hbm_bytes);
    }

    #[test]
    fn conservation_accesses() {
        let topo = presets::mi300x();
        let cfg = GemmConfig::default();
        let r = simulate_gemm(&topo, &cfg, true);
        let expected = (cfg.tiles_m * cfg.tiles_n * cfg.tiles_k * 2) as u64;
        assert_eq!(r.l2.accesses(), expected);
    }
}
