//! The tick-level simulation engine.

use crate::attn::trace::WgCursor;
use crate::attn::{AttnConfig, KernelKind};
use crate::cache::{CacheStats, LruCache};
use crate::mapping::Mapping;
use crate::mem::{HbmModel, HbmStats};
use crate::sched::Dispatcher;
use crate::topology::Topology;

use super::{avg_stream_len, SimConfig, SimReport};

/// One resident workgroup.
#[derive(Debug)]
struct Wg {
    cursor: WgCursor,
    /// Demand reads still waiting for an HBM fill.
    outstanding: u16,
    /// Tick at which the current step's compute completes (valid when
    /// `outstanding == 0`).
    ready_at: u64,
    /// Compute ticks to charge once the outstanding reads arrive.
    staged_ticks: u64,
    /// Steps executed so far (jitter hash input).
    steps_done: u64,
    /// Keys this WG already *issued* L2 transactions for (double-buffered
    /// loads): their hit/miss was recorded at issue time, so the consume
    /// step must not double-count. Small ring, cleared on consume.
    issued: [u64; 16],
    issued_len: u8,
    /// Issued keys whose fill has NOT yet arrived. Once a fill arrives
    /// the data is in the CU's LDS/register double buffer, so later L2
    /// eviction cannot invalidate it.
    pending: [u64; 16],
    pending_len: u8,
    /// Keys the current step's consume is blocked on (subset of pending).
    blocked: [u64; 8],
    blocked_len: u8,
}

impl Wg {
    fn ring_remove(ring: &mut [u64], len: &mut u8, key: u64) -> bool {
        for i in 0..*len as usize {
            if ring[i] == key {
                ring[i] = ring[*len as usize - 1];
                *len -= 1;
                return true;
            }
        }
        false
    }

    fn ring_contains(ring: &[u64], len: u8, key: u64) -> bool {
        ring[..len as usize].contains(&key)
    }

    fn ring_push(ring: &mut [u64], len: &mut u8, key: u64) {
        if (*len as usize) < ring.len() {
            ring[*len as usize] = key;
            *len += 1;
        }
    }

    fn was_issued(&mut self, key: u64) -> bool {
        Self::ring_remove(&mut self.issued, &mut self.issued_len, key)
    }

    fn mark_issued(&mut self, key: u64) {
        Self::ring_push(&mut self.issued, &mut self.issued_len, key);
    }

    fn mark_pending(&mut self, key: u64) {
        Self::ring_push(&mut self.pending, &mut self.pending_len, key);
    }

    fn is_pending(&self, key: u64) -> bool {
        Self::ring_contains(&self.pending, self.pending_len, key)
    }

    fn block_on(&mut self, key: u64) {
        Self::ring_push(&mut self.blocked, &mut self.blocked_len, key);
        self.outstanding += 1;
    }

    /// A fill arrived: clear pending; if the consume was blocked on it,
    /// unblock. Returns true if this was the last blocking read.
    fn note_arrival(&mut self, key: u64) -> bool {
        Self::ring_remove(&mut self.pending, &mut self.pending_len, key);
        if Self::ring_remove(&mut self.blocked, &mut self.blocked_len, key) {
            debug_assert!(self.outstanding > 0);
            self.outstanding -= 1;
            return self.outstanding == 0;
        }
        false
    }
}

/// The tick-level simulation engine for one kernel launch: per-XCD
/// slots and L2s, the shared HBM queue, and the dispatcher.
pub struct Engine {
    topo: Topology,
    attn: AttnConfig,
    sim: SimConfig,
    dispatcher: Dispatcher,
    caches: Vec<LruCache>,
    hbm: HbmModel,
    /// XCD-major slot array: index = xcd * slots_per_xcd + local.
    slots: Vec<Option<Wg>>,
    slots_per_xcd: usize,
    /// (xcd, key) -> global slot indices waiting on the fill.
    waiters: crate::util::fxhash::FastMap<(u32, u64), Vec<u32>>,
    tick: u64,
    completed: usize,
    target: usize,
    /// Seconds represented by one tick (see `SimConfig` docs).
    sec_per_tick: f64,
    /// Measurement window bookkeeping.
    warmup_done: bool,
    window_start_tick: u64,
    window_start_completed: usize,
    hbm_baseline: HbmStats,
}

impl Engine {
    /// Build an engine for one (topology, workload, sim-config) triple.
    /// Panics on invalid configs — the driver's job keys are validated
    /// upstream.
    pub fn new(topo: Topology, attn: AttnConfig, sim: SimConfig) -> Self {
        topo.validate().expect("invalid topology");
        attn.validate().expect("invalid attention config");
        if let KernelKind::DecodeSplitKv { num_splits } | KernelKind::DecodeReduce { num_splits } =
            sim.kernel
        {
            assert!(num_splits > 0, "decode kernels require num_splits >= 1");
        }
        let mapping = Mapping::for_kernel(sim.policy, &attn, sim.kernel, topo.num_xcds)
            .expect("invalid mapping");
        let dispatcher = Dispatcher::new(mapping, topo.dispatch_chunk, topo.num_xcds);

        let step_flops = attn.step_flops_for(sim.kernel);
        // compute_efficiency_factor models D_HEAD effects (MFMA K-granule
        // padding + softmax overhead — paper Sec. 4.5's D=56 slowdown).
        let cu_eff = topo.cu_flops_per_sec
            * sim.compute_efficiency
            * attn.compute_efficiency_factor();
        let sec_per_tick = step_flops * sim.compute_overhead / cu_eff;
        // Achievable DRAM efficiency for streaming tile reads (row
        // activations, refresh, read/write turnaround) — ~90% of pin rate.
        const DRAM_EFFICIENCY: f64 = 0.9;
        let hbm_bytes_per_tick =
            ((topo.hbm_bytes_per_sec * DRAM_EFFICIENCY * sec_per_tick) as u64).max(1);
        let hbm_latency_ticks = (topo.hbm_latency_sec / sec_per_tick).ceil() as u64;
        let hbm = HbmModel::new(hbm_bytes_per_tick, hbm_latency_ticks);

        // Effective L2 capacity available to the K/V streams: half the
        // physical L2. The other half holds the resident working set the
        // tile streams compete with — every in-flight WG's Q row block and
        // O write-allocate lines (38 x 64 KiB ~ 2.4 MiB on MI300X), lse/
        // delta vectors, and metadata. This is a large part of why many
        // concurrent ACC streams per XCD thrash (Fig. 13's collapse).
        let slots_per_xcd = topo.wg_slots_per_xcd();
        let effective_l2 = (topo.l2_bytes_per_xcd / 2).max(attn.kv_tile_bytes());
        let caches = (0..topo.num_xcds)
            .map(|_| LruCache::new(effective_l2))
            .collect();
        let slots = (0..topo.num_xcds * slots_per_xcd).map(|_| None).collect();

        let grid = dispatcher.grid_size();
        let target = if sim.max_wg_completions == 0 {
            grid
        } else {
            sim.max_wg_completions.min(grid)
        };

        Engine {
            topo,
            attn,
            sim,
            dispatcher,
            caches,
            hbm,
            slots,
            slots_per_xcd,
            waiters: Default::default(),
            tick: 0,
            completed: 0,
            target,
            sec_per_tick,
            warmup_done: false,
            window_start_tick: 0,
            window_start_completed: 0,
            hbm_baseline: HbmStats::default(),
        }
    }

    /// Deterministic per-step jitter: models wavefront-scheduling noise.
    #[inline]
    fn jitter(&self, slot: u32, step: u64) -> u64 {
        if self.sim.jitter_denom == 0 {
            return 0;
        }
        let mut x = self
            .sim
            .seed
            .wrapping_add((slot as u64) << 32)
            .wrapping_add(step)
            .wrapping_mul(0x9E3779B97F4A7C15);
        x ^= x >> 30;
        x = x.wrapping_mul(0xBF58476D1CE4E5B9);
        x ^= x >> 27;
        u64::from(x % self.sim.jitter_denom == 0)
    }

    /// Run to the completion target (or `max_ticks`) and report.
    pub fn run(mut self) -> SimReport {
        let exact = self.target == self.dispatcher.grid_size();
        let mut truncated = false;

        while self.completed < self.target {
            if self.tick >= self.sim.max_ticks {
                truncated = true;
                break;
            }
            self.step_tick();
            self.tick += 1;
            // Warmup boundary: reset measurement window.
            if !exact
                && !self.warmup_done
                && self.completed >= self.sim.warmup_completions
            {
                self.warmup_done = true;
                self.window_start_tick = self.tick;
                self.window_start_completed = self.completed;
                for c in &mut self.caches {
                    c.reset_stats();
                }
                self.hbm_baseline = *self.hbm.stats();
            }
        }
        self.report(exact, truncated)
    }

    fn step_tick(&mut self) {
        // 1. HBM completions: fill caches, wake waiters.
        let completions = self.hbm.step(self.tick);
        for c in completions {
            self.caches[c.xcd as usize].fill(c.key, c.bytes);
            if let Some(ws) = self.waiters.remove(&(c.xcd, c.key)) {
                for slot_idx in ws {
                    // Slot may have been recycled if the WG retired with
                    // non-blocking prefetches still in flight.
                    let Some(wg) = self.slots[slot_idx as usize].as_mut() else {
                        continue;
                    };
                    if wg.note_arrival(c.key) {
                        wg.ready_at = self.tick + wg.staged_ticks;
                    }
                }
            }
        }

        // 2. Advance every XCD's slots: dispatch into empty ones, issue
        //    the next step for ready ones.
        for xcd in 0..self.topo.num_xcds as u32 {
            for local in 0..self.slots_per_xcd {
                let idx = xcd as usize * self.slots_per_xcd + local;
                // Retire / dispatch loop: a retiring WG frees the slot for
                // a new dispatch in the same tick (hardware back-to-back).
                loop {
                    match &mut self.slots[idx] {
                        None => {
                            let Some((dispatch_slot, item)) = self.dispatcher.next_for_xcd(xcd)
                            else {
                                break;
                            };
                            let cursor = WgCursor::new(&self.attn, self.sim.kernel, item);
                            // Bounded launch stagger (see SimConfig docs).
                            // Phase spread grows with kernel duration
                            // (longer streams accumulate more completion
                            // skew), capped at `launch_stagger`.
                            let span = (8 + cursor.stream_len() as u64 / 64)
                                .min(self.sim.launch_stagger.max(1));
                            let stagger = if self.sim.launch_stagger == 0 {
                                0
                            } else {
                                crate::util::rng::mix(
                                    self.sim.seed ^ (dispatch_slot as u64) << 17,
                                ) % (span + 1)
                            };
                            self.slots[idx] = Some(Wg {
                                cursor,
                                outstanding: 0,
                                ready_at: self.tick + stagger,
                                staged_ticks: 0,
                                steps_done: 0,
                                issued: [0; 16],
                                issued_len: 0,
                                pending: [0; 16],
                                pending_len: 0,
                                blocked: [0; 8],
                                blocked_len: 0,
                            });
                            // fall through (advances this tick if stagger 0)
                        }
                        Some(wg) => {
                            if wg.outstanding > 0 || wg.ready_at > self.tick {
                                break; // stalled or computing
                            }
                            if !self.advance_wg(xcd, idx as u32) {
                                // retired: slot now empty; loop dispatches.
                                continue;
                            }
                            break;
                        }
                    }
                }
            }
        }
    }

    /// Issue the next step of the WG in `slot`. Returns false if the WG
    /// retired (slot cleared).
    fn advance_wg(&mut self, xcd: u32, slot: u32) -> bool {
        let wg = self.slots[slot as usize].as_mut().expect("advance empty");
        let Some(step) = wg.cursor.next_step() else {
            // Retire: write outputs, free the slot.
            let bytes = wg.cursor.write_bytes();
            self.hbm.write(bytes);
            self.slots[slot as usize] = None;
            self.completed += 1;
            return false;
        };
        wg.steps_done += 1;
        let steps_done = wg.steps_done;
        let compute = if step.flops > 0.0 { 1 } else { 0 };

        // Double-buffered loads for the step `prefetch_depth` ahead. On
        // real hardware these ARE the L2 read transactions (the kernel
        // issues tile j+1's loads while computing tile j), so hit/miss is
        // recorded HERE, at issue time. The first advance issues the whole
        // window 0..depth so every stream step is issued exactly once.
        let mut prefetch_keys: [(u64, u32); 8] = [(0, 0); 8];
        let mut n_prefetch = 0;
        if self.sim.prefetch_depth > 0 {
            let first = steps_done == 1;
            let range = if first { 0..self.sim.prefetch_depth } else { self.sim.prefetch_depth - 1..self.sim.prefetch_depth };
            for ahead in range {
                let Some(p) = wg.cursor.peek(ahead) else { break };
                for r in p.reads() {
                    if n_prefetch < prefetch_keys.len() {
                        prefetch_keys[n_prefetch] = (r.key, r.bytes);
                        n_prefetch += 1;
                    }
                }
            }
        }

        // Consume this step's reads. If this WG issued the load earlier
        // (double buffering), the L2 transaction was already counted; we
        // only wait for data that has not arrived. Otherwise (prologue,
        // depth 0, ring overflow) this IS the L2 transaction.
        let mut reads: [(u64, u32); 4] = [(0, 0); 4];
        let n_reads = step.reads().len();
        for (dst, r) in reads.iter_mut().zip(step.reads()) {
            *dst = (r.key, r.bytes);
        }
        for &(key, bytes) in &reads[..n_reads] {
            let (pre_issued, still_pending) = {
                let wg = self.slots[slot as usize].as_mut().unwrap();
                let pending = wg.is_pending(key);
                (wg.was_issued(key), pending)
            };
            if pre_issued {
                // Stats were counted at issue. If the fill already
                // arrived, the data sits in the CU's double buffer (L2
                // eviction irrelevant); otherwise block on it.
                if still_pending {
                    self.slots[slot as usize].as_mut().unwrap().block_on(key);
                }
                continue;
            }
            // Un-prefetched access (prologue / depth 0 / ring overflow):
            // present -> hit; another WG's fill in flight -> shared hit
            // (MSHR); else miss + fetch.
            let cache = &mut self.caches[xcd as usize];
            if cache.try_hit(key, bytes) {
                continue;
            }
            match self.hbm.inflight_origin(xcd, key) {
                Some(origin) if origin != slot => {
                    self.caches[xcd as usize].record_shared_hit(bytes);
                }
                Some(_) => self.caches[xcd as usize].record_miss(bytes),
                None => {
                    self.caches[xcd as usize].record_miss(bytes);
                    self.hbm.request(self.tick, xcd, key, bytes, slot);
                }
            }
            self.waiters.entry((xcd, key)).or_default().push(slot);
            let wg = self.slots[slot as usize].as_mut().unwrap();
            wg.mark_pending(key);
            wg.block_on(key);
        }

        // Issue the double-buffered loads (after demand so demand sits
        // earlier in the FIFO queue), recording their hit/miss now.
        for &(key, bytes) in &prefetch_keys[..n_prefetch] {
            let cache = &mut self.caches[xcd as usize];
            let mut in_flight = false;
            if cache.try_hit(key, bytes) {
                // Already resident: free hit, lands in the double buffer.
            } else {
                match self.hbm.inflight_origin(xcd, key) {
                    Some(origin) if origin != slot => {
                        cache.record_shared_hit(bytes);
                        in_flight = true;
                    }
                    Some(_) => in_flight = true, // own earlier issue
                    None => {
                        cache.record_miss(bytes);
                        self.hbm.request(self.tick, xcd, key, bytes, slot);
                        in_flight = true;
                    }
                }
            }
            if in_flight {
                self.waiters.entry((xcd, key)).or_default().push(slot);
            }
            let wg = self.slots[slot as usize].as_mut().unwrap();
            wg.mark_issued(key);
            if in_flight {
                wg.mark_pending(key);
            }
        }

        let jitter = self.jitter(slot, steps_done);
        let wg = self.slots[slot as usize].as_mut().unwrap();
        if wg.outstanding == 0 {
            wg.ready_at = self.tick + compute + jitter;
        } else {
            wg.staged_ticks = compute + jitter;
        }
        true
    }

    fn report(&self, exact: bool, truncated: bool) -> SimReport {
        let grid = self.dispatcher.grid_size();
        let mut l2 = CacheStats::default();
        for c in &self.caches {
            l2.merge(c.stats());
        }
        let l2_stats_per_xcd: Vec<CacheStats> = self.caches.iter().map(|c| *c.stats()).collect();
        let l2_per_xcd = l2_stats_per_xcd.iter().map(|s| s.hit_rate()).collect();

        let hbm_raw = *self.hbm.stats();
        let hbm = HbmStats {
            bytes_read: hbm_raw.bytes_read - self.hbm_baseline.bytes_read,
            requests: hbm_raw.requests - self.hbm_baseline.requests,
            mshr_merges: hbm_raw.mshr_merges - self.hbm_baseline.mshr_merges,
            busy_ticks: hbm_raw.busy_ticks - self.hbm_baseline.busy_ticks,
            queue_depth_sum: hbm_raw.queue_depth_sum - self.hbm_baseline.queue_depth_sum,
            bytes_written: hbm_raw.bytes_written - self.hbm_baseline.bytes_written,
        };

        let window_ticks = self.tick - self.window_start_tick;
        let window_completions = self.completed - self.window_start_completed;
        let throughput = if window_ticks > 0 {
            window_completions as f64 / window_ticks as f64
        } else {
            0.0
        };
        let est_total_ticks = if exact && !truncated {
            self.tick as f64
        } else if throughput > 0.0 {
            grid as f64 / throughput
        } else {
            f64::INFINITY
        };
        let est_total_sec = est_total_ticks * self.sec_per_tick;

        let step_flops = self.attn.step_flops_for(self.sim.kernel);
        let total_flops =
            grid as f64 * step_flops * avg_stream_len(&self.attn, self.sim.kernel);

        SimReport {
            policy: self.sim.policy,
            kernel: self.sim.kernel,
            grid_size: grid,
            simulated_wgs: self.completed,
            ticks: window_ticks,
            sec_per_tick: self.sec_per_tick,
            l2,
            l2_stats_per_xcd,
            l2_hit_rate_per_xcd: l2_per_xcd,
            hbm,
            throughput_wgs_per_tick: throughput,
            est_total_ticks,
            est_total_sec,
            achieved_tflops: total_flops / est_total_sec / 1e12,
            truncated,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::Policy;
    use crate::topology::presets;

    fn topo4() -> Topology {
        Topology {
            name: "t4".into(),
            num_xcds: 4,
            cus_per_xcd: 8,
            l2_bytes_per_xcd: 1024 * 1024,
            ..presets::mi300x()
        }
    }

    #[test]
    fn conservation_all_wgs_complete() {
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(2, 8, 2048, 64) };
        let sim = SimConfig::forward(Policy::SwizzledHeadFirst);
        let r = Engine::new(topo4(), cfg, sim).run();
        assert_eq!(r.simulated_wgs, cfg.grid_size(KernelKind::Forward));
    }

    #[test]
    fn access_count_matches_trace_math() {
        // Non-causal forward: each WG does 1 Q read + 2 reads/stream step.
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 4, 2048, 64) };
        let sim = SimConfig { jitter_denom: 0, ..SimConfig::forward(Policy::NaiveHeadFirst) };
        let r = Engine::new(topo4(), cfg, sim).run();
        let wgs = cfg.grid_size(KernelKind::Forward) as u64;
        let expected = wgs * (1 + 2 * cfg.num_col_blocks() as u64);
        assert_eq!(r.l2.accesses(), expected);
    }

    #[test]
    fn deterministic_same_seed() {
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 8, 2048, 64) };
        let sim = SimConfig::forward(Policy::NaiveBlockFirst);
        let a = Engine::new(topo4(), cfg, sim).run();
        let b = Engine::new(topo4(), cfg, sim).run();
        assert_eq!(a.ticks, b.ticks);
        assert_eq!(a.l2.hits, b.l2.hits);
        assert_eq!(a.hbm.bytes_read, b.hbm.bytes_read);
    }

    #[test]
    fn different_seed_changes_jitter_not_conservation() {
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 8, 2048, 64) };
        let a = Engine::new(topo4(), cfg, SimConfig::forward(Policy::NaiveBlockFirst)).run();
        let sim_b = SimConfig { seed: 123, ..SimConfig::forward(Policy::NaiveBlockFirst) };
        let b = Engine::new(topo4(), cfg, sim_b).run();
        assert_eq!(a.simulated_wgs, b.simulated_wgs);
        assert_eq!(a.l2.accesses(), b.l2.accesses());
    }

    #[test]
    fn hbm_reads_bounded_by_compulsory_and_capacity() {
        // Total HBM read bytes can never be less than one copy of the
        // distinct data actually touched per XCD that touches it.
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 4, 2048, 64) };
        let sim = SimConfig::forward(Policy::SwizzledHeadFirst);
        let r = Engine::new(topo4(), cfg, sim).run();
        // SHF: each head's K/V fetched once on its own XCD (plus Q).
        let kv_bytes = 4 * cfg.kv_bytes_per_head() as u64;
        let q_bytes = (4 * cfg.n_ctx * cfg.d_head * cfg.dtype_bytes) as u64;
        let compulsory = kv_bytes + q_bytes;
        assert!(r.hbm.bytes_read >= compulsory, "{} < {compulsory}", r.hbm.bytes_read);
        // ... and is not wildly above it for the swizzled policy.
        assert!(
            (r.hbm.bytes_read as f64) < 2.5 * compulsory as f64,
            "{} vs {compulsory}",
            r.hbm.bytes_read
        );
    }

    #[test]
    fn no_deadlock_with_tiny_cache() {
        // Cache smaller than a single tile: everything streams through.
        let mut topo = topo4();
        topo.l2_bytes_per_xcd = 1024;
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 4, 1024, 64) };
        let r = Engine::new(topo, cfg, SimConfig::forward(Policy::NaiveHeadFirst)).run();
        assert_eq!(r.simulated_wgs, cfg.grid_size(KernelKind::Forward));
        assert!(r.l2.hit_rate() < 0.2);
    }

    #[test]
    fn prefetch_improves_or_equals_performance() {
        // Double buffering hides fill latency: never slower, usually
        // faster. (Hit RATE semantics differ — with prefetch the counted
        // transaction happens at issue time — so only time is compared.)
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 8, 4096, 128) };
        let with = Engine::new(
            topo4(),
            cfg,
            SimConfig { prefetch_depth: 1, ..SimConfig::forward(Policy::SwizzledHeadFirst) },
        )
        .run();
        let without = Engine::new(
            topo4(),
            cfg,
            SimConfig { prefetch_depth: 0, ..SimConfig::forward(Policy::SwizzledHeadFirst) },
        )
        .run();
        assert!(
            with.est_total_sec <= without.est_total_sec * 1.02,
            "with {} vs without {}",
            with.est_total_sec,
            without.est_total_sec
        );
    }

    #[test]
    fn decode_conservation_and_access_math() {
        // Split-KV decode: every WG completes; accesses = 1 Q-vector
        // prologue read + 2 reads per streamed K/V tile, and the splits
        // exactly partition each head's column blocks.
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(2, 8, 2048, 64) };
        let num_splits = 4;
        let sim = SimConfig::decode(Policy::SwizzledHeadFirst, num_splits);
        let r = Engine::new(topo4(), cfg, sim).run();
        let grid = cfg.grid_size(KernelKind::DecodeSplitKv { num_splits });
        assert_eq!(r.simulated_wgs, grid);
        let expected = grid as u64 + 2 * (cfg.batch * cfg.h_q * cfg.num_col_blocks()) as u64;
        assert_eq!(r.l2.accesses(), expected);
    }

    #[test]
    fn decode_reduce_conservation() {
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(2, 8, 2048, 64) };
        let num_splits = 4;
        let sim = SimConfig {
            kernel: KernelKind::DecodeReduce { num_splits },
            ..SimConfig::decode(Policy::SwizzledHeadFirst, num_splits)
        };
        let r = Engine::new(topo4(), cfg, sim).run();
        assert_eq!(r.simulated_wgs, cfg.batch * cfg.h_q);
        // 2 reads per split per WG, prologue reads nothing.
        assert_eq!(r.l2.accesses(), (cfg.batch * cfg.h_q * num_splits * 2) as u64);
    }

    #[test]
    fn max_ticks_truncates() {
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(4, 16, 8192, 128) };
        let sim = SimConfig { max_ticks: 50, ..SimConfig::forward(Policy::NaiveBlockFirst) };
        let r = Engine::new(topo4(), cfg, sim).run();
        assert!(r.truncated);
    }
}
