//! The tick-level simulation engine.
//!
//! Two execution strategies produce bit-identical [`SimReport`]s
//! (DESIGN.md §13):
//!
//! * **Reference** — the original per-tick scan of every slot on every
//!   XCD. Cost is O(slots) per tick even when nothing can move.
//! * **Event-driven** (the default) — slots are advanced from a ready
//!   queue keyed on `ready_at`, idle gaps are skipped to
//!   `min(next ready slot, next HBM completion)` with the HBM model
//!   bulk-advanced over the gap, and XCDs whose provable working-set
//!   bound fits their effective L2 run the cache in no-evict mode (hits
//!   skip the LRU relink). Cost scales with state *transitions*, not
//!   ticks — the win is largest in latency-epoch regimes (decode reduce)
//!   where the reference spins thousands of dead ticks per HBM round
//!   trip.
//!
//! Exactness is pinned by `tests/engine_equivalence.rs` and the in-module
//! differential tests below: every report field, including debug
//! counters, must match the reference byte-for-byte.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashSet};

use crate::attn::trace::WgCursor;
use crate::attn::{AttnConfig, KernelKind};
use crate::cache::{CacheStats, LruCache};
use crate::mapping::Mapping;
use crate::mem::{FetchKind, HbmModel, HbmStats};
use crate::sched::{xcd_of_slot, Dispatcher};
use crate::topology::Topology;

use super::{avg_stream_len, EngineDebugStats, SimConfig, SimReport};

/// One resident workgroup.
#[derive(Debug)]
struct Wg {
    cursor: WgCursor,
    /// Demand reads still waiting for an HBM fill.
    outstanding: u16,
    /// Tick at which the current step's compute completes (valid when
    /// `outstanding == 0`).
    ready_at: u64,
    /// Compute ticks to charge once the outstanding reads arrive.
    staged_ticks: u64,
    /// Steps executed so far (jitter hash input).
    steps_done: u64,
    /// Keys this WG already *issued* L2 transactions for (double-buffered
    /// loads): their hit/miss was recorded at issue time, so the consume
    /// step must not double-count. Small ring, cleared on consume.
    issued: [u64; 16],
    issued_len: u8,
    /// Issued keys whose fill has NOT yet arrived. Once a fill arrives
    /// the data is in the CU's LDS/register double buffer, so later L2
    /// eviction cannot invalidate it.
    pending: [u64; 16],
    pending_len: u8,
    /// Keys the current step's consume is blocked on (subset of pending).
    blocked: [u64; 8],
    blocked_len: u8,
}

impl Wg {
    fn ring_remove(ring: &mut [u64], len: &mut u8, key: u64) -> bool {
        for i in 0..*len as usize {
            if ring[i] == key {
                ring[i] = ring[*len as usize - 1];
                *len -= 1;
                return true;
            }
        }
        false
    }

    fn ring_contains(ring: &[u64], len: u8, key: u64) -> bool {
        ring[..len as usize].contains(&key)
    }

    /// Push a key; returns false when the ring is full and the key was
    /// dropped (the caller counts the overflow — see
    /// [`EngineDebugStats`]).
    #[must_use]
    fn ring_push(ring: &mut [u64], len: &mut u8, key: u64) -> bool {
        if (*len as usize) < ring.len() {
            ring[*len as usize] = key;
            *len += 1;
            true
        } else {
            false
        }
    }

    fn was_issued(&mut self, key: u64) -> bool {
        Self::ring_remove(&mut self.issued, &mut self.issued_len, key)
    }

    #[must_use]
    fn mark_issued(&mut self, key: u64) -> bool {
        Self::ring_push(&mut self.issued, &mut self.issued_len, key)
    }

    #[must_use]
    fn mark_pending(&mut self, key: u64) -> bool {
        Self::ring_push(&mut self.pending, &mut self.pending_len, key)
    }

    fn is_pending(&self, key: u64) -> bool {
        Self::ring_contains(&self.pending, self.pending_len, key)
    }

    /// Block the consume on `key`. `outstanding` is bumped even when the
    /// ring drops the key (preserving the historical engine's timing);
    /// returns false on that drop so the engine can count it.
    #[must_use]
    fn block_on(&mut self, key: u64) -> bool {
        let pushed = Self::ring_push(&mut self.blocked, &mut self.blocked_len, key);
        self.outstanding += 1;
        pushed
    }

    /// A fill arrived: clear pending; if the consume was blocked on it,
    /// unblock. Returns true if this was the last blocking read.
    fn note_arrival(&mut self, key: u64) -> bool {
        Self::ring_remove(&mut self.pending, &mut self.pending_len, key);
        if Self::ring_remove(&mut self.blocked, &mut self.blocked_len, key) {
            debug_assert!(self.outstanding > 0);
            self.outstanding -= 1;
            return self.outstanding == 0;
        }
        false
    }
}

/// Execution strategy; both produce bit-identical reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum EngineMode {
    Reference,
    EventDriven,
}

/// The tick-level simulation engine for one kernel launch: per-XCD
/// slots and L2s, the shared HBM queue, and the dispatcher.
pub struct Engine {
    topo: Topology,
    attn: AttnConfig,
    sim: SimConfig,
    dispatcher: Dispatcher,
    caches: Vec<LruCache>,
    hbm: HbmModel,
    /// XCD-major slot array: index = xcd * slots_per_xcd + local.
    slots: Vec<Option<Wg>>,
    slots_per_xcd: usize,
    tick: u64,
    completed: usize,
    target: usize,
    /// Seconds represented by one tick (see `SimConfig` docs).
    sec_per_tick: f64,
    /// Measurement window bookkeeping.
    warmup_done: bool,
    window_start_tick: u64,
    window_start_completed: usize,
    hbm_baseline: HbmStats,
    mode: EngineMode,
    /// Event-driven ready queue: (tick, global slot index), min-first.
    /// Popping in (tick, slot) order reproduces the reference engine's
    /// xcd-major scan order exactly, which is what keeps the HBM FIFO,
    /// LRU state, and waiter order bit-identical.
    events: BinaryHeap<Reverse<(u64, u32)>>,
    debug: EngineDebugStats,
}

impl Engine {
    /// Build the (default) event-driven engine for one
    /// (topology, workload, sim-config) triple. Bit-identical to
    /// [`Engine::new_reference`] on every report field. Panics on invalid
    /// configs — the driver's job keys are validated upstream.
    pub fn new(topo: Topology, attn: AttnConfig, sim: SimConfig) -> Self {
        Self::with_mode(topo, attn, sim, EngineMode::EventDriven)
    }

    /// Build the reference engine: the original per-tick slot scan, kept
    /// as the behavioral oracle the event-driven path is differentially
    /// tested against.
    pub fn new_reference(topo: Topology, attn: AttnConfig, sim: SimConfig) -> Self {
        Self::with_mode(topo, attn, sim, EngineMode::Reference)
    }

    fn with_mode(topo: Topology, attn: AttnConfig, sim: SimConfig, mode: EngineMode) -> Self {
        topo.validate().expect("invalid topology");
        attn.validate().expect("invalid attention config");
        if let KernelKind::DecodeSplitKv { num_splits } | KernelKind::DecodeReduce { num_splits } =
            sim.kernel
        {
            assert!(num_splits > 0, "decode kernels require num_splits >= 1");
        }
        let mapping = Mapping::for_kernel(sim.policy, &attn, sim.kernel, topo.num_xcds)
            .expect("invalid mapping");

        let step_flops = attn.step_flops_for(sim.kernel);
        // compute_efficiency_factor models D_HEAD effects (MFMA K-granule
        // padding + softmax overhead — paper Sec. 4.5's D=56 slowdown).
        let cu_eff = topo.cu_flops_per_sec
            * sim.compute_efficiency
            * attn.compute_efficiency_factor();
        let sec_per_tick = step_flops * sim.compute_overhead / cu_eff;
        // Achievable DRAM efficiency for streaming tile reads (row
        // activations, refresh, read/write turnaround) — ~90% of pin rate.
        const DRAM_EFFICIENCY: f64 = 0.9;
        let hbm_bytes_per_tick =
            ((topo.hbm_bytes_per_sec * DRAM_EFFICIENCY * sec_per_tick) as u64).max(1);
        let hbm_latency_ticks = (topo.hbm_latency_sec / sec_per_tick).ceil() as u64;
        let hbm = HbmModel::new(hbm_bytes_per_tick, hbm_latency_ticks);

        // Effective L2 capacity available to the K/V streams: half the
        // physical L2. The other half holds the resident working set the
        // tile streams compete with — every in-flight WG's Q row block and
        // O write-allocate lines (38 x 64 KiB ~ 2.4 MiB on MI300X), lse/
        // delta vectors, and metadata. This is a large part of why many
        // concurrent ACC streams per XCD thrash (Fig. 13's collapse).
        let slots_per_xcd = topo.wg_slots_per_xcd();
        let effective_l2 = (topo.l2_bytes_per_xcd / 2).max(attn.kv_tile_bytes());
        let mut caches: Vec<LruCache> = (0..topo.num_xcds)
            .map(|_| LruCache::new(effective_l2))
            .collect();
        // Analytic no-evict fast path (event-driven only, so the
        // differential test pins its exactness against a reference that
        // never takes it): when an XCD's distinct working set provably
        // fits its effective L2, eviction cannot occur, recency order is
        // unobservable, and hits can skip the LRU relink.
        if mode == EngineMode::EventDriven {
            for (cache, bound) in caches
                .iter_mut()
                .zip(working_set_bounds(&attn, sim.kernel, &mapping, &topo, effective_l2))
            {
                if bound <= effective_l2 {
                    cache.set_no_evict(true);
                }
            }
        }
        let slots = (0..topo.num_xcds * slots_per_xcd).map(|_| None).collect();

        let dispatcher = Dispatcher::new(mapping, topo.dispatch_chunk, topo.num_xcds);
        let grid = dispatcher.grid_size();
        let target = if sim.max_wg_completions == 0 {
            grid
        } else {
            sim.max_wg_completions.min(grid)
        };

        Engine {
            topo,
            attn,
            sim,
            dispatcher,
            caches,
            hbm,
            slots,
            slots_per_xcd,
            tick: 0,
            completed: 0,
            target,
            sec_per_tick,
            warmup_done: false,
            window_start_tick: 0,
            window_start_completed: 0,
            hbm_baseline: HbmStats::default(),
            mode,
            events: BinaryHeap::new(),
            debug: EngineDebugStats::default(),
        }
    }

    /// Deterministic per-step jitter: models wavefront-scheduling noise.
    #[inline]
    fn jitter(&self, slot: u32, step: u64) -> u64 {
        if self.sim.jitter_denom == 0 {
            return 0;
        }
        let mut x = self
            .sim
            .seed
            .wrapping_add((slot as u64) << 32)
            .wrapping_add(step)
            .wrapping_mul(0x9E3779B97F4A7C15);
        x ^= x >> 30;
        x = x.wrapping_mul(0xBF58476D1CE4E5B9);
        x ^= x >> 27;
        u64::from(x % self.sim.jitter_denom == 0)
    }

    /// Run to the completion target (or `max_ticks`) and report.
    pub fn run(mut self) -> SimReport {
        let exact = self.target == self.dispatcher.grid_size();
        let truncated = match self.mode {
            EngineMode::Reference => self.run_reference(exact),
            EngineMode::EventDriven => self.run_event_driven(exact),
        };
        self.report(exact, truncated)
    }

    fn run_reference(&mut self, exact: bool) -> bool {
        while self.completed < self.target {
            if self.tick >= self.sim.max_ticks {
                return true;
            }
            self.step_tick();
            self.tick += 1;
            self.maybe_end_warmup(exact);
        }
        false
    }

    /// The event-driven main loop: process the current tick's events,
    /// then jump straight to the next tick on which anything can happen —
    /// `min(next ready slot, next HBM completion)` — bulk-advancing the
    /// HBM model over the gap. With no events and no completions pending
    /// (a stalled grid), it skips to `max_ticks`, which is exactly where
    /// the reference scan ends up after spinning.
    fn run_event_driven(&mut self, exact: bool) -> bool {
        for idx in 0..self.slots.len() as u32 {
            self.events.push(Reverse((0, idx)));
        }
        while self.completed < self.target {
            if self.tick >= self.sim.max_ticks {
                return true;
            }
            self.step_tick_event();
            self.tick += 1;
            self.maybe_end_warmup(exact);
            // Tick skip. Both candidates are >= self.tick here: processed
            // slots rescheduled at >= tick and the HBM front completes no
            // earlier than the current tick.
            let next_ready = self.events.peek().map(|Reverse((t, _))| *t);
            let next_fill = self.hbm.next_completion_tick(self.tick);
            let next_tick = match (next_ready, next_fill) {
                (Some(a), Some(b)) => a.min(b),
                (Some(a), None) => a,
                (None, Some(b)) => b,
                // Nothing will ever move again: the reference spins to
                // max_ticks, draining only the HBM write backlog.
                (None, None) => self.sim.max_ticks,
            }
            .min(self.sim.max_ticks);
            if next_tick > self.tick {
                self.hbm.skip_to(self.tick, next_tick);
                self.tick = next_tick;
            }
        }
        false
    }

    /// Warmup boundary: reset the measurement window once enough WGs
    /// completed (sampled runs only).
    fn maybe_end_warmup(&mut self, exact: bool) {
        if !exact && !self.warmup_done && self.completed >= self.sim.warmup_completions {
            self.warmup_done = true;
            self.window_start_tick = self.tick;
            self.window_start_completed = self.completed;
            for c in &mut self.caches {
                c.reset_stats();
            }
            self.hbm_baseline = *self.hbm.stats();
        }
    }

    /// HBM completions for this tick: fill caches, wake waiters. In
    /// event-driven mode a wake that unblocks a WG also schedules its
    /// next event (possibly this same tick, drained by the caller).
    fn apply_hbm_completions(&mut self) {
        let completions = self.hbm.step(self.tick);
        for c in completions {
            self.caches[c.xcd as usize].fill(c.key, c.bytes);
            for slot_idx in c.waiters {
                // Slot may have been recycled if the WG retired with
                // non-blocking prefetches still in flight.
                let Some(wg) = self.slots[slot_idx as usize].as_mut() else {
                    continue;
                };
                if wg.note_arrival(c.key) {
                    wg.ready_at = self.tick + wg.staged_ticks;
                    if self.mode == EngineMode::EventDriven {
                        self.events.push(Reverse((wg.ready_at, slot_idx)));
                    }
                }
            }
        }
    }

    fn step_tick(&mut self) {
        // 1. HBM completions: fill caches, wake waiters.
        self.apply_hbm_completions();

        // 2. Advance every XCD's slots: dispatch into empty ones, issue
        //    the next step for ready ones.
        for xcd in 0..self.topo.num_xcds as u32 {
            for local in 0..self.slots_per_xcd {
                let idx = xcd as usize * self.slots_per_xcd + local;
                // Retire / dispatch loop: a retiring WG frees the slot for
                // a new dispatch in the same tick (hardware back-to-back).
                loop {
                    match &mut self.slots[idx] {
                        None => {
                            if !self.dispatch_into(xcd, idx) {
                                break;
                            }
                            // fall through (advances this tick if stagger 0)
                        }
                        Some(wg) => {
                            if wg.outstanding > 0 || wg.ready_at > self.tick {
                                break; // stalled or computing
                            }
                            if !self.advance_wg(xcd, idx as u32) {
                                // retired: slot now empty; loop dispatches.
                                continue;
                            }
                            break;
                        }
                    }
                }
            }
        }
    }

    /// Event-driven tick: completions first (their wakes may schedule
    /// events at this very tick), then drain every event due now, in
    /// (tick, slot) order — the reference scan order.
    fn step_tick_event(&mut self) {
        self.apply_hbm_completions();
        while let Some(&Reverse((t, idx))) = self.events.peek() {
            debug_assert!(t >= self.tick, "stale event ({t}) behind tick {}", self.tick);
            if t > self.tick {
                break;
            }
            self.events.pop();
            self.process_slot(idx);
        }
    }

    /// Replay the reference per-slot state machine for one due event and
    /// schedule this slot's next event. Invariant: a slot has at most one
    /// live event; blocked slots (outstanding > 0) have none — their wake
    /// in `apply_hbm_completions` schedules it.
    fn process_slot(&mut self, idx: u32) {
        let xcd = (idx as usize / self.slots_per_xcd) as u32;
        loop {
            match &mut self.slots[idx as usize] {
                None => {
                    if !self.dispatch_into(xcd, idx as usize) {
                        return; // grid exhausted for this XCD: stays idle
                    }
                    // Loop (= reference fall-through): advances this tick
                    // if the stagger is 0, else the Some arm schedules.
                }
                Some(wg) => {
                    if wg.outstanding > 0 {
                        return; // stalled on HBM: the wake reschedules
                    }
                    if wg.ready_at > self.tick {
                        self.events.push(Reverse((wg.ready_at, idx)));
                        return; // mid-compute (or staggered launch)
                    }
                    if !self.advance_wg(xcd, idx) {
                        continue; // retired: dispatch into the freed slot
                    }
                    // One advance per slot per tick (the reference breaks
                    // here): if still runnable, the next advance is at
                    // ready_at but never before the next tick.
                    let wg = self.slots[idx as usize].as_ref().unwrap();
                    if wg.outstanding == 0 {
                        let at = wg.ready_at.max(self.tick + 1);
                        self.events.push(Reverse((at, idx)));
                    }
                    return;
                }
            }
        }
    }

    /// Dispatch the next workgroup for `xcd` into empty slot `idx`.
    /// Returns false when the dispatcher has no more work for this XCD.
    fn dispatch_into(&mut self, xcd: u32, idx: usize) -> bool {
        let Some((dispatch_slot, item)) = self.dispatcher.next_for_xcd(xcd) else {
            return false;
        };
        let cursor = WgCursor::new(&self.attn, self.sim.kernel, item);
        // Bounded launch stagger (see SimConfig docs). Phase spread grows
        // with kernel duration (longer streams accumulate more completion
        // skew), capped at `launch_stagger`.
        let span = (8 + cursor.stream_len() as u64 / 64).min(self.sim.launch_stagger.max(1));
        let stagger = if self.sim.launch_stagger == 0 {
            0
        } else {
            crate::util::rng::mix(self.sim.seed ^ (dispatch_slot as u64) << 17) % (span + 1)
        };
        self.slots[idx] = Some(Wg {
            cursor,
            outstanding: 0,
            ready_at: self.tick + stagger,
            staged_ticks: 0,
            steps_done: 0,
            issued: [0; 16],
            issued_len: 0,
            pending: [0; 16],
            pending_len: 0,
            blocked: [0; 8],
            blocked_len: 0,
        });
        true
    }

    /// Issue the next step of the WG in `slot`. Returns false if the WG
    /// retired (slot cleared).
    fn advance_wg(&mut self, xcd: u32, slot: u32) -> bool {
        let wg = self.slots[slot as usize].as_mut().expect("advance empty");
        let Some(step) = wg.cursor.next_step() else {
            // Retire: write outputs, free the slot.
            let bytes = wg.cursor.write_bytes();
            self.hbm.write(bytes);
            self.slots[slot as usize] = None;
            self.completed += 1;
            return false;
        };
        wg.steps_done += 1;
        let steps_done = wg.steps_done;
        let compute = if step.flops > 0.0 { 1 } else { 0 };

        // Double-buffered loads for the step `prefetch_depth` ahead. On
        // real hardware these ARE the L2 read transactions (the kernel
        // issues tile j+1's loads while computing tile j), so hit/miss is
        // recorded HERE, at issue time. The first advance issues the whole
        // window 0..depth so every stream step is issued exactly once.
        let mut prefetch_keys: [(u64, u32); 8] = [(0, 0); 8];
        let mut n_prefetch = 0;
        if self.sim.prefetch_depth > 0 {
            let first = steps_done == 1;
            let range = if first {
                0..self.sim.prefetch_depth
            } else {
                self.sim.prefetch_depth - 1..self.sim.prefetch_depth
            };
            for ahead in range {
                let Some(p) = wg.cursor.peek(ahead) else { break };
                for r in p.reads() {
                    if n_prefetch < prefetch_keys.len() {
                        prefetch_keys[n_prefetch] = (r.key, r.bytes);
                        n_prefetch += 1;
                    }
                }
            }
        }

        // Consume this step's reads. If this WG issued the load earlier
        // (double buffering), the L2 transaction was already counted; we
        // only wait for data that has not arrived. Otherwise (prologue,
        // depth 0, ring overflow) this IS the L2 transaction.
        let mut reads: [(u64, u32); 4] = [(0, 0); 4];
        let n_reads = step.reads().len();
        debug_assert!(
            n_reads <= reads.len(),
            "kernel step has {n_reads} reads; the consume buffer holds {}",
            reads.len()
        );
        for (dst, r) in reads.iter_mut().zip(step.reads()) {
            *dst = (r.key, r.bytes);
        }
        for &(key, bytes) in &reads[..n_reads] {
            let (pre_issued, still_pending) = {
                let wg = self.slots[slot as usize].as_mut().unwrap();
                let pending = wg.is_pending(key);
                (wg.was_issued(key), pending)
            };
            if pre_issued {
                // Stats were counted at issue. If the fill already
                // arrived, the data sits in the CU's double buffer (L2
                // eviction irrelevant); otherwise block on it.
                if still_pending
                    && !self.slots[slot as usize].as_mut().unwrap().block_on(key)
                {
                    self.debug.blocked_ring_overflows += 1;
                }
                continue;
            }
            // Un-prefetched access (prologue / depth 0 / ring overflow):
            // present -> hit; another WG's fill in flight -> shared hit
            // (MSHR); own still-pending fetch or fresh fetch -> miss. One
            // MSHR-file probe classifies and registers the waiter.
            if self.caches[xcd as usize].try_hit(key, bytes) {
                continue;
            }
            match self.hbm.fetch(self.tick, xcd, key, bytes, slot) {
                FetchKind::MergedShared => self.caches[xcd as usize].record_shared_hit(bytes),
                FetchKind::MergedOwn | FetchKind::Started => {
                    self.caches[xcd as usize].record_miss(bytes)
                }
            }
            let wg = self.slots[slot as usize].as_mut().unwrap();
            if !wg.mark_pending(key) {
                self.debug.pending_ring_overflows += 1;
            }
            if !wg.block_on(key) {
                self.debug.blocked_ring_overflows += 1;
            }
        }

        // Issue the double-buffered loads (after demand so demand sits
        // earlier in the FIFO queue), recording their hit/miss now.
        for &(key, bytes) in &prefetch_keys[..n_prefetch] {
            let mut in_flight = false;
            if self.caches[xcd as usize].try_hit(key, bytes) {
                // Already resident: free hit, lands in the double buffer.
            } else {
                in_flight = true;
                match self.hbm.fetch(self.tick, xcd, key, bytes, slot) {
                    FetchKind::MergedShared => self.caches[xcd as usize].record_shared_hit(bytes),
                    FetchKind::MergedOwn => {} // own earlier issue
                    FetchKind::Started => self.caches[xcd as usize].record_miss(bytes),
                }
            }
            let wg = self.slots[slot as usize].as_mut().unwrap();
            if !wg.mark_issued(key) {
                self.debug.issued_ring_overflows += 1;
            }
            if in_flight && !wg.mark_pending(key) {
                self.debug.pending_ring_overflows += 1;
            }
        }

        let jitter = self.jitter(slot, steps_done);
        let wg = self.slots[slot as usize].as_mut().unwrap();
        if wg.outstanding == 0 {
            wg.ready_at = self.tick + compute + jitter;
        } else {
            wg.staged_ticks = compute + jitter;
        }
        true
    }

    fn report(&self, exact: bool, truncated: bool) -> SimReport {
        let grid = self.dispatcher.grid_size();
        let mut l2 = CacheStats::default();
        for c in &self.caches {
            l2.merge(c.stats());
        }
        let l2_stats_per_xcd: Vec<CacheStats> = self.caches.iter().map(|c| *c.stats()).collect();
        let l2_per_xcd = l2_stats_per_xcd.iter().map(|s| s.hit_rate()).collect();

        let hbm_raw = *self.hbm.stats();
        let hbm = HbmStats {
            bytes_read: hbm_raw.bytes_read - self.hbm_baseline.bytes_read,
            requests: hbm_raw.requests - self.hbm_baseline.requests,
            mshr_merges: hbm_raw.mshr_merges - self.hbm_baseline.mshr_merges,
            busy_ticks: hbm_raw.busy_ticks - self.hbm_baseline.busy_ticks,
            queue_depth_sum: hbm_raw.queue_depth_sum - self.hbm_baseline.queue_depth_sum,
            bytes_written: hbm_raw.bytes_written - self.hbm_baseline.bytes_written,
        };

        let window_ticks = self.tick - self.window_start_tick;
        let window_completions = self.completed - self.window_start_completed;
        let throughput = if window_ticks > 0 {
            window_completions as f64 / window_ticks as f64
        } else {
            0.0
        };
        let est_total_ticks = if exact && !truncated {
            self.tick as f64
        } else if throughput > 0.0 {
            grid as f64 / throughput
        } else {
            f64::INFINITY
        };
        let est_total_sec = est_total_ticks * self.sec_per_tick;

        let step_flops = self.attn.step_flops_for(self.sim.kernel);
        let total_flops =
            grid as f64 * step_flops * avg_stream_len(&self.attn, self.sim.kernel);

        SimReport {
            policy: self.sim.policy,
            kernel: self.sim.kernel,
            grid_size: grid,
            simulated_wgs: self.completed,
            ticks: window_ticks,
            sec_per_tick: self.sec_per_tick,
            l2,
            l2_stats_per_xcd,
            l2_hit_rate_per_xcd: l2_per_xcd,
            hbm,
            throughput_wgs_per_tick: throughput,
            est_total_ticks,
            est_total_sec,
            achieved_tflops: total_flops / est_total_sec / 1e12,
            truncated,
            debug: self.debug,
        }
    }
}

/// Per-XCD upper bound on the bytes the kernel can EVER insert into that
/// XCD's L2: resident operands per workgroup plus the full (causal-
/// unmasked) streamed tensors of each distinct head mapped there. When
/// the bound fits the effective L2, eviction is provably unreachable —
/// the precondition of the no-evict fast path. Returns all-`u64::MAX`
/// without scanning the grid when even a single head's stream exceeds
/// the capacity (the common at-scale case — the scan is O(grid)).
fn working_set_bounds(
    attn: &AttnConfig,
    kernel: KernelKind,
    mapping: &Mapping,
    topo: &Topology,
    effective_l2: u64,
) -> Vec<u64> {
    let num_xcds = topo.num_xcds;
    let ncol = attn.num_col_blocks() as u64;
    let nrow = attn.num_row_blocks() as u64;
    let kv_stream = ncol * 2 * attn.kv_tile_bytes();
    let q_stream = nrow * 2 * (attn.q_block_bytes() + attn.vec_block_bytes());
    // Any XCD with at least one workgroup pays at least one head's
    // streamed tensors; if that alone overflows, skip the grid scan.
    let per_head_floor = match kernel {
        KernelKind::Forward | KernelKind::BwdDq | KernelKind::DecodeSplitKv { .. } => kv_stream,
        KernelKind::BwdDkDv => q_stream,
        KernelKind::DecodeReduce { num_splits } => {
            num_splits as u64 * attn.decode_partial_bytes()
        }
    };
    if per_head_floor > effective_l2 {
        return vec![u64::MAX; num_xcds];
    }

    let mut wgs = vec![0u64; num_xcds];
    let mut qheads: Vec<HashSet<(u32, u32)>> = vec![HashSet::new(); num_xcds];
    let mut kvheads: Vec<HashSet<(u32, u32)>> = vec![HashSet::new(); num_xcds];
    for slot in 0..mapping.grid_size() {
        let x = xcd_of_slot(slot, topo.dispatch_chunk, num_xcds) as usize;
        let item = mapping.decode(slot);
        wgs[x] += 1;
        qheads[x].insert((item.z, item.h));
        kvheads[x].insert((item.z, attn.kv_head(item.h as usize) as u32));
    }
    (0..num_xcds)
        .map(|x| {
            let (w, q, kv) = (wgs[x], qheads[x].len() as u64, kvheads[x].len() as u64);
            match kernel {
                // Per-WG Q prologue + each distinct KV head's K/V stream.
                KernelKind::Forward => w * attn.q_block_bytes() + kv * kv_stream,
                // Per-WG K/V prologue + each distinct Q head's
                // Q/dO/lse/delta row streams.
                KernelKind::BwdDkDv => w * 2 * attn.kv_tile_bytes() + q * q_stream,
                // Per-WG Q/dO/lse/delta prologue + K/V streams.
                KernelKind::BwdDq => {
                    w * 2 * (attn.q_block_bytes() + attn.vec_block_bytes()) + kv * kv_stream
                }
                // Per-WG query vector + K/V streams (splits partition
                // each head's columns, so one full stream bounds them).
                KernelKind::DecodeSplitKv { .. } => w * attn.q_vec_bytes() + kv * kv_stream,
                // Each distinct head streams its num_splits partials.
                KernelKind::DecodeReduce { num_splits } => {
                    q * num_splits as u64 * attn.decode_partial_bytes()
                }
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::{Policy, ALL_POLICIES};
    use crate::topology::presets;

    fn topo4() -> Topology {
        Topology {
            name: "t4".into(),
            num_xcds: 4,
            cus_per_xcd: 8,
            l2_bytes_per_xcd: 1024 * 1024,
            ..presets::mi300x()
        }
    }

    #[test]
    fn conservation_all_wgs_complete() {
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(2, 8, 2048, 64) };
        let sim = SimConfig::forward(Policy::SwizzledHeadFirst);
        let r = Engine::new(topo4(), cfg, sim).run();
        assert_eq!(r.simulated_wgs, cfg.grid_size(KernelKind::Forward));
    }

    #[test]
    fn access_count_matches_trace_math() {
        // Non-causal forward: each WG does 1 Q read + 2 reads/stream step.
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 4, 2048, 64) };
        let sim = SimConfig { jitter_denom: 0, ..SimConfig::forward(Policy::NaiveHeadFirst) };
        let r = Engine::new(topo4(), cfg, sim).run();
        let wgs = cfg.grid_size(KernelKind::Forward) as u64;
        let expected = wgs * (1 + 2 * cfg.num_col_blocks() as u64);
        assert_eq!(r.l2.accesses(), expected);
    }

    #[test]
    fn deterministic_same_seed() {
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 8, 2048, 64) };
        let sim = SimConfig::forward(Policy::NaiveBlockFirst);
        let a = Engine::new(topo4(), cfg, sim).run();
        let b = Engine::new(topo4(), cfg, sim).run();
        assert_eq!(a.ticks, b.ticks);
        assert_eq!(a.l2.hits, b.l2.hits);
        assert_eq!(a.hbm.bytes_read, b.hbm.bytes_read);
    }

    #[test]
    fn different_seed_changes_jitter_not_conservation() {
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 8, 2048, 64) };
        let a = Engine::new(topo4(), cfg, SimConfig::forward(Policy::NaiveBlockFirst)).run();
        let sim_b = SimConfig { seed: 123, ..SimConfig::forward(Policy::NaiveBlockFirst) };
        let b = Engine::new(topo4(), cfg, sim_b).run();
        assert_eq!(a.simulated_wgs, b.simulated_wgs);
        assert_eq!(a.l2.accesses(), b.l2.accesses());
    }

    #[test]
    fn hbm_reads_bounded_by_compulsory_and_capacity() {
        // Total HBM read bytes can never be less than one copy of the
        // distinct data actually touched per XCD that touches it.
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 4, 2048, 64) };
        let sim = SimConfig::forward(Policy::SwizzledHeadFirst);
        let r = Engine::new(topo4(), cfg, sim).run();
        // SHF: each head's K/V fetched once on its own XCD (plus Q).
        let kv_bytes = 4 * cfg.kv_bytes_per_head() as u64;
        let q_bytes = (4 * cfg.n_ctx * cfg.d_head * cfg.dtype_bytes) as u64;
        let compulsory = kv_bytes + q_bytes;
        assert!(r.hbm.bytes_read >= compulsory, "{} < {compulsory}", r.hbm.bytes_read);
        // ... and is not wildly above it for the swizzled policy.
        assert!(
            (r.hbm.bytes_read as f64) < 2.5 * compulsory as f64,
            "{} vs {compulsory}",
            r.hbm.bytes_read
        );
    }

    #[test]
    fn no_deadlock_with_tiny_cache() {
        // Cache smaller than a single tile: everything streams through.
        let mut topo = topo4();
        topo.l2_bytes_per_xcd = 1024;
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 4, 1024, 64) };
        let r = Engine::new(topo, cfg, SimConfig::forward(Policy::NaiveHeadFirst)).run();
        assert_eq!(r.simulated_wgs, cfg.grid_size(KernelKind::Forward));
        assert!(r.l2.hit_rate() < 0.2);
    }

    #[test]
    fn prefetch_improves_or_equals_performance() {
        // Double buffering hides fill latency: never slower, usually
        // faster. (Hit RATE semantics differ — with prefetch the counted
        // transaction happens at issue time — so only time is compared.)
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 8, 4096, 128) };
        let with = Engine::new(
            topo4(),
            cfg,
            SimConfig { prefetch_depth: 1, ..SimConfig::forward(Policy::SwizzledHeadFirst) },
        )
        .run();
        let without = Engine::new(
            topo4(),
            cfg,
            SimConfig { prefetch_depth: 0, ..SimConfig::forward(Policy::SwizzledHeadFirst) },
        )
        .run();
        assert!(
            with.est_total_sec <= without.est_total_sec * 1.02,
            "with {} vs without {}",
            with.est_total_sec,
            without.est_total_sec
        );
    }

    #[test]
    fn decode_conservation_and_access_math() {
        // Split-KV decode: every WG completes; accesses = 1 Q-vector
        // prologue read + 2 reads per streamed K/V tile, and the splits
        // exactly partition each head's column blocks.
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(2, 8, 2048, 64) };
        let num_splits = 4;
        let sim = SimConfig::decode(Policy::SwizzledHeadFirst, num_splits);
        let r = Engine::new(topo4(), cfg, sim).run();
        let grid = cfg.grid_size(KernelKind::DecodeSplitKv { num_splits });
        assert_eq!(r.simulated_wgs, grid);
        let expected = grid as u64 + 2 * (cfg.batch * cfg.h_q * cfg.num_col_blocks()) as u64;
        assert_eq!(r.l2.accesses(), expected);
    }

    #[test]
    fn decode_reduce_conservation() {
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(2, 8, 2048, 64) };
        let num_splits = 4;
        let sim = SimConfig {
            kernel: KernelKind::DecodeReduce { num_splits },
            ..SimConfig::decode(Policy::SwizzledHeadFirst, num_splits)
        };
        let r = Engine::new(topo4(), cfg, sim).run();
        assert_eq!(r.simulated_wgs, cfg.batch * cfg.h_q);
        // 2 reads per split per WG, prologue reads nothing.
        assert_eq!(r.l2.accesses(), (cfg.batch * cfg.h_q * num_splits * 2) as u64);
    }

    #[test]
    fn max_ticks_truncates() {
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(4, 16, 8192, 128) };
        let sim = SimConfig { max_ticks: 50, ..SimConfig::forward(Policy::NaiveBlockFirst) };
        let r = Engine::new(topo4(), cfg, sim).run();
        assert!(r.truncated);
    }

    // ---- event-driven vs reference differential pins ----

    fn assert_equivalent(topo: &Topology, cfg: AttnConfig, sim: SimConfig) {
        let fast = Engine::new(topo.clone(), cfg, sim).run();
        let slow = Engine::new_reference(topo.clone(), cfg, sim).run();
        assert_eq!(fast.ticks, slow.ticks, "{:?} {:?}", sim.policy, sim.kernel);
        assert_eq!(fast.l2, slow.l2);
        assert_eq!(fast.l2_stats_per_xcd, slow.l2_stats_per_xcd);
        assert_eq!(fast.hbm, slow.hbm);
        assert_eq!(fast.debug, slow.debug);
        assert_eq!(fast.simulated_wgs, slow.simulated_wgs);
        assert_eq!(fast.truncated, slow.truncated);
        assert_eq!(fast.est_total_sec.to_bits(), slow.est_total_sec.to_bits());
        assert_eq!(fast.to_json().render(), slow.to_json().render());
    }

    #[test]
    fn event_engine_matches_reference_all_policies_forward() {
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(2, 8, 2048, 64) };
        for p in ALL_POLICIES {
            assert_equivalent(&topo4(), cfg, SimConfig::forward(p));
        }
    }

    #[test]
    fn event_engine_matches_reference_backward_kernels() {
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 8, 2048, 64) };
        let sim = SimConfig::backward(Policy::SwizzledHeadFirst);
        assert_equivalent(&topo4(), cfg, sim);
        assert_equivalent(&topo4(), cfg, SimConfig { kernel: KernelKind::BwdDq, ..sim });
    }

    #[test]
    fn event_engine_matches_reference_decode_phases() {
        // The reduce phase is the latency-epoch regime the event engine
        // exists for — and the scale where the no-evict path fires.
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(2, 8, 2048, 64) };
        let sim = SimConfig::decode(Policy::SwizzledHeadFirst, 4);
        assert_equivalent(&topo4(), cfg, sim);
        assert_equivalent(
            &topo4(),
            cfg,
            SimConfig { kernel: KernelKind::DecodeReduce { num_splits: 4 }, ..sim },
        );
    }

    #[test]
    fn event_engine_matches_reference_with_jitter_and_causal() {
        let cfg = AttnConfig {
            block_m: 128,
            block_n: 64,
            causal: true,
            ..AttnConfig::mha(1, 8, 2048, 64)
        };
        let sim = SimConfig { jitter_denom: 7, ..SimConfig::forward(Policy::NaiveBlockFirst) };
        assert_equivalent(&topo4(), cfg, sim);
    }

    #[test]
    fn event_engine_matches_reference_sampled_window() {
        // Warmup boundary + steady-state window extrapolation.
        let topo = topo4();
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 16, 4096, 64) };
        let sim = SimConfig::sampled(Policy::SwizzledHeadFirst, &topo, 1);
        assert_equivalent(&topo, cfg, sim);
    }

    #[test]
    fn event_engine_matches_reference_truncated() {
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(4, 16, 8192, 128) };
        let sim = SimConfig { max_ticks: 500, ..SimConfig::forward(Policy::NaiveBlockFirst) };
        assert_equivalent(&topo4(), cfg, sim);
    }

    #[test]
    fn event_engine_matches_reference_when_no_evict_fires() {
        // Small working set: every XCD's bound fits the 512 KiB effective
        // L2, so the analytic path is active on the fast engine and the
        // reference still takes the full LRU path — results must agree.
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 4, 512, 64) };
        let topo = topo4();
        let bounds = {
            let mapping = Mapping::for_kernel(
                Policy::SwizzledHeadFirst,
                &cfg,
                KernelKind::Forward,
                topo.num_xcds,
            )
            .unwrap();
            working_set_bounds(&cfg, KernelKind::Forward, &mapping, &topo, 512 * 1024)
        };
        assert!(
            bounds.iter().all(|&b| b <= 512 * 1024),
            "test premise: bounds {bounds:?} must fit 512 KiB"
        );
        assert_equivalent(&topo, cfg, SimConfig::forward(Policy::SwizzledHeadFirst));
    }

    #[test]
    fn working_set_bounds_skip_scan_at_scale() {
        // A paper-scale stream can never fit: the cheap floor check must
        // return MAX without scanning the million-slot grid.
        let cfg = AttnConfig::mha(8, 128, 131_072, 128);
        let topo = presets::mi300x();
        let mapping =
            Mapping::for_kernel(Policy::SwizzledHeadFirst, &cfg, KernelKind::Forward, 8).unwrap();
        let b = working_set_bounds(&cfg, KernelKind::Forward, &mapping, &topo, 2 * 1024 * 1024);
        assert!(b.iter().all(|&x| x == u64::MAX));
    }
}
