//! The std-only simulation worker pool.
//!
//! Same construction as the serving worker in `coordinator/service.rs`:
//! plain `std::thread` workers, an `mpsc` job queue, and the repo's
//! [`oneshot`] channel for replies. Workers pull jobs from a shared
//! receiver (work stealing by contention), execute them through the
//! shared [`ReportCache`], and reply on the job's oneshot. Because every
//! job is independently deterministic, the *results* are identical for
//! any worker count — only wall-clock changes.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::sim::SimReport;
use crate::util::oneshot;

use super::cache::ReportCache;
use super::SimJob;

struct Task {
    job: SimJob,
    reply: oneshot::Sender<SimReport>,
}

/// Pending result of a submitted job.
pub struct JobHandle {
    rx: oneshot::Receiver<SimReport>,
}

impl JobHandle {
    /// Block until the job's report is ready.
    pub fn wait(self) -> SimReport {
        self.rx.wait().expect("driver worker dropped its reply")
    }
}

/// Handle to the worker pool. Dropping it drains the queue and joins the
/// workers (jobs already submitted still complete).
pub struct SimDriver {
    tx: Mutex<Option<mpsc::Sender<Task>>>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
    cache: Arc<ReportCache>,
}

impl SimDriver {
    /// Pool with `threads` workers (min 1) over a fresh enabled cache.
    pub fn new(threads: usize) -> Self {
        Self::with_cache(threads, Arc::new(ReportCache::new()))
    }

    /// Pool over an explicit (possibly shared or disabled) cache.
    pub fn with_cache(threads: usize, cache: Arc<ReportCache>) -> Self {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let cache = Arc::clone(&cache);
                std::thread::Builder::new()
                    .name(format!("sim-driver-{i}"))
                    .spawn(move || worker_loop(rx, cache))
                    .expect("spawning sim-driver worker")
            })
            .collect();
        SimDriver { tx: Mutex::new(Some(tx)), workers, threads, cache }
    }

    /// Worker thread count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The report cache jobs execute through.
    pub fn cache(&self) -> &ReportCache {
        &self.cache
    }

    /// Enqueue one job; returns immediately with a [`JobHandle`].
    pub fn submit(&self, job: SimJob) -> JobHandle {
        let (reply, rx) = oneshot::channel();
        self.tx
            .lock()
            .unwrap()
            .as_ref()
            .expect("driver running")
            .send(Task { job, reply })
            .expect("driver workers alive");
        JobHandle { rx }
    }

    /// Execute a batch, returning reports in submission order. This is
    /// the call every consumer (figures, advisor, CLI, benches) makes:
    /// submit the whole flat job list up front, then collect in order.
    pub fn run_all(&self, jobs: Vec<SimJob>) -> Vec<SimReport> {
        let handles: Vec<JobHandle> = jobs.into_iter().map(|j| self.submit(j)).collect();
        handles.into_iter().map(JobHandle::wait).collect()
    }

    /// Convenience: submit one job and wait.
    pub fn run(&self, job: SimJob) -> SimReport {
        self.submit(job).wait()
    }
}

impl Drop for SimDriver {
    fn drop(&mut self) {
        drop(self.tx.lock().unwrap().take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(rx: Arc<Mutex<mpsc::Receiver<Task>>>, cache: Arc<ReportCache>) {
    loop {
        // Hold the queue lock only for the dequeue, never across a run.
        let task = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        match task {
            Ok(t) => {
                let report = cache.get_or_run(&t.job);
                // A dropped handle just means the caller lost interest.
                let _ = t.reply.send(report);
            }
            Err(_) => break, // driver dropped the sender: shut down
        }
    }
}
