//! Memoization of simulation reports by canonical job key.

use std::sync::Mutex;

use crate::metrics::Counter;
use crate::sim::SimReport;
use crate::util::fxhash::FastMap;

use super::SimJob;

/// Snapshot of the cache's counters (CLI `--threads`/cache-stats output).
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheCounters {
    /// Cache hits served.
    pub hits: u64,
    /// Misses (== engine runs performed).
    pub misses: u64,
    /// Reports currently memoized.
    pub entries: usize,
}

/// Concurrency-safe memo table from [`SimJob`] to [`SimReport`].
///
/// Keys are full jobs (not just their hashes), so a fingerprint collision
/// can never alias two different simulations. The engine is deterministic
/// per job, which is the invariant that makes substituting a memoized
/// report for a fresh run safe — and lets two workers racing on the same
/// job both insert without coordination (they produce identical reports).
#[derive(Debug)]
pub struct ReportCache {
    enabled: bool,
    inner: Mutex<FastMap<SimJob, SimReport>>,
    hits: Counter,
    misses: Counter,
}

impl Default for ReportCache {
    fn default() -> Self {
        Self::new()
    }
}

impl ReportCache {
    /// An enabled, empty cache.
    pub fn new() -> Self {
        ReportCache {
            enabled: true,
            inner: Mutex::new(FastMap::default()),
            hits: Counter::new(),
            misses: Counter::new(),
        }
    }

    /// A pass-through cache (CLI `--no-cache`): every lookup misses and
    /// nothing is stored, but the miss counter still tallies engine runs.
    pub fn disabled() -> Self {
        ReportCache { enabled: false, ..Self::new() }
    }

    /// False for the `--no-cache` pass-through instance.
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Look a job up, counting the hit or miss.
    pub fn get(&self, job: &SimJob) -> Option<SimReport> {
        if !self.enabled {
            self.misses.inc();
            return None;
        }
        let found = self.inner.lock().unwrap().get(job).cloned();
        if found.is_some() {
            self.hits.inc();
        } else {
            self.misses.inc();
        }
        found
    }

    /// Memoize a report (no-op when disabled).
    pub fn insert(&self, job: SimJob, report: SimReport) {
        if self.enabled {
            self.inner.lock().unwrap().insert(job, report);
        }
    }

    /// Memoized execution: the cached report if present, else run the
    /// simulation and cache the result.
    pub fn get_or_run(&self, job: &SimJob) -> SimReport {
        if let Some(r) = self.get(job) {
            return r;
        }
        let report = job.run();
        self.insert(job.clone(), report.clone());
        report
    }

    /// Total cache hits.
    pub fn hits(&self) -> u64 {
        self.hits.get()
    }

    /// Total misses (each one was an engine run).
    pub fn misses(&self) -> u64 {
        self.misses.get()
    }

    /// Reports currently memoized.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// True when nothing is memoized.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot hits/misses/entries for stats output.
    pub fn counters(&self) -> CacheCounters {
        CacheCounters { hits: self.hits(), misses: self.misses(), entries: self.len() }
    }

    /// Drop all memoized reports (counters are preserved).
    pub fn clear(&self) {
        self.inner.lock().unwrap().clear();
    }
}
