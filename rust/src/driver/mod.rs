//! The shared simulation driver: ONE execution path for every consumer
//! of the simulator (figure generators, the serving advisor, the CLI,
//! and the benches).
//!
//! Historically each consumer called [`crate::sim::simulate`] serially
//! and from scratch: `figure all` replayed hundreds of (sweep-point ×
//! policy) runs one at a time, and every `advise` call re-simulated all
//! four policies even for a geometry it had already ranked. This module
//! replaces that with:
//!
//! * [`SimJob`] — a fully-specified, hashable simulation request
//!   (topology + attention geometry + sim knobs + forward/backward).
//!   Hash/Eq compare the f64-bearing configs by IEEE-754 *bit pattern*
//!   (see the manual impls on [`Topology`] and [`SimConfig`]), so a job
//!   is a canonical memoization key.
//! * [`ReportCache`] — a concurrency-safe memo table from job to
//!   [`SimReport`], with hit/miss [`crate::metrics::Counter`]s. The
//!   engine is deterministic per job, so a cached report is
//!   bit-identical to a fresh run.
//! * [`SimDriver`] — a std-only worker pool (`std::thread` + channels,
//!   the same idiom as `coordinator/service.rs` / `util/oneshot.rs`)
//!   that executes submitted jobs across N threads through the cache.
//!   `run_all` preserves submission order, so parallel execution is
//!   bit-identical to serial (asserted in `tests/driver_determinism.rs`).
//!
//! The CLI exposes the pool via `--threads N` / `--no-cache`;
//! [`global()`] provides the process-wide driver the serving advisor
//! shares so repeated advice is O(1).
//!
//! The heaviest cache consumer is the continuous-batching decode serving
//! loop ([`crate::coordinator::serve_decode`], DESIGN.md §10): every
//! decode step prices its kernel launches through this cache, so a run
//! touching hundreds of related (batch, KV-bucket) geometries performs
//! one engine pass per distinct geometry per policy and answers every
//! repeat — thousands of steps, plus the advisor's projections, plus the
//! other policies' runs over the same trace — from memoized reports.

mod cache;
mod pool;

pub use cache::{CacheCounters, ReportCache};
pub use pool::{JobHandle, SimDriver};

use std::sync::OnceLock;

use crate::attn::AttnConfig;
use crate::cluster::{ClusterTopology, ShardPlan};
use crate::sim::{self, SimConfig, SimReport};
use crate::topology::Topology;

/// Which multi-kernel composition a [`SimJob`] executes. Part of the
/// memoization key: the same (topology, attention, sim config) simulated
/// as a lone kernel vs. a two-phase pass are different reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SimPass {
    /// A single kernel run via [`sim::simulate`] (whatever
    /// `sim.kernel` names — forward by convention).
    Single,
    /// Both backward kernels (dK/dV then dQ) via
    /// [`sim::simulate_backward`].
    Backward,
    /// Split-KV decode plus its reduction via [`sim::simulate_decode`];
    /// `sim.kernel` must be `DecodeSplitKv`.
    Decode,
}

/// A fully-specified simulation request — the unit of work the driver
/// schedules and the key the report cache memoizes on.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct SimJob {
    /// Topology the simulation runs on.
    pub topo: Topology,
    /// Attention workload geometry.
    pub attn: AttnConfig,
    /// Engine knobs (kernel, policy, sampling, seeds).
    pub sim: SimConfig,
    /// Single kernel, backward pair, or decode pair.
    pub pass: SimPass,
}

impl SimJob {
    /// Forward-kernel job.
    pub fn forward(topo: &Topology, attn: &AttnConfig, sim: SimConfig) -> SimJob {
        SimJob { topo: topo.clone(), attn: *attn, sim, pass: SimPass::Single }
    }

    /// Combined backward-pass job (dK/dV + dQ).
    pub fn backward(topo: &Topology, attn: &AttnConfig, sim: SimConfig) -> SimJob {
        SimJob { topo: topo.clone(), attn: *attn, sim, pass: SimPass::Backward }
    }

    /// Combined decode-pass job (split-KV + reduction). `sim.kernel`
    /// must be [`crate::attn::KernelKind::DecodeSplitKv`] (see
    /// [`SimConfig::decode`]).
    pub fn decode(topo: &Topology, attn: &AttnConfig, sim: SimConfig) -> SimJob {
        debug_assert!(
            matches!(sim.kernel, crate::attn::KernelKind::DecodeSplitKv { .. }),
            "decode jobs require a DecodeSplitKv sim config"
        );
        SimJob { topo: topo.clone(), attn: *attn, sim, pass: SimPass::Decode }
    }

    /// Forward-kernel job for one shard of a cluster deployment: the
    /// plan's shard-local geometry on `device`'s own topology. Reports
    /// are memoized per (device topology, shard geometry, sim config) —
    /// on a homogeneous cluster with a balanced [`ShardPlan`] every
    /// shard's job is the same key, so the whole cluster-wide launch
    /// costs one engine run and every other (device, shard) pair is a
    /// cache hit.
    pub fn sharded_forward(
        cluster: &ClusterTopology,
        plan: &ShardPlan,
        device: usize,
        attn: &AttnConfig,
        sim: SimConfig,
    ) -> SimJob {
        SimJob::forward(cluster.device(device), &plan.local_attn(attn), sim)
    }

    /// Decode-pass job for one shard of a cluster deployment (see
    /// [`SimJob::sharded_forward`] for the per-(device, shard)
    /// memoization contract; `sim.kernel` must be `DecodeSplitKv` like
    /// [`SimJob::decode`]).
    pub fn sharded_decode(
        cluster: &ClusterTopology,
        plan: &ShardPlan,
        device: usize,
        attn: &AttnConfig,
        sim: SimConfig,
    ) -> SimJob {
        SimJob::decode(cluster.device(device), &plan.local_attn(attn), sim)
    }

    /// Execute the job directly (no cache, no pool). The pool's workers
    /// call this through [`ReportCache::get_or_run`].
    pub fn run(&self) -> SimReport {
        match self.pass {
            SimPass::Single => sim::simulate(&self.topo, &self.attn, &self.sim),
            SimPass::Backward => sim::simulate_backward(&self.topo, &self.attn, &self.sim),
            SimPass::Decode => sim::simulate_decode(&self.topo, &self.attn, &self.sim),
        }
    }

    /// Canonical 64-bit fingerprint of the job key (debug/display aid;
    /// the cache itself keys on the full job to rule out collisions).
    pub fn fingerprint(&self) -> u64 {
        use std::hash::BuildHasher;
        crate::util::fxhash::MixBuildHasher::default().hash_one(self)
    }
}

/// Default worker count: one per available hardware thread.
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

static GLOBAL: OnceLock<SimDriver> = OnceLock::new();

/// The process-wide shared driver. All callers share one report cache,
/// which is what makes repeated [`crate::coordinator::advise`] calls on
/// the same (topology, geometry) free after the first.
pub fn global() -> &'static SimDriver {
    GLOBAL.get_or_init(|| SimDriver::new(default_threads().min(8)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::{Policy, ALL_POLICIES};
    use crate::topology::presets;

    fn tiny_topo() -> Topology {
        Topology {
            name: "tiny".into(),
            num_xcds: 4,
            cus_per_xcd: 4,
            l2_bytes_per_xcd: 512 * 1024,
            ..presets::mi300x()
        }
    }

    fn tiny_jobs() -> Vec<SimJob> {
        let topo = tiny_topo();
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 8, 1024, 64) };
        ALL_POLICIES
            .iter()
            .map(|&p| SimJob::forward(&topo, &cfg, SimConfig::forward(p)))
            .collect()
    }

    #[test]
    fn job_key_roundtrip() {
        let jobs = tiny_jobs();
        assert_eq!(jobs[0], jobs[0].clone());
        assert_ne!(jobs[0], jobs[1]); // policies differ
        assert_ne!(jobs[0].fingerprint(), jobs[1].fingerprint());
        let bwd = SimJob { pass: SimPass::Backward, ..jobs[0].clone() };
        assert_ne!(jobs[0], bwd);
    }

    #[test]
    fn decode_jobs_run_both_phases_and_memoize() {
        let topo = tiny_topo();
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::mha(1, 8, 1024, 64) };
        let sim = SimConfig::decode(Policy::SwizzledHeadFirst, 2);
        let driver = SimDriver::new(2);
        let job = SimJob::decode(&topo, &cfg, sim);
        let first = driver.run_all(vec![job.clone()]);
        assert_eq!(
            first[0].simulated_wgs,
            cfg.grid_size(crate::attn::KernelKind::DecodeSplitKv { num_splits: 2 })
                + cfg.grid_size(crate::attn::KernelKind::DecodeReduce { num_splits: 2 })
        );
        let second = driver.run_all(vec![job]);
        assert_eq!(driver.cache().hits(), 1, "repeat decode job served from cache");
        assert_eq!(first[0].to_json().render(), second[0].to_json().render());
    }

    #[test]
    fn sharded_jobs_of_identical_shards_share_one_cache_entry() {
        // The cluster memoization contract: on a homogeneous cluster
        // with a balanced plan, the per-(device, shard) jobs of one
        // launch are one cache key — N devices cost ONE engine run.
        use crate::cluster::{ClusterTopology, ShardPlan, ShardStrategy};
        let cluster = ClusterTopology::node_of(&tiny_topo(), 4);
        let cfg = AttnConfig { block_m: 128, block_n: 64, ..AttnConfig::gqa(1, 16, 8, 1024, 64) };
        let plan = ShardPlan::new(&cfg, 4, ShardStrategy::Contiguous).unwrap();
        let sim = SimConfig::forward(Policy::SwizzledHeadFirst);
        let jobs: Vec<SimJob> = (0..4)
            .map(|d| SimJob::sharded_forward(&cluster, &plan, d, &cfg, sim))
            .collect();
        assert_eq!(jobs[0], jobs[3], "identical shards, identical key");
        assert_eq!(jobs[0].attn.h_q, 4, "shard-local heads");
        assert_eq!(jobs[0].attn.h_k, 2);
        // One worker: the dedup count is deterministic (two workers may
        // race the same key and both miss — documented in cache.rs).
        let driver = SimDriver::new(1);
        let reports = driver.run_all(jobs);
        assert_eq!(driver.cache().misses(), 1, "one engine run for the whole launch");
        assert_eq!(driver.cache().hits(), 3);
        assert_eq!(reports[0].to_json().render(), reports[3].to_json().render());
        // Decode variant goes through the same path.
        let dsim = SimConfig::decode(Policy::SwizzledHeadFirst, 2);
        let djob = SimJob::sharded_decode(&cluster, &plan, 0, &cfg, dsim);
        assert_eq!(djob.pass, SimPass::Decode);
        assert_eq!(djob.attn.h_q, 4);
    }

    #[test]
    fn pool_preserves_submission_order() {
        let driver = SimDriver::new(4);
        let jobs = tiny_jobs();
        let reports = driver.run_all(jobs.clone());
        assert_eq!(reports.len(), jobs.len());
        for (job, report) in jobs.iter().zip(&reports) {
            assert_eq!(report.policy, job.sim.policy);
            // Each result must equal a direct, in-thread run.
            let direct = job.run();
            assert_eq!(report.to_json().render(), direct.to_json().render());
        }
    }

    #[test]
    fn cache_memoizes_repeat_batches() {
        let driver = SimDriver::new(2);
        let jobs = tiny_jobs();
        let first = driver.run_all(jobs.clone());
        assert_eq!(driver.cache().misses(), jobs.len() as u64);
        assert_eq!(driver.cache().hits(), 0);
        let second = driver.run_all(jobs.clone());
        assert_eq!(driver.cache().misses(), jobs.len() as u64, "no new engine runs");
        assert_eq!(driver.cache().hits(), jobs.len() as u64);
        assert_eq!(driver.cache().len(), jobs.len());
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.to_json().render(), b.to_json().render());
        }
    }

    #[test]
    fn disabled_cache_always_runs() {
        let driver =
            SimDriver::with_cache(2, std::sync::Arc::new(ReportCache::disabled()));
        let jobs = tiny_jobs();
        driver.run_all(jobs.clone());
        driver.run_all(jobs.clone());
        assert_eq!(driver.cache().hits(), 0);
        assert_eq!(driver.cache().misses(), 2 * jobs.len() as u64);
        assert_eq!(driver.cache().len(), 0);
    }

    #[test]
    fn single_job_submit() {
        let driver = SimDriver::new(1);
        let job = tiny_jobs().remove(0);
        let report = driver.submit(job.clone()).wait();
        assert_eq!(report.policy, job.sim.policy);
        assert!(report.ticks > 0);
    }
}
