//! Chiplet GPU architecture models (paper Fig. 1, Table 1).
//!
//! A [`Topology`] describes the NUMA-relevant structure of an accelerator:
//! how many compute dies (XCDs) it has, how much private L2 each die owns,
//! aggregate HBM bandwidth, and the compute rate of one CU — all in
//! physical units. The simulator ([`crate::sim`]) normalizes these to
//! discrete *ticks* per workload (one tick = the time one CU needs for one
//! FA2 K/V tile step), so the same experiment can be replayed on a
//! traditional unified-cache GPU (Fig. 1a), a dual-die part (Fig. 1b), or
//! MI300X (Fig. 1c / Table 1).

pub mod presets;

/// Architecture description of a (possibly chiplet-based) GPU.
///
/// Equality and hashing compare the f64 rate fields by IEEE-754 *bit
/// pattern* (see the manual impls below), which makes `Topology` usable
/// as part of a hash key — the simulation driver's report cache
/// ([`crate::driver`]) memoizes on (topology, attention, sim config).
#[derive(Debug, Clone)]
pub struct Topology {
    /// Human-readable name, e.g. `"mi300x"`.
    pub name: String,
    /// Number of accelerator complex dies (NUMA domains). 1 = unified GPU.
    pub num_xcds: usize,
    /// Compute units per XCD (MI300X: 38).
    pub cus_per_xcd: usize,
    /// Private L2 capacity per XCD in bytes (MI300X: 4 MiB).
    pub l2_bytes_per_xcd: u64,
    /// Cacheline size in bytes; tile accesses are line-quantized.
    pub line_bytes: u64,
    /// Aggregate HBM bandwidth in bytes/second, shared by all XCDs
    /// (MI300X: 5.3 TB/s).
    pub hbm_bytes_per_sec: f64,
    /// Uncontended HBM access latency in seconds (queueing on top of this
    /// is modeled by the bandwidth budget).
    pub hbm_latency_sec: f64,
    /// Peak dense-matmul throughput of one CU in FLOP/second
    /// (MI300X bf16: ~1307 TFLOP/s over 304 CUs ≈ 4.3 TFLOP/s per CU).
    pub cu_flops_per_sec: f64,
    /// Workgroups resident per CU (occupancy). FA2 WGs are register/LDS
    /// heavy, so 1 on MI300X.
    pub wgs_per_cu: usize,
    /// Dispatcher chunk size: how many consecutive WGs each XCD receives
    /// before the scheduler advances (paper Sec. 2.2: 1 on current HW).
    pub dispatch_chunk: usize,
}

impl Topology {
    /// Total compute units across all XCDs.
    pub fn total_cus(&self) -> usize {
        self.num_xcds * self.cus_per_xcd
    }

    /// Maximum workgroups in flight per XCD.
    pub fn wg_slots_per_xcd(&self) -> usize {
        self.cus_per_xcd * self.wgs_per_cu
    }

    /// Maximum workgroups in flight device-wide.
    pub fn total_wg_slots(&self) -> usize {
        self.num_xcds * self.wg_slots_per_xcd()
    }

    /// Aggregate L2 capacity across dies. Fragmented: data cached on one
    /// die gives no benefit to another — the whole point of the paper.
    pub fn total_l2_bytes(&self) -> u64 {
        self.num_xcds as u64 * self.l2_bytes_per_xcd
    }

    /// Peak device matmul throughput in FLOP/second.
    pub fn device_flops_per_sec(&self) -> f64 {
        self.cu_flops_per_sec * self.total_cus() as f64
    }

    /// Machine-balance point in FLOP/byte: arithmetic intensities above
    /// this are compute-bound, below are HBM-bound.
    pub fn balance_flops_per_byte(&self) -> f64 {
        self.device_flops_per_sec() / self.hbm_bytes_per_sec
    }

    /// Check the architecture description for degenerate values.
    pub fn validate(&self) -> Result<(), String> {
        if self.num_xcds == 0 {
            return Err("num_xcds must be > 0".into());
        }
        if self.cus_per_xcd == 0 || self.wgs_per_cu == 0 {
            return Err("cus_per_xcd and wgs_per_cu must be > 0".into());
        }
        if self.l2_bytes_per_xcd == 0 {
            return Err("l2_bytes_per_xcd must be > 0".into());
        }
        if self.hbm_bytes_per_sec <= 0.0 || self.cu_flops_per_sec <= 0.0 {
            return Err("bandwidth and compute rates must be > 0".into());
        }
        if self.dispatch_chunk == 0 {
            return Err("dispatch_chunk must be > 0".into());
        }
        if self.line_bytes == 0 || !self.line_bytes.is_power_of_two() {
            return Err("line_bytes must be a power of two".into());
        }
        Ok(())
    }
}

// Hash/Eq by bits: the three f64 fields are compared and hashed via
// `to_bits()`, so a `Topology` can key the driver's report cache. The
// bit convention means `NaN == NaN` and `0.0 != -0.0`, which is exactly
// the canonical-key behavior a memoization table wants (and no preset
// ever carries a NaN — `validate()` rejects non-positive rates).
impl PartialEq for Topology {
    fn eq(&self, other: &Self) -> bool {
        self.name == other.name
            && self.num_xcds == other.num_xcds
            && self.cus_per_xcd == other.cus_per_xcd
            && self.l2_bytes_per_xcd == other.l2_bytes_per_xcd
            && self.line_bytes == other.line_bytes
            && self.hbm_bytes_per_sec.to_bits() == other.hbm_bytes_per_sec.to_bits()
            && self.hbm_latency_sec.to_bits() == other.hbm_latency_sec.to_bits()
            && self.cu_flops_per_sec.to_bits() == other.cu_flops_per_sec.to_bits()
            && self.wgs_per_cu == other.wgs_per_cu
            && self.dispatch_chunk == other.dispatch_chunk
    }
}

impl Eq for Topology {}

impl std::hash::Hash for Topology {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.name.hash(state);
        self.num_xcds.hash(state);
        self.cus_per_xcd.hash(state);
        self.l2_bytes_per_xcd.hash(state);
        self.line_bytes.hash(state);
        self.hbm_bytes_per_sec.to_bits().hash(state);
        self.hbm_latency_sec.to_bits().hash(state);
        self.cu_flops_per_sec.to_bits().hash(state);
        self.wgs_per_cu.hash(state);
        self.dispatch_chunk.hash(state);
    }
}

#[cfg(test)]
mod tests {
    use super::presets;

    #[test]
    fn mi300x_matches_table1() {
        // Paper Table 1: 8 XCDs, 38 CUs/XCD (304 total), 4 MB L2/XCD
        // (32 MB total), 5.3 TB/s HBM3.
        let t = presets::mi300x();
        assert_eq!(t.num_xcds, 8);
        assert_eq!(t.cus_per_xcd, 38);
        assert_eq!(t.total_cus(), 304);
        assert_eq!(t.l2_bytes_per_xcd, 4 * 1024 * 1024);
        assert_eq!(t.total_l2_bytes(), 32 * 1024 * 1024);
        assert!((t.hbm_bytes_per_sec - 5.3e12).abs() < 1e9);
        t.validate().unwrap();
    }

    #[test]
    fn balance_point_is_near_roofline_knee() {
        // MI300X bf16 peak ~1307 TFLOP/s over 5.3 TB/s ~= 247 FLOP/byte.
        let t = presets::mi300x();
        let b = t.balance_flops_per_byte();
        assert!(b > 150.0 && b < 350.0, "balance {b}");
    }

    #[test]
    fn unified_preset_has_single_domain() {
        let t = presets::unified_single_die();
        assert_eq!(t.num_xcds, 1);
        assert_eq!(t.total_l2_bytes(), 32 * 1024 * 1024);
        t.validate().unwrap();
    }

    #[test]
    fn dual_and_quad_die_presets() {
        assert_eq!(presets::dual_die().num_xcds, 2);
        assert_eq!(presets::quad_die().num_xcds, 4);
        presets::dual_die().validate().unwrap();
        presets::quad_die().validate().unwrap();
    }

    #[test]
    fn presets_have_equal_aggregate_resources() {
        // The Fig. 1 evolution keeps total compute/L2/HBM roughly constant
        // while increasing disaggregation, isolating the NUMA effect.
        let uni = presets::unified_single_die();
        let quad = presets::quad_die();
        let mi = presets::mi300x();
        assert_eq!(uni.total_l2_bytes(), quad.total_l2_bytes());
        assert_eq!(uni.total_l2_bytes(), mi.total_l2_bytes());
        assert_eq!(uni.total_cus(), mi.total_cus());
    }

    #[test]
    fn validation_rejects_degenerate() {
        let mut t = presets::mi300x();
        t.num_xcds = 0;
        assert!(t.validate().is_err());
        let mut t = presets::mi300x();
        t.line_bytes = 100; // not a power of two
        assert!(t.validate().is_err());
        let mut t = presets::mi300x();
        t.dispatch_chunk = 0;
        assert!(t.validate().is_err());
    }

    #[test]
    fn hash_eq_by_bits() {
        use std::collections::hash_map::DefaultHasher;
        use std::hash::{Hash, Hasher};
        let hash_of = |t: &super::Topology| {
            let mut h = DefaultHasher::new();
            t.hash(&mut h);
            h.finish()
        };
        let a = presets::mi300x();
        let b = presets::mi300x();
        assert_eq!(a, b);
        assert_eq!(hash_of(&a), hash_of(&b));
        let mut c = presets::mi300x();
        c.hbm_bytes_per_sec *= 2.0;
        assert_ne!(a, c);
        assert_ne!(hash_of(&a), hash_of(&c));
    }

    #[test]
    fn preset_lookup_by_name() {
        for name in ["mi300x", "unified", "dual_die", "quad_die"] {
            let t = presets::by_name(name).unwrap();
            t.validate().unwrap();
        }
        assert!(presets::by_name("nonexistent").is_none());
    }
}
