//! Named topology presets mirroring the paper's Fig. 1 architecture
//! evolution plus the MI300X specification of Table 1.
//!
//! The unified/dual/quad presets keep aggregate compute, L2, and HBM equal
//! to MI300X while varying only the number of NUMA domains, so ablations
//! isolate the effect of disaggregation itself.

use super::Topology;

/// MI300X bf16 matmul peak (TFLOP/s) — used to derive the per-CU rate.
const MI300X_BF16_TFLOPS: f64 = 1307.0;
const MI300X_TOTAL_CUS: f64 = 304.0;

/// AMD Instinct MI300X (paper Table 1): 8 XCDs × 38 CUs, 4 MB L2 per XCD,
/// 5.3 TB/s HBM3, NUMA effects exposed to software.
pub fn mi300x() -> Topology {
    Topology {
        name: "mi300x".into(),
        num_xcds: 8,
        cus_per_xcd: 38,
        l2_bytes_per_xcd: 4 * 1024 * 1024,
        line_bytes: 128,
        hbm_bytes_per_sec: 5.3e12,
        hbm_latency_sec: 600e-9,
        cu_flops_per_sec: MI300X_BF16_TFLOPS * 1e12 / MI300X_TOTAL_CUS,
        wgs_per_cu: 1,
        dispatch_chunk: 1,
    }
}

/// Traditional single-die GPU (Fig. 1a — A100/H100/MI200 style): one
/// unified L2 shared by all CUs, uniform memory access. Same aggregate
/// resources as MI300X so comparisons isolate NUMA.
pub fn unified_single_die() -> Topology {
    Topology {
        name: "unified".into(),
        num_xcds: 1,
        cus_per_xcd: 304,
        l2_bytes_per_xcd: 32 * 1024 * 1024,
        ..mi300x()
    }
}

/// Dual-die chiplet architecture (Fig. 1b — Blackwell-class geometry,
/// but with NUMA *exposed* rather than hidden by hardware coherency).
pub fn dual_die() -> Topology {
    Topology {
        name: "dual_die".into(),
        num_xcds: 2,
        cus_per_xcd: 152,
        l2_bytes_per_xcd: 16 * 1024 * 1024,
        ..mi300x()
    }
}

/// Quad-die chiplet architecture (Fig. 1c — Rubin-Ultra/MI300-class).
pub fn quad_die() -> Topology {
    Topology {
        name: "quad_die".into(),
        num_xcds: 4,
        cus_per_xcd: 76,
        l2_bytes_per_xcd: 8 * 1024 * 1024,
        ..mi300x()
    }
}

/// The 4-XCD toy configuration used by the paper's Figs. 7-10
/// illustrations (8 query heads, 128 row blocks, 4 XCDs).
pub fn paper_illustration() -> Topology {
    Topology {
        name: "paper_fig7_10".into(),
        ..quad_die()
    }
}

/// Look a preset up by name (CLI `--topo` flag).
pub fn by_name(name: &str) -> Option<Topology> {
    match name {
        "mi300x" => Some(mi300x()),
        "unified" | "single_die" => Some(unified_single_die()),
        "dual_die" => Some(dual_die()),
        "quad_die" => Some(quad_die()),
        "paper_fig7_10" => Some(paper_illustration()),
        _ => None,
    }
}

/// [`by_name`] with a self-describing error: an unknown preset name
/// reports the full list of available presets. The one place the CLI,
/// the experiment-file parser, and the cluster builder format that error.
pub fn by_name_or_err(name: &str) -> Result<Topology, String> {
    by_name(name).ok_or_else(|| {
        format!("unknown topology preset '{name}' (available: {})", all_names().join(", "))
    })
}

/// All preset names, for CLI help and sweep tooling.
pub fn all_names() -> &'static [&'static str] {
    &["mi300x", "unified", "dual_die", "quad_die", "paper_fig7_10"]
}
