//! Hardware dispatcher model (paper Sec. 2.2).
//!
//! Modern multi-die GPUs schedule workgroups across compute dies with a
//! *chunked round-robin* policy: each die receives `chunk` consecutive
//! dispatch slots before the scheduler advances to the next die. Current
//! hardware uses chunk = 1. This module provides the slot ↔ XCD algebra
//! and a [`Dispatcher`] that hands out work in dispatch order per XCD —
//! exactly the behavior the mapping policies are designed against (and,
//! because the chunk size is a driver detail that "is subject to change
//! across GPU generations", an ablation axis: see
//! `rust/tests/ablation.rs` for what happens to a chunk=1 swizzle on
//! chunk=2 hardware).

use crate::mapping::Mapping;
use crate::attn::WorkItem;

/// XCD that dispatch slot `slot` lands on under chunked round-robin.
#[inline]
pub fn xcd_of_slot(slot: usize, chunk: usize, num_xcds: usize) -> u32 {
    ((slot / chunk) % num_xcds) as u32
}

/// The `n`-th dispatch slot that lands on XCD `x` (inverse of
/// [`xcd_of_slot`] restricted to one XCD).
#[inline]
pub fn slot_of_xcd_local(n: usize, x: u32, chunk: usize, num_xcds: usize) -> usize {
    let group = n / chunk;
    let r = n % chunk;
    (group * num_xcds + x as usize) * chunk + r
}

/// Hands out workgroups to XCDs in hardware dispatch order.
#[derive(Debug, Clone)]
pub struct Dispatcher {
    mapping: Mapping,
    chunk: usize,
    num_xcds: usize,
    /// Per-XCD count of workgroups already dispatched.
    issued: Vec<usize>,
}

impl Dispatcher {
    /// A dispatcher over `mapping`'s grid with the given chunk size.
    pub fn new(mapping: Mapping, chunk: usize, num_xcds: usize) -> Self {
        assert!(chunk > 0 && num_xcds > 0);
        Dispatcher { mapping, chunk, num_xcds, issued: vec![0; num_xcds] }
    }

    /// Total workgroups in the grid.
    pub fn grid_size(&self) -> usize {
        self.mapping.grid_size()
    }

    /// Total workgroups dispatched so far.
    pub fn total_issued(&self) -> usize {
        self.issued.iter().sum()
    }

    /// Workgroups not yet dispatched.
    pub fn remaining(&self) -> usize {
        self.grid_size() - self.total_issued()
    }

    /// Next workgroup for XCD `x`, if any remain for it.
    ///
    /// Note: an XCD can run out of work while others still have some when
    /// the grid size is not a multiple of `num_xcds * chunk` — the tail
    /// imbalance real hardware has too.
    pub fn next_for_xcd(&mut self, x: u32) -> Option<(usize, WorkItem)> {
        let n = self.issued[x as usize];
        let slot = slot_of_xcd_local(n, x, self.chunk, self.num_xcds);
        if slot >= self.grid_size() {
            return None;
        }
        self.issued[x as usize] += 1;
        Some((slot, self.mapping.decode(slot)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::Policy;

    #[test]
    fn chunk1_round_robin() {
        for slot in 0..32 {
            assert_eq!(xcd_of_slot(slot, 1, 8), (slot % 8) as u32);
        }
    }

    #[test]
    fn chunk2_pairs() {
        let xcds: Vec<u32> = (0..12).map(|s| xcd_of_slot(s, 2, 4)).collect();
        assert_eq!(xcds, vec![0, 0, 1, 1, 2, 2, 3, 3, 0, 0, 1, 1]);
    }

    #[test]
    fn slot_inverse_roundtrip() {
        for chunk in [1, 2, 4] {
            for num_xcds in [2, 4, 8] {
                for x in 0..num_xcds as u32 {
                    for n in 0..20 {
                        let slot = slot_of_xcd_local(n, x, chunk, num_xcds);
                        assert_eq!(xcd_of_slot(slot, chunk, num_xcds), x);
                    }
                }
                // All slots covered exactly once.
                let mut seen: Vec<usize> = (0..num_xcds as u32)
                    .flat_map(|x| (0..8).map(move |n| (n, x)))
                    .map(|(n, x)| slot_of_xcd_local(n, x, chunk, num_xcds))
                    .collect();
                seen.sort_unstable();
                let expected: Vec<usize> = (0..8 * num_xcds).collect();
                assert_eq!(seen, expected);
            }
        }
    }

    #[test]
    fn dispatcher_exhausts_grid_exactly_once() {
        let m = Mapping::new(Policy::SwizzledHeadFirst, 1, 8, 5, 4).unwrap();
        let mut d = Dispatcher::new(m, 1, 4);
        let mut items = Vec::new();
        loop {
            let mut any = false;
            for x in 0..4 {
                if let Some((slot, w)) = d.next_for_xcd(x) {
                    assert_eq!(xcd_of_slot(slot, 1, 4), x);
                    items.push((w.z, w.h, w.b));
                    any = true;
                }
            }
            if !any {
                break;
            }
        }
        assert_eq!(items.len(), 40);
        items.sort_unstable();
        items.dedup();
        assert_eq!(items.len(), 40, "every work item exactly once");
        assert_eq!(d.remaining(), 0);
    }

    #[test]
    fn dispatcher_tail_imbalance() {
        // 10 WGs over 4 XCDs: XCD0/1 get 3, XCD2/3 get 2.
        let m = Mapping::new(Policy::NaiveHeadFirst, 1, 1, 10, 4).unwrap();
        let mut d = Dispatcher::new(m, 1, 4);
        let mut counts = [0; 4];
        for x in 0..4u32 {
            while d.next_for_xcd(x).is_some() {
                counts[x as usize] += 1;
            }
        }
        assert_eq!(counts, [3, 3, 2, 2]);
    }

    #[test]
    fn shf_dispatch_keeps_head_on_xcd() {
        // End-to-end: SHF through the dispatcher gives each XCD
        // consecutive blocks of "its" heads.
        let m = Mapping::new(Policy::SwizzledHeadFirst, 1, 8, 16, 4).unwrap();
        let mut d = Dispatcher::new(m, 1, 4);
        for x in 0..4u32 {
            let mut heads = Vec::new();
            while let Some((_, w)) = d.next_for_xcd(x) {
                heads.push(w.h);
            }
            let expected: Vec<u32> = std::iter::repeat(x * 2)
                .take(16)
                .chain(std::iter::repeat(x * 2 + 1).take(16))
                .collect();
            assert_eq!(heads, expected, "XCD {x}");
        }
    }
}
